"""Sharded fabric driver tests (DESIGN.md §17).

  * shard_map replay — the per-shard replay is the SAME vmap composition
    as the single-device stacked replay, so per-expander counters and
    every pool leaf are BIT-identical to the vmap oracle (asserted at
    D=1 unconditionally; at D=2/D=4 when the session forced enough host
    devices — CI runs these under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=4``);
  * collective migration — the psum/ppermute collective apply replays
    the host planner's exact move sequence: spill parity vs the
    host-planned synchronous driver under pool invariants I1–I5;
  * in-jit planning — ``shard.plan_in_jit`` reproduces the host
    ``SpillPressure`` / ``TrafficRebalance`` plans (pages, srcs, dsts,
    urgency, move order) on scripted SegmentViews with clear margins
    (the rebalance time comparison is float32 in-jit vs float64 host —
    ties are scripted away, as documented in shard.py);
  * sync contract — one fused fetch per boundary (migration on), one
    deferred drain per replay() (migration off), and strictly fewer
    epoch host syncs than the PR 5 pipelined driver on the same trace;
  * per-device obs — ``Fabric.device_times`` reconciles with the
    Recorder-reconstructed per-device Perfetto track totals at
    rtol=1e-9, with recording changing no pool state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import state as S
from repro.fabric import migration as MG
from repro.fabric import shard as FS
from repro.fabric.replay import Fabric
from repro.fabric.placement import WeightedInterleave
from helpers import check_pool_invariants
from test_fabric import POLICY, WINDOW, _saturating_fabric, _small_cfg, _trace

needs = lambda d: pytest.mark.skipif(
    jax.device_count() < d,
    reason=f"needs {d} XLA devices (force_host_device_count before jax init)")


def _saturating_pair(n_devices, **kw):
    """(sharded fabric, vmap synchronous reference) on the saturating
    spill fixture — same trace, same seed, independent state."""
    cfg, placement, fab, trace = _saturating_fabric()
    del fab
    rates = np.full((cfg.n_pages, cfg.blocks_per_page), 2, np.int32)

    def mk(**extra):
        return Fabric(cfg, POLICY, WeightedInterleave(2, cfg.n_pages,
                                                      [1.0, 0.0]),
                      seed=0, rates_table=jnp.asarray(rates), window=WINDOW,
                      spill=True, spill_interval=WINDOW, spill_k=8,
                      spill_low=40, **extra)

    return cfg, mk(shard_devices=n_devices, **kw), mk(sync_migration=True), \
        trace


# ---------------------------------------------------------------------------
# shard_map replay bit-identity vs the vmap oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [
    1, pytest.param(2, marks=needs(2)), pytest.param(4, marks=needs(4))])
def test_shard_replay_bit_identical_to_vmap(n_devices):
    """Migration off: the shard_map-ed replay is bit-identical per
    expander to the vmap driver on a real workload trace — every pool
    leaf, counters included."""
    n_exp = 4
    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=120, seed=1)

    def mk(**kw):
        return Fabric(cfg, POLICY,
                      WeightedInterleave(n_exp, cfg.n_pages,
                                         [0.55, 0.15, 0.15, 0.15]),
                      seed=0, rates_table=jnp.asarray(rates), window=WINDOW,
                      spill=False, **kw)

    fab = mk(shard_devices=n_devices)
    ref = mk()
    fab.replay(ospn, wr, blk)
    ref.replay(ospn, wr, blk)
    assert fab.state_identical(ref)
    assert fab.counters_by_expander() == ref.counters_by_expander()
    ss = fab.sync_stats()
    assert ss["drain_syncs"] == 1 and ss["boundary_syncs"] == 0
    # the deferred drain delivered per-segment telemetry identical to the
    # eager per-segment fetches
    assert len(fab.segment_deltas) == len(ref.segment_deltas)
    for a, b in zip(fab.segment_deltas, ref.segment_deltas):
        assert (a == b).all()


@pytest.mark.parametrize("n_devices", [
    1, pytest.param(2, marks=needs(2))])
def test_collective_spill_parity_and_invariants(n_devices):
    """Migration live: the in-jit planned + collectively applied spill
    epochs land bit-identically to the host-planned synchronous driver,
    with I1–I5 holding on every expander afterwards."""
    cfg, fab, ref, (ospn, wr, blk) = _saturating_pair(n_devices)
    fab.replay(ospn, wr, blk)
    ref.replay(ospn, wr, blk)
    assert ref.spill_stats()["events"] > 0, "fixture no longer saturates"
    assert fab.spill_stats()["events"] == ref.spill_stats()["events"]
    assert fab.state_identical(ref)
    for e in range(2):
        check_pool_invariants(S.pool_slice(fab.pools, e), cfg)
    ss = fab.sync_stats()
    assert ss["boundary_syncs"] == ss["boundaries"]
    assert ss["segment_syncs"] == 0 and ss["epoch_syncs"] == 0
    # strictly below the reference's segment+epoch sync count
    assert ss["host_syncs"] < ref.sync_stats()["host_syncs"]


def test_sharded_beats_pipelined_sync_count():
    """The acceptance comparison: epoch host-sync count on the sharded
    path is strictly below the PR 5 pipelined driver's on the same
    trace (one fused fetch per boundary vs one per segment + one per
    epoch)."""
    cfg, fab, _, (ospn, wr, blk) = _saturating_pair(1)
    _, _, _, _ = cfg, fab, None, None
    cfg2, placement, pipe, trace = _saturating_fabric()
    pipe.replay(*trace)
    assert pipe.epochs_applied > 0
    fab.replay(ospn, wr, blk)
    assert fab.sync_stats()["host_syncs"] < pipe.sync_stats()["host_syncs"]


# ---------------------------------------------------------------------------
# in-jit planner parity vs the host policies on scripted SegmentViews
# ---------------------------------------------------------------------------

def _view(free_units, free_singles, free_groups, eligible, referenced,
          delta, times, blocked=None, n_pages=32):
    n = len(free_units)
    return MG.SegmentView(
        free_units=np.asarray(free_units, np.int64),
        free_singles=np.asarray(free_singles, np.int64),
        free_groups=np.asarray(free_groups, np.int64),
        eligible=np.asarray(eligible, bool),
        referenced=np.asarray(referenced, bool),
        counters=np.zeros((n, S.NUM_COUNTERS), np.int64),
        delta=np.asarray(delta, np.int64),
        times=np.asarray(times, np.float64),
        recent=np.zeros((n_pages,), bool),
        blocked=np.zeros((n_pages,), bool) if blocked is None
        else np.asarray(blocked, bool))


def _jit_plan(policy, view):
    params = FS.plan_params(policy)
    pages, srcs, dsts, urgent = FS.plan_in_jit(
        params, jnp.asarray(view.free_units), jnp.asarray(view.free_singles),
        jnp.asarray(view.free_groups), jnp.asarray(view.eligible),
        jnp.asarray(view.referenced), jnp.asarray(view.delta),
        jnp.asarray(view.times, jnp.float32), jnp.asarray(view.blocked))
    pages = np.asarray(pages).reshape(-1)
    srcs = np.asarray(srcs).reshape(-1)
    dsts = np.asarray(dsts).reshape(-1)
    sel = pages >= 0
    if not sel.any():
        return None, bool(urgent)
    return MG.MigrationPlan(pages[sel].astype(np.int32),
                            srcs[sel].astype(np.int32),
                            dsts[sel].astype(np.int32)), bool(urgent)


def _assert_plans_equal(host_plan, jit_plan, jit_urgent):
    if host_plan is None:
        assert jit_plan is None
        assert not jit_urgent
        return
    assert jit_plan is not None
    assert (jit_plan.pages == host_plan.pages).all(), \
        (jit_plan.pages, host_plan.pages)
    assert (jit_plan.srcs == host_plan.srcs).all()
    assert (jit_plan.dsts == host_plan.dsts).all()
    assert jit_urgent == host_plan.urgent


def test_in_jit_spill_planner_matches_host():
    """Multi-source spill with donor decrements: two starved expanders,
    one urgent, conservative donor accounting making the donor
    ineligible for the second source — plan and order bit-equal."""
    n_pages = 32
    policy = MG.SpillPressure(k=3, low=16, proactive=1.5)
    eligible = np.zeros((4, n_pages), bool)
    eligible[0, [2, 5, 9, 11]] = True       # 4 candidates, k=3 clips
    eligible[1, [1, 30]] = True
    eligible[3, [7]] = True                 # starved but donor runs dry
    view = _view(
        free_units=[10, 20, 200, 23],       # e0 urgent (<low), e1/e3 proactive
        free_singles=[8, 8, 64, 8], free_groups=[2, 2, 16, 2],
        eligible=eligible, referenced=np.zeros_like(eligible),
        delta=np.zeros((4, S.NUM_COUNTERS)), times=[1.0, 1.0, 1.0, 1.0],
        n_pages=n_pages)
    host = policy.plan(view)
    assert host is not None and host.urgent     # sanity: scripted as intended
    assert len(host) > 3                        # multiple sources fired
    jit_plan, jit_urgent = _jit_plan(policy, view)
    _assert_plans_equal(host, jit_plan, jit_urgent)


def test_in_jit_spill_planner_respects_blocked_and_empty():
    policy = MG.SpillPressure(k=4, low=16, proactive=1.5)
    n_pages = 16
    eligible = np.zeros((2, n_pages), bool)
    eligible[0, [3, 4]] = True
    blocked = np.zeros((n_pages,), bool)
    blocked[[3, 4]] = True                      # livelock guard bars both
    view = _view(free_units=[10, 200], free_singles=[4, 32],
                 free_groups=[1, 8], eligible=eligible,
                 referenced=np.zeros_like(eligible),
                 delta=np.zeros((2, S.NUM_COUNTERS)), times=[1.0, 1.0],
                 blocked=blocked, n_pages=n_pages)
    host = policy.plan(view)
    jit_plan, jit_urgent = _jit_plan(policy, view)
    _assert_plans_equal(host, jit_plan, jit_urgent)
    assert jit_plan is None


def test_in_jit_rebalance_planner_matches_host():
    """Traffic trigger fires: hot expander 0 carries the host delta and a
    clear delivered-time lead; referenced-first candidate ordering and
    the pressure-claimed-page exclusion both exercised."""
    n_pages = 24
    policy = MG.TrafficRebalance(k=4, low=8, proactive=1.5,
                                 trigger=1.5, time_ratio=1.05)
    n = 3
    eligible = np.zeros((n, n_pages), bool)
    eligible[0, [1, 3, 5, 7, 9, 11]] = True
    referenced = np.zeros_like(eligible)
    referenced[0, [5, 9]] = True            # referenced move first
    delta = np.zeros((n, S.NUM_COUNTERS), np.int64)
    delta[0, S.C_HOST_RD] = 90              # hot: 90 of 100 accesses
    delta[1, S.C_HOST_RD] = 6
    delta[2, S.C_HOST_RD] = 4
    view = _view(free_units=[100, 60, 200], free_singles=[16, 16, 64],
                 free_groups=[4, 4, 16], eligible=eligible,
                 referenced=referenced, delta=delta,
                 times=[4.0, 1.5, 1.0], n_pages=n_pages)
    host = policy.plan(view)
    assert host is not None and len(host) == 4
    assert host.pages.tolist() == [5, 9, 1, 3]  # referenced first
    jit_plan, jit_urgent = _jit_plan(policy, view)
    _assert_plans_equal(host, jit_plan, jit_urgent)


def test_in_jit_rebalance_quiet_when_balanced():
    """No pressure, no traffic skew → both planners return nothing."""
    n_pages = 16
    policy = MG.TrafficRebalance(k=4, low=8)
    n = 2
    eligible = np.ones((n, n_pages), bool)
    delta = np.zeros((n, S.NUM_COUNTERS), np.int64)
    delta[:, S.C_HOST_RD] = 50              # perfectly balanced
    view = _view(free_units=[100, 100], free_singles=[16, 16],
                 free_groups=[4, 4], eligible=eligible,
                 referenced=np.zeros_like(eligible), delta=delta,
                 times=[1.0, 1.0], n_pages=n_pages)
    host = policy.plan(view)
    jit_plan, jit_urgent = _jit_plan(policy, view)
    _assert_plans_equal(host, jit_plan, jit_urgent)


def test_plan_params_rejects_host_only_policies():
    with pytest.raises(ValueError):
        FS.plan_params(MG.NoMigration())


# ---------------------------------------------------------------------------
# per-device observability (zero extra syncs)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_devices", [
    1, pytest.param(2, marks=needs(2))])
def test_device_tracks_reconcile_device_times(n_devices):
    from repro.obs import Recorder
    from repro.obs import export as OBX
    cfg, fab_plain, ref, (ospn, wr, blk) = _saturating_pair(n_devices)
    del fab_plain
    rec = Recorder()
    rates = np.full((cfg.n_pages, cfg.blocks_per_page), 2, np.int32)
    fab = Fabric(cfg, POLICY, WeightedInterleave(2, cfg.n_pages, [1.0, 0.0]),
                 seed=0, rates_table=jnp.asarray(rates), window=WINDOW,
                 spill=True, spill_interval=WINDOW, spill_k=8, spill_low=40,
                 shard_devices=n_devices, obs=rec)
    fab.replay(ospn, wr, blk)
    ref.replay(ospn, wr, blk)
    assert fab.state_identical(ref), "recording changed sharded state"
    ss = fab.sync_stats()
    assert ss["boundary_syncs"] == ss["boundaries"]   # zero extra syncs
    dt = fab.device_times()
    tot = OBX.fabric_device_totals(rec)
    assert np.allclose(tot["device_s"], dt["device_s"], rtol=1e-9, atol=0)
    assert (tot["owners"] == dt["owners"]).all()
    # each device's extent bounds its owned expanders' delivered seconds
    per = np.asarray(fab.pipeline_times()["delivered_s"])
    for d in range(n_devices):
        assert dt["device_s"][d] >= per[dt["owners"] == d].max() - 1e-15
    trace = OBX.build_trace(rec)
    assert not OBX.validate_trace(trace)
    spans = [e for e in trace["traceEvents"]
             if e["ph"] == "X" and e.get("tid", 0) >= 1000]
    assert spans, "no per-device spans on a sharded run"
    for d in range(n_devices):
        ext = max(e["ts"] + e["dur"] for e in spans
                  if e["tid"] == 1000 + d) / 1e6
        assert np.isclose(ext, dt["device_s"][d], rtol=1e-9)


def test_vmap_runs_emit_no_device_tracks():
    from repro.obs import Recorder
    from repro.obs import export as OBX
    cfg, placement, fab, trace = _saturating_fabric()
    rec = Recorder()
    rates = np.full((cfg.n_pages, cfg.blocks_per_page), 2, np.int32)
    fab = Fabric(cfg, POLICY, WeightedInterleave(2, cfg.n_pages, [1.0, 0.0]),
                 seed=0, rates_table=jnp.asarray(rates), window=WINDOW,
                 spill=True, spill_interval=WINDOW, spill_k=8, spill_low=40,
                 obs=rec)
    fab.replay(*trace)
    assert fab.device_times() is None
    assert OBX.fabric_device_totals(rec) is None
    t = OBX.build_trace(rec)
    assert not any(e.get("tid", 0) >= 1000 for e in t["traceEvents"])


# ---------------------------------------------------------------------------
# mesh plumbing
# ---------------------------------------------------------------------------

def test_shard_devices_must_divide_expanders():
    cfg = _small_cfg()
    rates = np.zeros((cfg.n_pages, cfg.blocks_per_page), np.int32)
    with pytest.raises(ValueError):
        Fabric(cfg, POLICY, WeightedInterleave(3, cfg.n_pages,
                                               [0.5, 0.25, 0.25]),
               seed=0, rates_table=jnp.asarray(rates), shard_devices=2)


def test_device_of_expander_block_layout():
    assert FS.device_of_expander(8, 2).tolist() == [0, 0, 0, 0, 1, 1, 1, 1]
    assert FS.device_of_expander(4, 4).tolist() == [0, 1, 2, 3]
    assert FS.device_of_expander(4, 1).tolist() == [0, 0, 0, 0]
