"""Shared test fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches
must see the single real CPU device; only launch/dryrun.py forces 512."""
import warnings

import numpy as np
import pytest

warnings.filterwarnings("ignore", message=".*dtype int64.*")
warnings.filterwarnings("ignore", message=".*x64.*")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="module", autouse=True)
def _bound_jit_cache():
    """Clear XLA caches between modules: 90+ accumulated compilations make
    later compiles pathologically slow on this single-core container."""
    yield
    import jax
    jax.clear_caches()
