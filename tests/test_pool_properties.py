"""Pool state-machine invariants I1-I5 (engine/state.py docstring, DESIGN.md
§9), enforced without optional dependencies:

  I1  every C-chunk is free XOR referenced by exactly one page
  I2  promoted(page) <=> P-chunk allocated <=> activity entry allocated
  I3  dirty <=> num_chunks == 0 for promoted pages (no compressed copy)
  I4  clean promoted pages have shadow_valid=1 and intact chunks (§4.5)
  I5  read-your-writes at block granularity

Random-but-deterministic op interleavings drive the serial front-end; the
batched front-end replays traces through the same machinery payload-less.
The structural clauses (I1-I4 + conservation) live in
helpers.check_pool_invariants; I5 is asserted against a numpy oracle here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import PoolConfig
from repro.core.engine import batch as B
from repro.core.engine import ops, state as S
from repro.core.engine.policy import DEFAULT_POLICY, POLICIES
from helpers import check_pool_invariants

CFG = PoolConfig(n_pages=24, n_cchunks=256, n_pchunks=16, mcache_sets=2,
                 mcache_ways=2, demote_watermark=2, store_payload=True)

write_page = jax.jit(ops._host_write_page, static_argnums=(1, 2))
read_block = jax.jit(ops._host_read_block, static_argnums=(1, 2))
write_block = jax.jit(ops._host_write_block, static_argnums=(1, 2))


def _run_ops(seed: int, n_ops: int):
    """Apply a deterministic random interleaving of page writes / block reads
    / block writes; returns (pool, oracle dict ospn -> np page)."""
    rng = np.random.default_rng(seed)
    pool = S.make_pool(CFG)
    oracle = {}
    for _ in range(n_ops):
        kind = rng.choice(["wp", "rb", "wb"])
        ospn = int(rng.integers(0, CFG.n_pages))
        blk = int(rng.integers(0, CFG.blocks_per_page))
        key = jax.random.PRNGKey(int(rng.integers(0, 2 ** 16)))
        if kind == "wp":
            vals = (jax.random.normal(key, (CFG.vals_per_page,)) * 0.1
                    ).astype(jnp.bfloat16)
            pool = write_page(pool, CFG, DEFAULT_POLICY, jnp.asarray(ospn),
                              vals)
            oracle[ospn] = np.asarray(vals, np.float32)
        elif kind == "rb":
            pool, vals = read_block(pool, CFG, DEFAULT_POLICY,
                                    jnp.asarray(ospn), jnp.asarray(blk))
            if ospn in oracle:
                ref = oracle[ospn][blk * CFG.vals_per_block:
                                   (blk + 1) * CFG.vals_per_block]
                got = np.asarray(vals, np.float32)
                # I5 (read side): quantization re-cycles may compound a bit
                tol = 2.5 * CFG.tol4 * max(np.abs(ref).max(), 1e-6) + 1e-6
                assert np.abs(got - ref).max() <= tol
            else:
                assert np.all(np.asarray(vals) == 0)
        else:
            bvals = (jax.random.normal(key, (CFG.vals_per_block,)) * 0.2
                     ).astype(jnp.bfloat16)
            pool = write_block(pool, CFG, DEFAULT_POLICY, jnp.asarray(ospn),
                               jnp.asarray(blk), bvals)
            if ospn not in oracle:
                oracle[ospn] = np.zeros((CFG.vals_per_page,), np.float32)
            oracle[ospn][blk * CFG.vals_per_block:
                         (blk + 1) * CFG.vals_per_block] = \
                np.asarray(bvals, np.float32)
    return pool, oracle


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_invariants_random_interleavings(seed):
    """I1-I4 + freelist/ownership conservation after arbitrary op mixes."""
    pool, _ = _run_ops(seed, n_ops=30)
    check_pool_invariants(pool, CFG)


def test_read_your_writes_exact():
    """I5: a freshly written block reads back bit-exactly (it is resident in
    the promoted region; no quantization cycle in between)."""
    pool = S.make_pool(CFG)
    blk = (jax.random.normal(jax.random.PRNGKey(7), (CFG.vals_per_block,))
           * 0.3).astype(jnp.bfloat16)
    pool = write_block(pool, CFG, DEFAULT_POLICY, jnp.asarray(3),
                       jnp.asarray(1), blk)
    pool, got = read_block(pool, CFG, DEFAULT_POLICY, jnp.asarray(3),
                           jnp.asarray(1))
    assert jnp.all(got == blk)
    check_pool_invariants(pool, CFG)


def test_dirty_xor_shadow():
    """I3/I4 word-level check: after a write the page is dirty with no
    chunks; after demote+promote of an unmodified page it is clean with
    shadow_valid=1 and chunks intact."""
    pool, _ = _run_ops(3, n_ops=25)
    meta = np.asarray(pool.meta)
    for ospn in range(CFG.n_pages):
        w0 = int(meta[ospn, 0])
        if not (w0 >> 31) & 1 or not (w0 >> 30) & 1:
            continue
        dirty = (w0 >> 29) & 1
        shadow = (w0 >> 28) & 1
        nchunks = (w0 >> 20) & 0xF
        if dirty:
            assert nchunks == 0 and shadow == 0, hex(w0)   # I3
        else:
            assert shadow == 1 and nchunks > 0, hex(w0)    # I4


def test_batched_replay_preserves_invariants():
    """The batched front-end drives the same mechanisms: I1-I4 hold after a
    windowed payload-less replay under the full policy set's default."""
    cfg = PoolConfig(n_pages=64, n_cchunks=1024, n_pchunks=16, mcache_sets=2,
                     mcache_ways=4, demote_watermark=2, store_payload=False)
    rng = np.random.default_rng(0)
    rates = rng.integers(0, 4, size=(64, 4)).astype(np.int32)
    pool = S.make_pool(cfg, rates_table=jnp.asarray(rates))
    n = 256
    ospns = rng.integers(0, 48, size=n).astype(np.int32)
    writes = rng.random(n) < 0.3
    blocks = rng.integers(0, 4, size=n).astype(np.int32)
    pool = B.replay_trace(pool, cfg, POLICIES["ibex"], ospns, writes, blocks,
                          window=16)
    check_pool_invariants(pool, cfg)
    c = S.counters_dict(pool)
    assert c["host_reads"] + c["host_writes"] == n
