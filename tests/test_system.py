"""End-to-end behaviour tests for the paper's system: the IBEX mechanism's
headline claims exercised through the full stack (fast versions of the
benchmark cells)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.simx.engine import SCHEMES, run_workload
from repro.simx.trace import WORKLOADS

# full-size cells are slow; tier-1 scheme checks live in test_simx_schemes.py
pytestmark = pytest.mark.slow


def test_ibex_beats_tmcc_on_migration_heavy_workload():
    """Paper Fig. 9/11: on migration-heavy traffic IBEX moves far fewer
    internal bytes than TMCC and ends up faster."""
    ibex = run_workload("ibex", WORKLOADS["pr"], n_accesses=3000,
                        promoted_pages=48)
    tmcc = run_workload("tmcc", WORKLOADS["pr"], n_accesses=3000,
                        promoted_pages=48)
    assert ibex["internal_accesses"] < tmcc["internal_accesses"]
    assert ibex["time_s"] < tmcc["time_s"]


def test_shadowed_promotion_eliminates_recompression_readonly():
    """Paper §6.2: the read-only workload (XSBench) has ~zero dirty
    demotions under shadowed promotion."""
    r = run_workload("ibex", WORKLOADS["xsbench"], n_accesses=3000,
                     promoted_pages=48)
    total = r["demotions_clean"] + r["demotions_dirty"]
    if total:
        # a page's FIRST demotion is necessarily dirty (first-touch data was
        # never compressed); steady-state demotions are clean. At this trace
        # length the first-compression tail is ~10-15% of demotions.
        assert r["demotions_clean"] / total > 0.8
    # and the no-shadow ablation recompresses
    base = run_workload("ibex_base", WORKLOADS["xsbench"], n_accesses=3000,
                        promoted_pages=48)
    assert base["demotions_dirty"] >= base["demotions_clean"]


def test_random_fallback_is_rare():
    """Paper §4.4: random selection in <~1% of demotions at sane ratios."""
    r = run_workload("ibex", WORKLOADS["mcf"], n_accesses=3000,
                     promoted_pages=48)
    total = max(r["demotions_clean"] + r["demotions_dirty"], 1)
    assert r["random_fallback"] / total < 0.25  # loose: tiny test config


def test_compression_expands_capacity():
    r = run_workload("ibex", WORKLOADS["omnetpp"], n_accesses=2000,
                     promoted_pages=48)
    assert r["compression_ratio"] > 1.1
