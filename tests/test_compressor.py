"""Unit + property tests for the rate-adaptive block compressor."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.common.types import PoolConfig
from repro.core import compressor as comp
from repro.core.bitpack import (RATE_4BIT, RATE_8BIT, RATE_RAW, RATE_ZERO,
                                pack4, unpack4, quantize_block,
                                dequantize_block)

CFG = PoolConfig(store_payload=True)
KEY = jax.random.PRNGKey(0)


def _page(kind: str, key=KEY) -> jnp.ndarray:
    n = CFG.vals_per_page
    if kind == "zero":
        return jnp.zeros((n,), jnp.bfloat16)
    if kind == "smooth":
        return (jax.random.normal(key, (n,)) * 0.1).astype(jnp.bfloat16)
    if kind == "random_bits":
        bits = jax.random.randint(key, (n,), 0, 2 ** 16).astype(jnp.uint16)
        x = jax.lax.bitcast_convert_type(bits, jnp.bfloat16)
        return jnp.where(jnp.isfinite(x), x, jnp.bfloat16(1.0))
    raise ValueError(kind)


def test_pack4_roundtrip():
    q = jnp.arange(-8, 8, dtype=jnp.int8)
    assert jnp.all(unpack4(pack4(q), 16) == q)


def test_quantize_error_bound():
    x = _page("smooth")
    q, s = quantize_block(x.reshape(4, -1), 8)
    y = dequantize_block(q, s)
    err = jnp.max(jnp.abs(y.astype(jnp.float32) - x.reshape(4, -1).astype(jnp.float32)))
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    assert err <= amax / 127.0 * 0.51 + amax * 0.01  # half-step + bf16 rounding


@pytest.mark.parametrize("kind,expect_rate", [
    ("zero", RATE_ZERO), ("random_bits", RATE_RAW)])
def test_rate_selection(kind, expect_rate):
    # random bit patterns are only guaranteed RAW under the lossless rule;
    # lossy mode measures error relative to block amax (the KV-cache criterion)
    cfg = PoolConfig(store_payload=True, lossless=(kind == "random_bits"))
    x = _page(kind)
    buf, rates, quanta, nchunks = comp.encode_page(x, cfg)
    assert jnp.all(rates == expect_rate)
    y = comp.decode_page(buf, rates, cfg)
    if kind == "zero":
        assert int(nchunks) == 0 and jnp.all(y == 0)
    else:
        assert int(nchunks) == 8 and jnp.all(y == x)  # raw is exact


def test_mixed_page_block_decode():
    cfg = PoolConfig(store_payload=True, lossless=True)
    key = jax.random.PRNGKey(3)
    raw = _page("random_bits", key)
    # integers with amax pinned to 127 make the 8-bit grid exact (scale=1)
    ints = jax.random.randint(key, (512,), -126, 127).at[0].set(127)
    x = jnp.concatenate([
        jnp.zeros(512, jnp.bfloat16),
        ints.astype(jnp.bfloat16),
        raw[:1024]])
    buf, rates, quanta, nchunks = comp.encode_page(x, cfg)
    assert int(rates[0]) == RATE_ZERO and int(rates[2]) == RATE_RAW
    assert int(rates[1]) in (RATE_4BIT, RATE_8BIT)
    for b in range(4):
        blk = comp.decode_block(buf, rates, jnp.asarray(b), cfg)
        ref = x[b * 512:(b + 1) * 512]
        assert jnp.all(blk == ref)  # lossless mode: exact per-block decode


def test_quanta_match_num_chunks():
    for kind in ("zero", "smooth", "random_bits"):
        x = _page(kind)
        _, rates, quanta, nchunks = comp.encode_page(x, CFG)
        assert int(nchunks) == -(-int(jnp.sum(quanta)) // 4)


def test_coloc_off_single_block():
    cfg = PoolConfig(coloc=False, store_payload=True)
    x = _page("smooth")
    buf, rates, quanta, nchunks = comp.encode_page(x, cfg)
    assert rates.shape == (1,)
    y = comp.decode_page(buf, rates, cfg)
    amax = float(jnp.max(jnp.abs(x.astype(jnp.float32))))
    assert float(jnp.max(jnp.abs(y.astype(jnp.float32) - x.astype(jnp.float32)))) \
        <= cfg.tol4 * amax + 1e-6


def test_lossless_mode_exact():
    cfg = PoolConfig(lossless=True, store_payload=True)
    # grid-aligned integers (amax=127 -> scale=1) compress losslessly at 8-bit
    key = jax.random.PRNGKey(7)
    x = jax.random.randint(key, (cfg.vals_per_page,), -126, 127)
    x = x.at[0].set(127).astype(jnp.bfloat16)
    buf, rates, _, _ = comp.encode_page(x, cfg)
    assert int(rates[0]) == RATE_8BIT
    y = comp.decode_page(buf, rates, cfg)
    assert jnp.all(y == x)

    # and arbitrary bit patterns still roundtrip exactly (raw fallback)
    xr = _page("random_bits")
    buf, rates, _, _ = comp.encode_page(xr, cfg)
    assert jnp.all(comp.decode_page(buf, rates, cfg) == xr)


@settings(max_examples=25, deadline=None)
@given(scale=st.floats(1e-4, 1e3), seed=st.integers(0, 2 ** 16))
def test_property_error_bound(scale, seed):
    """decode(encode(x)) is within the configured relative tolerance for any
    block that was not stored raw; raw and zero blocks are exact."""
    key = jax.random.PRNGKey(seed)
    x = (jax.random.normal(key, (CFG.vals_per_page,)) * scale).astype(jnp.bfloat16)
    buf, rates, _, _ = comp.encode_page(x, CFG)
    y = comp.decode_page(buf, rates, CFG)
    xb = x.reshape(4, -1).astype(jnp.float32)
    yb = y.reshape(4, -1).astype(jnp.float32)
    for b in range(4):
        r = int(rates[b])
        err = float(jnp.max(jnp.abs(yb[b] - xb[b])))
        amax = float(jnp.max(jnp.abs(xb[b])))
        if r in (RATE_ZERO, RATE_RAW):
            assert err == 0.0
        elif r == RATE_4BIT:
            assert err <= CFG.tol4 * amax + 1e-6
        elif r == RATE_8BIT:
            assert err <= CFG.tol8 * amax + 1e-6


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), bits=st.sampled_from([4, 8]))
def test_property_flat_quantize_roundtrip(seed, bits):
    key = jax.random.PRNGKey(seed)
    x = (jax.random.normal(key, (2048,))).astype(jnp.bfloat16)
    codes, scales = comp.quantize_blocks(x, bits, 512)
    y = comp.dequantize_blocks(codes, scales, bits, 512)
    qmax = 2 ** (bits - 1) - 1
    xb = x.reshape(4, 512).astype(np.float32)
    yb = np.asarray(y, np.float32).reshape(4, 512)
    for b in range(4):
        amax = np.abs(xb[b]).max()
        assert np.abs(yb[b] - xb[b]).max() <= amax / qmax * 0.51 + amax * 0.01
