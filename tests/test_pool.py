"""Integration + property tests for the IBEX pool state machine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # optional dev dependency; see requirements-dev.txt
    HAVE_HYPOTHESIS = False

from repro.common.types import PoolConfig, replace
from repro.core import engine as E

POL = E.DEFAULT_POLICY
from helpers import check_pool_invariants

CFG = PoolConfig(n_pages=64, n_cchunks=512, n_pchunks=32, mcache_sets=4,
                 mcache_ways=4, demote_watermark=4, store_payload=True)
KEY = jax.random.PRNGKey(1)


def _page(i, scale=0.1):
    return (jax.random.normal(jax.random.fold_in(KEY, i),
                              (CFG.vals_per_page,)) * scale).astype(jnp.bfloat16)


@pytest.fixture(scope="module")
def warm_pool():
    pool = E.make_pool(CFG)
    for i in range(48):
        pool = E.host_write_page(pool, CFG, POL, jnp.asarray(i), _page(i))
    return pool


def test_write_read_cycle(warm_pool):
    pool = warm_pool
    for i in range(48):
        pool, vals = E.host_read_block(pool, CFG, POL, jnp.asarray(i), jnp.asarray(0))
        ref = np.asarray(_page(i)[:CFG.vals_per_block], np.float32)
        got = np.asarray(vals, np.float32)
        assert np.abs(got - ref).max() <= CFG.tol4 * np.abs(ref).max() + 1e-6, i
    check_pool_invariants(pool, CFG)


def test_shadowed_promotion_clean_demotions(warm_pool):
    """Read-only traffic after the warmup must produce clean demotions
    (§4.5: no recompression for unmodified pages)."""
    pool = warm_pool
    base = E.counters_dict(pool)
    for rep in range(2):
        for i in range(48):
            pool, _ = E.host_read_block(pool, CFG, POL, jnp.asarray(i), jnp.asarray(rep))
    c = E.counters_dict(pool)
    clean = c["demotions_clean"] - base["demotions_clean"]
    dirty = c["demotions_dirty"] - base["demotions_dirty"]
    # every page demoted in the read phase was re-promoted from its shadow at
    # some point; dirty demotions only happen for pages still carrying their
    # first-touch (never-compressed) state.
    assert clean > 0
    assert clean >= dirty
    check_pool_invariants(pool, CFG)


def test_zero_page_elision():
    pool = E.make_pool(CFG)
    pool = E.host_write_page(pool, CFG, POL, jnp.asarray(0), jnp.zeros((CFG.vals_per_page,), jnp.bfloat16))
    # force demotion so the zero page gets compressed (to nothing)
    for i in range(1, 40):
        pool = E.host_write_page(pool, CFG, POL, jnp.asarray(i), _page(i))
    before = E.counters_dict(pool)
    pool, vals = E.host_read_block(pool, CFG, POL, jnp.asarray(0), jnp.asarray(0))
    after = E.counters_dict(pool)
    assert jnp.all(vals == 0)
    if after["zero_served"] > before["zero_served"]:
        # zero pages are served from metadata alone: no data traffic
        assert after["data_rd"] == before["data_rd"]
        assert after["promo_rd"] == before["promo_rd"]
    check_pool_invariants(pool, CFG)


@pytest.mark.slow
def test_read_your_writes(warm_pool):
    pool = warm_pool
    for i in range(6):
        blk = (jax.random.normal(jax.random.fold_in(KEY, 999 + i),
                                 (CFG.vals_per_block,)) * 0.3).astype(jnp.bfloat16)
        pool = E.host_write_block(pool, CFG, POL, jnp.asarray(i), jnp.asarray(2), blk)
        pool, rb = E.host_read_block(pool, CFG, POL, jnp.asarray(i), jnp.asarray(2))
        assert jnp.all(rb == blk)
        # I5 extended: the *other* blocks survive the write
        pool, other = E.host_read_block(pool, CFG, POL, jnp.asarray(i), jnp.asarray(0))
        ref = np.asarray(_page(i)[:CFG.vals_per_block], np.float32)
        got = np.asarray(other, np.float32)
        assert np.abs(got - ref).max() <= CFG.tol4 * np.abs(ref).max() + 1e-6
    check_pool_invariants(pool, CFG)


def test_write_invalidates_shadow(warm_pool):
    pool = warm_pool
    blk = jnp.ones((CFG.vals_per_block,), jnp.bfloat16)
    pool = E.host_write_block(pool, CFG, POL, jnp.asarray(3), jnp.asarray(1), blk)
    w0 = int(np.asarray(pool.meta)[3, 0])
    assert (w0 >> 29) & 1 == 1      # dirty
    assert (w0 >> 28) & 1 == 0      # shadow dropped
    assert (w0 >> 20) & 0xF == 0    # chunks released (the §4.5 update moment)
    check_pool_invariants(pool, CFG)


def test_compression_ratio_sane(warm_pool):
    r = float(E.compression_ratio(warm_pool, CFG))
    assert 0.9 < r < 4.0


@pytest.mark.slow
def test_shadow_disabled_all_dirty():
    cfg = replace(CFG, shadow=False)
    pool = E.make_pool(cfg)
    for i in range(48):
        pool = E.host_write_page(pool, cfg, POL, jnp.asarray(i), _page(i))
    for rep in range(2):
        for i in range(48):
            pool, _ = E.host_read_block(pool, cfg, POL, jnp.asarray(i), jnp.asarray(0))
    c = E.counters_dict(pool)
    assert c["demotions_clean"] == 0          # no shadow -> every demotion recompresses
    assert c["demotions_dirty"] > 0
    check_pool_invariants(pool, cfg)


def _random_ops_invariants(ops):
    """I1-I5 hold under arbitrary interleavings of page writes, block reads
    and block writes."""
    cfg = PoolConfig(n_pages=24, n_cchunks=256, n_pchunks=16, mcache_sets=2,
                     mcache_ways=2, demote_watermark=2, store_payload=True)
    pool = E.make_pool(cfg)
    shadow = {}  # ospn -> np page (oracle, exact for raw/zero; quantized else)
    for kind, ospn, blk, seed in ops:
        if kind == "wp":
            vals = (jax.random.normal(jax.random.PRNGKey(seed),
                                      (cfg.vals_per_page,)) * 0.1).astype(jnp.bfloat16)
            pool = E.host_write_page(pool, cfg, POL, jnp.asarray(ospn), vals)
            shadow[ospn] = np.asarray(vals, np.float32)
        elif kind == "rb":
            pool, vals = E.host_read_block(pool, cfg, POL, jnp.asarray(ospn), jnp.asarray(blk))
            if ospn in shadow:
                ref = shadow[ospn][blk * cfg.vals_per_block:(blk + 1) * cfg.vals_per_block]
                got = np.asarray(vals, np.float32)
                # 2.5x: re-quantization across demote/promote cycles can
                # compound slightly when block amax drifts on the grid
                tol = 2.5 * cfg.tol4 * max(np.abs(ref).max(), 1e-6) + 1e-6
                assert np.abs(got - ref).max() <= tol
            else:
                assert np.all(np.asarray(vals) == 0)
        else:
            bvals = (jax.random.normal(jax.random.PRNGKey(seed),
                                       (cfg.vals_per_block,)) * 0.2).astype(jnp.bfloat16)
            pool = E.host_write_block(pool, cfg, POL, jnp.asarray(ospn), jnp.asarray(blk), bvals)
            if ospn not in shadow:
                shadow[ospn] = np.zeros((cfg.vals_per_page,), np.float32)
            shadow[ospn][blk * cfg.vals_per_block:(blk + 1) * cfg.vals_per_block] = \
                np.asarray(bvals, np.float32)
    check_pool_invariants(pool, cfg)


if HAVE_HYPOTHESIS:
    OPS = st.lists(
        st.tuples(st.sampled_from(["wp", "rb", "wb"]), st.integers(0, 23),
                  st.integers(0, 3), st.integers(0, 2 ** 16)),
        min_size=5, max_size=40)

    @pytest.mark.slow
    @settings(max_examples=12, deadline=None)
    @given(ops=OPS)
    def test_property_invariants_random_ops(ops):
        _random_ops_invariants(ops)
else:
    @pytest.mark.slow
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_property_invariants_random_ops():
        pass
