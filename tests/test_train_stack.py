"""Training substrate tests: optimizer, compressed state, grad compression,
data pipeline determinism, checkpoint atomicity/integrity/elastic restore,
and a short end-to-end loss-goes-down run."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import OptimizerConfig, TrainConfig, replace
from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, make_batch
from repro.models import transformer as T
from repro.optim import adamw, gradcomp
from repro.train import checkpoint as ckpt
from repro.train import elastic
from repro.train.trainer import grads_and_loss, make_train_step

CFG = get_reduced("llama3_8b")
KEY = jax.random.PRNGKey(0)


def _batch(step=0, b=4, s=32):
    return make_batch(CFG, step, global_batch=b, seq_len=s)


# -- optimizer ----------------------------------------------------------------

@pytest.mark.slow
def test_adamw_decreases_loss():
    params, _ = T.init_params(KEY, CFG)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=1)
    opt = adamw.init(params, ocfg)
    batch = _batch()
    losses = []
    for i in range(8):
        grads, loss = grads_and_loss(params, batch, CFG, 1)
        params, opt, m = adamw.update(grads, opt, params, ocfg)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


@pytest.mark.slow
def test_compressed_state_tracks_dense():
    params, _ = T.init_params(KEY, CFG)
    batch = _batch()
    dense_cfg = OptimizerConfig(lr=1e-3, warmup_steps=1)
    comp_cfg = replace(dense_cfg, compress_state=True, state_block=256)
    pd, pc = params, params
    od, oc = adamw.init(params, dense_cfg), adamw.init(params, comp_cfg)
    ld = lc = None
    for i in range(8):
        gd, ld = grads_and_loss(pd, batch, CFG, 1)
        gc_, lc = grads_and_loss(pc, batch, CFG, 1)
        pd, od, _ = adamw.update(gd, od, pd, dense_cfg)
        pc, oc, _ = adamw.update(gc_, oc, pc, comp_cfg)
    # individual parameter paths diverge chaotically under moment rounding
    # (expected for linear int8 moments); what must match is optimization
    # QUALITY: both runs make comparable progress from the same start. On an
    # untrained model over 8 steps, end-loss *proximity* is itself chaotic,
    # so assert relative progress instead.
    _, ld_end = grads_and_loss(pd, batch, CFG, 1)
    _, lc_end = grads_and_loss(pc, batch, CFG, 1)
    _, l0 = grads_and_loss(params, batch, CFG, 1)
    prog_d = float(l0) - float(ld_end)
    prog_c = float(l0) - float(lc_end)
    assert prog_d > 0 and prog_c > 0            # both optimize
    assert prog_c > 0.5 * prog_d                # compressed keeps >=50% of
    #                                             the dense run's progress


def test_compressed_state_smaller():
    params, _ = T.init_params(KEY, CFG)
    dense = adamw.init(params, OptimizerConfig())
    comp = adamw.init(params, OptimizerConfig(compress_state=True))
    assert adamw.state_bytes(comp) < 0.35 * adamw.state_bytes(dense)


# -- gradient compression ------------------------------------------------------

def test_gradcomp_error_feedback_reduces_bias():
    g = {"w": jax.random.normal(KEY, (2048,)) * 0.01}
    r = gradcomp.init_residual(g)
    # accumulated EF-compressed grads track accumulated true grads
    acc_true = jnp.zeros((2048,))
    acc_comp = jnp.zeros((2048,))
    for i in range(16):
        gi = {"w": jax.random.normal(jax.random.fold_in(KEY, i), (2048,)) * 0.01}
        q, r = gradcomp.compress_with_feedback(gi, r, block=256)
        back = gradcomp.decompress(q, gi, block=256)
        acc_true += gi["w"]
        acc_comp += back["w"]
    err = float(jnp.linalg.norm(acc_comp - acc_true) /
                jnp.linalg.norm(acc_true))
    assert err < 0.05, err     # EF bounds accumulated error


def test_gradcomp_bytes():
    g = {"w": jnp.zeros((4096,), jnp.float32)}
    q, _ = gradcomp.compress_with_feedback(g, gradcomp.init_residual(g))
    assert gradcomp.compressed_bytes(q) < 0.3 * 4096 * 4


# -- data pipeline -------------------------------------------------------------

def test_pipeline_deterministic_and_sharded():
    b1 = make_batch(CFG, 7, global_batch=8, seq_len=64, shard=0, num_shards=2)
    b2 = make_batch(CFG, 7, global_batch=8, seq_len=64, shard=0, num_shards=2)
    b3 = make_batch(CFG, 7, global_batch=8, seq_len=64, shard=1, num_shards=2)
    assert jnp.all(b1["tokens"] == b2["tokens"])          # replayable
    assert not bool(jnp.all(b1["tokens"] == b3["tokens"]))  # shards differ
    assert b1["tokens"].shape == (4, 64)
    assert jnp.all(b1["labels"][:, :-1] == b1["tokens"][:, 1:])


def test_pipeline_mix_exercises_compressor():
    b = make_batch(CFG, 0, global_batch=8, seq_len=256,
                   dcfg=DataConfig(zero_frac=0.3))
    frac_zero = float(jnp.mean(b["tokens"] == 0))
    assert 0.05 < frac_zero < 0.6


# -- checkpointing -------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(8, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 3), jnp.bfloat16)}}
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, tree, keep=2)
    assert ckpt.list_steps(d) == [3, 4]
    assert ckpt.latest(d) == 4
    like = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), tree)
    back, _ = ckpt.restore(d, 4, like)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        assert jnp.all(a == b)


def test_checkpoint_detects_corruption(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.arange(1024, dtype=jnp.float32)}
    ckpt.save(d, 1, tree)
    ckpt.save(d, 2, tree)
    # corrupt the newest payload
    import glob
    npz = glob.glob(os.path.join(d, "step_00000002", "arrays.npz"))[0]
    with open(npz, "r+b") as f:
        f.seek(120)
        f.write(b"\xde\xad\xbe\xef")
    assert ckpt.latest(d) == 1     # falls back to the last valid one


def test_checkpoint_async(tmp_path):
    d = str(tmp_path / "ck")
    tree = {"a": jnp.ones((64,), jnp.float32)}
    t = ckpt.save_async(d, 5, tree)
    ckpt.wait_pending()
    assert ckpt.latest(d) == 5


# -- elastic -------------------------------------------------------------------

def test_plan_mesh_factors():
    m = elastic.plan_mesh(512, prefer_model=16, pods=2)
    assert m.shape == (2, 16, 16) and m.axes == ("pod", "data", "model")
    m = elastic.plan_mesh(256, prefer_model=16)
    assert m.shape == (16, 16)
    m = elastic.plan_mesh(6, prefer_model=16)
    assert m.num_devices == 6


def test_degraded_plan():
    old = elastic.plan_mesh(512, prefer_model=16, pods=2)
    new = elastic.degraded_plan(old, lost_devices=16)
    assert new.num_devices <= 496
    assert new.num_devices % new.shape[-1] == 0


def test_straggler_monitor():
    mon = elastic.StragglerMonitor(4)
    for step in range(5):
        for r in range(4):
            mon.record(r, 1.0 if r != 2 else 3.5)
    assert mon.stragglers() == [2]


# -- end-to-end train step (jit path used by launch/train.py) -------------------

def test_make_train_step_runs():
    tcfg = TrainConfig(steps=3, seq_len=32, global_batch=4, microbatches=2,
                       optimizer=OptimizerConfig(lr=1e-3, warmup_steps=1))
    params, _ = T.init_params(KEY, CFG)
    opt = adamw.init(params, tcfg.optimizer)
    step_fn, _ = make_train_step(CFG, tcfg)
    batch = _batch(b=4, s=32)
    p, o, m = step_fn(params, opt, batch)
    assert np.isfinite(float(m["loss"]))
    p, o, m = step_fn(p, o, _batch(step=1, b=4, s=32))
    assert np.isfinite(float(m["loss"]))
