"""Fused demote/promote kernel parity + batched demotion bit-identity +
measurement-calibrated device model (DESIGN.md §14).

The fused Pallas kernels must be *bit-identical* to the jnp oracle in
``core/compressor.py`` — same reciprocal-multiply quantization, same byte
layout — across all four rate codes and both block modes, so the engine can
dispatch on ``compress_impl`` without changing any pool state. Off-TPU the
kernels run in interpret mode (this is the CI kernel-parity smoke)."""
import dataclasses
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import PoolConfig
from repro.core import compressor as comp
from repro.core import engine as E
from repro.core import mcache as mcc
from repro.core import metadata as md
from repro.core.engine import ops as OPS
from repro.kernels import ops as kops
from repro.kernels import qpack as qp
from repro.simx import time as TM

KEY = jax.random.PRNGKey(0)
POL = E.DEFAULT_POLICY


# -- crafted blocks covering every rate under lossless selection -------------

def _blocks_all_rates(v: int, n: int) -> jnp.ndarray:
    """n blocks of v values cycling zero -> exact-4bit -> exact-8bit -> raw.

    Exact 4-bit needs integer values with amax exactly 7 (scale = 7/7 = 1.0);
    exact 8-bit: integers with amax exactly 127. Both are bf16-exact, and the
    4-bit roundtrip of the 8-bit block fails (scale 127/7 is inexact), so
    lossless selection lands each block on the intended rate."""
    blocks = []
    for i in range(n):
        k = jax.random.fold_in(KEY, i)
        m = i % 4
        if m == 0:
            b = jnp.zeros((v,), jnp.bfloat16)
        elif m == 1:
            b = jax.random.randint(k, (v,), -7, 8).astype(jnp.bfloat16)
            b = b.at[0].set(7.0)
        elif m == 2:
            b = jax.random.randint(k, (v,), -120, 121).astype(jnp.bfloat16)
            b = b.at[0].set(127.0)
        else:
            b = (jax.random.normal(k, (v,)) * 3).astype(jnp.bfloat16)
        blocks.append(b)
    return jnp.stack(blocks)


def _assert_trees_equal(a, b, what=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# -- fused kernel vs jnp oracle ----------------------------------------------

@pytest.mark.parametrize("coloc", [True, False])
def test_fused_encode_decode_all_rates_bit_identical(coloc):
    cfg_j = PoolConfig(coloc=coloc, lossless=True, compress_impl="jnp")
    cfg_k = dataclasses.replace(cfg_j, compress_impl="kernel")
    nb = cfg_j.blocks_per_page if coloc else 1
    xs = _blocks_all_rates(cfg_j.vals_per_page // nb, 8 * nb) \
        .reshape(8, cfg_j.vals_per_page)
    bj, rj, qj, nj = comp.encode_pages(xs, cfg_j)
    bk, rk, qk, nk = comp.encode_pages(xs, cfg_k)
    # all four rates actually exercised
    assert set(np.asarray(rj).ravel().tolist()) == {0, 1, 2, 3}
    _assert_trees_equal((bj, rj, qj, nj), (bk, rk, qk, nk), "encode")
    dj = comp.decode_pages(bj, rj, cfg_j)
    dk = comp.decode_pages(bj, rj, cfg_k)
    np.testing.assert_array_equal(np.asarray(dj, np.float32),
                                  np.asarray(dk, np.float32))
    # lossless blocks roundtrip exactly on the kernel path too
    keep = np.asarray(rj).ravel() != 3
    got = np.asarray(dk, np.float32).reshape(8 * nb, -1)[keep]
    want = np.asarray(xs, np.float32).reshape(8 * nb, -1)[keep]
    np.testing.assert_array_equal(got, want)


def test_fused_single_page_dispatch_matches_batched():
    cfg_k = PoolConfig(lossless=True, compress_impl="kernel")
    x = _blocks_all_rates(cfg_k.vals_per_block, 4).reshape(-1)
    b1, r1, q1, n1 = comp.encode_page(x, cfg_k)
    bb, rb, qb, nb_ = comp.encode_pages(x[None], cfg_k)
    _assert_trees_equal((b1, r1, q1, n1), (bb[0], rb[0], qb[0], nb_[0]))
    d1 = comp.decode_page(b1, r1, cfg_k)
    dj = comp.decode_page(b1, r1, dataclasses.replace(cfg_k,
                                                      compress_impl="jnp"))
    np.testing.assert_array_equal(np.asarray(d1, np.float32),
                                  np.asarray(dj, np.float32))


def test_fused_default_tol_random_parity():
    cfg_j = PoolConfig(compress_impl="jnp")
    cfg_k = dataclasses.replace(cfg_j, compress_impl="kernel")
    xs = (jax.random.normal(KEY, (4, cfg_j.vals_per_page)) *
          0.7).astype(jnp.bfloat16)
    _assert_trees_equal(comp.encode_pages(xs, cfg_j),
                        comp.encode_pages(xs, cfg_k))


def test_fused_zero_elision_clamp_parity():
    cfg_j = PoolConfig(zero_elision=False, compress_impl="jnp")
    cfg_k = dataclasses.replace(cfg_j, compress_impl="kernel")
    xs = jnp.zeros((2, cfg_j.vals_per_page), jnp.bfloat16)
    out_j = comp.encode_pages(xs, cfg_j)
    out_k = comp.encode_pages(xs, cfg_k)
    # all-zero blocks are clamped to the 4-bit rate, never elided
    assert (np.asarray(out_j[1]) == 1).all()
    _assert_trees_equal(out_j, out_k)


def test_fused_quanta_match_rate_table():
    cfg_k = PoolConfig(lossless=True, compress_impl="kernel")
    xs = _blocks_all_rates(cfg_k.vals_per_block, 16) \
        .reshape(4, cfg_k.vals_per_page)
    _, rates, quanta, _ = comp.encode_pages(xs, cfg_k)
    qt = np.asarray(comp.block_quanta_table(cfg_k.vals_per_block))
    np.testing.assert_array_equal(np.asarray(quanta), qt[np.asarray(rates)])


def test_quantize_blocks_fast_parity():
    x = (jax.random.normal(KEY, (4, 1024)) * 2).astype(jnp.bfloat16)
    for bits in (4, 8):
        cj, sj = comp.quantize_blocks(x, bits, 256)
        ck, sk = comp.quantize_blocks_fast(x, bits, 256, impl="kernel")
        np.testing.assert_array_equal(np.asarray(cj), np.asarray(ck))
        np.testing.assert_array_equal(np.asarray(sj), np.asarray(sk))


def test_interpret_auto_detect():
    """Satellite 1: interpret defaults to backend detection, not True."""
    on_tpu = jax.default_backend() == "tpu"
    assert qp.resolve_interpret(None) == (not on_tpu)
    assert qp.resolve_interpret(True) is True
    assert qp.resolve_interpret(False) is False
    assert kops.INTERPRET == (not on_tpu)


def test_resolve_impl_dispatch():
    assert comp.resolve_impl(PoolConfig(compress_impl="jnp")) == "jnp"
    assert comp.resolve_impl(PoolConfig(compress_impl="kernel")) == "kernel"
    auto = comp.resolve_impl(PoolConfig())
    assert auto == ("kernel" if jax.default_backend() == "tpu" else "jnp")


# -- batched multi-victim demotion vs the serial reference -------------------

def _demotions(c):
    return c["demotions_clean"] + c["demotions_dirty"]


def _burst_pool(cfg, n_writes):
    """Oversubscribed write burst: every P-chunk allocated + dirty."""
    pool = E.make_pool(cfg)
    for i in range(n_writes):
        x = (jax.random.normal(jax.random.fold_in(KEY, i),
                               (cfg.vals_per_page,)) * 0.1).astype(jnp.bfloat16)
        pool = E.host_write_page(pool, cfg, POL, jnp.asarray(i), x)
    return pool


def _victim_ready(pool, cfg):
    """Make clock_scan victims deterministically findable: clear every
    allocated entry's referenced bit and flush the metadata cache, so the
    eligibility mask ``alloc & ~ref & ~probed`` covers the whole promoted
    region (a freshly written burst is all-referenced and cache-resident,
    which starves the non-forced demotion site)."""
    alloc = md.act_allocated(pool.activity) == 1
    cleared = jnp.where(alloc, md.act_set_referenced(pool.activity, 0),
                        pool.activity)
    return pool._replace(activity=cleared,
                         cache=mcc.make_mcache(cfg.mcache_sets,
                                               cfg.mcache_ways))


def _demote_cfg(**kw):
    # 36 written pages over 24 P-chunks: the burst exhausts the promoted
    # region, so the victim-ready pool starts at free_count(pfree) == 0
    return PoolConfig(n_pages=48, n_cchunks=384, n_pchunks=24, mcache_sets=4,
                      mcache_ways=4, demote_watermark=4, **kw)


def _demote_pair(base, n_writes=36, max_demotes=3, watermark=8,
                 ser_impl="jnp", bat_impl="jnp"):
    """One victim-ready pool through serial demote_if_needed vs demote_batch.

    Returns (input_pool, serial_out, batched_out)."""
    ser_cfg = dataclasses.replace(base, fused_demote="off",
                                  compress_impl=ser_impl)
    bat_cfg = dataclasses.replace(base, fused_demote="on",
                                  compress_impl=bat_impl)
    pool = _victim_ready(_burst_pool(ser_cfg, n_writes), base)
    run = lambda cfg: jax.jit(functools.partial(
        OPS.demote_if_needed, cfg=cfg, policy=POL, max_demotes=max_demotes,
        watermark=watermark))(pool)
    return pool, run(ser_cfg), run(bat_cfg)


def _check_pair(pool, ser, bat, max_demotes=3, what=""):
    delta = _demotions(E.counters_dict(ser)) - _demotions(E.counters_dict(pool))
    assert delta == max_demotes, \
        f"serial demote_if_needed demoted {delta}/{max_demotes} — " \
        "demote_batch not genuinely exercised"
    _assert_trees_equal(ser, bat, what)
    assert E.counters_dict(ser) == E.counters_dict(bat)


def test_batched_demote_bit_identical_payload():
    base = _demote_cfg(store_payload=True)
    pool, ser, bat = _demote_pair(base)
    _check_pair(pool, ser, bat, what="payload pools")
    # the burst leaves every written page dirty, so the batch recompressed
    # real payloads (the fused-encode path), not just clean revalidations
    assert E.counters_dict(ser)["demotions_dirty"] > \
        E.counters_dict(pool)["demotions_dirty"]


def test_batched_demote_bit_identical_metadata_only():
    base = _demote_cfg(store_payload=False)
    pool, ser, bat = _demote_pair(base)
    _check_pair(pool, ser, bat, what="metadata-only pools")


def test_batched_demote_end_to_end_steps():
    """Dispatch inside a jitted access loop: watermark top-up + read each
    step, serial vs batched configs end on bit-identical state."""
    base = _demote_cfg(store_payload=True)

    def run(cfg):
        @jax.jit
        def step(pool, ospn, blk):
            pool = OPS.demote_if_needed(pool, cfg, POL, max_demotes=3,
                                        watermark=8)
            pool, _ = OPS.read_block_op(pool, cfg, POL, ospn, blk)
            return pool
        pool = _victim_ready(_burst_pool(cfg, 36), cfg)
        for r in range(8):
            pool = step(pool, jnp.asarray(r % 36), jnp.asarray(r % 4))
        return pool

    ser = run(dataclasses.replace(base, fused_demote="off",
                                  compress_impl="jnp"))
    bat = run(dataclasses.replace(base, fused_demote="on",
                                  compress_impl="jnp"))
    _assert_trees_equal(ser, bat, "end-to-end pools")
    assert E.counters_dict(ser) == E.counters_dict(bat)


@pytest.mark.slow
def test_batched_demote_kernel_impl_bit_identical():
    """The full stack: batched demotion routed through the fused Pallas
    encode kernel (interpret mode off-TPU) vs the serial jnp reference."""
    base = PoolConfig(n_pages=32, n_cchunks=256, n_pchunks=16, mcache_sets=4,
                      mcache_ways=4, demote_watermark=4, store_payload=True)
    pool, ser, ker = _demote_pair(base, n_writes=24, bat_impl="kernel")
    _check_pair(pool, ser, ker, what="kernel-impl pools")


# -- measurement-calibrated device model -------------------------------------

def test_calibrated_device_from_bench_file(tmp_path):
    p = tmp_path / "BENCH_kernels.json"
    p.write_text(json.dumps({"calibration": {
        "compress_gbps": 4.0, "decompress_gbps": 64.0,
        "block_bytes": 1024}}))
    cal = TM.calibrated_device(path=p)
    base = TM.DeviceConfig()
    # cycles = clock * block_bytes / measured B/s
    assert cal.comp_cycles == round(base.clock * 1024 / 4e9)
    assert cal.decomp_cycles == round(base.clock * 1024 / 64e9)
    assert cal != base
    # everything but the engine constants is untouched
    assert dataclasses.replace(cal, comp_cycles=base.comp_cycles,
                               decomp_cycles=base.decomp_cycles) == base


def test_calibrated_device_fallback_paths(tmp_path):
    base = TM.DeviceConfig()
    assert TM.calibrated_device(path=tmp_path / "missing.json") == base
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert TM.calibrated_device(path=bad) == base
    empty = tmp_path / "empty.json"
    empty.write_text("{}")
    assert TM.calibrated_device(path=empty) == base
    # custom base is respected
    slow = TM.DEVICE_PROFILES["slow_engine"]
    assert TM.calibrated_device(path=tmp_path / "missing.json",
                                base=slow) == slow


def test_calibrated_device_committed_artifact():
    """The committed BENCH_kernels.json must actually move the engine
    constants away from the paper fallback (acceptance criterion)."""
    if not TM._BENCH_KERNELS.exists():
        pytest.skip("no committed BENCH_kernels.json")
    cal = TM.calibrated_device()
    base = TM.DeviceConfig()
    assert (cal.comp_cycles, cal.decomp_cycles) != \
        (base.comp_cycles, base.decomp_cycles)
    data = json.loads(TM._BENCH_KERNELS.read_text())
    assert data["calibration"]["compress_gbps"] > 0
    assert data["fused_vs_unfused"]["fused_ge_unfused"] is True
