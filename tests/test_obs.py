"""Telemetry subsystem tests (DESIGN.md §16): the piggyback contract.

  * recording on vs off is BIT-IDENTICAL in pool/counter state — the
    Recorder only consumes host values the contracted fetches already
    produced, so attaching it cannot perturb the run;
  * the declared sync budgets hold with the Recorder attached:
    ``segment_syncs == segments``, ``epoch_syncs == epochs`` (fabric) and
    ``step_syncs == steps`` (serve) — zero extra syncs, asserted against
    the ``@sync_contract`` declarations, not bench constants;
  * the Perfetto export validates (spans nest, timestamps monotone per
    track) and its per-expander track totals reconcile with
    ``Fabric.pipeline_times()`` — the trace is the same accounting, drawn;
  * histogram merge is associative (fixed bounds, bucket-wise add), so
    partial aggregations compose in any order;
  * ``manifest()`` stamps the run facts every BENCH_*.json shares.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.contracts import verify_sync_counters
from repro.common.types import ServeConfig
from repro.configs import get_reduced
from repro.core.engine.policy import POLICIES
from repro.fabric import Fabric, WeightedInterleave
from repro.models import transformer as T
from repro.obs import Recorder, manifest
from repro.obs import export as OBX
from repro.obs.registry import Histogram, MetricsRegistry, merge_histograms
from repro.serve.engine import Engine
from repro.simx.engine import pool_cfg_for
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace

POLICY = POLICIES["ibex"]
WINDOW = 8

CFG = get_reduced("llama3_8b")
SCFG = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                   kv_rate_bits=8)


# -- shared fixtures ---------------------------------------------------------

def _small_cfg(prom=16, n_pages=64):
    return pool_cfg_for(POLICY, n_pages=n_pages, n_pchunks=prom,
                        n_cchunks=2 * n_pages * 8)


def _trace(cfg, n_accesses, seed=0, wl="mcf"):
    spec = WORKLOADS[wl]
    rates = make_rates_table(spec, cfg.n_pages, seed=seed)
    ospn, wr, blk = make_trace(spec, n_accesses=n_accesses,
                               n_pages=cfg.n_pages, seed=seed)
    return rates, ospn, wr, blk


def _rebalance_fabric(cfg, rates, obs=None):
    """The migration-live operating point (2 expanders, 0.8 skew,
    rebalance policy, overlapped pipeline) — the configuration where the
    Recorder sees segments, plans AND epochs."""
    return Fabric(cfg, POLICY, WeightedInterleave(2, cfg.n_pages, [0.8, 0.2]),
                  seed=0, rates_table=jnp.asarray(rates), window=WINDOW,
                  migration="rebalance", spill_interval=8 * WINDOW, obs=obs)


@pytest.fixture(scope="module")
def recorded_fabric():
    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=512, seed=7)
    rec = Recorder()
    fab = _rebalance_fabric(cfg, rates, obs=rec)
    fab.replay(ospn, wr, blk)
    return cfg, rates, (ospn, wr, blk), rec, fab


@pytest.fixture(scope="module")
def params():
    return T.init_params(jax.random.PRNGKey(0), CFG)[0]


def _prompt(seed, n=20):
    return list(np.random.default_rng(seed).integers(
        1, CFG.vocab_size, size=n))


# -- fabric: bit-identity + sync budgets -------------------------------------

def test_fabric_recording_is_bit_identical(recorded_fabric):
    """Attaching a Recorder changes NOTHING device-side: every pool leaf
    and every counter of the recorded run equals the recording-off run."""
    cfg, rates, (ospn, wr, blk), rec, fab_on = recorded_fabric
    fab_off = _rebalance_fabric(cfg, rates)
    fab_off.replay(ospn, wr, blk)
    assert fab_on.state_identical(fab_off), \
        "recording perturbed pool/counter state"
    assert fab_on.counters() == fab_off.counters()


def test_fabric_sync_budgets_hold_with_recorder(recorded_fabric):
    """Zero extra syncs: the measured per-segment/per-epoch sync counts
    with the Recorder draining every fetch match the @sync_contract
    budgets exactly, and the Recorder saw every one of those events."""
    _, _, _, rec, fab = recorded_fabric
    ss = fab.sync_stats()
    assert ss["segment_syncs"] == ss["segments"]
    assert ss["epoch_syncs"] == ss["epochs"]
    verify_sync_counters(Fabric._fetch_view, ss["segments"],
                         ss["segment_syncs"], what=str(ss))
    verify_sync_counters(Fabric._commit_epoch, ss["epochs"],
                         ss["epoch_syncs"], what=str(ss))
    assert len(rec.segments) == ss["segments"]
    assert len(rec.epochs) == ss["epochs"]
    assert ss["epochs"] > 0, "rebalance point recorded no epochs"
    # the metrics registry aggregated the same deltas the scheduler kept:
    # summed replay deltas == the name-keyed fabric.* counter metrics
    from repro.core.engine import state as S
    snap = rec.metrics.snapshot()["counters"]
    total = int(sum(d["delta"].sum() for d in rec.segments))
    agg = sum(snap.get(f"fabric.{name}", 0) for name in S.COUNTER_NAMES)
    assert total == agg


def test_fabric_trace_validates_and_reconciles(recorded_fabric, tmp_path):
    """The exported Perfetto timeline is well-formed AND is the same
    accounting as ``pipeline_times()``: rebuilding the per-expander track
    totals from the recorded samples reproduces the scheduler's overlapped
    and sync delivered seconds to float64 tolerance."""
    _, _, _, rec, fab = recorded_fabric
    pt = fab.pipeline_times()
    totals = OBX.fabric_track_totals(rec)
    assert np.allclose(totals["overlapped_s"], pt["overlapped_s"],
                       rtol=1e-9), (totals, pt)
    assert np.allclose(totals["sync_s"], pt["sync_s"], rtol=1e-9)
    trace = OBX.build_trace(rec)
    assert OBX.validate_trace(trace) == []
    # a track per expander for replay and one for migration epochs
    tids = {(ev["pid"], ev["tid"]) for ev in trace["traceEvents"]
            if ev["ph"] == "X"}
    assert {(1, 0), (1, 2)} <= tids, tids            # replay tracks e0/e1
    assert any(t in tids for t in [(1, 1), (1, 3)]), \
        "no migration track emitted on a migration-live run"
    path = tmp_path / "fabric.trace.json"
    OBX.write_trace(rec, path)
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"] and on_disk["otherData"]["manifest"]
    mpath = tmp_path / "fabric.metrics.json"
    OBX.write_metrics(rec, mpath, seed=7)
    snap = json.loads(mpath.read_text())
    assert snap["manifest"]["seed"] == 7
    assert snap["fabric"]["epochs"] == len(rec.epochs)
    assert "fabric.pages_moved" in snap["metrics"]["counters"]
    # the human-readable summary covers every pipeline row
    table = OBX.fabric_summary_table(rec)
    assert table.count("\n") >= len(rec.segments)


def test_trace_validator_rejects_malformed():
    """The validator actually checks something: out-of-order timestamps
    on one track and a span overrunning its parent are both findings."""
    base = {"otherData": {}, "displayTimeUnit": "ms"}
    bad_order = dict(base, traceEvents=[
        {"ph": "X", "pid": 1, "tid": 0, "ts": 10.0, "dur": 1.0, "name": "a"},
        {"ph": "X", "pid": 1, "tid": 0, "ts": 5.0, "dur": 1.0, "name": "b"},
    ])
    assert OBX.validate_trace(bad_order)
    bad_nest = dict(base, traceEvents=[
        {"ph": "X", "pid": 1, "tid": 0, "ts": 0.0, "dur": 5.0, "name": "p"},
        {"ph": "X", "pid": 1, "tid": 0, "ts": 2.0, "dur": 10.0, "name": "c"},
    ])
    assert OBX.validate_trace(bad_nest)
    bad_phase = dict(base, traceEvents=[
        {"ph": "B", "pid": 1, "tid": 0, "ts": 0.0, "name": "p"},
    ])
    assert OBX.validate_trace(bad_phase)


# -- serve: bit-identity + sync budget ----------------------------------------

def test_serve_recording_identical_and_one_sync_per_step(params):
    """The batched engine with a Recorder attached finishes with counters
    identical to the recording-off run, still syncing exactly once per
    decode step; the Recorder saw every step and the motion events."""
    def run(obs=None):
        eng = Engine(CFG, SCFG, params, max_len=128, obs=obs)
        rids = [eng.submit(_prompt(i), max_new_tokens=6) for i in range(4)]
        eng.run_until_done(max_steps=400)
        return eng, [eng.result(r) for r in rids]

    rec = Recorder()
    eng_on, out_on = run(obs=rec)
    eng_off, out_off = run()
    assert eng_on.counters == eng_off.counters, \
        "recording changed the engine's counters"
    assert out_on == out_off, "recording changed decoded tokens"
    assert eng_on.counters["step_syncs"] == eng_on.counters["steps"]
    verify_sync_counters(Engine.step, eng_on.counters["steps"],
                         eng_on.counters["step_syncs"],
                         what="recorder attached")
    assert len(rec.steps) == eng_on.counters["steps"]
    kinds = {ev["type"] for ev in rec.serve_events}
    assert "admission" in kinds
    # 4 requests through 2 lanes must have parked someone
    assert "preempt" in kinds and "resume" in kinds
    snap = rec.metrics.snapshot()["counters"]
    assert snap["serve.preempt_bytes"] == eng_on.counters["preempt_bytes"]
    assert snap["serve.resume_bytes"] == eng_on.counters["resume_bytes"]
    trace = OBX.build_trace(rec)
    assert OBX.validate_trace(trace) == []


# -- registry ------------------------------------------------------------------

def test_histogram_merge_is_associative_and_pure():
    bounds = (1.0, 2.0, 5.0, 10.0)
    rng = np.random.default_rng(0)
    hs = []
    for i in range(3):
        h = Histogram(f"h{i}", bounds)
        for v in rng.uniform(0, 15, size=50):
            h.observe(float(v))
        hs.append(h)
    a, b, c = hs
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.snapshot() == right.snapshot()
    folded = merge_histograms(hs)
    assert folded.snapshot() == left.snapshot()
    assert left.n == 150 and sum(left.counts) == 150
    # merge returned NEW histograms — inputs untouched
    assert a.n == 50 and b.n == 50 and c.n == 50
    with pytest.raises(ValueError):
        a.merge(Histogram("other", (1.0, 2.0)))


def test_registry_get_or_create_and_counter_monotonicity():
    reg = MetricsRegistry()
    assert reg.counter("x") is reg.counter("x")
    reg.counter("x").inc(3)
    with pytest.raises(ValueError):
        reg.counter("x").inc(-1)
    reg.gauge("g").set(2.5)
    reg.histogram("h", (1.0,)).observe(0.5)
    snap = reg.snapshot()
    assert snap["counters"] == {"x": 3}
    assert snap["gauges"] == {"g": 2.5}
    assert snap["histograms"]["h"]["count"] == 1


# -- manifest --------------------------------------------------------------------

def test_manifest_stamps_run_facts():
    m = manifest(seed=3, suite="test")
    for key in ("python", "platform", "git_sha", "jax", "jaxlib",
                "backend", "device_count"):
        assert key in m
    assert m["seed"] == 3 and m["suite"] == "test"
    # jax IS importable in this test process, so the stamp must be live
    assert m["jax"] is not None and m["backend"] is not None
    json.dumps(m)   # JSON-serializable by construction
