"""Per-architecture smoke tests: reduced config, one forward + grad step and
one decode step on CPU; asserts shapes and finiteness (assignment brief)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ServeConfig, replace
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.models import decode as D
from repro.models import transformer as T

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    if cfg.frontend != "none":
        batch["embeds"] = jax.random.normal(
            KEY, (B, S, cfg.d_model)).astype(jnp.bfloat16) * 0.02
    return batch


# tier-1 keeps three cheap, family-diverse configs (dense/GQA, MLA, audio
# frontend); the rest are slow-marked and run with `pytest -m ""`
_FAST_ARCHS = {"llama3_8b", "minicpm3_4b", "musicgen_medium"}
_ARCH_PARAMS = [a if a in _FAST_ARCHS else
                pytest.param(a, marks=pytest.mark.slow) for a in ARCH_IDS]


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_forward_and_grad(arch):
    cfg = get_reduced(arch)
    params, axes = T.init_params(KEY, cfg)
    # axes tree mirrors params tree
    assert set(axes.keys()) == set(params.keys())
    batch = _batch(cfg)
    logits, aux = T.forward(params, batch, cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, metrics = T.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))
    grads = jax.grad(lambda p: T.loss_fn(p, batch, cfg)[0])(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", _ARCH_PARAMS)
def test_decode_step(arch):
    cfg = get_reduced(arch)
    scfg = ServeConfig(hot_window=16, attn_chunk=32, kv_rate_bits=8)
    max_len = 128
    params, _ = T.init_params(KEY, cfg)
    cache = D.init_cache(cfg, scfg, B, max_len)
    tokens = jax.random.randint(KEY, (B,), 0, cfg.vocab_size)
    pos = jnp.asarray([0, 5], jnp.int32)
    embeds = (jax.random.normal(KEY, (B, cfg.d_model)).astype(jnp.bfloat16)
              if cfg.frontend != "none" else None)
    logits, cache2 = D.decode_step(params, cache, tokens, pos, cfg, scfg,
                                   embeds=embeds)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    # structure preserved
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


def test_full_config_param_counts():
    """FULL configs instantiate only as metadata (no allocation) — sanity of
    the published sizes (loose bands; active counts for MoE)."""
    expect = {
        "chameleon_34b": (25e9, 45e9), "qwen3_moe_235b_a22b": (150e9, 300e9),
        "arctic_480b": (350e9, 560e9), "deepseek_7b": (5e9, 9e9),
        "minicpm3_4b": (2.5e9, 6e9), "codeqwen15_7b": (5e9, 9e9),
        "llama3_8b": (6e9, 10e9), "zamba2_2p7b": (2e9, 4.5e9),
        "musicgen_medium": (1e9, 2.5e9), "falcon_mamba_7b": (5e9, 9e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"
