"""Tests for the jit-hygiene static analyzer (DESIGN.md §15).

Layout mirrors the satellite spec: per-rule positive / negative /
suppressed fixtures, a baseline round-trip, a self-check that the
committed baseline matches a fresh run over src/ (no stale entries), and
the two acceptance demos — a synthetic ``int(traced)`` injected into a
real jitted body fails the lint, and stripping any one ``@sync_contract``
annotation fails the lint.

Everything except the runtime-contract cross-checks is stdlib-only (the
analyzer must run with no jax installed).
"""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import ModuleInfo
from repro.analysis.lint import lint_file, run_lint
from repro.common.contracts import (SyncContract, get_sync_contract,
                                    sync_contract, verify_sync_counters)

REPO = Path(__file__).resolve().parent.parent
BASELINE = REPO / "src" / "repro" / "analysis" / "baseline.json"


def _lint_src(code: str, name: str = "snippet.py"):
    return lint_file(name, relpath=name, src=textwrap.dedent(code))


def _rules(findings, *, suppressed=False):
    return [f.rule for f in findings if f.suppressed == suppressed]


# ---------------------------------------------------------------------------
# R1 — hidden host sync
# ---------------------------------------------------------------------------

def test_r1_positive_casts_and_branches():
    fs = _lint_src("""
        import jax

        @jax.jit
        def f(x):
            if x > 0:
                y = int(x)
            while x < 9:
                x = x + 1
            return x.item()
    """)
    msgs = [f.message for f in fs]
    assert _rules(fs).count("R1") == 4, fs
    assert any("`if`" in m for m in msgs)
    assert any("`while`" in m for m in msgs)
    assert any("int()" in m for m in msgs)
    assert any(".item()" in m for m in msgs)


def test_r1_numpy_print_device_get():
    fs = _lint_src("""
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            a = np.sum(x)
            print(x)
            b = jax.device_get(x)
            return a
    """)
    assert _rules(fs).count("R1") == 3, fs


def test_r1_negative_static_metadata_structural():
    fs = _lint_src("""
        import functools
        import jax
        import numpy as np

        @functools.partial(jax.jit, static_argnums=(1,))
        def f(x, cfg, valid=None):
            if cfg.mode == "fast":          # static param
                x = x + 1
            if x.shape[0] > 4:              # trace-time metadata
                x = x * 2
            if valid is None:               # structural identity
                valid = x
            out = {"x": x}
            if "x" in out:                  # structural membership
                x = out["x"]
            n = np.arange(cfg.n)            # numpy on static only
            return x
    """)
    assert fs == [], [f.render() for f in fs]


def test_r1_suppressed_counts_but_passes():
    fs = _lint_src("""
        import jax

        @jax.jit
        def f(x):
            n = int(x)  # lint: host-ok(debug counter, removed in prod)
            return x
    """)
    assert _rules(fs, suppressed=True) == ["R1"]
    assert _rules(fs) == []
    assert fs[0].suppress_reason == "debug counter, removed in prod"


def test_r1_combinator_bodies_and_call_propagation():
    fs = _lint_src("""
        import jax

        def helper(v, cfg):
            if cfg.fast:            # static at the only call site
                v = v + 1
            return int(v)           # tainted via propagation

        def outer(xs, cfg):
            def body(c, x):
                return helper(c, cfg), x
            return jax.lax.scan(body, 0, xs)
    """)
    assert _rules(fs) == ["R1"], [f.render() for f in fs]
    assert "int()" in fs[0].message


def test_r1_jit_call_site_partial_kwargs_static():
    fs = _lint_src("""
        import functools
        import jax

        def impl(state, cfg=None):
            if cfg.windows > 1:     # partial-bound -> static
                state = state + 1
            return state

        step = jax.jit(functools.partial(impl, cfg=object()))
    """)
    assert fs == [], [f.render() for f in fs]


# ---------------------------------------------------------------------------
# R2 — recompile hazards
# ---------------------------------------------------------------------------

def test_r2_mutable_default_and_bad_static_names():
    fs = _lint_src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("nope",))
        def f(x, real):
            return x

        @jax.jit
        def g(x, cache={}):
            return x

        @functools.partial(jax.jit, static_argnums=(5,))
        def h(x, y):
            return x
    """)
    assert sorted(_rules(fs)) == ["R2", "R2", "R2"], [f.render() for f in fs]


def test_r2_varying_static_kwarg_at_call():
    fs = _lint_src("""
        import jax

        def impl(x, mode):
            return x

        f = jax.jit(impl, static_argnames=("mode",))

        def call(x, i):
            return f(x, mode=f"bucket{i}")
    """)
    assert _rules(fs) == ["R2"], [f.render() for f in fs]
    assert "per-call-varying" in fs[0].message


def test_r2_negative_clean_jit():
    fs = _lint_src("""
        import functools
        import jax

        @functools.partial(jax.jit, static_argnames=("cfg",))
        def f(x, cfg=None):
            return x
    """)
    assert fs == []


# ---------------------------------------------------------------------------
# R3 — counter layout drift
# ---------------------------------------------------------------------------

def test_r3_literal_counter_index():
    fs = _lint_src("""
        def report(counters, tvec):
            a = counters[3]
            b = tvec[0]
            return a + b
    """)
    assert _rules(fs) == ["R3", "R3"]


def test_r3_negative_named_and_variable_indices():
    fs = _lint_src("""
        from repro.core.engine import state as S

        def report(counters, i):
            a = counters[S.C_DATA_RD]
            b = counters[i]
            c = counters[2:5]          # slices allowed
            flags = [0, 1][0]          # not a counter vector
            return a + b
    """)
    assert fs == [], [f.render() for f in fs]


def test_r3_suppressed():
    fs = _lint_src("""
        def report(ctrs):
            return ctrs[0]  # lint: host-ok(layout pinned by golden file)
    """)
    assert _rules(fs, suppressed=True) == ["R3"]
    assert _rules(fs) == []


# ---------------------------------------------------------------------------
# R4 — pallas hygiene
# ---------------------------------------------------------------------------

def test_r4_literal_interpret():
    fs = _lint_src("""
        from jax.experimental import pallas as pl

        def launch(x, kern):
            return pl.pallas_call(kern, grid=(4,), interpret=True)(x)
    """)
    assert _rules(fs) == ["R4"]
    assert "resolve_interpret" in fs[0].message


def test_r4_blockspec_arity_mismatches():
    fs = _lint_src("""
        from jax.experimental import pallas as pl

        def launch(x, kern, out_shape):
            return pl.pallas_call(
                kern, grid=(4, 2),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_specs=pl.BlockSpec((8, 8), lambda i, j: (i,)),
                out_shape=out_shape)(x)
    """)
    msgs = [f.message for f in fs]
    assert _rules(fs) == ["R4", "R4"], [f.render() for f in fs]
    assert any("grid has 2" in m for m in msgs)
    assert any("1 index(es) for a 2-axis" in m for m in msgs)


def test_r4_negative_resolved_interpret():
    fs = _lint_src("""
        from jax.experimental import pallas as pl

        def launch(x, kern, interpret, out_shape):
            return pl.pallas_call(
                kern, grid=(4,),
                in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
                out_shape=out_shape,
                interpret=interpret)(x)
    """)
    assert fs == [], [f.render() for f in fs]


# ---------------------------------------------------------------------------
# R5 — sync contracts
# ---------------------------------------------------------------------------

def test_r5_budget_and_loop_findings():
    fs = _lint_src("""
        import jax
        import numpy as np
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="step", fetches=1)
            def step(self):
                a = jax.device_get(self.state)
                b = jax.device_get(self.pools.counters)   # over budget
                for lane in self.lanes:
                    c = self.tok.item()                   # loop fetch
                return a, b, c
    """)
    msgs = [f.message for f in fs]
    assert _rules(fs).count("R5") == 2, [f.render() for f in fs]
    assert any("exceeds the declared budget" in m for m in msgs)
    assert any("inside a host loop" in m for m in msgs)


def test_r5_negative_single_fused_fetch():
    fs = _lint_src("""
        import jax
        import numpy as np
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="segment", fetches=1)
            def fetch_view(self, times, stats):
                stats, ctrs, t = jax.device_get(
                    (stats, self.pools.counters, times))
                ctrs = np.asarray(ctrs, np.int64)         # host already
                free = np.asarray(stats.free_units, np.int64)
                return ctrs, free, t
    """)
    assert fs == [], [f.render() for f in fs]


def test_r5_device_sourced_np_asarray_counts():
    fs = _lint_src("""
        import jax
        import numpy as np
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="epoch", fetches=1)
            def commit(self):
                moved = jax.device_get(self.moved)
                extra = np.asarray(self.pools.counters)   # 2nd fetch
                return moved, extra
    """)
    assert _rules(fs) == ["R5"], [f.render() for f in fs]
    assert "exceeds the declared budget" in fs[0].message


def test_r5_suppressed_site_excluded_from_budget():
    fs = _lint_src("""
        import jax
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="step", fetches=1)
            def step(self):
                a = jax.device_get(self.state)
                b = jax.device_get(self.dbg)  # lint: host-ok(debug-only path)
                return a, b
    """)
    assert _rules(fs) == [], [f.render() for f in fs]
    assert _rules(fs, suppressed=True) == ["R5"]


# ---------------------------------------------------------------------------
# R6 — obs telemetry piggyback
# ---------------------------------------------------------------------------

def test_r6_emission_inside_jit_region():
    fs = _lint_src("""
        import jax

        @jax.jit
        def kernel(pool, obs):
            obs.record_segment(0, pool.counters, None, None)
            return pool
    """)
    assert _rules(fs).count("R6") == 1, [f.render() for f in fs]
    assert "inside a jit region" in fs[0].message


def test_r6_emission_in_traced_combinator_body():
    fs = _lint_src("""
        import jax

        def scan_all(xs, obs):
            def body(carry, x):
                obs.record_step(carry, x, x, x, [])
                return carry, x
            return jax.lax.scan(body, 0, xs)
    """)
    assert "R6" in _rules(fs), [f.render() for f in fs]


def test_r6_device_value_handed_to_drain_in_contract():
    fs = _lint_src("""
        import jax
        import numpy as np
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="segment", fetches=1)
            def fetch_view(self, times):
                ctrs = jax.device_get(self.pools.counters)
                # the drain below is handed LIVE device state — the
                # Recorder's np.asarray would be a hidden second sync
                self.obs.record_segment(0, self.pools.counters,
                                        np.asarray(ctrs), None)
                return ctrs
    """)
    r6 = [f for f in fs if f.rule == "R6"]
    assert len(r6) == 1, [f.render() for f in fs]
    assert "hidden second sync" in r6[0].message


def test_r6_negative_host_drain_is_sanctioned():
    """The repo's actual drain shape: everything the Recorder is handed
    was bound from the single contracted fetch (or is host bookkeeping,
    like a string-keyed dict counter) — no findings."""
    fs = _lint_src("""
        import jax
        import numpy as np
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="step", fetches=1)
            def step(self, done, active):
                tok_h, done_h, ref_h, pos_h = self._fetch(
                    (self.state, done, self.ref, self.pos))
                if self.obs is not None:
                    self.obs.record_step(self.counters["steps"], tok_h,
                                         done_h, pos_h,
                                         [lane for lane, _ in active])
                return tok_h
    """)
    assert _rules(fs) == [], [f.render() for f in fs]


def test_r6_device_producer_call_as_drain_arg():
    fs = _lint_src("""
        import jax
        import jax.numpy as jnp
        from repro.common.contracts import sync_contract

        class Eng:
            @sync_contract(syncs_per="epoch", fetches=1)
            def commit(self):
                moved = jax.device_get(self.moved)
                self.obs.record_epoch(0, jnp.sum(self.pools.counters),
                                      kind="sync", overlapped=False,
                                      planned=0, moved=0, urgent=False,
                                      free_units=moved)
                return moved
    """)
    r6 = [f for f in fs if f.rule == "R6"]
    assert len(r6) >= 1, [f.render() for f in fs]


# ---------------------------------------------------------------------------
# Baseline ratchet
# ---------------------------------------------------------------------------

def test_baseline_round_trip(tmp_path):
    code = """
        import jax

        @jax.jit
        def f(x):
            return int(x)
    """
    findings = _lint_src(code)
    assert _rules(findings) == ["R1"]
    bpath = tmp_path / "baseline.json"
    baseline_mod.save(bpath, findings, note="test")
    loaded = baseline_mod.load(bpath)
    new, old, stale = baseline_mod.diff(findings, loaded)
    assert new == [] and len(old) == 1 and stale == []
    # fingerprints are line-number-free: shifting the file leaves the
    # finding grandfathered
    shifted = _lint_src("\n\n# moved\n" + textwrap.dedent(code))
    new, old, stale = baseline_mod.diff(shifted, loaded)
    assert new == [] and len(old) == 1 and stale == []
    # a SECOND instance of the same mistake is new (multiset semantics)
    doubled = _lint_src(code + """
        @jax.jit
        def f(x):
            return int(x)
    """)
    new, old, stale = baseline_mod.diff(doubled, loaded)
    assert len(new) == 1 and len(old) == 1
    # fixing the finding leaves a stale entry the self-check reports
    new, old, stale = baseline_mod.diff([], loaded)
    assert new == [] and old == [] and len(stale) == 1


def test_committed_baseline_matches_fresh_run():
    """The committed baseline is exactly the debt a fresh run over src/
    reports: no new findings (lint passes) and no stale entries (the
    baseline never overstates the debt)."""
    report = run_lint([str(REPO / "src")], baseline_path=BASELINE)
    assert report["counts"]["parse_errors"] == 0
    assert report["new"] == [], json.dumps(report["new"], indent=2)
    assert report["stale_baseline"] == [], report["stale_baseline"]


# ---------------------------------------------------------------------------
# Acceptance demos: the lint fails when the contracts regress
# ---------------------------------------------------------------------------

def test_injected_int_traced_fails_lint():
    """Adding a synthetic ``int(traced)`` to a real jitted body in
    core/engine/batch.py produces a new R1 finding — the CI step
    (which diffs against the committed baseline) would fail."""
    path = REPO / "src" / "repro" / "core" / "engine" / "batch.py"
    src = path.read_text()
    marker = "def _window_step(pool"
    assert marker in src
    lines = src.splitlines()
    idx = next(i for i, l in enumerate(lines) if marker in l)
    while not lines[idx].rstrip().endswith(":"):  # signature may wrap
        idx += 1
    # first statement line of the body: inject a concretizing cast of a
    # parameter that is traced (pool) under the jitted callers
    indent = " " * 4
    lines.insert(idx + 1, f"{indent}_dbg = int(pool.counters[0] * 1)")
    mutated = "\n".join(lines)
    before = [f for f in lint_file(path, relpath="src/repro/core/engine/"
                                   "batch.py") if not f.suppressed]
    after = [f for f in lint_file(path, relpath="src/repro/core/engine/"
                                  "batch.py", src=mutated)
             if not f.suppressed]
    new_rules = sorted(_rules(after))
    for f in before:
        assert not f.rule == "R1", "hot path must be R1-clean"
    assert "R1" in new_rules, [f.render() for f in after]
    base = baseline_mod.load(BASELINE)
    new, _, _ = baseline_mod.diff(after, base)
    assert any(f.rule == "R1" for f in new)


@pytest.mark.parametrize("relsuffix, qualname", [
    ("src/repro/serve/engine.py", "Engine.step"),
    ("src/repro/fabric/replay.py", "Fabric._fetch_view"),
    ("src/repro/fabric/replay.py", "Fabric._commit_epoch"),
])
def test_stripping_any_sync_contract_fails_lint(relsuffix, qualname):
    """Deleting any one @sync_contract annotation is itself a new R5
    finding (REQUIRED_CONTRACTS), so the annotation cannot be removed to
    appease the fetch count."""
    path = REPO / relsuffix
    src = path.read_text()
    method = qualname.split(".")[-1]
    lines = src.splitlines()
    hits = [i for i, l in enumerate(lines)
            if l.strip().startswith("@sync_contract")
            and f"def {method}(" in "\n".join(lines[i + 1:i + 3])]
    assert len(hits) == 1, f"expected one annotation for {qualname}"
    del lines[hits[0]]
    stripped = "\n".join(lines)
    clean = [f for f in lint_file(path, relpath=relsuffix)
             if not f.suppressed]
    assert not any(f.rule == "R5" for f in clean)
    after = [f for f in lint_file(path, relpath=relsuffix, src=stripped)
             if not f.suppressed]
    missing = [f for f in after if f.rule == "R5"
               and "missing" in f.message and qualname in f.message]
    assert missing, [f.render() for f in after]
    base = baseline_mod.load(BASELINE)
    new, _, _ = baseline_mod.diff(after, base)
    assert any(f.rule == "R5" for f in new)


# ---------------------------------------------------------------------------
# Runtime half: @sync_contract attribute + verify_sync_counters
# ---------------------------------------------------------------------------

def test_contract_attribute_no_wrapper():
    calls = []

    @sync_contract(syncs_per="step", fetches=1)
    def f(x):
        calls.append(x)
        return x + 1

    assert f(1) == 2 and calls == [1]
    assert f.__name__ == "f"                      # no wrapper frame
    assert get_sync_contract(f) == SyncContract("step", 1)
    assert get_sync_contract(f).expected_syncs(7) == 7


def test_verify_sync_counters():
    @sync_contract(syncs_per="segment", fetches=1)
    def f():
        pass

    verify_sync_counters(f, n_events=5, n_syncs=5)
    with pytest.raises(AssertionError, match="measured 6 syncs"):
        verify_sync_counters(f, n_events=5, n_syncs=6)

    def bare():
        pass

    with pytest.raises(AssertionError, match="declares no @sync_contract"):
        verify_sync_counters(bare, n_events=1, n_syncs=1)


def test_hot_paths_declare_contracts():
    """The three load-bearing contracts are attached at runtime too (the
    bench cross-checks resolve them via get_sync_contract)."""
    from repro.fabric.replay import Fabric
    from repro.serve.engine import Engine

    assert get_sync_contract(Engine.step) == SyncContract("step", 1)
    assert get_sync_contract(Fabric._fetch_view) == \
        SyncContract("segment", 1)
    assert get_sync_contract(Fabric._commit_epoch) == \
        SyncContract("epoch", 1)
