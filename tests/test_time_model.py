"""Delivered-time accounting tests (DESIGN.md §12).

  * parity — the dict shim and the vectorized counter-array model are
    BITWISE identical to the pre-refactor scalar model (the legacy formula
    is transcribed verbatim below as the reference), on homogeneous
    configs, including the host=0 edge and the uncompressed baseline;
  * array-native — ``exec_time_vec`` runs inside jit/vmap over a stacked
    ``DeviceLanes`` fleet and agrees with the host float64 path;
  * monotonicity — more internal accesses never decreases delivered time,
    and the fig14 (CXL latency) / fig15 (decompression cycles) sensitivity
    sweeps are monotone per scheme — pinned as regression tests, not just
    bench output;
  * drift guards — ``DeviceLanes`` mirrors every ``DeviceConfig`` field and
    ``ideal_bandwidth`` preserves every field except ``ch_bw``;
  * serving — ``serve_modeled_time`` prices byte/sync counters sanely
    (monotone in bytes, bottleneck across expanders).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import replace
from repro.core.engine import state as S
from repro.simx import device as DEV
from repro.simx import time as TM


def _legacy_exec_time(traffic, dev):
    """The pre-refactor scalar model, verbatim — the parity reference."""
    host = traffic["host_reads"] + traffic["host_writes"]
    internal = traffic["internal_accesses"]
    t_mem = internal * 64 / (dev.channels * dev.ch_bw)
    t_cxl = host * 64 / dev.cxl_bw
    n_comp = (traffic.get("demotions_dirty", 0)
              + traffic.get("recompress_retry", 0)) * dev.block_scale * 4
    n_decomp = traffic.get("promotions", 0) * dev.block_scale
    t_engine = (n_comp * dev.comp_cycles + n_decomp * dev.decomp_cycles) \
        / dev.clock
    zero_frac = traffic.get("zero_served", 0) / max(host, 1)
    accesses_per_host = internal / max(host, 1)
    decomp_lat_frac = traffic.get("promotions", 0) / max(host, 1)
    l_avg = dev.cxl_lat + (1 - zero_frac) * dev.dram_lat \
        + accesses_per_host * dev.dram_lat * 0.25 \
        + decomp_lat_frac * dev.decomp_cycles / dev.clock
    t_lat = host * l_avg / dev.mlp
    return max(t_mem, t_cxl, t_engine, t_lat)


def _traffic_samples(n=64, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        t = {k: int(rng.integers(0, 50000)) for k in S.COUNTER_NAMES}
        t["internal_accesses"] = sum(t[k] for k in S.TRAFFIC_NAMES)
        out.append(t)
    out.append({k: 0 for k in S.COUNTER_NAMES} | {"internal_accesses": 0})
    out.append({k: 0 for k in S.COUNTER_NAMES}
               | {"internal_accesses": 17, "zero_served": 3})  # host == 0
    return out


DEVICES = [TM.DeviceConfig(), TM.DeviceConfig(block_scale=4.0),
           TM.DEVICE_PROFILES["gen4"], TM.DEVICE_PROFILES["far"],
           TM.ideal_bandwidth(TM.DeviceConfig())]


def test_dict_shim_bitwise_parity_with_legacy_scalar():
    for t in _traffic_samples():
        for dev in DEVICES:
            assert DEV.exec_time(t, dev) == _legacy_exec_time(t, dev)


def test_vectorized_counters_bitwise_parity_with_legacy_scalar():
    """The counter-array model (float64 host path) == the legacy scalar,
    bitwise, when internal equals the category sum (homogeneous config)."""
    for t in _traffic_samples():
        vec = TM.counters_from_dict(t)
        t = dict(t, internal_accesses=sum(t[k] for k in S.TRAFFIC_NAMES))
        for dev in DEVICES:
            assert float(TM.exec_time_vec(vec, dev)) == \
                _legacy_exec_time(t, dev)


def test_uncompressed_time_matches_legacy_and_counter_layout():
    """Baseline derived from COUNTER_NAMES == the legacy hand-built dict."""
    for n in (0, 1, 7, 12345):
        legacy = _legacy_exec_time(
            {"host_reads": n, "host_writes": 0, "internal_accesses": n,
             "zero_served": 0, "promotions": 0, "demotions_dirty": 0},
            TM.DeviceConfig())
        assert DEV.uncompressed_time(n, TM.DeviceConfig()) == legacy
    vec = TM.uncompressed_counters(9)
    assert vec.shape == (S.NUM_COUNTERS,)
    assert vec[S.C_HOST_RD] == 9 and S.traffic_vector(vec).sum() == 9


def test_ideal_bandwidth_preserves_every_other_field():
    """dataclasses.replace-based: a new DeviceConfig field can never be
    silently dropped by the ideal-bandwidth variant."""
    kw = {"channels": 3, "cxl_bw": 1.0, "cxl_lat": 2.0, "dram_lat": 3.0,
          "clock": 4.0, "comp_cycles": 5, "decomp_cycles": 6, "mlp": 7.0,
          "block_scale": 8.0}
    ideal = TM.ideal_bandwidth(TM.DeviceConfig(ch_bw=44.8e9, **kw))
    assert ideal.ch_bw == 1e15
    for f in dataclasses.fields(TM.DeviceConfig):
        if f.name != "ch_bw":
            assert getattr(ideal, f.name) == kw[f.name], f.name


def test_device_lanes_mirror_device_config_fields():
    """Drift guard: DeviceLanes must carry every DeviceConfig field (and
    stack_devices round-trips the values)."""
    names = {f.name for f in dataclasses.fields(TM.DeviceConfig)}
    assert names == set(TM.DeviceLanes._fields)
    devs = [TM.DeviceConfig(), TM.DEVICE_PROFILES["gen4"]]
    lanes = TM.stack_devices(devs, xp=np)
    for n in names:
        assert lanes._asdict()[n].shape == (2,)
        assert list(lanes._asdict()[n]) == [getattr(d, n) for d in devs]


def test_exec_time_vec_inside_jit_vmap_matches_host_float64():
    """The array path runs under jit + vmap over a stacked (mixed-
    generation) fleet and agrees with the float64 host path."""
    rng = np.random.default_rng(1)
    counters = rng.integers(0, 20000, (4, S.NUM_COUNTERS)).astype(np.int32)
    devs = [TM.DeviceConfig(), TM.DEVICE_PROFILES["gen4"],
            TM.DEVICE_PROFILES["far"], TM.DeviceConfig(block_scale=4.0)]
    lanes_j = TM.stack_devices(devs, xp=jnp)
    times_j = jax.jit(jax.vmap(TM.exec_time_vec))(jnp.asarray(counters),
                                                  lanes_j)
    times_h = TM.exec_time_vec(np.asarray(counters, np.float64),
                               TM.stack_devices(devs, xp=np))
    assert np.allclose(np.asarray(times_j, np.float64), times_h, rtol=1e-4)
    # per-lane: each expander priced by its OWN config
    for e, dev in enumerate(devs):
        assert times_h[e] == float(TM.exec_time_vec(
            np.asarray(counters[e], np.float64), dev))


def test_more_internal_accesses_never_decreases_time():
    """Delivered-time monotonicity: traffic rows that differ only by extra
    internal accesses sort the same way in time — checked in one
    vectorized call over a 64-point ramp."""
    base = TM.counters_from_dict(
        {"host_reads": 500, "host_writes": 100, "data_rd": 1000,
         "promotions": 20, "demotions_dirty": 10, "zero_served": 5})
    ramp = np.broadcast_to(base, (64, S.NUM_COUNTERS)).copy()
    ramp[:, S.C_DATA_RD] += 250 * np.arange(64)
    for dev in DEVICES:
        t = TM.exec_time_vec(ramp, dev)
        assert (np.diff(t) >= 0).all(), dev


@pytest.fixture(scope="module")
def small_cells():
    from repro.simx.engine import run_workload
    from repro.simx.trace import WORKLOADS
    kw = dict(n_accesses=768, promoted_pages=32)
    return {s: run_workload(s, WORKLOADS["pr"], **kw)
            for s in ("ibex", "tmcc")}


def test_fig14_cxl_latency_sweep_monotone_per_scheme(small_cells):
    """Fig. 14 regression: per scheme, delivered time (and the uncompressed
    baseline) never decreases as CXL latency grows, and the normalized-perf
    curve is monotone — its slope never changes sign across the sweep (the
    direction depends on which side is latency-bound: the uncompressed
    baseline is, so the ratio may rise with latency)."""
    lats = (70e-9, 110e-9, 150e-9, 250e-9, 400e-9)
    for scheme, r in small_cells.items():
        devs = [replace(TM.DeviceConfig(), cxl_lat=lat) for lat in lats]
        lanes = TM.stack_devices(devs, xp=np)
        vec = TM.counters_from_dict(r)
        t = TM.exec_time_vec(np.broadcast_to(vec, (len(devs),) + vec.shape),
                             lanes)
        host = r["host_reads"] + r["host_writes"]
        base = TM.uncompressed_time(np.full((len(devs),), host), lanes)
        assert (np.diff(t) >= 0).all(), scheme
        assert (np.diff(base) >= 0).all(), scheme
        d = np.diff(base / t)
        assert (d >= -1e-12).all() or (d <= 1e-12).all(), (scheme, d)


def test_fig15_decomp_cycles_sweep_monotone_per_scheme(small_cells):
    """Fig. 15 regression: per scheme, delivered time is monotone
    non-decreasing in decompression cycles."""
    cycs = (64, 96, 128, 256, 512)
    for scheme, r in small_cells.items():
        devs = [replace(TM.DeviceConfig(), decomp_cycles=c) for c in cycs]
        lanes = TM.stack_devices(devs, xp=np)
        vec = TM.counters_from_dict(r)
        t = TM.exec_time_vec(np.broadcast_to(vec, (len(devs),) + vec.shape),
                             lanes)
        assert (np.diff(t) >= 0).all(), scheme


def test_serve_modeled_time_monotone_and_bottlenecked():
    counters = {"step_syncs": 100, "admit_syncs": 10, "steps": 100}
    stats = {"preempt_bytes": np.array([1 << 20, 1 << 18]),
             "resume_bytes": np.array([1 << 19, 1 << 17])}
    devs = [TM.DeviceConfig(), TM.DEVICE_PROFILES["gen4"]]
    m = TM.serve_modeled_time(counters, stats, devs)
    assert m["modeled_s"] > m["sync_s"] > 0
    assert m["modeled_s_per_step"] == pytest.approx(m["modeled_s"] / 100)
    assert len(m["motion_s_per_expander"]) == 2
    # more parked bytes on the same expander -> no less time
    stats2 = {"preempt_bytes": stats["preempt_bytes"] * 4,
              "resume_bytes": stats["resume_bytes"]}
    m2 = TM.serve_modeled_time(counters, stats2, devs)
    assert m2["modeled_s"] >= m["modeled_s"]
    # bottleneck: the modeled total uses the max lane, not the sum
    assert m["modeled_s"] == pytest.approx(
        m["sync_s"] + max(m["motion_s_per_expander"]))


def test_resolve_fleet_shapes():
    d = TM.DeviceConfig()
    assert TM.resolve_fleet(None, 3) == [d] * 3
    assert TM.resolve_fleet(d, 2) == [d, d]
    g = TM.DEVICE_PROFILES["gen4"]
    assert TM.resolve_fleet([d, g], 4) == [d, g, d, g]
    with pytest.raises(ValueError):
        TM.resolve_fleet([d, g, d], 2)
    with pytest.raises(ValueError):
        TM.resolve_fleet([], 2)
