"""Unit + property tests: metadata packing, freelists, mcache, clock."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import activity as act
from repro.core import freelist as fl
from repro.core import mcache as mcc
from repro.core import metadata as md


# -- metadata ---------------------------------------------------------------

@settings(max_examples=30, deadline=None)
@given(bt=st.lists(st.integers(0, 3), min_size=4, max_size=4),
       sz=st.lists(st.integers(0, 7), min_size=4, max_size=4),
       nch=st.integers(0, 8), wc=st.integers(0, 15),
       flags=st.tuples(st.booleans(), st.booleans(), st.booleans(), st.booleans()))
def test_meta_header_roundtrip(bt, sz, nch, wc, flags):
    w = jnp.uint32(0)
    for i in range(4):
        w = md.set_block_type(w, i, bt[i])
        w = md.set_block_sz(w, i, sz[i])
    w = md.set_num_chunks(w, nch)
    w = md.set_wr_cntr(w, wc)
    w = md.set_shadow_valid(w, int(flags[0]))
    w = md.set_dirty(w, int(flags[1]))
    w = md.set_promoted(w, int(flags[2]))
    w = md.set_valid(w, int(flags[3]))
    for i in range(4):
        assert int(md.get_block_type(w, i)) == bt[i]
        assert int(md.get_block_sz(w, i)) == sz[i]
        assert int(md.get_block_type_dyn(w, jnp.asarray(i))) == bt[i]
    assert int(md.get_num_chunks(w)) == nch
    assert int(md.get_wr_cntr(w)) == wc
    assert int(md.get_shadow_valid(w)) == int(flags[0])
    assert int(md.get_dirty(w)) == int(flags[1])
    assert int(md.get_promoted(w)) == int(flags[2])
    assert int(md.get_valid(w)) == int(flags[3])


@settings(max_examples=20, deadline=None)
@given(ptrs=st.lists(st.integers(0, 2 ** 28 - 1), min_size=7, max_size=7))
def test_meta_ptr_roundtrip(ptrs):
    e = md.empty_entry()
    for i, p in enumerate(ptrs):
        e = md.set_ptr(e, i, p)
    for i, p in enumerate(ptrs):
        assert int(md.get_ptr(e, i)) == p


def test_rates_header_roundtrip():
    from repro.core.bitpack import RATE_4BIT, RATE_8BIT, RATE_RAW, RATE_ZERO
    for rates in ([0, 1, 2, 3], [3, 3, 3, 3], [0, 0, 0, 0], [2, 1, 0, 3]):
        r = jnp.asarray(rates, jnp.int32)
        w = md.header_from_rates(r)
        back = md.rates_from_header(w)
        assert list(np.asarray(back)) == rates


def test_activity_pack():
    e = md.act_pack(1, 0, 12345)
    assert int(md.act_allocated(e)) == 1
    assert int(md.act_referenced(e)) == 0
    assert int(md.act_ospn(e)) == 12345
    e2 = md.act_set_referenced(e, 1)
    assert int(md.act_referenced(e2)) == 1
    assert int(md.act_ospn(e2)) == 12345


# -- freelist ---------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(ops=st.lists(st.booleans(), min_size=1, max_size=60))
def test_freelist_conservation(ops):
    """Arbitrary pop/push sequences never duplicate or lose an index."""
    n = 16
    f = fl.make_freelist(n)
    held: list[int] = []
    for is_pop in ops:
        if is_pop:
            f, idx = fl.pop(f)
            i = int(idx)
            if i >= 0:
                assert i not in held
                held.append(i)
            else:
                assert int(f.top) == 0
        elif held:
            f = fl.push(f, jnp.asarray(held.pop()))
    free = set(int(x) for x in np.asarray(f.items)[: int(f.top)])
    assert len(free) == int(f.top)
    assert free | set(held) == set(range(n))
    assert not (free & set(held))


def test_freelist_pop_n_push_n():
    f = fl.make_freelist(8)
    f, got = fl.pop_n(f, 7, jnp.asarray(3))
    got = np.asarray(got)
    assert (got[:3] >= 0).all() and (got[3:] == -1).all()
    assert int(f.top) == 5
    f = fl.push_n(f, jnp.asarray(got))
    assert int(f.top) == 8


# -- mcache -----------------------------------------------------------------

def test_mcache_lru_and_evict():
    mc = mcc.make_mcache(1, 2)  # 1 set, 2 ways
    mc, hit, ev = mcc.access(mc, jnp.asarray(10))
    assert not bool(hit) and int(ev) == -1
    mc, hit, ev = mcc.access(mc, jnp.asarray(11))
    assert not bool(hit) and int(ev) == -1
    mc, hit, ev = mcc.access(mc, jnp.asarray(10))   # 10 -> MRU
    assert bool(hit)
    mc, hit, ev = mcc.access(mc, jnp.asarray(12))   # evicts LRU == 11
    assert not bool(hit) and int(ev) == 11
    assert bool(mcc.probe(mc, jnp.asarray(10)))
    assert bool(mcc.probe(mc, jnp.asarray(12)))
    assert not bool(mcc.probe(mc, jnp.asarray(11)))


@settings(max_examples=15, deadline=None)
@given(seq=st.lists(st.integers(0, 30), min_size=1, max_size=80))
def test_mcache_matches_reference_lru(seq):
    sets, ways = 2, 4
    mc = mcc.make_mcache(sets, ways)
    import collections
    ref = [collections.OrderedDict() for _ in range(sets)]
    for ospn in seq:
        s = int(mcc._set_index(jnp.asarray(ospn), sets))
        mc, hit, ev = mcc.access(mc, jnp.asarray(ospn))
        rhit = ospn in ref[s]
        assert bool(hit) == rhit
        rev = -1
        if rhit:
            ref[s].move_to_end(ospn)
        else:
            if len(ref[s]) == ways:
                rev, _ = ref[s].popitem(last=False)
            ref[s][ospn] = True
        assert int(ev) == rev


# -- clock ------------------------------------------------------------------

def _mk_activity(entries):
    return jnp.asarray([md.act_pack(a, r, o) for (a, r, o) in entries],
                       dtype=jnp.uint32)


def test_clock_second_chance():
    # 16 entries: all allocated; entry 5 unreferenced -> victim; others get
    # their referenced bit cleared.
    entries = [(1, 1, 100 + i) for i in range(16)]
    entries[5] = (1, 0, 105)
    a = _mk_activity(entries)
    cache = mcc.make_mcache(2, 2)  # empty: probe misses
    res = act.clock_scan(a, jnp.asarray(0, jnp.int32), cache, jax.random.PRNGKey(0))
    assert int(res.victim_pidx) == 5
    assert int(res.victim_ospn) == 105
    assert not bool(res.used_random)
    assert int(res.groups_scanned) == 1
    refs = np.asarray(md.act_referenced(res.activity))
    assert refs.sum() == 0  # all cleared in the scanned group


def test_clock_probe_skips_cached():
    entries = [(1, 1, 100 + i) for i in range(16)]
    entries[5] = (1, 0, 105)
    entries[9] = (1, 0, 109)
    a = _mk_activity(entries)
    cache = mcc.make_mcache(2, 2)
    cache, _, _ = mcc.access(cache, jnp.asarray(105))  # 105 is hot-in-cache
    res = act.clock_scan(a, jnp.asarray(0, jnp.int32), cache, jax.random.PRNGKey(0))
    assert int(res.victim_pidx) == 9  # skipped the cache-resident page


def test_clock_random_fallback():
    entries = [(1, 1, 100 + i) for i in range(16)]  # all referenced
    a = _mk_activity(entries)
    cache = mcc.make_mcache(2, 2)
    res = act.clock_scan(a, jnp.asarray(0, jnp.int32), cache, jax.random.PRNGKey(0))
    assert bool(res.used_random)
    assert 0 <= int(res.victim_pidx) < 16
    assert int(res.groups_scanned) == 1  # bounded to one fetch (the paper's rule)


def test_clock_skips_empty_group():
    entries = [(0, 0, 0) for _ in range(16)] + [(1, 0, 200 + i) for i in range(16)]
    a = _mk_activity(entries)
    cache = mcc.make_mcache(2, 2)
    res = act.clock_scan(a, jnp.asarray(0, jnp.int32), cache, jax.random.PRNGKey(0))
    assert int(res.victim_pidx) == 16
    assert int(res.groups_scanned) == 2


def test_clock_lazy_touch():
    a = _mk_activity([(1, 0, 7)] * 16)
    a2 = act.lazy_touch(a, jnp.asarray(3))
    assert int(md.act_referenced(a2[3])) == 1
    a3 = act.lazy_touch(a2, jnp.asarray(-1))  # no-op
    assert jnp.all(a3 == a2)
