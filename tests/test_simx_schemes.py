"""Policy-layer tests: scheme hooks charge traffic in place (no post-hoc
adjustments), the batched front-end agrees with the serial engine within
noise, and scheme-relative ordering survives. Cheap versions of the
test_system cells plus eager (un-jitted) unit checks of the Policy hooks."""
import jax.numpy as jnp
import pytest

from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import (POLICIES, DmcPolicy, DylectPolicy,
                                      IbexPolicy, MxtPolicy,
                                      SecondChanceLanes, TmccPolicy)
from repro.simx.engine import (SCHEMES, first_touch_populate, pool_cfg_for,
                               run_workload)
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace

TRAFFIC = ("metadata_rd", "metadata_wr", "data_rd", "data_wr", "promo_rd",
           "promo_wr", "demo_rd", "demo_wr", "activity_rd", "activity_wr")


def _zeros():
    return jnp.zeros((S.NUM_COUNTERS,), S.CTR_DTYPE)


def test_policy_registry_covers_paper_schemes():
    for name in ("ibex", "ibex_base", "ibex_s", "ibex_sc", "ibex_scm",
                 "tmcc", "dylect", "mxt", "dmc", "compresso"):
        assert name in POLICIES
        assert POLICIES[name].name == name
    assert SCHEMES is POLICIES


def test_tmcc_hooks_charge_in_place():
    """TMCC: +1 recency-list access per host op, +2 bookkeeping writes per
    compression store, +1 reclaim access per demotion — at the hook sites."""
    p = TmccPolicy()
    c = p.on_host_access(_zeros(), False)
    assert int(c[S.C_ACT_WR]) == 1
    c = p.on_compress_store(_zeros())
    assert int(c[S.C_META_WR]) == 2
    c = p.on_demotion(_zeros(), clean=True)
    assert int(c[S.C_DEMO_WR]) == 1
    # the base policy charges none of these
    base = IbexPolicy()
    assert int(jnp.sum(base.on_host_access(_zeros(), False))) == 0
    assert int(jnp.sum(base.on_compress_store(_zeros()))) == 0


def test_dylect_second_table_probe():
    c = DylectPolicy().on_mcache_miss(_zeros(), n=5)
    assert int(c[S.C_META_RD]) == 5
    assert int(jnp.sum(TmccPolicy().on_mcache_miss(_zeros(), n=5))) == 0


def test_dmc_migration_multiplier():
    c = DmcPolicy().charge_migration(_zeros(), S.C_PROMO_RD, 3)
    assert int(c[S.C_PROMO_RD]) == 24          # 8x (32KB granularity)
    c = IbexPolicy().charge_migration(_zeros(), S.C_PROMO_RD, 3)
    assert int(c[S.C_PROMO_RD]) == 3


def test_mxt_on_chip_tags_suppress_activity_traffic():
    c = MxtPolicy().charge_activity(_zeros(), S.C_ACT_RD, 7)
    assert int(jnp.sum(c)) == 0
    c = IbexPolicy().charge_activity(_zeros(), S.C_ACT_RD, 7)
    assert int(c[S.C_ACT_RD]) == 7


def test_second_chance_lanes_policy():
    """Referenced lanes get a second chance; the first un-referenced occupied
    lane after the hand is the victim; all-referenced falls back."""
    sel = SecondChanceLanes(4)
    occupied = [True, False, True, True]
    ref = {0: True, 2: False, 3: True}
    victim = sel.select(lambda l: occupied[l], lambda l: ref[l],
                        lambda l: ref.__setitem__(l, False))
    assert victim == 2
    assert ref[0] is False                     # lane 0 got its chance cleared
    ref = {0: True, 2: True, 3: True}
    sel2 = SecondChanceLanes(4)
    v2 = sel2.select(lambda l: occupied[l], lambda l: True, lambda l: None)
    assert v2 in (0, 2, 3)                     # round-robin fallback


def test_activity_write_charge_parity_serial_vs_batched():
    """C_ACT_WR must be charged exactly where the activity word is written:
    a metadata-cache eviction of a promoted page whose referenced bit is
    *already set* costs nothing, on both the serial (ops.mcache_step) and
    batched (_mcache_window) paths.

    Direct construction: 6 promoted pages, a 1-set/2-way cache, distinct
    accesses -> both paths evict pages 0..3 in the same multiset. Pages 0-1
    have cleared referenced bits (2 flips -> 2 charges); pages 2-3 arrive
    referenced (0 charges)."""
    import jax
    from repro.common.types import PoolConfig
    from repro.core import metadata as md
    from repro.core.engine import ops as O

    policy = POLICIES["ibex"]
    cfg = PoolConfig(n_pages=32, n_cchunks=256, n_pchunks=16, mcache_sets=1,
                     mcache_ways=2, demote_watermark=0, store_payload=False)
    pool = S.make_pool(cfg)
    for i in range(6):   # first-touch writes promote; activity arrives ref=1
        pool = O.write_page_op(pool, cfg, policy,
                               jnp.asarray(i),
                               jnp.zeros((cfg.vals_per_page,), jnp.bfloat16))
    # clear the referenced bit of pages 0 and 1 (their P-chunk activity word)
    act = pool.activity
    for ospn in (0, 1):
        pidx = int(md.get_ptr(pool.meta[ospn], md.PCHUNK_SLOT))
        act = act.at[pidx].set(md.act_set_referenced(act[pidx], 0))
    pool = pool._replace(counters=jnp.zeros_like(pool.counters), activity=act)

    ospns = jnp.arange(6, dtype=jnp.int32)     # distinct: evicts pages 0..3
    serial = pool
    for i in range(6):
        serial, _ = O.mcache_step(serial, cfg, policy, ospns[i])
    batched = B._mcache_window(pool, cfg, policy, ospns)

    cs, cb = S.counters_dict(serial), S.counters_dict(batched)
    assert cs["activity_wr"] == cb["activity_wr"] == 2, (cs["activity_wr"],
                                                         cb["activity_wr"])
    assert cs["mcache_misses"] == cb["mcache_misses"]
    # both paths leave identical referenced bits behind
    ref_s = jax.numpy.asarray([md.act_referenced(e) for e in serial.activity])
    ref_b = jax.numpy.asarray([md.act_referenced(e) for e in batched.activity])
    assert (ref_s == ref_b).all()


def test_replay_tail_pads_to_one_window():
    """The batched front-end's serial tail is padded to exactly one window
    with masked no-op accesses: a 5-access tail padded to window=8 must give
    byte-identical counters to replaying the 5 accesses unpadded."""
    policy = POLICIES["ibex"]
    cfg = pool_cfg_for(policy, n_pages=32, n_pchunks=16, n_cchunks=512)
    rates = make_rates_table(WORKLOADS["mcf"], 32, seed=3)
    pool = S.make_pool(cfg, rates_table=jnp.asarray(rates))
    pool = first_touch_populate(pool, cfg, policy, n_used=16)
    ospn, wr, blk = make_trace(WORKLOADS["mcf"], n_accesses=21, n_pages=16,
                               seed=3)
    # window=16 -> one full window + a 5-access tail (padded to 16 inside)
    pb = B.replay_trace(pool, cfg, policy, ospn, wr, blk, window=16)
    # reference: same window head, tail replayed unpadded
    ph = B._replay_windows(pool, cfg, policy,
                           jnp.asarray(ospn[:16]).reshape(1, 16),
                           jnp.asarray(wr[:16]).reshape(1, 16),
                           jnp.asarray(blk[:16]).reshape(1, 16))
    ps = B._replay_serial(ph, cfg, policy, jnp.asarray(ospn[16:]),
                          jnp.asarray(wr[16:]), jnp.asarray(blk[16:]))
    assert S.counters_dict(pb) == S.counters_dict(ps)


@pytest.fixture(scope="module")
def small_replay():
    # NOTE: the promoted region must be well above the demotion watermark —
    # when the watermark is a sizable fraction of the pool, the serial
    # engine's per-access demotion cadence thrashes in a way the batched
    # per-window cadence (faithfully) avoids, and traffic diverges.
    policy = POLICIES["ibex"]
    prom = 48
    n_pages = 4 * prom
    cfg = pool_cfg_for(policy, n_pages=n_pages, n_pchunks=prom,
                       n_cchunks=2 * n_pages * 8)
    spec = WORKLOADS["mcf"]
    rates = make_rates_table(spec, n_pages, seed=0)
    n_used = min(max(int(prom * spec.footprint_pages), 32), n_pages)
    ospn, wr, blk = make_trace(spec, n_accesses=1024, n_pages=n_used, seed=0)
    pool = S.make_pool(cfg, rates_table=jnp.asarray(rates))
    pool = first_touch_populate(pool, cfg, policy, n_used=n_used)
    return policy, cfg, pool, (ospn, wr, blk)


def test_batched_matches_serial_within_noise(small_replay):
    """The window front-end's traffic totals track the one-access-per-step
    engine; only background-demotion *timing* differs."""
    policy, cfg, pool, (ospn, wr, blk) = small_replay
    ps = B._replay_serial(pool, cfg, policy, jnp.asarray(ospn),
                          jnp.asarray(wr), jnp.asarray(blk))
    pb = B.replay_trace(pool, cfg, policy, ospn, wr, blk, window=16)
    cs, cb = S.counters_dict(ps), S.counters_dict(pb)
    assert cb["host_reads"] == cs["host_reads"]
    assert cb["host_writes"] == cs["host_writes"]
    ts = sum(cs[k] for k in TRAFFIC)
    tb = sum(cb[k] for k in TRAFFIC)
    assert abs(tb - ts) / max(ts, 1) < 0.15, (ts, tb)
    assert cb["promotions"] > 0


def test_small_pool_cadence_knob_bounds_divergence():
    """Regression for the small-pool watermark divergence (the fixture note
    above): at prom=16 the watermark is half the promoted region and the
    batched per-window demotion cadence diverges from the serial engine by
    ~48% total traffic. ``PoolConfig.demote_cadence="access"`` reproduces
    the serial cadence inside the batched front-end (no raised per-window
    target + a watermark re-check before every slow access) and must keep
    the divergence within 25% (measured ~18%; the residue is window-granular
    mcache recency and RNG-dependent random-fallback victims, not cadence —
    the default "window" cadence stays the default everywhere else and its
    large-pool bound is pinned by test_batched_matches_serial_within_noise
    above)."""
    import dataclasses

    policy = POLICIES["ibex"]
    prom = 16
    n_pages = 4 * prom
    base = pool_cfg_for(policy, n_pages=n_pages, n_pchunks=prom,
                        n_cchunks=2 * n_pages * 8)
    spec = WORKLOADS["mcf"]
    rates = make_rates_table(spec, n_pages, seed=0)
    n_used = min(max(int(prom * spec.footprint_pages), 32), n_pages)
    ospn, wr, blk = make_trace(spec, n_accesses=1024, n_pages=n_used, seed=0)

    def divergence(cfg):
        pool = S.make_pool(cfg, rates_table=jnp.asarray(rates))
        pool = first_touch_populate(pool, cfg, policy, n_used=n_used,
                                    window=16)
        ps = B._replay_serial(pool, cfg, policy, jnp.asarray(ospn),
                              jnp.asarray(wr), jnp.asarray(blk))
        pb = B.replay_trace(pool, cfg, policy, ospn, wr, blk, window=16)
        cs, cb = S.counters_dict(ps), S.counters_dict(pb)
        assert cs["host_reads"] == cb["host_reads"]
        assert cs["host_writes"] == cb["host_writes"]
        ts = sum(cs[k] for k in TRAFFIC)
        tb = sum(cb[k] for k in TRAFFIC)
        return abs(tb - ts) / max(ts, 1)

    matched = divergence(dataclasses.replace(base, demote_cadence="access"))
    assert matched < 0.25, matched


def test_scheme_relative_traffic_ordering():
    """Fig. 9/11 headline at test scale: IBEX moves less internal traffic
    than TMCC and ends up faster. Deliberately NOT slow-marked — this is the
    tier-1 guard for the acceptance criterion that scheme-relative results
    survive the engine refactor (the full-size cells live in
    test_system.py). DyLeCT/MXT/DMC deltas are guarded by the cheap hook
    unit tests above."""
    kw = dict(n_accesses=1024, promoted_pages=32)
    ibex = run_workload("ibex", WORKLOADS["pr"], **kw)
    tmcc = run_workload("tmcc", WORKLOADS["pr"], **kw)
    assert ibex["internal_accesses"] < tmcc["internal_accesses"]
    assert ibex["time_s"] < tmcc["time_s"]
