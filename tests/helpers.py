"""Test helpers: pool invariant checker (DESIGN.md §9, pool.py I1-I5)."""
from __future__ import annotations

import numpy as np

from repro.common.types import PoolConfig
from repro.core import freelist as fl
from repro.core import metadata as md
from repro.core import engine as E


def _np(x):
    return np.asarray(x)


def check_pool_invariants(pool: E.Pool, cfg: PoolConfig) -> None:
    meta = _np(pool.meta)
    activity = _np(pool.activity)
    cfree_items = _np(pool.cfree.items)[: int(pool.cfree.top)]
    gfree_items = _np(pool.gfree.items)[: int(pool.gfree.top)]
    pfree_items = _np(pool.pfree.items)[: int(pool.pfree.top)]

    free_chunks = set(int(c) for c in cfree_items)
    for g in gfree_items:
        free_chunks.update(range(int(g), int(g) + 8))
    free_p = set(int(p) for p in pfree_items)
    assert len(free_chunks) == len(cfree_items) + 8 * len(gfree_items), \
        "duplicate entries in chunk freelists"
    assert len(free_p) == len(pfree_items), "duplicate entries in P freelist"

    referenced_chunks: dict[int, int] = {}
    owned_p: dict[int, int] = {}
    for ospn in range(meta.shape[0]):
        w0 = int(meta[ospn, 0])
        valid = (w0 >> 31) & 1
        if not valid:
            continue
        promoted = (w0 >> 30) & 1
        dirty = (w0 >> 29) & 1
        shadow = (w0 >> 28) & 1
        nchunks = (w0 >> 20) & 0xF
        ptrs = [int(meta[ospn, 1 + s]) & ((1 << 29) - 1) for s in range(7)]
        # I3: dirty promoted pages hold no compressed copy
        if promoted and dirty:
            assert nchunks == 0, f"I3 violated: page {ospn} dirty with chunks"
        # I4: clean promoted pages keep the shadow
        if promoted and not dirty:
            assert shadow == 1 and nchunks > 0, \
                f"I4 violated: page {ospn} clean promoted without shadow"
        # collect chunk references
        if nchunks == 8:
            chunk_set = list(range(ptrs[0], ptrs[0] + 8))
        else:
            chunk_set = ptrs[:nchunks]
        for c in chunk_set:
            assert c not in free_chunks, \
                f"I1 violated: page {ospn} references free chunk {c}"
            assert c not in referenced_chunks, \
                f"I1 violated: chunk {c} shared by {referenced_chunks[c]} and {ospn}"
            referenced_chunks[c] = ospn
        # I2: promoted pages own exactly one allocated P-chunk
        if promoted:
            pidx = ptrs[6] if nchunks < 7 else int(meta[ospn, 7]) & ((1 << 29) - 1)
            pidx = int(meta[ospn, 1 + md.PCHUNK_SLOT]) & ((1 << 29) - 1)
            assert pidx not in free_p, f"I2: page {ospn} P-chunk {pidx} is free"
            assert pidx not in owned_p, \
                f"I2: P-chunk {pidx} owned by {owned_p[pidx]} and {ospn}"
            owned_p[pidx] = ospn
            a = int(activity[pidx])
            assert (a >> 31) & 1 == 1, f"I2: activity[{pidx}] not allocated"
            assert (a & ((1 << 30) - 1)) == ospn, \
                f"I2: activity[{pidx}] OSPN mismatch"

    # every allocated activity entry belongs to a promoted page
    for pidx in range(activity.shape[0]):
        a = int(activity[pidx])
        if (a >> 31) & 1:
            ospn = a & ((1 << 30) - 1)
            assert owned_p.get(pidx) == ospn, \
                f"activity[{pidx}] allocated but page {ospn} does not own it"

    # conservation: singles partition into free + referenced
    n_single = E.n_single_chunks(cfg)
    n_groups = (cfg.n_cchunks - n_single) // 8
    total = n_single + 8 * n_groups
    assert len(free_chunks) + len(referenced_chunks) == total, \
        f"I1 conservation: {len(free_chunks)} free + {len(referenced_chunks)} ref != {total}"
    assert len(free_p) + len(owned_p) == cfg.n_pchunks, "P-chunk conservation"
