"""Multi-expander fabric tests (DESIGN.md §11/§13).

  * parity — the vmapped masked replay adds ZERO counter drift: per-expander
    counters are bit-identical to single-pool ``batch.replay_trace`` runs of
    each partition (and the N=1 fabric is bit-identical to a plain
    single-pool replay of the merged trace);
  * spill — a skew-saturated expander (cfree/gfree draining) spills to an
    idle donor: invariants I1–I5 hold on every expander afterwards and
    traffic lands on the right expander's counters;
  * segment scheduler — the depth-1 pipeline is bit-identical to the
    synchronous reference driver; overlapped (depth-2) migration defers
    in-flight pages' accesses to the page's final home; the rebalance
    policy shrinks the per-expander delivered-time spread on a skewed
    trace while I1–I5 hold after every epoch; pipeline pricing satisfies
    overlapped <= sync on the same deltas; one host sync per segment and
    one per epoch;
  * serving — lanes stripe across expanders, parked payloads are charged
    per-expander and victim selection balances parked load.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import replace
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import POLICIES, SecondChanceLanes
from repro.fabric import (CapacityAware, Fabric, LocalityAffinity,
                          StaticInterleave, WeightedInterleave)
from repro.fabric import migration as MG
from repro.fabric import ops as fops
from repro.simx.engine import pool_cfg_for
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace
from helpers import check_pool_invariants

POLICY = POLICIES["ibex"]
WINDOW = 8   # small windows keep the masked-path compiles test-sized


def _small_cfg(prom=16, n_pages=64, n_cchunks=None):
    return pool_cfg_for(POLICY, n_pages=n_pages, n_pchunks=prom,
                        n_cchunks=n_cchunks or 2 * n_pages * 8)


def _trace(cfg, n_accesses, seed=0, wl="mcf"):
    spec = WORKLOADS[wl]
    rates = make_rates_table(spec, cfg.n_pages, seed=seed)
    ospn, wr, blk = make_trace(spec, n_accesses=n_accesses,
                               n_pages=cfg.n_pages, seed=seed)
    return rates, ospn, wr, blk


def test_single_expander_fabric_matches_single_pool_exact():
    """N=1 fabric == plain ``replay_trace`` of the merged trace, counter for
    counter (the masked window path reuses the single-pool bodies)."""
    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=90)
    fab = Fabric(cfg, POLICY, StaticInterleave(1, cfg.n_pages), seed=0,
                 rates_table=jnp.asarray(rates), window=WINDOW, spill=False)
    fab.replay(ospn, wr, blk)
    pool = S.pool_slice(S.make_pool_stack(cfg, 1, seed=0,
                                          rates_table=jnp.asarray(rates)), 0)
    pool = B.replay_trace(pool, cfg, POLICY, ospn, wr, blk, window=WINDOW)
    assert fab.counters() == S.counters_dict(pool)


@pytest.mark.parametrize("placement_cls", [StaticInterleave, LocalityAffinity,
                                           CapacityAware])
def test_fabric_counter_sum_parity_per_shard_exact(placement_cls):
    """Summed fabric counters == sum of single-pool replays of the same
    merged trace's per-expander partitions, exactly, for every placement
    mode — and each expander's own counters match its shard's replay."""
    n_exp = 3
    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=120, seed=1)
    placement = placement_cls(n_exp, cfg.n_pages)
    fab = Fabric(cfg, POLICY, placement, seed=0,
                 rates_table=jnp.asarray(rates), window=WINDOW, spill=False)
    fab.replay(ospn, wr, blk)
    # reference: each shard through the canonical single-pool front-end,
    # from the identical starting state (same derived RNG stream)
    eids = placement.route(ospn)
    stack0 = S.make_pool_stack(cfg, n_exp, seed=0,
                               rates_table=jnp.asarray(rates))
    total = {k: 0 for k in S.COUNTER_NAMES}
    for e in range(n_exp):
        sel = eids == e
        ref = B.replay_trace(S.pool_slice(stack0, e), cfg, POLICY,
                             ospn[sel], wr[sel], blk[sel], window=WINDOW)
        ce = S.counters_dict(ref)
        assert fab.counters_by_expander()[e] == ce, f"expander {e} drifted"
        for k, v in ce.items():
            total[k] += v
    assert fab.counters() == total
    # invariants hold on every expander
    for e in range(n_exp):
        check_pool_invariants(S.pool_slice(fab.pools, e), cfg)


def test_fabric_vs_merged_single_pool_within_tolerance():
    """An N-expander fabric vs ONE pool with N× the physical regions (and
    N× the metadata cache), replaying the same merged trace: host access
    counts match exactly (they are per-access), total internal traffic
    agrees within the documented tolerance — the shared-vs-sharded metadata
    cache and per-expander demotion cadence shift counters, they do not
    change the traffic story (DESIGN.md §11)."""
    n_exp = 2
    cfg = _small_cfg(prom=16, n_pages=64)
    rates, ospn, wr, blk = _trace(cfg, n_accesses=512, seed=2)
    fab = Fabric(cfg, POLICY, StaticInterleave(n_exp, cfg.n_pages), seed=0,
                 rates_table=jnp.asarray(rates), window=WINDOW, spill=False)
    fab.replay(ospn, wr, blk)
    big = replace(cfg, n_pchunks=cfg.n_pchunks * n_exp,
                  n_cchunks=cfg.n_cchunks * n_exp,
                  mcache_sets=cfg.mcache_sets * n_exp)
    pool = S.make_pool(big, seed=0, rates_table=jnp.asarray(rates))
    pool = B.replay_trace(pool, big, POLICY, ospn, wr, blk, window=WINDOW)
    cf, cs = fab.counters(), S.counters_dict(pool)
    assert cf["host_reads"] == cs["host_reads"]
    assert cf["host_writes"] == cs["host_writes"]
    from repro.simx.engine import TRAFFIC_KEYS
    tf = sum(cf[k] for k in TRAFFIC_KEYS)
    ts = sum(cs[k] for k in TRAFFIC_KEYS)
    assert abs(tf - ts) / max(ts, 1) < 0.35, (tf, ts)


def _saturating_fabric(n_pages=96, n_used=40):
    """A fabric rigged to exhaust expander 0's compressed region: every
    page placed on expander 0 (WeightedInterleave [1, 0]); every page
    8-bit-compressible (4 single chunks, no aligned groups), so first-touch
    writes + watermark demotions demand ~160 chunks against 80 singles —
    the spill path must carry the overflow to the idle expander 1. (prom
    must be >= the clock engine's 16-entry fetch group; spill cadence is
    one window so within-segment demand never outruns the watermark.)"""
    cfg = _small_cfg(prom=16, n_pages=n_pages, n_cchunks=96)
    rates = np.full((n_pages, cfg.blocks_per_page), 2, np.int32)
    placement = WeightedInterleave(2, n_pages, [1.0, 0.0])
    fab = Fabric(cfg, POLICY, placement, seed=0,
                 rates_table=jnp.asarray(rates), window=WINDOW,
                 spill=True, spill_interval=WINDOW, spill_k=8, spill_low=40)
    # one first-touch write per used page (single lap: the donor sees no
    # host access unless overrides redirect a later lap)
    ospn = np.arange(n_used, dtype=np.int32)
    wr = np.ones((n_used,), bool)
    blk = np.zeros((n_used,), np.int32)
    return cfg, placement, fab, (ospn, wr, blk)


def test_skewed_saturation_spills_and_keeps_invariants():
    """Freelist exhaustion under skewed placement: expander 0 saturates
    while expander 1 idles. The spill path must fire, move pages to the
    donor, keep I1–I5 on BOTH expanders, and charge migration traffic where
    it physically happens: demotion-reads on the starved source,
    demotion-writes + compression-store bookkeeping on the donor — which
    sees no host accesses at all."""
    cfg, placement, fab, (ospn, wr, blk) = _saturating_fabric()
    fab.replay(ospn, wr, blk)

    stats = fab.spill_stats()
    assert stats["events"] > 0, "spill never fired"
    assert stats["pages_out"][0] > 0 and stats["pages_in"][1] > 0
    assert (placement.overrides >= 0).sum() == stats["pages_out"][0]
    for e in range(2):
        check_pool_invariants(S.pool_slice(fab.pools, e), cfg)
    c0, c1 = fab.counters_by_expander()
    # all host traffic on expander 0; the donor has zero host accesses
    assert c0["host_writes"] == int(wr.sum()) and c1["host_writes"] == 0
    assert c1["host_reads"] == 0
    # migration charged on the right sides
    assert c0["demo_rd"] > 0, "source not charged for spill reads"
    assert c1["demo_wr"] > 0, "donor not charged for spill writes"
    assert c1["promotions"] == 0 == c1["demotions_dirty"]


def test_spilled_page_follows_to_donor():
    """After a spill, accesses to a migrated page are routed (and charged)
    to the donor expander — the placement override re-routes mid-trace."""
    cfg, placement, fab, (ospn, wr, blk) = _saturating_fabric()
    fab.replay(ospn, wr, blk)
    assert fab.spill_stats()["events"] > 0
    moved = np.nonzero(placement.overrides >= 0)[0]
    assert len(moved) > 0
    # read a migrated page: the donor serves (and is charged for) it
    tail = np.full((WINDOW,), moved[0], np.int32)
    before = fab.counters_by_expander()[1]["host_reads"]
    fab.replay(tail, np.zeros((WINDOW,), bool), np.zeros((WINDOW,), np.int32))
    after = fab.counters_by_expander()[1]["host_reads"]
    assert after - before == WINDOW
    for e in range(2):
        check_pool_invariants(S.pool_slice(fab.pools, e), cfg)


def test_delivered_time_mixed_fleet_per_expander_devices():
    """Delivered-time accounting (DESIGN.md §12) through the fabric: a
    mixed-generation fleet prices each expander's counters through its OWN
    DeviceConfig inside the vmapped replay. The in-jit float32 values match
    the host float64 recompute; the host float64 values are bitwise the
    legacy scalar model per expander; and with identical traffic the gen4
    expander is strictly slower than the gen5 one."""
    from repro.simx import device as DEV
    from repro.simx import time as TM
    from repro.simx.engine import TRAFFIC_KEYS

    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=96, seed=4)
    devices = [TM.DeviceConfig(), TM.DEVICE_PROFILES["gen4"]]
    fab = Fabric(cfg, POLICY, StaticInterleave(2, cfg.n_pages), seed=0,
                 rates_table=jnp.asarray(rates), window=WINDOW, spill=False,
                 devices=devices)
    fab.replay(ospn, wr, blk)
    per = fab.delivered_time()                       # float64 host path
    in_jit = fab.delivered_time(exact=False)         # computed in the vmap
    assert per.shape == (2,) and (per > 0).all()
    assert np.allclose(per, in_jit, rtol=1e-4), (per, in_jit)
    for e, c in enumerate(fab.counters_by_expander()):
        internal = sum(c[k] for k in TRAFFIC_KEYS)
        legacy = DEV.exec_time(dict(c, internal_accesses=internal),
                               devices[e])
        assert per[e] == legacy, f"expander {e} drifted from scalar model"
    assert fab.bottleneck_time() == per.max()
    # same counters on the slower generation must cost at least as much
    t_gen4 = TM.exec_time_vec(
        np.asarray(jax.device_get(fab.pools.counters), np.float64),
        TM.DEVICE_PROFILES["gen4"])
    t_gen5 = TM.exec_time_vec(
        np.asarray(jax.device_get(fab.pools.counters), np.float64),
        TM.DeviceConfig())
    assert (t_gen4 > t_gen5).all()


def test_delivered_time_charges_spill_on_the_expander_where_it_occurs():
    """Spill migration traffic lands in the source/donor counters, so the
    donor's delivered time rises above an idle expander's even though it
    serves ZERO host accesses — the per-expander time model sees the
    migration where it physically happened."""
    cfg, placement, fab, (ospn, wr, blk) = _saturating_fabric()
    fab.replay(ospn, wr, blk)
    assert fab.spill_stats()["events"] > 0
    c0, c1 = fab.counters_by_expander()
    assert c1["host_reads"] + c1["host_writes"] == 0
    per = fab.delivered_time()
    assert per[1] > 0, "donor's spill traffic not priced"
    # and the donor's time is exactly its own demo-write/store traffic
    # priced by its own device (internal-bandwidth term; no host terms)
    dev = fab.devices[1]
    internal1 = sum(c1[k] for k in S.TRAFFIC_NAMES)
    assert per[1] == internal1 * 64 / (dev.channels * dev.ch_bw)


def test_fabric_segment_delta_tracking():
    """track_segments records one per-expander counter delta per replayed
    segment (the async-migration / rebalancing hook): deltas are
    non-negative and sum to the final counters."""
    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=128, seed=5)
    fab = Fabric(cfg, POLICY, StaticInterleave(2, cfg.n_pages), seed=0,
                 rates_table=jnp.asarray(rates), window=WINDOW,
                 spill=True, spill_interval=2 * WINDOW,
                 track_segments=True)
    fab.replay(ospn, wr, blk)
    assert fab.segment_deltas, "no segments recorded"
    assert fab.segment_syncs == len(fab.segment_deltas)
    # no spill fired (plenty of chunk headroom at this scale), so the
    # replay deltas alone must reconstruct the final counters; spill
    # migration charges land between segments and are tracked separately
    # (spill_stats), not inside the per-segment replay deltas
    assert fab.spill_stats()["events"] == 0
    total = np.zeros((2, S.NUM_COUNTERS), np.int64)
    for d in fab.segment_deltas:
        assert d.shape == (2, S.NUM_COUNTERS)
        assert (d >= 0).all()
        total += d
    final = np.asarray(jax.device_get(fab.pools.counters), np.int64)
    assert (total == final).all()


def test_depth1_pipeline_bit_identical_to_sync():
    """The degenerate (depth-1) pipeline — plan and apply at the same
    boundary — must produce bit-identical final pool state, counters, and
    override tables to the synchronous reference driver, on a config
    where migration actually fires. This pins the overlap machinery
    (pending masks, deferral, delayed apply) against the PR 3 parity
    suite: at depth 1 it must all be invisible."""
    cfg, pl_d1, f_d1, tr = _saturating_fabric()
    f_d1.pipeline_depth = 1
    _, pl_sync, f_sync, tr2 = _saturating_fabric()
    f_sync.sync_migration = True
    f_d1.replay(*tr)
    f_sync.replay(*tr2)
    assert f_sync.spill_stats()["events"] > 0, "reference never migrated"
    assert f_d1.state_identical(f_sync), \
        "depth-1 pipeline drifted from the synchronous driver"
    assert f_d1.counters() == f_sync.counters()
    assert f_d1.spill_stats() == f_sync.spill_stats()


def test_overlapped_urgent_spill_keeps_invariants():
    """The default (depth-2) scheduler on the saturating config: pressure
    plans from a starved source are URGENT and apply at the same boundary
    (relief one segment late is relief after the freelists ran dry), so
    invariants hold on both expanders and migration traffic lands on the
    right sides even under overlap."""
    cfg, placement, fab, (ospn, wr, blk) = _saturating_fabric()
    assert fab.pipeline_depth == 2 and not fab.sync_migration
    fab.replay(ospn, wr, blk)
    assert fab.spill_stats()["events"] > 0
    for e in range(2):
        check_pool_invariants(S.pool_slice(fab.pools, e), cfg)
    ss = fab.sync_stats()
    assert ss["segment_syncs"] == ss["segments"]
    assert ss["epoch_syncs"] == ss["epochs"] == fab.placement.epoch
    c0, c1 = fab.counters_by_expander()
    assert c0["demo_rd"] > 0 and c1["demo_wr"] > 0


class _ScriptedOnce(MG.MigrationPolicy):
    """Plans a fixed page set exactly once, when armed (test harness for
    the in-flight deferral path)."""
    name = "scripted"

    def __init__(self):
        self.pages = None
        self.armed = False

    def plan(self, view):
        if not self.armed or self.pages is None:
            return None
        self.armed = False
        k = len(self.pages)
        return MG.MigrationPlan(np.asarray(self.pages, np.int32),
                                np.zeros((k,), np.int32),
                                np.ones((k,), np.int32))


def test_overlapped_migration_defers_inflight_accesses():
    """Depth-2 overlap: accesses to a page whose migration plan is in
    flight are deferred by the carried pending mask and replayed after
    the epoch commits — served (and charged) on the page's FINAL home,
    never on the source mid-migration."""
    cfg = _small_cfg()
    scripted = _ScriptedOnce()
    placement = WeightedInterleave(2, cfg.n_pages, [1.0, 0.0])
    fab = Fabric(cfg, POLICY, placement, seed=0,
                 rates_table=jnp.asarray(
                     np.full((cfg.n_pages, cfg.blocks_per_page), 2,
                             np.int32)),
                 window=WINDOW, migration=scripted,
                 spill_interval=WINDOW)
    # warm: 32 first-touch writes overflow the 16-P-chunk promoted region,
    # demoting early pages into the compressed region (migration-eligible)
    warm = np.arange(32, dtype=np.int32)
    fab.replay(warm, np.ones((32,), bool), np.zeros((32,), np.int32))
    stats = fops.segment_stats(S.pool_slice(fab.pools, 0), cfg)
    eligible = np.nonzero(np.asarray(jax.device_get(stats.eligible)))[0]
    assert len(eligible) >= 4, "warm phase left no eligible pages"
    pages = eligible[:4]
    scripted.pages = pages
    scripted.armed = True
    # segment 1: filler writes (plan fires at its boundary); segment 2:
    # reads of the planned pages — IN FLIGHT, so all deferred; segment 3:
    # more filler. The deferred reads replay after the commit, on e1.
    filler1 = np.arange(32, 40, dtype=np.int32)
    reads = np.concatenate([pages, pages]).astype(np.int32)
    filler2 = np.arange(40, 48, dtype=np.int32)
    ospn = np.concatenate([filler1, reads, filler2])
    wr = np.concatenate([np.ones(8, bool), np.zeros(8, bool),
                         np.ones(8, bool)])
    blk = np.zeros((24,), np.int32)
    before = fab.counters_by_expander()
    assert before[1]["host_reads"] + before[1]["host_writes"] == 0
    fab.replay(ospn, wr, blk)
    assert (placement.route(pages) == 1).all(), "pages did not migrate"
    c0, c1 = fab.counters_by_expander()
    # every deferred read was served by the donor, none leaked to the
    # source mid-migration; writes stayed on e0
    assert c1["host_reads"] == len(reads), (c0["host_reads"],
                                            c1["host_reads"])
    assert c0["host_reads"] == 0
    assert c0["host_writes"] == 48 and c1["host_writes"] == 0
    for e in range(2):
        check_pool_invariants(S.pool_slice(fab.pools, e), cfg)
    ss = fab.sync_stats()
    assert ss["segment_syncs"] == ss["segments"]
    assert ss["epoch_syncs"] == ss["epochs"] == 1


class _ScriptedAlways(MG.MigrationPolicy):
    """Re-plans the same pages at every boundary (livelock-guard probe)."""
    name = "scripted-always"

    def __init__(self, pages):
        self.pages = np.asarray(pages, np.int32)
        self.armed = False

    def plan(self, view):
        if not self.armed:
            return None
        k = len(self.pages)
        return MG.MigrationPlan(self.pages, np.zeros((k,), np.int32),
                                np.ones((k,), np.int32))


def test_unappliable_plan_does_not_livelock():
    """A plan whose every move the apply refuses (here: the page is
    promoted, so ineligible) while the remaining trace keeps accessing
    the planned page would recur forever — deferred accesses rebuild the
    same remainder and the policy re-plans the same page. The livelock
    guard bars zero-progress pages from re-planning, so the replay
    terminates and the deferred accesses are served on the source."""
    cfg = _small_cfg()
    scripted = _ScriptedAlways([0])
    placement = WeightedInterleave(2, cfg.n_pages, [1.0, 0.0])
    fab = Fabric(cfg, POLICY, placement, seed=0,
                 rates_table=jnp.asarray(
                     np.full((cfg.n_pages, cfg.blocks_per_page), 2,
                             np.int32)),
                 window=WINDOW, migration=scripted,
                 spill_interval=WINDOW)
    # page 0 is written once -> promoted (first-touch lands hot; only 4
    # writes, so the demotion watermark never fires) -> never
    # migration-eligible
    warm = np.arange(4, dtype=np.int32)
    fab.replay(warm, np.ones((4,), bool), np.zeros((4,), np.int32))
    scripted.armed = True
    reads = np.concatenate([np.arange(8, 16, dtype=np.int32),
                            np.zeros((16,), np.int32)])
    fab.replay(reads, np.zeros((24,), bool), np.zeros((24,), np.int32))
    c0, c1 = fab.counters_by_expander()
    assert c0["host_reads"] == 24 and c1["host_reads"] == 0
    assert fab.spill_stats()["pages_out"] == [0, 0]
    assert fab._blocked[0], "zero-progress page was not barred"
    assert (placement.overrides == -1).all()


def test_rebalance_reduces_delivered_time_spread():
    """The traffic-imbalance trigger on a 0.8-skewed trace: referenced
    compressed pages migrate hot -> cold, so the per-expander
    delivered-time spread shrinks vs the pressure-only spill policy
    (which never fires here — chunk headroom is ample), and I1–I5 hold
    on source and destination after EVERY migration epoch."""
    cfg = _small_cfg()
    rates, ospn, wr, blk = _trace(cfg, n_accesses=512, seed=7)

    epochs_checked = []

    def check_epoch(fab, plan, moved):
        for e in range(2):
            check_pool_invariants(S.pool_slice(fab.pools, e), fab.cfg)
        epochs_checked.append(len(moved))

    def run(mode, cb=None):
        fab = Fabric(cfg, POLICY,
                     WeightedInterleave(2, cfg.n_pages, [0.8, 0.2]),
                     seed=0, rates_table=jnp.asarray(rates), window=WINDOW,
                     migration=mode, spill_interval=8 * WINDOW,
                     on_epoch=cb)
        fab.replay(ospn, wr, blk)
        return fab

    fab_spill = run("spill")
    fab_reb = run("rebalance", check_epoch)
    assert fab_spill.spill_stats()["events"] == 0, \
        "pressure spill fired; the comparison is no longer rebalance-only"
    assert fab_reb.epochs_applied > 0 and sum(epochs_checked) > 0, \
        "rebalance trigger never fired"
    t_spill = fab_spill.delivered_time()
    t_reb = fab_reb.delivered_time()
    spread = lambda t: float(t.max() / max(t.min(), 1e-18))  # noqa: E731
    assert spread(t_reb) < spread(t_spill), (t_reb, t_spill)
    ss = fab_reb.sync_stats()
    assert ss["segment_syncs"] == ss["segments"]
    assert ss["epoch_syncs"] == ss["epochs"]
    # rebalance epochs are proactive (never urgent here: headroom is
    # ample), so they genuinely overlapped foreground replay — the
    # pipeline pricing must show a strict win somewhere
    pt = fab_reb.pipeline_times()
    assert pt["mode"] == "overlapped"
    assert (pt["overlapped_s"] <= pt["sync_s"] + 1e-15).all()
    assert (pt["overlapped_s"] < pt["sync_s"]).any(), \
        "no migration epoch was hidden behind replay"


def test_pipeline_pricing_urgent_epochs_stay_on_critical_path():
    """With ``proactive=1.0`` the spill trigger IS the hard watermark, so
    every plan is URGENT and applies synchronously — the pipeline pricing
    must NOT grant those epochs the overlap discount: overlapped and sync
    pricing coincide exactly, even on an overlapped-mode run."""
    cfg, placement, fab, (ospn, wr, blk) = _saturating_fabric()
    fab.migration_policy = MG.SpillPressure(k=8, low=40, proactive=1.0)
    fab.replay(ospn, wr, blk)
    assert fab.epochs_applied > 0
    assert all(not over for _, _, over in fab.migration_deltas), \
        "saturation epochs should all be urgent/synchronous"
    pt = fab.pipeline_times()
    assert pt is not None and pt["mode"] == "overlapped"
    assert (pt["overlapped_s"] == pt["sync_s"]).all(), \
        "urgent epochs were granted the overlap discount"
    assert (pt["delivered_s"] == pt["overlapped_s"]).all()


def test_second_chance_lanes_group_balancing():
    """With groups, the sweep picks the candidate on the least-loaded
    expander (clearing swept ref bits as usual); without, behavior is the
    unchanged clock."""
    sel = SecondChanceLanes(4)
    occupied = np.array([True, True, True, True])
    ref = np.array([False, False, False, False])
    groups = np.array([0, 1, 0, 1])
    load = np.array([5, 0])
    victim, _ = sel.select_mask(occupied, ref, groups=groups,
                                group_load=load)
    assert victim == 1           # first candidate on expander 1 (load 0)
    sel2 = SecondChanceLanes(4)
    victim2, _ = sel2.select_mask(occupied, ref)
    assert victim2 == 0          # plain clock unchanged


def test_serve_engine_parks_per_expander():
    """Fabric-aware serving: lanes stripe across expanders, preempted
    payloads are charged to their lane's expander, and totals reconcile."""
    jax_decode = pytest.importorskip("repro.models.decode")  # noqa: F841
    import jax
    from repro.common.types import ServeConfig
    from repro.configs import get_reduced
    from repro.models import transformer as T
    from repro.serve import Engine

    cfg = get_reduced("llama3_8b")
    scfg = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                       kv_rate_bits=8, n_expanders=2)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, scfg, params, max_len=128)
    assert list(eng.lane_expander) == [0, 1]
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(list(rng.integers(1, cfg.vocab_size, 12 + 2 * i)), 6)
    eng.run_until_done(max_steps=500)
    st = eng.expander_stats
    assert int(st["preempt_bytes"].sum()) == eng.counters["preempt_bytes"]
    assert int(st["resume_bytes"].sum()) == eng.counters["resume_bytes"]
    if eng.counters["demotions"] >= 2:
        # victim balancing spread parks across both expanders
        assert (st["preempt_bytes"] > 0).all()
    assert (st["parked"] >= 0).all()
    assert int(st["parked"].sum()) == sum(
        1 for r in eng.requests.values() if r.parked is not None)
