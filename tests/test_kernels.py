"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


# -- qpack -------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("shape,block", [
    ((2048,), 512), ((4, 1024), 256), ((2, 3, 512), 256), ((8192,), 512)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_qpack_encode_matches_ref(bits, shape, block, dtype):
    x = (jax.random.normal(KEY, shape) * 2.0).astype(dtype)
    codes, scales = ops.qpack_encode(x, bits=bits, block=block)
    rcodes, rscales = ref.qpack_encode_ref(x, bits, block)
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(rcodes))
    np.testing.assert_allclose(np.asarray(scales), np.asarray(rscales),
                               rtol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("shape,block", [((2048,), 512), ((4, 1024), 256)])
def test_qpack_decode_matches_ref(bits, shape, block):
    x = (jax.random.normal(KEY, shape) * 0.5).astype(jnp.bfloat16)
    codes, scales = ref.qpack_encode_ref(x, bits, block)
    got = ops.qpack_decode(codes, scales, bits=bits, block=block)
    want = ref.qpack_decode_ref(codes, scales, bits, block)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("bits", [4, 8])
def test_qpack_roundtrip_error_bound(bits):
    x = (jax.random.normal(KEY, (16, 1024)) * 3.0).astype(jnp.bfloat16)
    codes, scales = ops.qpack_encode(x, bits=bits, block=256)
    y = ops.qpack_decode(codes, scales, bits=bits, block=256)
    qmax = 2 ** (bits - 1) - 1
    xb = np.asarray(x, np.float32).reshape(16, 4, 256)
    yb = np.asarray(y, np.float32).reshape(16, 4, 256)
    amax = np.abs(xb).max(axis=-1, keepdims=True)
    assert (np.abs(yb - xb) <= amax / qmax * 0.51 + amax * 0.01).all()


def test_qpack_zero_block():
    x = jnp.zeros((8, 512), jnp.bfloat16)
    codes, scales = ops.qpack_encode(x.reshape(-1), bits=4, block=512)
    assert np.asarray(codes).sum() == 0
    y = ops.qpack_decode(codes, scales, bits=4, block=512)
    assert np.asarray(y, np.float32).sum() == 0


# -- flash attention ----------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (1, 256, 4, 4, 64), (2, 128, 4, 2, 64), (1, 256, 8, 2, 128),
    (1, 128, 2, 1, 128)])
def test_flash_attention_matches_ref(causal, B, S, Hq, Hkv, D):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=causal, tq=128, tk=128)
    want = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_attention_small_tiles():
    q = jax.random.normal(KEY, (1, 64, 2, 64), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 2, 64), jnp.float32)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 2, 64), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, tq=32, tk=32)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


# -- fused dequant decode attention -------------------------------------------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (2, 256, 4, 2, 64), (1, 512, 8, 2, 128), (2, 256, 4, 4, 128)])
def test_kvc_attention_matches_ref(bits, B, S, Hq, Hkv, D):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    from repro.core.compressor import quantize_blocks
    kc, ksc = quantize_blocks(k, bits, D)
    vc, vsc = quantize_blocks(v, bits, D)
    ksc, vsc = ksc[..., 0], vsc[..., 0]
    lengths = jnp.asarray([S, S // 2][:B][: B] + [S] * max(0, B - 2), jnp.int32)[:B]
    got = ops.kvc_decode_attention(q, kc, ksc, vc, vsc, lengths, bits=bits,
                                   t_blk=128)
    want = ref.kvc_attn_ref(q, kc, ksc, vc, vsc, bits=bits, lengths=lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_kvc_attention_respects_length_mask():
    """Tokens beyond `length` must not influence the output."""
    B, S, Hq, Hkv, D = 1, 256, 2, 1, 64
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    from repro.core.compressor import quantize_blocks
    out = []
    for tail_scale in (1.0, 100.0):
        k2 = k.at[:, 100:].mul(tail_scale)
        v2 = v.at[:, 100:].mul(tail_scale)
        kc, ksc = quantize_blocks(k2, 8, D)
        vc, vsc = quantize_blocks(v2, 8, D)
        out.append(ops.kvc_decode_attention(
            q, kc, ksc[..., 0], vc, vsc[..., 0],
            jnp.asarray([100], jnp.int32), bits=8))
    np.testing.assert_allclose(np.asarray(out[0], np.float32),
                               np.asarray(out[1], np.float32), atol=1e-6)
