"""Serving-engine integration tests: continuous batching, preemption
(demotion), resume (promotion), second-chance victim selection, and output
consistency under preemption."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ServeConfig
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.serve.engine import Engine, DONE

CFG = get_reduced("llama3_8b")
KEY = jax.random.PRNGKey(0)
SCFG = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                   kv_rate_bits=8)


@pytest.fixture(scope="module")
def params():
    return T.init_params(KEY, CFG)[0]


def _prompt(seed, n=20):
    return list(np.random.default_rng(seed).integers(
        1, CFG.vocab_size, size=n))


def test_single_request_completes(params):
    eng = Engine(CFG, SCFG, params, max_len=128)
    rid = eng.submit(_prompt(0), max_new_tokens=8)
    eng.run_until_done()
    assert eng.requests[rid].state == DONE
    assert len(eng.result(rid)) == 8
    assert all(0 <= t < CFG.vocab_size for t in eng.result(rid))


def test_oversubscription_preempts_and_finishes(params):
    eng = Engine(CFG, SCFG, params, max_len=128)
    rids = [eng.submit(_prompt(i), max_new_tokens=6) for i in range(5)]
    eng.run_until_done(max_steps=400)
    for rid in rids:
        assert eng.requests[rid].state == DONE, rid
        assert len(eng.result(rid)) == 6
    # 5 requests through 2 lanes must have demoted someone
    assert eng.counters["demotions"] >= 1
    assert eng.counters["promotions"] >= 5


def test_preemption_consistency(params):
    """A request preempted mid-decode continues from its compressed KV; its
    tokens must match an uninterrupted run (8-bit KV is near-lossless for the
    argmax at these scales)."""
    # both engines use lanes=1 so the compiled programs (and bf16 reduction
    # orders) are identical — only the preemption differs
    scfg1 = ServeConfig(max_running=1, hot_window=16, attn_chunk=32,
                        kv_rate_bits=8)
    base = Engine(CFG, scfg1, params, max_len=128)
    r0 = base.submit(_prompt(42), max_new_tokens=10)
    base.run_until_done()
    want = base.result(r0)

    eng = Engine(CFG, scfg1, params, max_len=128)
    ra = eng.submit(_prompt(42), max_new_tokens=10)
    # interleave a competitor so ra gets preempted at least once
    for _ in range(3):
        eng.step()
    rb = eng.submit(_prompt(7), max_new_tokens=4)
    eng.run_until_done(max_steps=400)
    assert eng.requests[ra].state == DONE
    assert eng.requests[rb].state == DONE
    got = eng.result(ra)
    assert len(got) == len(want)
    # tokens generated BEFORE the first preemption must match exactly (ra ran
    # >= 3 steps before rb arrived). After resume the whole context is 8-bit
    # (the bf16 ring was demoted), and an untrained model's argmax margins
    # are razor-thin, so the tail may legitimately diverge — on a *trained*
    # model the quantization noise is far below the logit margins.
    assert got[:3] == want[:3], (got, want)
    assert all(0 <= t < CFG.vocab_size for t in got)


def test_resume_moves_zero_kv_bytes(params):
    eng = Engine(CFG, SCFG, params, max_len=128)
    rids = [eng.submit(_prompt(i), max_new_tokens=6) for i in range(4)]
    eng.run_until_done(max_steps=400)
    if eng.counters["demotions"]:
        # resume installs codes only (uint8); preempt parks codes only
        assert eng.counters["resume_bytes"] >= 0
        assert eng.counters["preempt_bytes"] > 0
