"""Serving-engine integration tests: continuous batching, preemption
(demotion), resume (promotion), second-chance victim selection, output
consistency under preemption, the batched scheduler's host-sync contract
(one sync per decode step), shadowed lane re-preemption (zero bytes), and
the padded-prefill regression (padded rows must decode identically to
unpadded ones)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.types import ServeConfig
from repro.configs import get_reduced
from repro.models import decode as D
from repro.models import transformer as T
from repro.serve.engine import Engine, DONE
from repro.serve.serial import SerialEngine

CFG = get_reduced("llama3_8b")
KEY = jax.random.PRNGKey(0)
SCFG = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                   kv_rate_bits=8)


@pytest.fixture(scope="module")
def params():
    return T.init_params(KEY, CFG)[0]


def _prompt(seed, n=20):
    return list(np.random.default_rng(seed).integers(
        1, CFG.vocab_size, size=n))


def test_single_request_completes(params):
    eng = Engine(CFG, SCFG, params, max_len=128)
    rid = eng.submit(_prompt(0), max_new_tokens=8)
    eng.run_until_done()
    assert eng.requests[rid].state == DONE
    assert len(eng.result(rid)) == 8
    assert all(0 <= t < CFG.vocab_size for t in eng.result(rid))


def test_oversubscription_preempts_and_finishes(params):
    eng = Engine(CFG, SCFG, params, max_len=128)
    rids = [eng.submit(_prompt(i), max_new_tokens=6) for i in range(5)]
    eng.run_until_done(max_steps=400)
    for rid in rids:
        assert eng.requests[rid].state == DONE, rid
        assert len(eng.result(rid)) == 6
    # 5 requests through 2 lanes must have demoted someone
    assert eng.counters["demotions"] >= 1
    assert eng.counters["promotions"] >= 5


def test_preemption_consistency(params):
    """A request preempted mid-decode continues from its compressed KV; its
    tokens must match an uninterrupted run (8-bit KV is near-lossless for the
    argmax at these scales)."""
    # both engines use lanes=1 so the compiled programs (and bf16 reduction
    # orders) are identical — only the preemption differs
    scfg1 = ServeConfig(max_running=1, hot_window=16, attn_chunk=32,
                        kv_rate_bits=8)
    base = Engine(CFG, scfg1, params, max_len=128)
    r0 = base.submit(_prompt(42), max_new_tokens=10)
    base.run_until_done()
    want = base.result(r0)

    eng = Engine(CFG, scfg1, params, max_len=128)
    ra = eng.submit(_prompt(42), max_new_tokens=10)
    # interleave a competitor so ra gets preempted at least once
    for _ in range(3):
        eng.step()
    rb = eng.submit(_prompt(7), max_new_tokens=4)
    eng.run_until_done(max_steps=400)
    assert eng.requests[ra].state == DONE
    assert eng.requests[rb].state == DONE
    got = eng.result(ra)
    assert len(got) == len(want)
    # tokens generated BEFORE the first preemption must match exactly (ra ran
    # >= 3 steps before rb arrived). After resume the whole context is 8-bit
    # (the bf16 ring was demoted), and an untrained model's argmax margins
    # are razor-thin, so the tail may legitimately diverge — on a *trained*
    # model the quantization noise is far below the logit margins.
    assert got[:3] == want[:3], (got, want)
    assert all(0 <= t < CFG.vocab_size for t in got)


def test_resume_moves_zero_kv_bytes(params):
    eng = Engine(CFG, SCFG, params, max_len=128)
    rids = [eng.submit(_prompt(i), max_new_tokens=6) for i in range(4)]
    eng.run_until_done(max_steps=400)
    if eng.counters["demotions"]:
        # preempt parks the compressed payload only (the ring is quantized
        # on device first); resume installs the same compressed bytes
        assert eng.counters["resume_bytes"] >= 0
        assert eng.counters["preempt_bytes"] > 0


def test_one_host_sync_per_decode_step(params):
    """The host-sync contract: lane bookkeeping advances on device, and the
    host fetches exactly one (tokens, done, ref) triple per decode step."""
    eng = Engine(CFG, SCFG, params, max_len=128)
    for i in range(3):
        eng.submit(_prompt(i), max_new_tokens=8)
    eng.run_until_done(max_steps=400)
    assert eng.counters["steps"] > 0
    assert eng.counters["step_syncs"] == eng.counters["steps"]


def test_shadow_repreempt_moves_zero_bytes(params):
    """§4.5 at request granularity: re-preempting a resumed request that has
    not generated a new token re-validates the shadow — zero bytes move. And
    because KV is append-only, the shadow's prefix never goes stale: after N
    new tokens a preempt moves only the N-token suffix, not the context."""
    scfg1 = ServeConfig(max_running=1, hot_window=16, attn_chunk=32,
                        kv_rate_bits=8)
    eng = Engine(CFG, scfg1, params, max_len=128)
    rid = eng.submit(_prompt(3), max_new_tokens=12)
    for _ in range(3):
        eng.step()
    req = eng.requests[rid]
    pos0 = req.pos
    eng._preempt(0)
    first = eng.counters["preempt_bytes"]
    assert first > 0
    eng.queue.remove(rid)
    eng.lane_req[0] = rid
    eng._resume(req, 0)
    assert req.parked is not None and req.shadow_pos == req.pos
    eng._preempt(0)                       # untouched since resume
    assert eng.counters["preempt_bytes"] == first
    assert eng.counters["shadow_repreempts"] == 1
    # resume again, generate two tokens -> the shadow covers all but the
    # 2-token suffix; the next preempt pays exactly that delta
    eng.queue.remove(rid)
    eng.lane_req[0] = rid
    eng._resume(req, 0)
    eng.step()
    eng.step()
    assert req.parked is not None and req.shadow_pos == req.pos - 2
    eng._preempt(0)
    delta = eng.counters["preempt_bytes"] - first
    per_tok = first // pos0               # compressed bytes per parked token
    assert first == per_tok * pos0
    assert 0 < delta < first
    assert delta == 2 * per_tok


def test_padded_prefill_matches_exact(params):
    """Regression for the left-pad bug: a short prompt right-padded into a
    length bucket must produce the same logits and the same cache semantics
    as the unpadded prefill (padded positions used to enter the attended
    range as garbage KV)."""
    S, L = 12, 32                          # S < hot_window < L
    prompt = np.asarray(_prompt(9, n=S), np.int32)
    lg_e, c_e = D.prefill(params, {"tokens": jnp.asarray(prompt[None, :])},
                          CFG, SCFG, 128)
    padded = np.zeros((1, L), np.int32)
    padded[0, :S] = prompt
    lg_p, c_p = D.prefill(params, {"tokens": jnp.asarray(padded)}, CFG, SCFG,
                          128, lens=jnp.asarray([S]))
    assert np.array_equal(np.asarray(lg_e), np.asarray(lg_p))
    assert np.array_equal(np.asarray(c_e["cold_len"]),
                          np.asarray(c_p["cold_len"]))
    # decode from both caches with the same compiled step: identical tokens
    import functools
    step = jax.jit(functools.partial(D.decode_step, cfg=CFG, scfg=SCFG))

    def decode(cache, tok0):
        toks = []
        t = jnp.asarray([tok0], jnp.int32)
        p = jnp.asarray([S], jnp.int32)
        for _ in range(6):
            lg, cache = step(params, cache, t, p)
            t = jnp.argmax(lg, -1).astype(jnp.int32)
            p = p + 1
            toks.append(int(t[0]))
        return toks

    t0 = int(jnp.argmax(lg_e[0]))
    assert decode(c_e, t0) == decode(c_p, t0)


def test_batched_engine_matches_serial_engine(params):
    """The batched scheduler is a pure restructuring: same model, same decode
    step, same victim policy — generations must match the per-lane baseline
    token for token, across mixed prompt lengths and preemptions."""
    prompts = [_prompt(i, n=n) for i, n in enumerate((16, 12, 32, 20, 16))]

    def serve(engine_cls):
        eng = engine_cls(CFG, SCFG, params, max_len=128)
        rids = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run_until_done(max_steps=400)
        assert all(eng.requests[r].state == DONE for r in rids)
        return eng, [eng.result(r) for r in rids]

    se, got_s = serve(SerialEngine)
    be, got_b = serve(Engine)
    assert got_s == got_b
    # both engines demoted someone (5 requests through 2 lanes) and counted
    # the same honest byte unit (compressed payload per parked token)
    assert se.counters["demotions"] >= 1 and be.counters["demotions"] >= 1
    assert be.counters["step_syncs"] == be.counters["steps"]


def test_modeled_time_prices_motion_and_syncs(params):
    """Delivered-time accounting (DESIGN.md §12): the engine converts its
    preempt/resume byte and host-sync counters into modeled seconds —
    per-expander on a fabric-striped config, reconciling with the
    expander_stats byte totals, and monotone in the demotion traffic."""
    import dataclasses
    from repro.simx import time as TM

    eng = Engine(CFG, dataclasses.replace(SCFG, n_expanders=2), params,
                 max_len=128)
    rids = [eng.submit(_prompt(i), max_new_tokens=6) for i in range(5)]
    eng.run_until_done(max_steps=400)
    assert all(eng.requests[r].state == DONE for r in rids)
    assert eng.counters["demotions"] >= 1

    m = eng.modeled_time()
    assert len(m["motion_s_per_expander"]) == 2
    assert m["modeled_s"] > 0 and m["modeled_s_per_step"] > 0
    assert m["modeled_s"] == pytest.approx(
        m["sync_s"] + max(m["motion_s_per_expander"]))
    # sync term: one CXL round trip per host sync
    syncs = eng.counters["step_syncs"] + eng.counters["admit_syncs"]
    assert m["sync_s"] == pytest.approx(syncs * TM.DeviceConfig().cxl_lat)
    # motion term reconciles with the per-expander byte stats
    recomputed = TM.serve_motion_time(
        np.asarray(eng.expander_stats["preempt_bytes"], np.float64),
        np.asarray(eng.expander_stats["resume_bytes"], np.float64),
        TM.stack_devices([TM.DeviceConfig()] * 2, xp=np))
    assert list(recomputed) == m["motion_s_per_expander"]
    # a slower fleet can only cost more
    m_gen4 = eng.modeled_time(devices=TM.DEVICE_PROFILES["gen4"])
    assert m_gen4["modeled_s"] >= m["modeled_s"]
