"""Layered pool engine (DESIGN.md §1): mechanism/policy split of the former
``repro.core.pool`` monolith.

  * ``state``  — the ``Pool`` pytree over the four device-memory regions
                 (DESIGN.md §3), traffic counters, and metrics;
  * ``ops``    — pure, individually-jittable *mechanism* functions:
                 allocation/free, metadata read-modify-write, store I/O,
                 promotion/demotion, host-facing access bodies;
  * ``policy`` — the ``Policy`` protocol + per-scheme implementations
                 (ibex / tmcc / dylect / mxt / dmc / compresso): promotion
                 trigger, victim selection, and *in-place* residency/traffic
                 accounting hooks (no post-hoc counter arithmetic);
  * ``batch``  — the batched access front-end: a window of W accesses per
                 scan step, vectorized classification + conflict
                 serialization only for same-page hits.
"""
from repro.core.engine import batch, ops, policy, state
from repro.core.engine.ops import (demote_if_needed, demote_one,
                                   host_read_block, host_write_block,
                                   host_write_page)
from repro.core.engine.policy import (DEFAULT_POLICY, POLICIES, CompressoPolicy,
                                      DmcPolicy, DylectPolicy, IbexPolicy,
                                      MxtPolicy, Policy, SecondChanceLanes,
                                      TmccPolicy)
from repro.core.engine.state import (COUNTER_NAMES, CTR_DTYPE, NUM_COUNTERS,
                                     Pool, compression_ratio, counters_dict,
                                     make_pool, make_pool_stack,
                                     n_single_chunks, per_expander_counters,
                                     pool_slice, pool_unslice,
                                     stacked_counters, stacked_counters_dict,
                                     total_traffic)

__all__ = [
    "batch", "ops", "policy", "state",
    "Pool", "make_pool", "n_single_chunks", "counters_dict",
    "compression_ratio", "total_traffic", "COUNTER_NAMES", "NUM_COUNTERS",
    "CTR_DTYPE",
    "make_pool_stack", "pool_slice", "pool_unslice", "stacked_counters",
    "stacked_counters_dict", "per_expander_counters",
    "Policy", "IbexPolicy", "TmccPolicy", "DylectPolicy", "MxtPolicy",
    "DmcPolicy", "CompressoPolicy", "SecondChanceLanes", "POLICIES",
    "DEFAULT_POLICY",
    "host_read_block", "host_write_block", "host_write_page", "demote_one",
    "demote_if_needed",
]
