"""Mechanism ops (DESIGN.md §4): pure, individually-jittable state-machine
transitions over the pool regions — allocation/free, metadata
read-modify-write, store I/O, promotion (§4.1, §4.5, §4.6), demotion
(§4.4 + §4.5), and traffic accounting in 64B units.

Every function takes ``(pool, cfg, policy, ...)``: ``cfg`` fixes the
mechanism shape (region sizes, co-location, compaction, shadowing), while
``policy`` decides victim selection and charges scheme-specific traffic at
the site where it occurs (see engine/policy.py). Host-facing front-ends
(``host_read_block`` etc.) are the serial one-access path; the batched
front-end in engine/batch.py reuses the ``*_op`` bodies.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.core import activity as act
from repro.core import compressor as comp
from repro.core import freelist as fl
from repro.core import mcache as mcc
from repro.core import metadata as md
from repro.core.bitpack import RATE_RAW, RATE_ZERO
from repro.core.engine.policy import Policy
from repro.core.engine.state import (C_ACT_RD, C_ACT_WR, C_DATA_RD, C_DATA_WR,
                                     C_DEMO_CLEAN, C_DEMO_DIRTY, C_DEMO_RD,
                                     C_DEMO_WR, C_HOST_RD, C_HOST_WR,
                                     C_MC_HIT, C_MC_MISS, C_META_RD,
                                     C_META_WR, C_PROMO_RD, C_PROMO_WR,
                                     C_PROMOTIONS, C_RANDOM_FB,
                                     C_RECOMP_RETRY, C_ZERO_SERVED, CTR_DTYPE,
                                     Pool, bump)


def content_rates(pool: Pool, cfg: PoolConfig, ospn) -> jnp.ndarray:
    """Per-block rates from the content model (simx, payload-less mode)."""
    r = pool.rates_table[ospn]
    if not cfg.zero_elision:
        r = jnp.maximum(r, 1)
    if cfg.coloc:
        return r
    # 4KB-block mode: one rate for the whole page (zero only if all-zero)
    return jnp.max(r, keepdims=True)[:1]


def rates_to_chunks(rates: jnp.ndarray, cfg: PoolConfig):
    """(quanta_total, num_chunks) for a page with these block rates."""
    nblocks = rates.shape[0]
    vals = cfg.vals_per_page // nblocks
    qt = comp.block_quanta_table(vals)
    quanta = jnp.sum(qt[rates])
    qpc = cfg.chunk_bytes // comp.QUANTUM
    return quanta, (-(-quanta // qpc)).astype(jnp.uint32)


def meta_width(cfg: PoolConfig, ospn) -> jnp.ndarray:
    """64B accesses per metadata fetch: 1 compacted; uncompacted 283b entries
    straddle the 64B boundary for ~half of all pages (§4.7)."""
    if cfg.compact:
        return jnp.asarray(1, CTR_DTYPE)
    return (1 + (jnp.asarray(ospn, CTR_DTYPE) & 1))


# ---------------------------------------------------------------------------
# Metadata-cache step with lazy reference update (§4.4).
# ---------------------------------------------------------------------------

def mcache_step(pool: Pool, cfg: PoolConfig, policy: Policy, ospn
                ) -> Tuple[Pool, jnp.ndarray]:
    cache, hit, evicted = mcc.access(pool.cache, ospn)
    miss_counters = bump(bump(pool.counters, C_MC_MISS),
                         C_META_RD, meta_width(cfg, ospn))
    miss_counters = policy.on_mcache_miss(miss_counters)
    counters = jax.lax.select(hit, bump(pool.counters, C_MC_HIT),
                              miss_counters)
    # lazy update: evicted page, if promoted, gets its referenced bit set now
    safe_ev = jnp.maximum(evicted, 0)
    ev_entry = pool.meta[safe_ev]
    ev_promoted = (md.get_promoted(ev_entry[0]) == 1) & (evicted >= 0) & \
        (md.get_valid(ev_entry[0]) == 1)
    ev_pidx = md.get_ptr(ev_entry, md.PCHUNK_SLOT).astype(jnp.int32)
    safe_pidx = jnp.clip(ev_pidx, 0, pool.activity.shape[0] - 1)
    already = md.act_referenced(pool.activity[safe_pidx]) == 1
    new_act = act.lazy_touch(pool.activity, jnp.where(ev_promoted, ev_pidx, -1))
    # the activity word is written only when the referenced bit flips; an
    # already-referenced entry costs nothing (same charge as the batched
    # front-end's masked scatter in engine/batch.py)
    counters = jax.lax.select(
        ev_promoted & (~already),
        policy.charge_activity(counters, C_ACT_WR), counters)
    return pool._replace(cache=cache, activity=new_act, counters=counters), hit


# ---------------------------------------------------------------------------
# Payload helpers (no-ops when store_payload=False).
# ---------------------------------------------------------------------------

def _chunk_ptrs(entry: jnp.ndarray) -> jnp.ndarray:
    """int32[7] pointer slots 0..6 (slot 6 doubles as the P-chunk slot)."""
    return jnp.stack([md.get_ptr(entry, i) for i in range(7)]).astype(jnp.int32)


def _gather_page_buf(pool: Pool, cfg: PoolConfig, entry: jnp.ndarray) -> jnp.ndarray:
    """Reassemble the compacted compressed-page buffer from its chunks."""
    if not cfg.store_payload:
        return jnp.zeros((cfg.page_bytes,), jnp.uint8)
    w0 = entry[0]
    nchunks = md.get_num_chunks(w0).astype(jnp.int32)
    is_group = nchunks == 8                      # incompressible: aligned group
    ptrs = _chunk_ptrs(entry)
    base = ptrs[0]
    cpp = cfg.chunks_per_page
    idxs = []
    for i in range(cpp):
        single = ptrs[min(i, 6)]
        grp = base + i
        idx = jnp.where(is_group, grp, jnp.where(i < nchunks, single, 0))
        idxs.append(jnp.clip(idx, 0, pool.c_store.shape[0] - 1))
    chunks = pool.c_store[jnp.stack(idxs)]       # [cpp, chunk_bytes]
    return chunks.reshape(cfg.page_bytes)


def _scatter_page_buf(pool: Pool, cfg: PoolConfig, buf: jnp.ndarray,
                      ptrs: jnp.ndarray, nchunks, is_group) -> Pool:
    if not cfg.store_payload:
        return pool
    cpp = cfg.chunks_per_page
    pieces = buf.reshape(cpp, cfg.chunk_bytes)
    c_store = pool.c_store
    base = ptrs[0]
    for i in range(cpp):
        idx = jnp.where(is_group, base + i, ptrs[min(i, 6)])
        idx = jnp.clip(idx, 0, c_store.shape[0] - 1)
        write = is_group | (i < nchunks)
        c_store = jax.lax.select(write, c_store.at[idx].set(pieces[i]), c_store)
    return pool._replace(c_store=c_store)


def _read_pchunk_block(pool: Pool, cfg: PoolConfig, pidx, block_idx) -> jnp.ndarray:
    if not cfg.store_payload:
        return jnp.zeros((cfg.vals_per_block,), jnp.bfloat16)
    safe = jnp.clip(pidx, 0, max(pool.p_store.shape[0] - 1, 0))
    page = pool.p_store[safe]
    b = jax.lax.dynamic_slice(page, (block_idx * cfg.block_bytes,),
                              (cfg.block_bytes,))
    from repro.core.bitpack import bytes_to_raw
    return bytes_to_raw(b)


def _write_pchunk_block(pool: Pool, cfg: PoolConfig, pidx, block_idx,
                        vals: jnp.ndarray) -> Pool:
    if not cfg.store_payload:
        return pool
    from repro.core.bitpack import raw_to_bytes
    safe = jnp.clip(pidx, 0, max(pool.p_store.shape[0] - 1, 0))
    page = pool.p_store[safe]
    page = jax.lax.dynamic_update_slice(page, raw_to_bytes(vals),
                                        (block_idx * cfg.block_bytes,))
    return pool._replace(p_store=pool.p_store.at[safe].set(page))


def _page_to_bytes(vals: jnp.ndarray) -> jnp.ndarray:
    from repro.core.bitpack import raw_to_bytes
    return raw_to_bytes(vals)


def _block_mask(cfg: PoolConfig, block_idx, full: jnp.ndarray) -> jnp.ndarray:
    pos = jnp.arange(cfg.page_bytes, dtype=jnp.int32) // cfg.block_bytes
    return full | (pos == jnp.asarray(block_idx, jnp.int32))


# ---------------------------------------------------------------------------
# Chunk (de)allocation.
# ---------------------------------------------------------------------------

def alloc_chunks(pool: Pool, cfg: PoolConfig, num_chunks
                 ) -> Tuple[Pool, jnp.ndarray, jnp.ndarray]:
    """Allocate ``num_chunks`` C-chunks (8 -> one aligned group). Returns
    (pool, ptrs int32[7], is_group)."""
    is_group = num_chunks >= 8

    def alloc_group(p: Pool):
        g, base = fl.pop(p.gfree)
        ptrs = jnp.full((7,), -1, jnp.int32).at[0].set(base)
        return p._replace(gfree=g), ptrs

    def alloc_singles(p: Pool):
        c, idxs = fl.pop_n(p.cfree, 7, jnp.minimum(num_chunks, 7))
        return p._replace(cfree=c), idxs

    poolg, ptrsg = alloc_group(pool)
    pools, ptrss = alloc_singles(pool)
    pool_out = jax.tree_util.tree_map(
        lambda a, b: jax.lax.select(is_group, a, b), poolg, pools)
    ptrs = jnp.where(is_group, ptrsg, ptrss)
    return pool_out, ptrs, is_group


def free_chunks(pool: Pool, cfg: PoolConfig, entry: jnp.ndarray) -> Pool:
    """Release all C-chunks referenced by ``entry`` (no-op if none)."""
    w0 = entry[0]
    nchunks = md.get_num_chunks(w0).astype(jnp.int32)
    is_group = nchunks == 8
    ptrs = _chunk_ptrs(entry)

    def free_group(p: Pool):
        return p._replace(gfree=fl.push(p.gfree, ptrs[0]))

    def free_singles(p: Pool):
        masked = jnp.where(jnp.arange(7) < nchunks, ptrs, -1)
        return p._replace(cfree=fl.push_n(p.cfree, masked))

    has = nchunks > 0
    pg = free_group(pool)
    ps = free_singles(pool)
    out = jax.tree_util.tree_map(lambda a, b: jax.lax.select(is_group, a, b), pg, ps)
    return jax.tree_util.tree_map(lambda a, b: jax.lax.select(has, a, b), out, pool)


# ---------------------------------------------------------------------------
# Demotion (§4.4 + §4.5).
# ---------------------------------------------------------------------------

def demote_one(pool: Pool, cfg: PoolConfig, policy: Policy, force=False) -> Pool:
    """Run the victim-selection policy once and demote the selected victim."""
    rng, sub = jax.random.split(pool.rng)
    res = policy.select_victim(pool.activity, pool.hand, pool.cache, sub,
                               force=force)
    counters = policy.charge_activity(pool.counters, C_ACT_RD,
                                      res.groups_scanned.astype(CTR_DTYPE))
    counters = policy.charge_activity(counters, C_ACT_WR,
                                      res.groups_scanned.astype(CTR_DTYPE))
    counters = jax.lax.select(res.used_random, bump(counters, C_RANDOM_FB),
                              counters)
    pool = pool._replace(activity=res.activity, hand=res.hand, rng=rng,
                         counters=counters)
    have = res.victim_ospn >= 0

    def do_demote(p: Pool) -> Pool:
        ospn = jnp.maximum(res.victim_ospn, 0)
        entry = p.meta[ospn]
        w0 = entry[0]
        clean = (md.get_dirty(w0) == 0) & (md.get_shadow_valid(w0) == 1)

        def demote_clean(p: Pool) -> Pool:
            # §4.5: re-validate shadow pointers by flipping type fields only.
            nblocks = cfg.blocks_per_page if cfg.coloc else 1
            raw_sz = 7 if cfg.coloc else RATE_RAW  # non-coloc sz holds the rate
            w = w0
            for i in range(nblocks):
                bt = md.get_block_type(w, i)
                sz = md.get_block_sz(w, i)
                restored = jnp.where(sz == raw_sz, md.BT_INCOMP, md.BT_COMP)
                w = md.set_block_type(w, i, jnp.where(bt == md.BT_PROM, restored, bt))
            w = md.set_promoted(w, 0)
            w = md.set_shadow_valid(w, 0)
            new_entry = entry.at[0].set(w)
            c = bump(p.counters, C_META_WR, meta_width(cfg, ospn))
            c = bump(c, C_DEMO_CLEAN)
            c = policy.on_demotion(c, clean=True)
            return p._replace(meta=p.meta.at[ospn].set(new_entry), counters=c)

        def demote_dirty(p: Pool) -> Pool:
            # read the promoted page, recompress, store chunks (§4.2 cost).
            pidx = md.get_ptr(entry, md.PCHUNK_SLOT).astype(jnp.int32)
            if cfg.store_payload:
                safe = jnp.clip(pidx, 0, max(p.p_store.shape[0] - 1, 0))
                from repro.core.bitpack import bytes_to_raw
                vals = bytes_to_raw(p.p_store[safe])
                buf, rates, quanta, nchunks = comp.encode_page(vals, cfg)
            else:
                # metadata-only mode: compressed sizes come from the content
                # model instead of actual bytes (simx)
                buf = jnp.zeros((cfg.page_bytes,), jnp.uint8)
                rates = content_rates(p, cfg, ospn)
                _, nchunks = rates_to_chunks(rates, cfg)
            p, ptrs, is_group = alloc_chunks(p, cfg, nchunks)
            p = _scatter_page_buf(p, cfg, buf, ptrs, nchunks, is_group)
            w = md.header_from_rates(rates) if cfg.coloc else \
                _header_4kb(rates[0], nchunks)
            w = md.set_num_chunks(w, nchunks)
            new_entry = md.empty_entry().at[0].set(w)
            for i in range(7):
                new_entry = md.set_ptr(new_entry, i, jnp.maximum(ptrs[i], 0))
            c = policy.charge_migration(p.counters, C_DEMO_RD,
                                        cfg.page_bytes // 64)
            c = policy.charge_migration(
                c, C_DEMO_WR, (nchunks * (cfg.chunk_bytes // 64)).astype(CTR_DTYPE))
            c = bump(c, C_META_WR, meta_width(cfg, ospn))
            c = bump(c, C_DEMO_DIRTY)
            c = policy.on_compress_store(c)
            c = policy.on_demotion(c, clean=False)
            return p._replace(meta=p.meta.at[ospn].set(new_entry), counters=c)

        p = jax.lax.cond(clean, demote_clean, demote_dirty, p)
        # free the P-chunk + activity entry in both cases
        pidx = md.get_ptr(entry, md.PCHUNK_SLOT).astype(jnp.int32)
        p = p._replace(pfree=fl.push(p.pfree, pidx),
                       activity=act.mark_free(p.activity, pidx))
        return p

    return jax.lax.cond(have, do_demote, lambda p: p, pool)


def _use_batched_demote(cfg: PoolConfig) -> bool:
    mode = getattr(cfg, "fused_demote", "auto")
    if mode == "auto":
        return comp.resolve_impl(cfg) == "kernel"
    return mode == "on"


def demote_batch(pool: Pool, cfg: PoolConfig, policy: Policy,
                 max_demotes: int, target) -> Pool:
    """Demote up to ``max_demotes`` victims with ONE batched recompression
    (a single fused-kernel launch on TPU) instead of a serial ``lax.cond``
    chain of per-victim ``encode_page`` calls.

    Bit-identical to the serial loop (tests/test_qpack_fused.py): phase 1
    replays victim selection serially (activity/hand/rng/pfree evolve in the
    exact serial order — demote bodies never touch them), phase 2 recompresses
    all dirty victims in one ``encode_pages`` call (victims are distinct, so
    per-victim meta/p_store reads see the same values the serial loop reads),
    and phase 3 applies the metadata/chunk effects in victim order (cfree/
    gfree pops in the serial sequence; counters are commutative adds)."""
    # -- phase 1: victim selection + P-chunk release, serial semantics -------
    def sel_step(p: Pool, _):
        def select(p: Pool):
            rng, sub = jax.random.split(p.rng)
            res = policy.select_victim(p.activity, p.hand, p.cache, sub,
                                       force=False)
            counters = policy.charge_activity(
                p.counters, C_ACT_RD, res.groups_scanned.astype(CTR_DTYPE))
            counters = policy.charge_activity(
                counters, C_ACT_WR, res.groups_scanned.astype(CTR_DTYPE))
            counters = jax.lax.select(res.used_random,
                                      bump(counters, C_RANDOM_FB), counters)
            p = p._replace(activity=res.activity, hand=res.hand, rng=rng,
                           counters=counters)
            have = res.victim_ospn >= 0
            ospn = jnp.maximum(res.victim_ospn, 0)
            pidx = md.get_ptr(p.meta[ospn], md.PCHUNK_SLOT).astype(jnp.int32)

            def free_slot(q: Pool) -> Pool:
                return q._replace(pfree=fl.push(q.pfree, pidx),
                                  activity=act.mark_free(q.activity, pidx))

            p = jax.lax.cond(have, free_slot, lambda q: q, p)
            return p, jnp.where(have, res.victim_ospn, -1).astype(jnp.int32)

        need = fl.free_count(p.pfree) < target
        return jax.lax.cond(need, select,
                            lambda q: (q, jnp.int32(-1)), p)

    pool, victims = jax.lax.scan(sel_step, pool, None, length=max_demotes)

    # -- phase 2: batched recompression of every dirty victim ----------------
    have = victims >= 0
    ospns = jnp.maximum(victims, 0)
    entries = pool.meta[ospns]                       # [K, ENTRY_WORDS]
    w0s = entries[:, 0]
    clean = (md.get_dirty(w0s) == 0) & (md.get_shadow_valid(w0s) == 1)
    pidxs = jax.vmap(lambda e: md.get_ptr(e, md.PCHUNK_SLOT))(
        entries).astype(jnp.int32)
    if cfg.store_payload:
        from repro.core.bitpack import bytes_to_raw
        safe = jnp.clip(pidxs, 0, max(pool.p_store.shape[0] - 1, 0))
        vals = jax.vmap(bytes_to_raw)(pool.p_store[safe])
        bufs, rates, _, nchunks = comp.encode_pages(vals, cfg)
    else:
        bufs = jnp.zeros((max_demotes, cfg.page_bytes), jnp.uint8)
        rates = jax.vmap(lambda o: content_rates(pool, cfg, o))(ospns)
        nchunks = jax.vmap(lambda r: rates_to_chunks(r, cfg)[1])(rates)

    # -- phase 3: per-victim metadata/chunk effects, in victim order ---------
    def fin_body(i, p: Pool) -> Pool:
        ospn = ospns[i]
        entry = entries[i]
        w0 = entry[0]

        def demote_clean(p: Pool) -> Pool:
            nblocks = cfg.blocks_per_page if cfg.coloc else 1
            raw_sz = 7 if cfg.coloc else RATE_RAW
            w = w0
            for j in range(nblocks):
                bt = md.get_block_type(w, j)
                sz = md.get_block_sz(w, j)
                restored = jnp.where(sz == raw_sz, md.BT_INCOMP, md.BT_COMP)
                w = md.set_block_type(w, j,
                                      jnp.where(bt == md.BT_PROM, restored, bt))
            w = md.set_promoted(w, 0)
            w = md.set_shadow_valid(w, 0)
            new_entry = entry.at[0].set(w)
            c = bump(p.counters, C_META_WR, meta_width(cfg, ospn))
            c = bump(c, C_DEMO_CLEAN)
            c = policy.on_demotion(c, clean=True)
            return p._replace(meta=p.meta.at[ospn].set(new_entry), counters=c)

        def demote_dirty(p: Pool) -> Pool:
            nch = nchunks[i]
            p, ptrs, is_group = alloc_chunks(p, cfg, nch)
            p = _scatter_page_buf(p, cfg, bufs[i], ptrs, nch, is_group)
            w = md.header_from_rates(rates[i]) if cfg.coloc else \
                _header_4kb(rates[i][0], nch)
            w = md.set_num_chunks(w, nch)
            new_entry = md.empty_entry().at[0].set(w)
            for j in range(7):
                new_entry = md.set_ptr(new_entry, j, jnp.maximum(ptrs[j], 0))
            c = policy.charge_migration(p.counters, C_DEMO_RD,
                                        cfg.page_bytes // 64)
            c = policy.charge_migration(
                c, C_DEMO_WR, (nch * (cfg.chunk_bytes // 64)).astype(CTR_DTYPE))
            c = bump(c, C_META_WR, meta_width(cfg, ospn))
            c = bump(c, C_DEMO_DIRTY)
            c = policy.on_compress_store(c)
            c = policy.on_demotion(c, clean=False)
            return p._replace(meta=p.meta.at[ospn].set(new_entry), counters=c)

        def apply(p: Pool) -> Pool:
            return jax.lax.cond(clean[i], demote_clean, demote_dirty, p)

        return jax.lax.cond(have[i], apply, lambda q: q, p)

    return jax.lax.fori_loop(0, max_demotes, fin_body, pool)


def demote_if_needed(pool: Pool, cfg: PoolConfig, policy: Policy,
                     max_demotes: int = 2, watermark: int = 0) -> Pool:
    """Keep >= watermark free P-chunks (the paper's background engine, amortized
    into the request path: at most ``max_demotes`` per host op). ``watermark``
    overrides ``cfg.demote_watermark`` when > 0 — the batched front-end tops
    up to a higher target once per window instead of checking per access.

    With ``cfg.fused_demote`` resolved on (or "auto" on TPU) the victims are
    recompressed by one batched kernel launch (``demote_batch``) instead of a
    serial chain of per-victim encodes."""
    target = watermark or cfg.demote_watermark
    if max_demotes > 1 and _use_batched_demote(cfg):
        return demote_batch(pool, cfg, policy, max_demotes, target)

    def body(i, p):
        need = fl.free_count(p.pfree) < target
        return jax.lax.cond(need, lambda q: demote_one(q, cfg, policy),
                            lambda q: q, p)
    return jax.lax.fori_loop(0, max_demotes, body, pool)


def ensure_free_pchunk(pool: Pool, cfg: PoolConfig, policy: Policy,
                       tries: int = 4) -> Pool:
    """Guarantee at least one free P-chunk before a promotion pops the list.

    The last attempts *force* the clock's random fallback to consider
    cache-resident pages — an emergency valve that cannot trigger at the
    paper's region ratios but keeps small test/sim configs live-safe (a pop
    from an empty list would alias P-chunk 0 and corrupt another page)."""
    def body(i, p):
        need = fl.free_count(p.pfree) == 0
        return jax.lax.cond(
            need, lambda q: demote_one(q, cfg, policy, force=(i >= tries // 2)),
            lambda q: q, p)
    return jax.lax.fori_loop(0, tries, body, pool)


# ---------------------------------------------------------------------------
# Promotion (§4.1, §4.5, §4.6).
# ---------------------------------------------------------------------------

def _header_4kb(rate, nchunks) -> jnp.ndarray:
    """word0 for co-location-disabled mode: rate kept in block_sz[0]."""
    w = jnp.uint32(0)
    w = md.set_block_type(w, 0, jnp.where(rate == RATE_ZERO, md.BT_ZERO,
                          jnp.where(rate == RATE_RAW, md.BT_INCOMP, md.BT_COMP)))
    w = md.set_block_sz(w, 0, rate)
    w = md.set_valid(w, 1)
    return w


def _rates_of(entry: jnp.ndarray, cfg: PoolConfig) -> jnp.ndarray:
    if cfg.coloc:
        return md.rates_from_header(entry[0], cfg.blocks_per_page)
    return md.get_block_sz(entry[0], 0).astype(jnp.int32)[None]


def promote(pool: Pool, cfg: PoolConfig, policy: Policy, ospn, block_idx) -> Pool:
    """Promote page ``ospn`` (fine-grained: materialize only ``block_idx``
    when the shadow can be kept; see DESIGN.md for the 7-chunk exception)."""
    already = md.get_promoted(pool.meta[ospn][0]) == 1
    # guarantee a free P-chunk first; demotion only touches *promoted* pages,
    # and ospn is not promoted on this path, so the entry below stays fresh.
    pool = jax.lax.cond(already, lambda p: p,
                        lambda p: ensure_free_pchunk(p, cfg, policy), pool)
    entry = pool.meta[ospn]
    w0 = entry[0]
    nchunks = md.get_num_chunks(w0).astype(jnp.int32)

    pfree, pidx_new = fl.pop(pool.pfree)
    pidx = jnp.where(already, md.get_ptr(entry, md.PCHUNK_SLOT).astype(jnp.int32),
                     pidx_new)
    pool = jax.tree_util.tree_map(
        lambda a, b: jax.lax.select(already, a, b),
        pool, pool._replace(pfree=pfree))

    # shadow feasibility: slot 6 must be free for the P-chunk pointer
    can_shadow = (nchunks <= 6) | (nchunks == 8)
    full_materialize = (~can_shadow) | (not cfg.coloc)

    rates = _rates_of(entry, cfg)
    buf = _gather_page_buf(pool, cfg, entry)
    nblocks = cfg.blocks_per_page if cfg.coloc else 1

    # traffic: chunk reads. fine-grained reads only the target block's quanta.
    q_all = comp.page_compressed_bytes(rates, cfg.vals_per_page // nblocks) // 64
    if cfg.coloc:
        qt = comp.block_quanta_table(cfg.vals_per_block)
        q_blk = (qt[rates[jnp.minimum(block_idx, nblocks - 1)]] *
                 (comp.QUANTUM // 64))
    else:
        q_blk = q_all
    rd = jnp.where(full_materialize, q_all, q_blk).astype(CTR_DTYPE)
    counters = policy.charge_migration(pool.counters, C_PROMO_RD, rd)

    # materialize into the P-chunk
    if cfg.store_payload:
        vals = comp.decode_page(buf, rates, cfg)
        page_bytes_arr = _page_to_bytes(vals)
        safe = jnp.clip(pidx, 0, max(pool.p_store.shape[0] - 1, 0))
        if cfg.coloc:
            old = pool.p_store[safe]
            mask = _block_mask(cfg, block_idx, full_materialize)
            newpage = jnp.where(mask, page_bytes_arr, old)
        else:
            newpage = page_bytes_arr
        p_store = pool.p_store.at[safe].set(newpage)
        pool = pool._replace(p_store=p_store)
    wr = jnp.where(full_materialize, cfg.page_bytes // 64,
                   cfg.block_bytes // 64).astype(CTR_DTYPE)
    counters = policy.charge_migration(counters, C_PROMO_WR, wr)
    counters = bump(counters, C_PROMOTIONS)

    # metadata update
    w = w0
    if cfg.coloc:
        for i in range(nblocks):
            is_tgt = (jnp.asarray(block_idx) == i) | full_materialize
            bt = md.get_block_type(w, i)
            promote_this = is_tgt & (bt != md.BT_ZERO)
            w = md.set_block_type(w, i, jnp.where(promote_this, md.BT_PROM, bt))
    else:
        w = md.set_block_type(w, 0, md.BT_PROM)
    w = md.set_promoted(w, 1)
    keep_shadow = can_shadow & jnp.asarray(cfg.shadow)
    w = md.set_shadow_valid(w, keep_shadow.astype(jnp.uint32))
    w = md.set_dirty(w, (~keep_shadow).astype(jnp.uint32))
    new_entry = entry.at[0].set(w)
    new_entry = md.set_ptr(new_entry, md.PCHUNK_SLOT, jnp.maximum(pidx, 0))

    # if the shadow cannot be kept (or shadowing disabled), free the chunks now
    pool = jax.lax.cond(keep_shadow | (nchunks == 0), lambda p: p,
                        lambda p: free_chunks(p, cfg, entry), pool)
    w = jax.lax.select(keep_shadow, md.get_num_chunks(w0), jnp.uint32(0))
    new_w0 = md.set_num_chunks(new_entry[0], w)
    new_entry = new_entry.at[0].set(new_w0)

    counters = bump(counters, C_META_WR, meta_width(cfg, ospn))
    pool = pool._replace(meta=pool.meta.at[ospn].set(new_entry),
                         counters=counters)
    # activity entry (arrives referenced=1)
    pool = pool._replace(activity=jax.lax.select(
        already, pool.activity, act.mark_allocated(pool.activity, pidx, ospn)))
    return pool


# ---------------------------------------------------------------------------
# Host-facing access bodies (block granularity; 64B accounting is analytic).
# The bodies assume the per-access prologue (background demotion + metadata
# cache step + host counter) already ran — both the serial front-ends below
# and the batched front-end (engine/batch.py) provide it.
# ---------------------------------------------------------------------------

def write_page_op(pool: Pool, cfg: PoolConfig, policy: Policy, ospn,
                  vals: jnp.ndarray) -> Pool:
    """First-touch page write: lands uncompressed in the promoted region
    (promotion-based management stores first-touched data hot, §4)."""
    was_promoted0 = md.get_promoted(pool.meta[ospn][0]) == 1
    pool = jax.lax.cond(was_promoted0, lambda p: p,
                        lambda p: ensure_free_pchunk(p, cfg, policy), pool)
    entry = pool.meta[ospn]
    # free any previous incarnation
    pool = free_chunks(pool, cfg, entry)
    was_promoted = md.get_promoted(entry[0]) == 1
    old_pidx = md.get_ptr(entry, md.PCHUNK_SLOT).astype(jnp.int32)
    pfree, pidx_new = fl.pop(pool.pfree)
    pidx = jnp.where(was_promoted, old_pidx, pidx_new)
    pool = jax.tree_util.tree_map(
        lambda a, b: jax.lax.select(was_promoted, a, b),
        pool, pool._replace(pfree=pfree))
    if cfg.store_payload:
        safe = jnp.clip(pidx, 0, max(pool.p_store.shape[0] - 1, 0))
        pool = pool._replace(p_store=pool.p_store.at[safe].set(_page_to_bytes(vals)))
    nblocks = cfg.blocks_per_page if cfg.coloc else 1
    w = jnp.uint32(0)
    for i in range(nblocks):
        w = md.set_block_type(w, i, md.BT_PROM)
        w = md.set_block_sz(w, i, 0)
    w = md.set_valid(w, 1)
    w = md.set_promoted(w, 1)
    w = md.set_dirty(w, 1)
    new_entry = md.empty_entry().at[0].set(w)
    new_entry = md.set_ptr(new_entry, md.PCHUNK_SLOT, jnp.maximum(pidx, 0))
    counters = bump(pool.counters, C_DATA_WR, cfg.page_bytes // 64)
    counters = bump(counters, C_META_WR, meta_width(cfg, ospn))
    pool = pool._replace(meta=pool.meta.at[ospn].set(new_entry), counters=counters)
    return pool._replace(activity=act.mark_allocated(pool.activity, pidx, ospn))


def _block_state(entry: jnp.ndarray, cfg: PoolConfig, block_idx):
    w0 = entry[0]
    if cfg.coloc:
        bt = md.get_block_type_dyn(w0, block_idx)
    else:
        bt = md.get_block_type(w0, 0)
    return (md.get_valid(w0) == 1, md.get_promoted(w0) == 1, bt)


def read_block_op(pool: Pool, cfg: PoolConfig, policy: Policy, ospn, block_idx
                  ) -> Tuple[Pool, jnp.ndarray]:
    """Read one 1KB block (paper Fig. 3 flow). Returns (pool, bf16 values)."""
    entry = pool.meta[ospn]
    valid, promoted, bt = _block_state(entry, cfg, block_idx)

    is_zero = valid & (bt == md.BT_ZERO)
    is_hot = valid & promoted & (bt == md.BT_PROM)
    needs_promo = valid & (~is_zero) & (~is_hot)

    def case_zero(p: Pool):
        return p._replace(counters=bump(p.counters, C_ZERO_SERVED)), \
            jnp.zeros((cfg.vals_per_block,), jnp.bfloat16)

    def case_hot(p: Pool):
        pidx = md.get_ptr(entry, md.PCHUNK_SLOT).astype(jnp.int32)
        vals = _read_pchunk_block(p, cfg, pidx, block_idx)
        return p._replace(counters=bump(p.counters, C_DATA_RD,
                                        cfg.block_bytes // 64)), vals

    def case_promote(p: Pool):
        p = promote(p, cfg, policy, ospn, block_idx)
        e = p.meta[ospn]
        pidx = md.get_ptr(e, md.PCHUNK_SLOT).astype(jnp.int32)
        vals = _read_pchunk_block(p, cfg, pidx, block_idx)
        return p, vals

    def case_invalid(p: Pool):
        return p, jnp.zeros((cfg.vals_per_block,), jnp.bfloat16)

    branch = jnp.where(is_zero, 0, jnp.where(is_hot, 1,
                       jnp.where(needs_promo, 2, 3))).astype(jnp.int32)
    pool, vals = jax.lax.switch(branch, [case_zero, case_hot, case_promote,
                                         case_invalid], pool)
    return pool, vals


def write_block_op(pool: Pool, cfg: PoolConfig, policy: Policy, ospn,
                   block_idx, vals: jnp.ndarray) -> Pool:
    """Write one 1KB block. Writes promote (whole-page materialization so the
    page's chunks can be released — §4.5: updates invalidate the shadow)."""
    entry = pool.meta[ospn]
    w0 = entry[0]
    valid = md.get_valid(w0) == 1

    def fresh(p: Pool) -> Pool:
        page = jnp.zeros((cfg.vals_per_page,), jnp.bfloat16)
        page = jax.lax.dynamic_update_slice(page, vals.astype(jnp.bfloat16),
                                            (block_idx * cfg.vals_per_block,))
        return write_page_op(p, cfg, policy, ospn, page)

    def write_inplace(p: Pool) -> Pool:
        """§4.1.2: incompressible (raw, non-promoted) pages are updated in
        place; wr_cntr counts updates and triggers a recompression attempt at
        the threshold (the page may have become compressible)."""
        entry0 = p.meta[ospn]
        ww = entry0[0]
        base = md.get_ptr(entry0, 0).astype(jnp.int32)
        if cfg.store_payload:
            from repro.core.bitpack import raw_to_bytes
            bb = raw_to_bytes(vals.astype(jnp.bfloat16))
            half = cfg.chunk_bytes
            cpb = cfg.block_bytes // cfg.chunk_bytes  # chunks per block (2)
            c_store = p.c_store
            for j in range(cpb):
                idx = jnp.clip(base + block_idx * cpb + j, 0,
                               c_store.shape[0] - 1)
                c_store = c_store.at[idx].set(
                    jax.lax.dynamic_slice(bb, (j * half,), (half,)))
            p = p._replace(c_store=c_store)
        c = bump(p.counters, C_DATA_WR, cfg.block_bytes // 64)
        cntr = md.get_wr_cntr(ww)
        trip = (cntr + 1) >= cfg.wr_thresh

        def retry(q: Pool) -> Pool:
            # recompression attempt: read the page, re-encode
            if cfg.store_payload:
                e = q.meta[ospn]
                buf0 = _gather_page_buf(q, cfg, e)
                from repro.core.bitpack import bytes_to_raw
                pv = bytes_to_raw(buf0)
                buf, rates, _, nch = comp.encode_page(pv, cfg)
            else:
                buf = jnp.zeros((cfg.page_bytes,), jnp.uint8)
                rates = content_rates(q, cfg, ospn)
                _, nch = rates_to_chunks(rates, cfg)
            cc = policy.charge_migration(q.counters, C_DEMO_RD,
                                         cfg.page_bytes // 64)
            cc = bump(cc, C_RECOMP_RETRY)
            # every retry is a compression-engine store attempt: zsmalloc-
            # style bookkeeping is paid whether or not the page compresses
            cc = policy.on_compress_store(cc)
            q = q._replace(counters=cc)

            def compressible(r: Pool) -> Pool:
                e = r.meta[ospn]
                r = free_chunks(r, cfg, e)
                r, ptrs, is_group = alloc_chunks(r, cfg, nch)
                r = _scatter_page_buf(r, cfg, buf, ptrs, nch, is_group)
                w = md.header_from_rates(rates) if cfg.coloc else \
                    _header_4kb(rates[0], nch)
                w = md.set_num_chunks(w, nch)
                ne = md.empty_entry().at[0].set(w)
                for i in range(7):
                    ne = md.set_ptr(ne, i, jnp.maximum(ptrs[i], 0))
                ccc = policy.charge_migration(
                    r.counters, C_DEMO_WR,
                    (nch * (cfg.chunk_bytes // 64)).astype(CTR_DTYPE))
                ccc = bump(ccc, C_META_WR, meta_width(cfg, ospn))
                return r._replace(meta=r.meta.at[ospn].set(ne), counters=ccc)

            def still_raw(r: Pool) -> Pool:
                e = r.meta[ospn]
                w = md.set_wr_cntr(e[0], 0)
                return r._replace(meta=r.meta.at[ospn].set(e.at[0].set(w)))

            return jax.lax.cond(nch < 8, compressible, still_raw, q)

        def just_count(q: Pool) -> Pool:
            e = q.meta[ospn]
            w = md.set_wr_cntr(e[0], cntr + 1)
            cc = bump(q.counters, C_META_WR, meta_width(cfg, ospn))
            return q._replace(meta=q.meta.at[ospn].set(e.at[0].set(w)),
                              counters=cc)

        p = p._replace(counters=c)
        return jax.lax.cond(trip, retry, just_count, p)

    def update(p: Pool) -> Pool:
        promoted = md.get_promoted(w0) == 1
        is_incomp_resident = (~promoted) & (md.get_num_chunks(w0) == 8)
        return jax.lax.cond(is_incomp_resident, write_inplace,
                            update_promote, p)

    def update_promote(p: Pool) -> Pool:
        promoted = md.get_promoted(w0) == 1

        def promote_first(q: Pool) -> Pool:
            # full materialization (a write invalidates the shadow anyway)
            return promote(q, cfg, policy, ospn, block_idx)

        p = jax.lax.cond(promoted, lambda q: q, promote_first, p)
        e = p.meta[ospn]
        ww = e[0]
        # materialize any still-cold blocks before dropping the chunks
        nblocks = cfg.blocks_per_page if cfg.coloc else 1
        pidx = md.get_ptr(e, md.PCHUNK_SLOT).astype(jnp.int32)
        needs_fill = jnp.asarray(False)
        for i in range(nblocks):
            bt = md.get_block_type(ww, i)
            needs_fill = needs_fill | ((bt != md.BT_PROM) & (bt != md.BT_ZERO))

        def fill_cold(q: Pool) -> Pool:
            rates = _rates_of(e, cfg)
            buf = _gather_page_buf(q, cfg, e)
            if cfg.store_payload:
                full_vals = comp.decode_page(buf, rates, cfg)
                pb = _page_to_bytes(full_vals)
                safe = jnp.clip(pidx, 0, max(q.p_store.shape[0] - 1, 0))
                old = q.p_store[safe]
                pos = jnp.arange(cfg.page_bytes, dtype=jnp.int32) // cfg.block_bytes
                keep_hot = jnp.zeros((cfg.page_bytes,), jnp.bool_)
                for i in range(nblocks):
                    hot_i = md.get_block_type(ww, i) == md.BT_PROM
                    keep_hot = keep_hot | (hot_i & (pos == i))
                q = q._replace(p_store=q.p_store.at[safe].set(
                    jnp.where(keep_hot, old, pb)))
            nb = comp.page_compressed_bytes(rates, cfg.vals_per_page // rates.shape[0]) // 64
            c = policy.charge_migration(q.counters, C_PROMO_RD,
                                        nb.astype(CTR_DTYPE))
            c = policy.charge_migration(c, C_PROMO_WR, cfg.page_bytes // 64)
            return q._replace(counters=c)

        p = jax.lax.cond(needs_fill, fill_cold, lambda q: q, p)
        # drop the shadow (the update moment, §4.5)
        had_chunks = md.get_num_chunks(ww) > 0
        p = jax.lax.cond(had_chunks, lambda q: free_chunks(q, cfg, e),
                         lambda q: q, p)
        ww2 = ww
        for i in range(nblocks):
            ww2 = md.set_block_type(ww2, i, md.BT_PROM)
        ww2 = md.set_num_chunks(ww2, 0)
        ww2 = md.set_shadow_valid(ww2, 0)
        ww2 = md.set_dirty(ww2, 1)
        new_entry = e.at[0].set(ww2)
        for i in range(6):
            new_entry = md.set_ptr(new_entry, i, 0)
        p = p._replace(meta=p.meta.at[ospn].set(new_entry))
        # the actual block write + activity touch (write = an access: hot)
        p = _write_pchunk_block(p, cfg, pidx, block_idx, vals.astype(jnp.bfloat16))
        c = bump(p.counters, C_DATA_WR, cfg.block_bytes // 64)
        c = bump(c, C_META_WR, meta_width(cfg, ospn))
        return p._replace(counters=c)

    return jax.lax.cond(valid, update, fresh, pool)


# ---------------------------------------------------------------------------
# Serial host-facing front-ends: per-access prologue + body, jitted.
# ---------------------------------------------------------------------------

def _prologue(pool: Pool, cfg: PoolConfig, policy: Policy, ospn, is_write
              ) -> Pool:
    pool = demote_if_needed(pool, cfg, policy)
    pool, _ = mcache_step(pool, cfg, policy, ospn)
    counters = bump(pool.counters, C_HOST_WR if is_write else C_HOST_RD)
    counters = policy.on_host_access(counters, is_write)
    return pool._replace(counters=counters)


def _host_write_page(pool: Pool, cfg: PoolConfig, policy: Policy, ospn,
                     vals: jnp.ndarray) -> Pool:
    pool = _prologue(pool, cfg, policy, ospn, is_write=True)
    return write_page_op(pool, cfg, policy, ospn, vals)


def _host_read_block(pool: Pool, cfg: PoolConfig, policy: Policy, ospn,
                     block_idx) -> Tuple[Pool, jnp.ndarray]:
    pool = _prologue(pool, cfg, policy, ospn, is_write=False)
    return read_block_op(pool, cfg, policy, ospn, block_idx)


def _host_write_block(pool: Pool, cfg: PoolConfig, policy: Policy, ospn,
                      block_idx, vals: jnp.ndarray) -> Pool:
    pool = _prologue(pool, cfg, policy, ospn, is_write=True)
    return write_block_op(pool, cfg, policy, ospn, block_idx, vals)


host_write_page = functools.partial(jax.jit, static_argnums=(1, 2))(_host_write_page)
host_read_block = functools.partial(jax.jit, static_argnums=(1, 2))(_host_read_block)
host_write_block = functools.partial(jax.jit, static_argnums=(1, 2))(_host_write_block)
