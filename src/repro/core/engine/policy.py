"""Policy layer (DESIGN.md §5): per-scheme residency + accounting decisions.

A ``Policy`` owns everything that differs *between the compared designs*
(paper §5/§6) while ``engine.ops`` owns the shared mechanisms:

  * **promotion trigger** — which block states promote on access;
  * **victim selection**  — how a demotion victim is chosen (the pool's clock
    engine; the serving engine reuses the same shape at lane granularity via
    ``SecondChanceLanes``);
  * **residency/traffic accounting** — hooks called at the *site* where a
    scheme's extra traffic physically occurs (LRU-list node updates, dual
    metadata-table probes, zsmalloc fragmentation bookkeeping, migration
    granularity multipliers). This replaces the old ``simx.engine._finalize``
    post-hoc counter arithmetic: traffic is counted where it happens.

Policies are frozen dataclasses so they hash and can be closed over by
``jax.jit`` as static arguments; hooks are pure jit-traceable functions of the
counters array.

Schemes (paper §5/§6):
  ibex        full IBEX (shadow + co-location + compaction, clock demotion);
              the Fig. 13 ablation ladder (ibex_base/_s/_sc/_scm) is the same
              policy with mechanism toggles flipped
  tmcc        4KB blocks, variable-size chunks (zsmalloc bookkeeping +
              fragmentation reclaim traffic), list-based recency, no shadow
  dylect      tmcc + dual metadata tables (2nd probe per mcache miss)
  mxt         4KB promotion cache with on-chip tags (no activity traffic)
              but page-granular promotion, no zero elision
  dmc         32KB migration granularity (promotion/demotion traffic x8)
  compresso   line-level: no promotion machinery at all, low ratio
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Dict, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import activity as act
from repro.core.engine.state import (C_ACT_WR, C_DEMO_WR, C_META_RD,
                                     C_META_WR, bump)


@dataclass(frozen=True)
class Policy:
    """Base policy: pure IBEX behavior. Subclasses override hooks to charge
    their design's extra traffic in place."""
    name: str = "ibex"
    # mechanism toggles the policy requires of its PoolConfig (ablation S/C/M)
    coloc: bool = True
    shadow: bool = True
    compact: bool = True
    zero_elision: bool = True
    # device-model knob: 4KB-block schemes pay 4x compression-engine latency
    block4k_engine: bool = False
    # line-level schemes bypass the pool entirely (no promotion machinery)
    line_level: bool = False

    # -- accounting hooks (pure: counters -> counters) ----------------------

    def on_host_access(self, counters: jnp.ndarray, is_write, n=1
                       ) -> jnp.ndarray:
        """Per host access, at access time (e.g. recency-list maintenance)."""
        return counters

    def on_mcache_miss(self, counters: jnp.ndarray, n=1) -> jnp.ndarray:
        """Extra traffic per metadata-cache miss (e.g. a second table probe);
        ``n`` misses at once from the batched front-end. The base metadata
        read itself is mechanism traffic (ops.mcache_step)."""
        return counters

    def on_compress_store(self, counters: jnp.ndarray) -> jnp.ndarray:
        """Per compressed-page store (dirty demotion or recompression)."""
        return counters

    def on_demotion(self, counters: jnp.ndarray, clean) -> jnp.ndarray:
        """Per demotion, after the mechanism's own traffic is charged."""
        return counters

    def charge_activity(self, counters: jnp.ndarray, idx: int, n=1
                        ) -> jnp.ndarray:
        """Activity-region traffic (clock scans, lazy reference updates).
        Schemes with on-chip recency state suppress this."""
        return bump(counters, idx, n)

    def charge_migration(self, counters: jnp.ndarray, idx: int, n=1
                         ) -> jnp.ndarray:
        """Promotion/demotion data movement (promo_rd/wr, demo_rd/wr).
        Coarser migration granularity multiplies it."""
        return bump(counters, idx, n)

    # -- residency decisions ------------------------------------------------

    def select_victim(self, activity: jnp.ndarray, hand: jnp.ndarray, cache,
                      rng: jnp.ndarray, force=False) -> act.ScanResult:
        """Victim selection: the §4.4 second-chance clock over the activity
        region. (The serving engine applies the same policy shape at lane
        granularity — see ``SecondChanceLanes``.)"""
        return act.clock_scan(activity, hand, cache, rng, force=force)


@dataclass(frozen=True)
class IbexPolicy(Policy):
    """Full IBEX. Ablation rungs are mechanism toggles on the same policy."""


@dataclass(frozen=True)
class TmccPolicy(Policy):
    """TMCC: 4KB blocks, zsmalloc-style variable chunks, LRU-list recency.

    Extra traffic charged where it occurs:
      * one recency-list node update per host access (list-based LRU);
      * two bookkeeping writes per compressed-page store (zspage alloc maps);
      * one reclaim access per demotion (fragmentation compaction).
    """
    name: str = "tmcc"
    coloc: bool = False
    shadow: bool = False
    block4k_engine: bool = True

    def on_host_access(self, counters, is_write, n=1):
        return bump(counters, C_ACT_WR, n)

    def on_compress_store(self, counters):
        return bump(counters, C_META_WR, 2)

    def on_demotion(self, counters, clean):
        return bump(counters, C_DEMO_WR, 1)


@dataclass(frozen=True)
class DylectPolicy(TmccPolicy):
    """DyLeCT: TMCC plus dual metadata tables — every metadata-cache miss
    probes both tables (one extra metadata read at the miss site)."""
    name: str = "dylect"

    def on_mcache_miss(self, counters, n=1):
        return bump(counters, C_META_RD, n)


@dataclass(frozen=True)
class MxtPolicy(Policy):
    """MXT-style 4KB promotion cache with on-chip tags: recency state never
    touches device memory, so activity traffic is suppressed at the charge
    site; page-granular promotion, no zero elision."""
    name: str = "mxt"
    coloc: bool = False
    zero_elision: bool = False
    block4k_engine: bool = True

    def charge_activity(self, counters, idx, n=1):
        return counters


@dataclass(frozen=True)
class DmcPolicy(Policy):
    """DMC: 32KB migration granularity — every promotion/demotion moves 8x
    the data, charged at the movement site."""
    name: str = "dmc"
    coloc: bool = False
    shadow: bool = False
    block4k_engine: bool = True
    migrate_mult: int = 8

    def charge_migration(self, counters, idx, n=1):
        return bump(counters, idx, jnp.asarray(n) * self.migrate_mult)


@dataclass(frozen=True)
class CompressoPolicy(Policy):
    """Compresso: line-level compression, no promotion machinery. The simx
    engine routes this through its dedicated line-level model."""
    name: str = "compresso"
    line_level: bool = True


DEFAULT_POLICY = IbexPolicy()

POLICIES: Dict[str, Policy] = {
    "ibex": IbexPolicy(),
    "ibex_base": dataclasses.replace(IbexPolicy(), name="ibex_base",
                                     coloc=False, shadow=False, compact=False,
                                     block4k_engine=True),
    "ibex_s": dataclasses.replace(IbexPolicy(), name="ibex_s", coloc=False,
                                  shadow=True, compact=False,
                                  block4k_engine=True),
    "ibex_sc": dataclasses.replace(IbexPolicy(), name="ibex_sc", coloc=True,
                                   shadow=True, compact=False),
    "ibex_scm": dataclasses.replace(IbexPolicy(), name="ibex_scm", coloc=True,
                                    shadow=True, compact=True),
    "tmcc": TmccPolicy(),
    "dylect": DylectPolicy(),
    "mxt": MxtPolicy(),
    "dmc": DmcPolicy(),
    "compresso": CompressoPolicy(),
}


class SecondChanceLanes:
    """The §4.4 second-chance victim-selection policy at *lane* (request)
    granularity, used by the serving engine: reference bit = "generated a
    token since last sweep". Mirrors ``Policy.select_victim`` over lane
    state instead of the activity region, including the bounded sweep +
    round-robin fallback (the paper's random fallback).

    ``select_mask`` is the vectorized form: one pass of array ops over all
    lanes (the serving engine keeps lane bookkeeping as arrays, so the sweep
    must not loop lane-by-lane). ``select`` keeps the callback form for
    callers holding per-lane Python state."""

    def __init__(self, n_lanes: int):
        self.n = n_lanes
        self.hand = 0

    def select_mask(self, occupied, referenced, groups=None, group_load=None):
        """One-pass sweep. occupied/referenced: bool[n] arrays. Returns
        (victim lane or None, new referenced bits). Semantics match the
        serial clock: ref bits of occupied lanes between the hand and the
        victim are cleared (their second chance); if every occupied lane is
        referenced, all are cleared and the first occupied lane after the
        hand is taken (round-robin fallback).

        ``groups``/``group_load`` (fabric-aware serving): lanes carry an
        expander id and every expander a current parked-payload load; among
        the sweep's candidates the victim is the first lane belonging to
        the least-loaded candidate expander, so preemptions park evenly
        across expanders instead of piling onto whichever expander the hand
        happens to point at. With ``groups=None`` behavior is unchanged."""
        occ = np.asarray(occupied, bool)
        ref = np.array(referenced, bool, copy=True)
        order = (self.hand + np.arange(self.n)) % self.n
        cand = occ[order] & ~ref[order]
        if cand.any():
            if groups is None:
                k = int(np.argmax(cand))
            else:
                pos = np.nonzero(cand)[0]
                loads = np.asarray(group_load)[
                    np.asarray(groups)[order[pos]]]
                k = int(pos[int(np.argmin(loads))])   # first-min: earliest
            swept = order[:k]
            ref[swept[occ[swept]]] = False
        elif occ.any():
            k = int(np.argmax(occ[order]))
            ref[occ] = False          # full revolution: everyone spent theirs
        else:
            return None, ref
        victim = int(order[k])
        self.hand = (victim + 1) % self.n
        return victim, ref

    def select(self, occupied: Callable[[int], bool],
               referenced: Callable[[int], bool],
               clear: Callable[[int], None]) -> Optional[int]:
        occ = np.array([bool(occupied(i)) for i in range(self.n)])
        ref = np.array([occ[i] and bool(referenced(i)) for i in range(self.n)])
        victim, new_ref = self.select_mask(occ, ref)
        for i in np.nonzero(ref & ~new_ref)[0]:
            clear(int(i))
        return victim
