"""Pool state: the four device-memory regions + counters (DESIGN.md §3).

Functional state machine over:
  * ``p_store``  — promoted region (uncompressed P-chunks, 4KB)
  * ``c_store``  — compressed region (512B C-chunks; an aligned-group tail
                   sub-region serves incompressible pages behind one pointer)
  * ``meta``     — 32B compacted metadata entries (metadata.py)
  * ``activity`` — 4B page-activity entries + clock hand (activity.py)

plus the metadata-cache model that drives lazy reference updates, and traffic
counters in 64B-access units (the paper's measurement unit).

State-machine invariants (enforced by tests/test_pool_properties.py,
DESIGN.md §9):
  I1  every C-chunk is free XOR referenced by exactly one page
  I2  promoted(page) <=> P-chunk allocated <=> activity entry allocated
  I3  dirty <=> num_chunks == 0 for promoted pages (no compressed copy)
  I4  clean promoted pages have shadow_valid=1 and intact chunks (§4.5)
  I5  read-your-writes at block granularity
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.core import freelist as fl
from repro.core import mcache as mcc
from repro.core import metadata as md

# ---------------------------------------------------------------------------
# Traffic counters (64B-access units unless noted).
# ---------------------------------------------------------------------------
C_META_RD, C_META_WR, C_DATA_RD, C_DATA_WR, C_PROMO_RD, C_PROMO_WR, \
    C_DEMO_RD, C_DEMO_WR, C_ACT_RD, C_ACT_WR, C_ZERO_SERVED, C_RANDOM_FB, \
    C_DEMO_CLEAN, C_DEMO_DIRTY, C_PROMOTIONS, C_HOST_RD, C_HOST_WR, \
    C_MC_HIT, C_MC_MISS, C_RECOMP_RETRY, NUM_COUNTERS = range(21)

CTR_DTYPE = jnp.int32  # 64B-access counts; int32 suffices at test/sim scale

COUNTER_NAMES = [
    "metadata_rd", "metadata_wr", "data_rd", "data_wr", "promo_rd", "promo_wr",
    "demo_rd", "demo_wr", "activity_rd", "activity_wr", "zero_served",
    "random_fallback", "demotions_clean", "demotions_dirty", "promotions",
    "host_reads", "host_writes", "mcache_hits", "mcache_misses",
    "recompress_retry",
]

# The ten *internal 64B-access* categories (excludes host accesses and event
# counters) — the canonical definition of "internal traffic" shared by the
# metrics here, simx.engine.TRAFFIC_KEYS, and the simx.time delivered-time
# model, so the counter layout and the model can never drift on key names.
TRAFFIC_IDX = (C_META_RD, C_META_WR, C_DATA_RD, C_DATA_WR, C_PROMO_RD,
               C_PROMO_WR, C_DEMO_RD, C_DEMO_WR, C_ACT_RD, C_ACT_WR)
TRAFFIC_NAMES = tuple(COUNTER_NAMES[i] for i in TRAFFIC_IDX)


class Pool(NamedTuple):
    meta: jnp.ndarray        # uint32[n_pages, 8]
    activity: jnp.ndarray    # uint32[n_pchunks]
    hand: jnp.ndarray        # int32[]
    cfree: fl.FreeList       # single C-chunks
    gfree: fl.FreeList       # aligned 8-chunk groups (values = base chunk idx)
    pfree: fl.FreeList       # P-chunks
    cache: mcc.MCache
    counters: jnp.ndarray    # int32[NUM_COUNTERS]
    rng: jnp.ndarray
    c_store: jnp.ndarray     # uint8[n_chunks_total, chunk_bytes] (or [0, _])
    p_store: jnp.ndarray     # uint8[n_pchunks, page_bytes]       (or [0, _])
    rates_table: jnp.ndarray  # int32[n_pages, 4] content model — used instead
    #                           of encode_page when store_payload=False (simx)


def n_single_chunks(cfg: PoolConfig) -> int:
    """Compressed region split: 7/8 singles, 1/8 aligned groups (static)."""
    return (cfg.n_cchunks * 7 // 8) // 8 * 8


def make_pool(cfg: PoolConfig, seed: int = 0,
              rates_table: jnp.ndarray | None = None) -> Pool:
    n_single = n_single_chunks(cfg)
    n_groups = (cfg.n_cchunks - n_single) // 8
    gbases = jnp.asarray(n_single, jnp.int32) + 8 * jnp.arange(n_groups, dtype=jnp.int32)
    pay_c = cfg.n_cchunks if cfg.store_payload else 0
    pay_p = cfg.n_pchunks if cfg.store_payload else 0
    if rates_table is None:
        rates_table = jnp.zeros((cfg.n_pages, cfg.blocks_per_page), jnp.int32)
    return Pool(
        meta=md.empty_table(cfg.n_pages),
        activity=jnp.zeros((cfg.n_pchunks,), jnp.uint32),
        hand=jnp.asarray(0, jnp.int32),
        cfree=fl.make_freelist(n_single),
        gfree=fl.FreeList(items=gbases, top=jnp.asarray(n_groups, jnp.int32)),
        pfree=fl.make_freelist(cfg.n_pchunks),
        cache=mcc.make_mcache(cfg.mcache_sets, cfg.mcache_ways),
        counters=jnp.zeros((NUM_COUNTERS,), CTR_DTYPE),
        rng=jax.random.PRNGKey(seed),
        c_store=jnp.zeros((pay_c, cfg.chunk_bytes), jnp.uint8),
        p_store=jnp.zeros((pay_p, cfg.page_bytes), jnp.uint8),
        rates_table=jnp.asarray(rates_table, jnp.int32),
    )


def bump(counters: jnp.ndarray, idx: int, n=1) -> jnp.ndarray:
    return counters.at[idx].add(jnp.asarray(n, CTR_DTYPE))


# ---------------------------------------------------------------------------
# Stacked pools (multi-expander fabric, repro.fabric): N independent pools as
# one pytree whose every leaf carries a leading expander axis, advanced in
# parallel with jax.vmap.
# ---------------------------------------------------------------------------

def make_pool_stack(cfg: PoolConfig, n_expanders: int, seed: int = 0,
                    rates_table: jnp.ndarray | None = None) -> Pool:
    """N identically-configured pools stacked leaf-wise. Every expander gets
    its own RNG stream derived from ``seed`` (fold_in by expander index), so
    a fabric run is bit-reproducible from one CLI seed and expanders never
    share randomness. The OSPA page space (and content model) is the full
    ``cfg.n_pages`` on every expander — placement decides which pages a
    given expander ever sees (fabric/placement.py)."""
    base = make_pool(cfg, seed=seed, rates_table=rates_table)
    stacked = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (n_expanders,) + a.shape), base)
    keys = jax.vmap(lambda e: jax.random.fold_in(
        jax.random.PRNGKey(seed), e))(jnp.arange(n_expanders))
    return stacked._replace(rng=keys)


def pool_slice(stack: Pool, e: int) -> Pool:
    """Expander ``e``'s pool out of a stacked state (host-side: spill
    orchestration, invariant checks)."""
    return jax.tree_util.tree_map(lambda a: a[e], stack)


def pool_unslice(stack: Pool, e: int, pool: Pool) -> Pool:
    """Write one expander's pool back into the stacked state."""
    return jax.tree_util.tree_map(lambda s, a: s.at[e].set(a), stack, pool)


def stacked_counters(stack: Pool) -> jnp.ndarray:
    """Summed counters across expanders: int32[NUM_COUNTERS]."""
    return jnp.sum(stack.counters, axis=0)


def stacked_counters_dict(stack: Pool) -> dict:
    """Aggregate counters of a stacked pool state, same keys as
    ``counters_dict`` — per-expander traffic sums are the fabric's parity
    contract with single-pool replay (benchmarks/fabric_bench.py)."""
    vals = [int(v) for v in stacked_counters(stack)]
    return dict(zip(COUNTER_NAMES, vals))


def per_expander_counters(stack: Pool) -> list:
    """One ``counters_dict`` per expander, in expander order."""
    arr = [[int(v) for v in row] for row in stack.counters]
    return [dict(zip(COUNTER_NAMES, row)) for row in arr]


# ---------------------------------------------------------------------------
# Metrics.
# ---------------------------------------------------------------------------

def compression_ratio(pool: Pool, cfg: PoolConfig) -> jnp.ndarray:
    """Effective ratio = logical bytes of valid pages / physical bytes used
    (chunks + promoted duplicates, i.e. shadowing costs what the paper says)."""
    valid = md.get_valid(pool.meta[:, 0]) == 1
    logical = jnp.sum(valid) * cfg.page_bytes
    n_single = n_single_chunks(cfg)
    n_groups = (cfg.n_cchunks - n_single) // 8
    used_chunks = (n_single - fl.free_count(pool.cfree)) + \
        8 * (n_groups - fl.free_count(pool.gfree))
    used_p = cfg.n_pchunks - fl.free_count(pool.pfree)
    physical = used_chunks * cfg.chunk_bytes + used_p * cfg.page_bytes
    return logical / jnp.maximum(physical, 1)


def counters_dict(pool: Pool) -> dict:
    vals = [int(v) for v in pool.counters]
    return dict(zip(COUNTER_NAMES, vals))


def traffic_vector(counters) -> jnp.ndarray:
    """Internal-traffic view of a counter vector: ``[..., NUM_COUNTERS]`` →
    ``[..., len(TRAFFIC_IDX)]`` in ``TRAFFIC_IDX`` order. Works on numpy
    and jnp arrays (inside jit/vmap), with any leading batch/expander axes
    — the array-native hook the delivered-time model (simx/time.py) and
    the fabric's per-segment accounting consume."""
    return counters[..., list(TRAFFIC_IDX)]


def counters_snapshot(pool: Pool) -> jnp.ndarray:
    """A point-in-time counter vector. Pool state is immutable, so the
    live array IS the snapshot; this names the intent at segment
    boundaries (fabric per-segment deltas)."""
    return pool.counters


def counters_delta(before: jnp.ndarray, after: jnp.ndarray) -> jnp.ndarray:
    """Per-segment counter delta between two snapshots (leading axes — e.g.
    the expander axis of a stacked pool — broadcast through). The hook
    that per-segment delivered-time accounting, async-migration overlap and
    traffic-imbalance rebalancing (ROADMAP) are built on."""
    return after - before


def counters_delta_dict(delta) -> dict:
    """Name-keyed view of a counter delta: ``[..., NUM_COUNTERS]`` (leading
    axes — e.g. the expander axis — summed) → ``{counter_name: int}``. The
    layout-safe way host-side consumers (the repro.obs telemetry drains,
    summary tables) read fetched deltas: keys come from ``COUNTER_NAMES``,
    never integer positions, so the R3 drift rule holds by construction.
    Accepts numpy or (host) jnp arrays."""
    vals = delta.reshape(-1, NUM_COUNTERS).sum(axis=0)
    return {k: int(v) for k, v in zip(COUNTER_NAMES, vals)}


def total_traffic(pool: Pool) -> jnp.ndarray:
    """Total internal 64B accesses (excludes host_reads/host_writes and
    event counters)."""
    return jnp.sum(traffic_vector(pool.counters), axis=-1)
