"""Batched access front-end (DESIGN.md §6).

Trace replay used to run one access per ``lax.scan`` step, paying the full
serial state-machine path for every access. This front-end processes a
window of W accesses per step:

  phase 0  background demotion engine tops up the free-P-chunk watermark
           once per window;
  phase 1  vectorized classification against a window-start metadata
           snapshot: accesses that resolve without metadata transitions —
           hot/zero/invalid reads, and writes to already-promoted all-hot
           dirty pages — are *fast*; their traffic is summed with window
           vector arithmetic;
  phase 2  vectorized metadata probes + activity updates: the whole window
           goes through ``mcache.access_window`` (window-granular LRU) and
           one masked scatter applies every lazy referenced-bit update;
  phase 3  conflict serialization: the remaining accesses — writes,
           promotions, and *same-page hits* whose predecessor in the window
           was itself slow — replay in order through the exact serial
           per-access bodies, looping only over the n_slow conflicts.

Fast accesses mutate nothing but counters, so a fast predecessor can never
invalidate a later classification; slow accesses re-read live metadata.
The divergences from the serial engine are (a) background-demotion timing
(per window instead of per access — ``cfg.demote_cadence="access"``
removes this one for small-pool comparisons), (b) window-granular
metadata-cache recency, and (c) a fast hot-read of a page a slow access
demoted earlier in the same window is still accounted as hot. All shift
counters within noise at sane region ratios (asserted by
tests/test_simx_schemes.py); invariants I1-I5 are unaffected
(tests/test_pool_properties.py).

``_replay_windows_masked`` is the window scan over a *padded* trace — the
multi-expander fabric (repro.fabric) vmaps it over a stacked pool state;
it reuses the window/serial bodies above unchanged so fabric counters are
bit-identical to single-pool replays of each expander's partition.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.core import mcache as mcc
from repro.core import metadata as md
from repro.core.engine import ops
from repro.core.engine.policy import Policy
from repro.core.engine.state import (C_ACT_WR, C_DATA_RD, C_DATA_WR,
                                     C_HOST_RD, C_HOST_WR, C_MC_HIT,
                                     C_MC_MISS, C_META_RD, C_META_WR,
                                     C_ZERO_SERVED, Pool, bump)

DEFAULT_WINDOW = 32
SLOW_FORI = 8      # slow accesses handled per window before the while loop


def _classify_window(pool: Pool, cfg: PoolConfig, ospns, writes, blocks):
    """Vectorized fast-path mask over a window (see module docstring).

    An access is *fast* when its window-start metadata snapshot resolves it
    without state transitions — a read of a hot block, zero block, or
    invalid page, or a write to an already-promoted dirty page with every
    block hot (§4.5 steady state: such a write leaves the metadata word
    bit-identical and only moves data + counters) — and no earlier access
    in the window both touched the same page and was itself slow. Fast
    accesses never mutate metadata, so a fast predecessor on the same page
    cannot invalidate the snapshot."""
    w0s = pool.meta[ospns, 0]                                  # [W]
    valid = md.get_valid(w0s) == 1
    promoted = md.get_promoted(w0s) == 1
    if cfg.coloc:
        bt = md.get_block_type_dyn(w0s, blocks)
        all_prom = jnp.ones_like(valid)
        for i in range(cfg.blocks_per_page):
            all_prom = all_prom & (md.get_block_type(w0s, i) == md.BT_PROM)
    else:
        bt = md.get_block_type(w0s, 0)
        all_prom = bt == md.BT_PROM
    is_zero = valid & (bt == md.BT_ZERO)
    is_hot = valid & promoted & (bt == md.BT_PROM)
    hot_write = valid & promoted & all_prom & \
        (md.get_dirty(w0s) == 1) & (md.get_num_chunks(w0s) == 0)
    candidate = jnp.where(writes, hot_write, is_zero | is_hot | (~valid))
    w = ospns.shape[0]
    earlier = jnp.arange(w)[None, :] < jnp.arange(w)[:, None]
    same = ospns[:, None] == ospns[None, :]
    slow_pred = jnp.any(same & earlier & (~candidate)[None, :], axis=1)
    fast = candidate & (~slow_pred)
    return fast, is_zero, is_hot


def _mcache_window(pool: Pool, cfg: PoolConfig, policy: Policy, ospns) -> Pool:
    """Vectorized metadata-cache walk + lazy activity updates for one window
    (mcache.access_window has the recency model). The ~W serial cache steps
    of the one-access-per-step engine collapse into a handful of vector ops."""
    cache, hits, evicted = mcc.access_window(pool.cache, ospns)
    n_hit = jnp.sum(hits)
    n_miss = ospns.shape[0] - n_hit
    if cfg.compact:
        widths = jnp.ones_like(ospns)
    else:
        widths = 1 + (ospns & 1)     # uncompacted entries straddle 64B (§4.7)
    counters = bump(pool.counters, C_MC_HIT, n_hit)
    counters = bump(counters, C_MC_MISS, n_miss)
    counters = bump(counters, C_META_RD, jnp.sum(jnp.where(hits, 0, widths)))
    counters = policy.on_mcache_miss(counters, n=n_miss)
    # lazy reference update (§4.4) for every eviction, as one masked scatter
    ev = evicted.reshape(-1)
    entries = pool.meta[jnp.maximum(ev, 0)]
    w0 = entries[:, 0]
    prom = (md.get_promoted(w0) == 1) & (md.get_valid(w0) == 1) & (ev >= 0)
    pidx = md.get_ptr(entries, md.PCHUNK_SLOT).astype(jnp.int32)
    safe_pidx = jnp.clip(jnp.where(prom, pidx, 0), 0,
                         pool.activity.shape[0] - 1)
    already = md.act_referenced(pool.activity[safe_pidx]) == 1
    ref_bit = jnp.uint32(1) << jnp.uint32(md.ACT_REFERENCED_BIT)
    flips = prom & (~already)
    delta = jnp.where(flips, ref_bit, jnp.uint32(0))
    activity = pool.activity.at[safe_pidx].add(delta)
    # charge exactly the activity words written: evictions whose referenced
    # bit actually flips (an already-referenced entry needs no write) —
    # matches the serial path's charge in ops.mcache_step
    counters = policy.charge_activity(counters, C_ACT_WR, jnp.sum(flips))
    return pool._replace(cache=cache, activity=activity, counters=counters)


def _window_step(pool: Pool, cfg: PoolConfig, policy: Policy, xs,
                 unroll_slow: bool = False):
    ospns, writes, blocks = xs
    window = ospns.shape[0]
    zero_block = jnp.zeros((cfg.vals_per_block,), jnp.bfloat16)

    # phase 0: background demotion engine — top up once per window to a
    # raised target (watermark + expected promotions per window) so the
    # free list rarely exhausts mid-window; a window with more promotions
    # than that stays live through the promote path's self-ensure.
    # fori-of-cond, not while: XLA executes a skipped cond branch as a
    # cheap copy, whereas demotions inside a dynamic-trip while loop cost
    # ~3x (measured on CPU).
    # the raise is bounded by the watermark so small pools keep (almost)
    # the serial engine's residency: a higher target would evict hot pages
    # the serial engine keeps resident and skew traffic at small scales.
    # cfg.demote_cadence == "access" drops the raise entirely and instead
    # re-checks the watermark before every slow access (below) — the serial
    # engine's cadence, for small pools where the raise itself skews traffic
    per_access = cfg.demote_cadence == "access"
    if per_access:
        # no raised target; the window-start top-up may fully catch up (the
        # serial engine had one demote opportunity before every one of the
        # preceding fast accesses) and every slow access re-checks below
        extra = 0
        budget = window
    else:
        extra = min(window // 4, max(2, cfg.demote_watermark // 2))
        budget = max(4, window // 4)
    pool = ops.demote_if_needed(pool, cfg, policy, max_demotes=budget,
                                watermark=cfg.demote_watermark + extra)

    # phase 1: classification snapshot (phase 2 never touches metadata)
    fast, is_zero, is_hot = _classify_window(pool, cfg, ospns, writes, blocks)

    # phase 2: vectorized metadata probes + activity updates for the window
    pool = _mcache_window(pool, cfg, policy, ospns)

    # vectorized accounting for the fast accesses
    fast_rd = fast & (~writes)
    fast_wr = fast & writes
    n_fast_rd = jnp.sum(fast_rd)
    n_fast_wr = jnp.sum(fast_wr)
    counters = bump(pool.counters, C_HOST_RD, n_fast_rd)
    counters = bump(counters, C_HOST_WR, n_fast_wr)
    counters = policy.on_host_access(counters, False, n=n_fast_rd)
    counters = policy.on_host_access(counters, True, n=n_fast_wr)
    counters = bump(counters, C_ZERO_SERVED, jnp.sum(fast_rd & is_zero))
    counters = bump(counters, C_DATA_RD,
                    jnp.sum(fast_rd & is_hot) * (cfg.block_bytes // 64))
    # fast (hot, dirty) writes: data write + metadata write-back, no
    # metadata *change* — see _classify_window
    counters = bump(counters, C_DATA_WR, n_fast_wr * (cfg.block_bytes // 64))
    if cfg.compact:
        wr_widths = n_fast_wr
    else:
        wr_widths = jnp.sum(jnp.where(fast_wr, 1 + (ospns & 1), 0))
    counters = bump(counters, C_META_WR, wr_widths)
    pool = pool._replace(counters=counters)

    # phase 3: serialized replay of the slow accesses only — fast accesses
    # pay no per-access control flow at all. The first SLOW_FORI slow
    # accesses run in a fori-of-cond (a skipped cond is a cheap copy, and a
    # taken branch executes at serial-engine cost); the rare overflow (a
    # window with more slow accesses than SLOW_FORI, e.g. first-touch
    # population) drains through a while loop, whose heavy bodies XLA runs
    # ~3x slower — hence the split.
    #
    # ``unroll_slow`` replaces BOTH lax loops with a statically unrolled
    # python loop over the full window: XLA:CPU deterministically
    # miscompiles this drain when the vmapped body sits inside a
    # ``shard_map`` manual region on any device other than 0 (a window's
    # slow write replays as a read; forced host devices, jax 0.4.37 —
    # isolated by tests/test_fabric_sharded.py's bit-identity suite),
    # while the unrolled form is bit-exact there. Single-device paths
    # keep the loops: same op sequence, smaller HLO.
    n_slow = jnp.sum(~fast)
    slow_order = jnp.argsort(jnp.where(fast, window + jnp.arange(window),
                                       jnp.arange(window)))

    def process(k, p: Pool) -> Pool:
        if per_access:
            p = ops.demote_if_needed(p, cfg, policy)

        def do_write(r: Pool) -> Pool:
            c = policy.on_host_access(bump(r.counters, C_HOST_WR), True)
            r = r._replace(counters=c)
            return ops.write_block_op(r, cfg, policy, ospns[k], blocks[k],
                                      zero_block)

        def do_read(r: Pool) -> Pool:
            c = policy.on_host_access(bump(r.counters, C_HOST_RD), False)
            r = r._replace(counters=c)
            return ops.read_block_op(r, cfg, policy, ospns[k], blocks[k])[0]

        return jax.lax.cond(writes[k], do_write, do_read, p)

    if unroll_slow:
        for i in range(window):
            pool = jax.lax.cond(i < n_slow,
                                functools.partial(process, slow_order[i]),
                                lambda q: q, pool)
        return pool, None

    k_fori = min(SLOW_FORI, window)
    pool = jax.lax.fori_loop(
        0, k_fori,
        lambda i, p: jax.lax.cond(i < n_slow,
                                  lambda q: process(slow_order[i], q),
                                  lambda q: q, p),
        pool)

    def slow_cond(carry):
        i, _ = carry
        return i < n_slow

    def slow_body(carry):
        i, p = carry
        return i + 1, process(slow_order[i], p)

    _, pool = jax.lax.while_loop(slow_cond, slow_body,
                                 (jnp.asarray(k_fori, jnp.int32), pool))
    return pool, None


@functools.partial(jax.jit, static_argnums=(1, 2))
def _replay_windows(pool: Pool, cfg: PoolConfig, policy: Policy, ospns,
                    writes, blocks) -> Pool:
    def scan_step(p, xs):
        return _window_step(p, cfg, policy, xs)

    pool, _ = jax.lax.scan(scan_step, pool, (ospns, writes, blocks))
    return pool


def _serial_access(pool: Pool, cfg: PoolConfig, policy: Policy, ospn, w, blk
                   ) -> Pool:
    """One access through the serial per-access path (full prologue — the
    exact body `_replay_serial` scans and the masked window path's partial
    windows replay; sharing it is what makes the fabric's padded replay
    counter-exact against `replay_trace`)."""
    zero_block = jnp.zeros((cfg.vals_per_block,), jnp.bfloat16)

    def do_write(q):
        return ops._host_write_block(q, cfg, policy, ospn, blk, zero_block)

    def do_read(q):
        return ops._host_read_block(q, cfg, policy, ospn, blk)[0]

    return jax.lax.cond(w, do_write, do_read, pool)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _replay_serial(pool: Pool, cfg: PoolConfig, policy: Policy, ospns,
                   writes, blocks, valid=None) -> Pool:
    """The seed's one-access-per-step scan (kept as the batched path's
    reference and for BENCH_simx.json before/after measurements).

    ``valid=None`` processes every access and traces the seed's plain
    two-way cond — the reference/baseline path must not pay for masking. A
    bool mask adds an outer cond that makes masked-out accesses exact no-ops
    (pool and counters untouched) — the batched path pads its trace tail
    with them so every tail compiles at one shape."""
    if valid is None:
        def step(p, x):
            return _serial_access(p, cfg, policy, *x), None
        pool, _ = jax.lax.scan(step, pool, (ospns, writes, blocks))
        return pool

    def step(p, x):
        ospn, w, blk, v = x
        return jax.lax.cond(
            v, lambda q: _serial_access(q, cfg, policy, ospn, w, blk),
            lambda q: q, p), None

    pool, _ = jax.lax.scan(step, pool, (ospns, writes, blocks, valid))
    return pool


def _replay_windows_masked(pool: Pool, cfg: PoolConfig, policy: Policy,
                           ospns, writes, blocks, valid,
                           pending=None, unroll_slow: bool = False) -> Pool:
    """Window scan over a *padded* trace: the multi-expander fabric's entry
    point (fabric/replay.py vmaps it over a stacked pool state).

    Each expander's trace partition is a prefix of real accesses followed by
    padding, reshaped to [n_win, W] with a bool validity mask. Per window:

      * all-valid   -> the exact `_window_step` body (same as
                       `_replay_windows`);
      * part-valid  -> the serial per-access body over the valid prefix
                       (same as `replay_trace`'s padded serial tail);
      * none-valid  -> exact no-op.

    Padding sits at the end, so a padded replay walks full windows then one
    partial window then no-ops — the very shapes `replay_trace` produces —
    and its counters are bit-identical to an unpadded `replay_trace` of the
    real prefix (asserted by tests/test_fabric.py). Under `vmap` the
    three-way branch lowers to selects, so every expander pays the heavier
    body's cost; fabric throughput numbers carry that constant honestly
    (benchmarks/fabric_bench.py).

    ``unroll_slow`` is forwarded to ``_window_step``: the sharded fabric
    passes True because XLA:CPU miscompiles the fori/while slow-access
    drain inside ``shard_map`` manual regions (see ``_window_step``).

    ``pending`` is the fabric scheduler's carried pending-migration mask
    (bool[n_pages], shared across expanders): accesses to pages whose
    migration plan is in flight are masked to exact no-ops mid-segment —
    the host defers and replays them after the epoch commits, routed to
    the page's final home — so an in-flight page is never touched by a
    replay racing its own migration. An all-False mask reduces to
    ``valid`` unchanged (identical numerics to ``pending=None``: the
    fabric's parity contract survives the overlap machinery)."""
    def scan_step(p, xs):
        o, w, b, v = xs
        if pending is not None:
            v = v & ~pending[o]

        def none_valid(q: Pool) -> Pool:
            return q

        def part_valid(q: Pool) -> Pool:
            def step(q2, x):
                ospn, wr, blk, vv = x
                return jax.lax.cond(
                    vv, lambda r: _serial_access(r, cfg, policy, ospn, wr,
                                                 blk),
                    lambda r: r, q2), None
            q, _ = jax.lax.scan(step, q, (o, w, b, v))
            return q

        def all_valid(q: Pool) -> Pool:
            return _window_step(q, cfg, policy, (o, w, b),
                                unroll_slow=unroll_slow)[0]

        branch = jnp.where(jnp.all(v), 2,
                           jnp.where(jnp.any(v), 1, 0)).astype(jnp.int32)
        return jax.lax.switch(branch, [none_valid, part_valid, all_valid],
                              p), None

    pool, _ = jax.lax.scan(scan_step, pool,
                           (ospns, writes, blocks, valid))
    return pool


def replay_trace(pool: Pool, cfg: PoolConfig, policy: Policy, ospns, writes,
                 blocks, *, window: int = DEFAULT_WINDOW) -> Pool:
    """Replay a (ospn, is_write, block) trace through the pool.

    ``window > 1`` uses the batched front-end; ``window <= 1`` runs the
    serial scan over the whole trace. The trace tail that does not fill a
    window (and any trace shorter than one window) replays serially, padded
    to exactly ``window`` accesses with masked no-ops — so the batched path
    compiles a fixed set of shapes (the window scan plus one window-sized
    serial tail) no matter the trace length, instead of one ``_replay_serial``
    per distinct tail length. Write accesses carry a zero-block payload
    (trace replay measures traffic, not data)."""
    ospns = jnp.asarray(ospns, jnp.int32)
    writes = jnp.asarray(writes, bool)
    blocks = jnp.asarray(blocks, jnp.int32)
    n = int(ospns.shape[0])
    if window <= 1:
        return _replay_serial(pool, cfg, policy, ospns, writes, blocks)
    n_win = n // window
    head = n_win * window
    if n_win:
        pool = _replay_windows(pool, cfg, policy,
                               ospns[:head].reshape(n_win, window),
                               writes[:head].reshape(n_win, window),
                               blocks[:head].reshape(n_win, window))
    tail = n - head
    if tail:
        pad = window - tail
        pz = lambda a: jnp.pad(a[head:], ((0, pad),))
        valid = jnp.arange(window) < tail
        pool = _replay_serial(pool, cfg, policy, pz(ospns), pz(writes),
                              pz(blocks), valid)
    return pool
