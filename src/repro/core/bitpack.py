"""Vectorized bit packing/unpacking for the rate-adaptive block compressor.

TPU adaptation note (DESIGN.md §3): the paper's LZ-family block compressors are
sequential symbol matchers with per-byte control flow — no VPU/MXU analogue.
The management layer only requires *variable-size chunked output*; we produce it
with SIMD-friendly rate-adaptive quantization. These helpers are the pure-jnp
packing primitives shared by the jnp compressor and the Pallas kernels' oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.common.utils import (bitcast_bf16_to_u16, bitcast_u16_to_bf16,
                                bytes_to_u16, u16_to_bytes)

# Rate codes (block_type in metadata, 2 bits — §4.6 co-location format):
RATE_ZERO = 0          # all-zero block: no chunks (paper's zero page type)
RATE_4BIT = 1          # 4-bit quantized + per-block scale
RATE_8BIT = 2          # 8-bit quantized + per-block scale
RATE_RAW = 3           # incompressible: raw bf16 payload


def pack4(q: jnp.ndarray) -> jnp.ndarray:
    """int8[N] in [-8,7] -> uint8[N/2]; pairs packed little-nibble-first."""
    u = (q.astype(jnp.int32) & 0xF).astype(jnp.uint8)
    lo = u[..., 0::2]
    hi = u[..., 1::2]
    return lo | (hi << jnp.uint8(4))


def unpack4(b: jnp.ndarray, n: int) -> jnp.ndarray:
    """uint8[N/2] -> int8[N] sign-extended from 4-bit."""
    lo = (b & jnp.uint8(0xF)).astype(jnp.int8)
    hi = (b >> jnp.uint8(4)).astype(jnp.int8)
    q = jnp.stack([lo, hi], axis=-1).reshape(b.shape[:-1] + (n,))
    # sign-extend 4-bit
    return jnp.where(q >= 8, q - 16, q)


def pack8(q: jnp.ndarray) -> jnp.ndarray:
    """int8[N] -> uint8[N] (bit identity)."""
    return q.astype(jnp.int8).view(jnp.uint8) if hasattr(q, "view") else \
        jax.lax.bitcast_convert_type(q.astype(jnp.int8), jnp.uint8)


def unpack8(b: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(b.astype(jnp.uint8), jnp.int8)


def quantize_block(x: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-block quantization. Returns (codes int8, scale f32).

    Uses explicit reciprocal multiplies (never divides) so the Pallas kernels
    and this oracle are bit-identical regardless of XLA's div lowering."""
    qmax = float(2 ** (bits - 1) - 1)
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax * jnp.float32(1.0 / qmax), 1.0)
    recip = jnp.float32(1.0) / scale
    q = jnp.clip(jnp.round(x.astype(jnp.float32) * recip), -qmax - 1, qmax)
    return q.astype(jnp.int8), scale[..., 0]


def dequantize_block(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.bfloat16) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def raw_to_bytes(x: jnp.ndarray) -> jnp.ndarray:
    """bf16[N] -> uint8[2N]."""
    return u16_to_bytes(bitcast_bf16_to_u16(x))


def bytes_to_raw(b: jnp.ndarray) -> jnp.ndarray:
    """uint8[2N] -> bf16[N]."""
    return bitcast_u16_to_bf16(bytes_to_u16(b))
