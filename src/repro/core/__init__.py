"""IBEX core: promotion-based block-level compression management (Layer A)."""
from repro.core import (activity, bitpack, compressor, engine, freelist,
                        mcache, metadata)  # noqa: F401
