"""IBEX core: promotion-based block-level compression management (Layer A)."""
from repro.core import (activity, bitpack, compressor, freelist, mcache,
                        metadata, pool)  # noqa: F401
