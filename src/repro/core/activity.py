"""Page-activity region + second-chance (clock) demotion engine (§4.4).

The activity region holds one 4B entry per P-chunk: ``allocated | referenced |
OSPN``; 16 entries per 64B fetch. The demotion cursor (clock hand) scans
fetch-group by fetch-group:

  * referenced=1 allocated entries get their bit reset (second chance);
  * the first allocated, unreferenced entry whose page is NOT resident in the
    metadata cache (probe, lazy-update safety) is the victim;
  * if a fetched group contains allocated entries but no candidate, one of the
    non-cache-resident allocated entries is chosen at random (bounded worst-case
    bandwidth — paper reports 0.6% of selections);
  * a group with no eligible entry at all advances the hand (rare: promoted
    region is near-full whenever demotion runs).

Each scanned group costs one 64B read + one 64B write (bit resets), which is
exactly the paper's "control traffic" — counters are returned to the caller.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import mcache as mc
from repro.core.metadata import (act_allocated, act_ospn, act_referenced,
                                 act_set_referenced)

GROUP = 16  # activity entries per 64B fetch


class ScanResult(NamedTuple):
    activity: jnp.ndarray
    hand: jnp.ndarray
    victim_pidx: jnp.ndarray     # P-chunk index, -1 if none found
    victim_ospn: jnp.ndarray     # -1 if none
    used_random: jnp.ndarray     # bool
    groups_scanned: jnp.ndarray  # int32 — traffic: 1 rd + 1 wr of 64B each


def clock_scan(activity: jnp.ndarray, hand: jnp.ndarray, cache: mc.MCache,
               rng: jnp.ndarray, max_groups: int = 8,
               force: jnp.ndarray | bool = False) -> ScanResult:
    """``force`` widens the random fallback to cache-resident pages — the
    emergency path when the promoted region is exhausted and every resident
    page probes hot (cannot occur at the paper's region ratios, but a correct
    device must not deadlock)."""
    force = jnp.asarray(force)
    n = activity.shape[0]
    n_groups = n // GROUP

    def probe_many(ospns: jnp.ndarray) -> jnp.ndarray:
        return jax.vmap(lambda o: mc.probe(cache, o))(ospns)

    def cond(carry):
        (_, _, found, _, _, groups, _) = carry
        return (~found) & (groups < max_groups)

    def body(carry):
        activity, hand, found, victim, used_rnd, groups, rng = carry
        g = (hand // GROUP) % n_groups
        start = g * GROUP
        entries = jax.lax.dynamic_slice(activity, (start,), (GROUP,))
        alloc = act_allocated(entries) == 1
        ref = act_referenced(entries) == 1
        ospns = act_ospn(entries).astype(jnp.int32)
        probed = probe_many(ospns)
        eligible = alloc & (~ref) & (~probed)
        any_eligible = jnp.any(eligible)
        first = jnp.argmax(eligible)
        # random fallback among allocated, non-resident entries
        rnd_pool = alloc & ((~probed) | force)
        any_rnd = jnp.any(rnd_pool)
        rng, sub = jax.random.split(rng)
        weights = rnd_pool.astype(jnp.float32)
        rnd_pick = jax.random.categorical(sub, jnp.log(weights + 1e-9))
        pick = jnp.where(any_eligible, first, rnd_pick)
        got = any_eligible | any_rnd
        used_rnd_now = (~any_eligible) & any_rnd
        victim_new = jnp.where(got, start + pick, -1)
        # second chance: clear referenced bits of allocated entries in group
        cleared = jnp.where(alloc, act_set_referenced(entries, 0), entries)
        activity = jax.lax.dynamic_update_slice(activity, cleared, (start,))
        hand = hand + GROUP
        return (activity, hand, got, victim_new.astype(jnp.int32),
                used_rnd_now, groups + 1, rng)

    init = (activity, hand, jnp.asarray(False), jnp.asarray(-1, jnp.int32),
            jnp.asarray(False), jnp.asarray(0, jnp.int32), rng)
    activity, hand, found, victim, used_rnd, groups, _ = \
        jax.lax.while_loop(cond, body, init)
    ospn = jnp.where(victim >= 0, act_ospn(activity[jnp.maximum(victim, 0)]), -1)
    return ScanResult(activity, hand, victim, ospn.astype(jnp.int32),
                      used_rnd, groups)


def mark_allocated(activity: jnp.ndarray, pidx: jnp.ndarray,
                   ospn: jnp.ndarray) -> jnp.ndarray:
    """Allocate activity entry for P-chunk ``pidx`` (referenced=1 on arrival)."""
    from repro.core.metadata import act_pack
    return activity.at[pidx].set(act_pack(1, 1, ospn))


def mark_free(activity: jnp.ndarray, pidx: jnp.ndarray) -> jnp.ndarray:
    return activity.at[pidx].set(jnp.uint32(0))


def lazy_touch(activity: jnp.ndarray, pidx: jnp.ndarray) -> jnp.ndarray:
    """Set the referenced bit (the §4.4 lazy update, performed on metadata-cache
    eviction rather than on every access). pidx < 0 is a no-op."""
    safe = jnp.maximum(pidx, 0)
    e = activity[safe]
    updated = activity.at[safe].set(act_set_referenced(e, 1))
    return jax.lax.select(pidx >= 0, updated, activity)
