"""Compatibility shim — the pool monolith now lives in ``repro.core.engine``
(DESIGN.md §1): ``engine.state`` (regions + counters), ``engine.ops``
(mechanisms), ``engine.policy`` (scheme policies), ``engine.batch`` (batched
access front-end).

This module preserves the old cfg-only call signatures by closing over the
default (IBEX) policy. New code should import from ``repro.core.engine``;
this shim is kept for one PR.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.core.engine import ops as _ops
from repro.core.engine.policy import DEFAULT_POLICY
from repro.core.engine.state import (C_ACT_RD, C_ACT_WR, C_DATA_RD, C_DATA_WR,
                                     C_DEMO_CLEAN, C_DEMO_DIRTY, C_DEMO_RD,
                                     C_DEMO_WR, C_HOST_RD, C_HOST_WR,
                                     C_MC_HIT, C_MC_MISS, C_META_RD,
                                     C_META_WR, C_PROMO_RD, C_PROMO_WR,
                                     C_PROMOTIONS, C_RANDOM_FB,
                                     C_RECOMP_RETRY, C_ZERO_SERVED,
                                     COUNTER_NAMES, CTR_DTYPE, NUM_COUNTERS,
                                     Pool, compression_ratio, counters_dict,
                                     make_pool, n_single_chunks, total_traffic)

__all__ = [
    "Pool", "make_pool", "n_single_chunks", "compression_ratio",
    "counters_dict", "total_traffic", "COUNTER_NAMES", "NUM_COUNTERS",
    "CTR_DTYPE", "host_write_page", "host_read_block", "host_write_block",
    "demote_one", "demote_if_needed",
]


def _default_policy_host_write_page(pool: Pool, cfg: PoolConfig, ospn,
                                    vals: jnp.ndarray) -> Pool:
    return _ops._host_write_page(pool, cfg, DEFAULT_POLICY, ospn, vals)


def _default_policy_host_read_block(pool: Pool, cfg: PoolConfig, ospn,
                                    block_idx) -> Tuple[Pool, jnp.ndarray]:
    return _ops._host_read_block(pool, cfg, DEFAULT_POLICY, ospn, block_idx)


def _default_policy_host_write_block(pool: Pool, cfg: PoolConfig, ospn,
                                     block_idx, vals: jnp.ndarray) -> Pool:
    return _ops._host_write_block(pool, cfg, DEFAULT_POLICY, ospn, block_idx,
                                  vals)


host_write_page = functools.partial(jax.jit, static_argnums=(1,))(
    _default_policy_host_write_page)
host_read_block = functools.partial(jax.jit, static_argnums=(1,))(
    _default_policy_host_read_block)
host_write_block = functools.partial(jax.jit, static_argnums=(1,))(
    _default_policy_host_write_block)


def demote_one(pool: Pool, cfg: PoolConfig, force=False) -> Pool:
    return _ops.demote_one(pool, cfg, DEFAULT_POLICY, force=force)


def demote_if_needed(pool: Pool, cfg: PoolConfig, max_demotes: int = 2) -> Pool:
    return _ops.demote_if_needed(pool, cfg, DEFAULT_POLICY,
                                 max_demotes=max_demotes)
