"""Set-associative LRU metadata-cache model (§4.1.2, Table 1: 16-way 96KB).

The cache holds metadata entries keyed by OSPN. It drives two paper mechanisms:
  * traffic: a hit serves translation with zero memory accesses; a miss costs a
    metadata read (1 access compacted; 2 when uncompacted entries straddle 64B);
  * the lazy reference update (§4.4): the activity-region ``referenced`` bit is
    written only when an entry is *evicted* from this cache, and the demotion
    engine *probes* this cache to avoid demoting resident (hot) pages.

Functional state: tags int32[sets, ways] (OSPN, -1 invalid) + age uint8 (LRU
stack position, 0 = MRU).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class MCache(NamedTuple):
    tags: jnp.ndarray    # int32[sets, ways]
    age: jnp.ndarray     # int32[sets, ways]; 0 == MRU


def make_mcache(sets: int, ways: int) -> MCache:
    return MCache(tags=jnp.full((sets, ways), -1, jnp.int32),
                  age=jnp.tile(jnp.arange(ways, dtype=jnp.int32), (sets, 1)))


def _set_index(ospn: jnp.ndarray, sets: int) -> jnp.ndarray:
    # simple xor-fold hash; OSPNs are random-allocated (paper §5) so low bits ok
    x = jnp.asarray(ospn, jnp.uint32)
    x = x ^ (x >> jnp.uint32(13))
    return (x % jnp.uint32(sets)).astype(jnp.int32)


def access(mc: MCache, ospn: jnp.ndarray) -> Tuple[MCache, jnp.ndarray, jnp.ndarray]:
    """Touch ``ospn``: returns (new_cache, hit, evicted_ospn).

    evicted_ospn is -1 unless a valid entry was displaced (the lazy-update
    moment). The inserted/hit way becomes MRU."""
    s = _set_index(ospn, mc.tags.shape[0])
    tags = mc.tags[s]
    age = mc.age[s]
    match = tags == jnp.asarray(ospn, jnp.int32)
    hit = jnp.any(match)
    hit_way = jnp.argmax(match)
    victim_way = jnp.argmax(age)                # LRU way
    way = jnp.where(hit, hit_way, victim_way)
    evicted = jnp.where(hit, -1, tags[victim_way])
    new_tags = tags.at[way].set(jnp.asarray(ospn, jnp.int32))
    # promote `way` to MRU: everything younger than it ages by one
    w_age = age[way]
    new_age = jnp.where(age < w_age, age + 1, age)
    new_age = new_age.at[way].set(0)
    return (MCache(mc.tags.at[s].set(new_tags), mc.age.at[s].set(new_age)),
            hit, evicted.astype(jnp.int32))


def probe(mc: MCache, ospn: jnp.ndarray) -> jnp.ndarray:
    """Non-destructive residency check (used by the demotion engine)."""
    s = _set_index(ospn, mc.tags.shape[0])
    return jnp.any(mc.tags[s] == jnp.asarray(ospn, jnp.int32))


def invalidate(mc: MCache, ospn: jnp.ndarray) -> MCache:
    s = _set_index(ospn, mc.tags.shape[0])
    tags = mc.tags[s]
    match = tags == jnp.asarray(ospn, jnp.int32)
    new_tags = jnp.where(match, -1, tags)
    new_age = jnp.where(match, mc.age.shape[1] - 1, mc.age[s])
    return MCache(mc.tags.at[s].set(new_tags), mc.age.at[s].set(new_age))
