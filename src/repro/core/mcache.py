"""Set-associative LRU metadata-cache model (§4.1.2, Table 1: 16-way 96KB).

The cache holds metadata entries keyed by OSPN. It drives two paper mechanisms:
  * traffic: a hit serves translation with zero memory accesses; a miss costs a
    metadata read (1 access compacted; 2 when uncompacted entries straddle 64B);
  * the lazy reference update (§4.4): the activity-region ``referenced`` bit is
    written only when an entry is *evicted* from this cache, and the demotion
    engine *probes* this cache to avoid demoting resident (hot) pages.

Functional state: tags int32[sets, ways] (OSPN, -1 invalid) + age uint8 (LRU
stack position, 0 = MRU).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class MCache(NamedTuple):
    tags: jnp.ndarray    # int32[sets, ways]
    age: jnp.ndarray     # int32[sets, ways]; 0 == MRU


def make_mcache(sets: int, ways: int) -> MCache:
    return MCache(tags=jnp.full((sets, ways), -1, jnp.int32),
                  age=jnp.tile(jnp.arange(ways, dtype=jnp.int32), (sets, 1)))


def _set_index(ospn: jnp.ndarray, sets: int) -> jnp.ndarray:
    # simple xor-fold hash; OSPNs are random-allocated (paper §5) so low bits ok
    x = jnp.asarray(ospn, jnp.uint32)
    x = x ^ (x >> jnp.uint32(13))
    return (x % jnp.uint32(sets)).astype(jnp.int32)


def access(mc: MCache, ospn: jnp.ndarray) -> Tuple[MCache, jnp.ndarray, jnp.ndarray]:
    """Touch ``ospn``: returns (new_cache, hit, evicted_ospn).

    evicted_ospn is -1 unless a valid entry was displaced (the lazy-update
    moment). The inserted/hit way becomes MRU."""
    s = _set_index(ospn, mc.tags.shape[0])
    tags = mc.tags[s]
    age = mc.age[s]
    match = tags == jnp.asarray(ospn, jnp.int32)
    hit = jnp.any(match)
    hit_way = jnp.argmax(match)
    victim_way = jnp.argmax(age)                # LRU way
    way = jnp.where(hit, hit_way, victim_way)
    evicted = jnp.where(hit, -1, tags[victim_way])
    new_tags = tags.at[way].set(jnp.asarray(ospn, jnp.int32))
    # promote `way` to MRU: everything younger than it ages by one
    w_age = age[way]
    new_age = jnp.where(age < w_age, age + 1, age)
    new_age = new_age.at[way].set(0)
    return (MCache(mc.tags.at[s].set(new_tags), mc.age.at[s].set(new_age)),
            hit, evicted.astype(jnp.int32))


_BIG = jnp.int32(1 << 20)   # "never selected" recency score


def access_window(mc: MCache, ospns: jnp.ndarray
                  ) -> Tuple[MCache, jnp.ndarray, jnp.ndarray]:
    """Touch a window of W OSPNs at once (the batched front-end's vectorized
    metadata probe). Returns (new_cache, hits bool[W], evicted int32[sets,
    ways+W], -1 padded).

    Window-granular recency model: every access probes the window-start
    state (an access whose page appeared *earlier in the window* counts as a
    hit — the serial engine would have just inserted it); insertions and LRU
    updates are applied once per window by ranking, per set, the existing
    entries against the window's touches (later touch = more recent, every
    touch more recent than every untouched entry) and keeping the top
    ``ways``. This coarsens intra-window LRU ordering relative to the serial
    one-access-at-a-time walk — hit/miss totals agree within noise — in
    exchange for a fully vectorized update.
    """
    sets, ways = mc.tags.shape
    w = ospns.shape[0]
    ospns = jnp.asarray(ospns, jnp.int32)
    s = _set_index(ospns, sets)                                   # [W]
    in0 = jnp.any(mc.tags[s] == ospns[:, None], axis=1)           # [W]
    idx = jnp.arange(w)
    same = ospns[:, None] == ospns[None, :]
    dup = jnp.any(same & (idx[None, :] < idx[:, None]), axis=1)   # [W]
    hits = in0 | dup

    # per-set candidate ranking: existing entries score = age (0 = MRU),
    # window touch i scores -(i+1) (later = more recent, all beat existing)
    keep_w = ~jnp.any(same & (idx[None, :] > idx[:, None]), axis=1)  # last occurrence
    set_ids = jnp.arange(sets)
    win_in_set = (s[None, :] == set_ids[:, None]) & keep_w[None, :]  # [sets, W]
    win_tags = jnp.where(win_in_set, ospns[None, :], -1)
    win_score = jnp.where(win_in_set, -(idx[None, :] + 1), _BIG)
    # existing copies of re-touched pages are superseded by their window copy
    touched = jnp.any((mc.tags[:, :, None] == win_tags[:, None, :]) &
                      (win_tags[:, None, :] >= 0), axis=2)         # [sets, ways]
    ex_valid = (mc.tags >= 0) & (~touched)
    ex_tags = jnp.where(ex_valid, mc.tags, -1)
    ex_score = jnp.where(ex_valid, mc.age, _BIG)
    cand_tags = jnp.concatenate([ex_tags, win_tags], axis=1)       # [sets, ways+W]
    cand_score = jnp.concatenate([ex_score, win_score], axis=1)
    order = jnp.argsort(cand_score, axis=1)
    ranked_tags = jnp.take_along_axis(cand_tags, order, axis=1)
    ranked_score = jnp.take_along_axis(cand_score, order, axis=1)
    new_tags = jnp.where(ranked_score[:, :ways] < _BIG,
                         ranked_tags[:, :ways], -1)
    new_age = jnp.tile(jnp.arange(ways, dtype=jnp.int32), (sets, 1))
    evicted = jnp.where(ranked_score >= _BIG, -1,
                        ranked_tags).at[:, :ways].set(-1)
    return MCache(new_tags, new_age), hits, evicted


def probe(mc: MCache, ospn: jnp.ndarray) -> jnp.ndarray:
    """Non-destructive residency check (used by the demotion engine)."""
    s = _set_index(ospn, mc.tags.shape[0])
    return jnp.any(mc.tags[s] == jnp.asarray(ospn, jnp.int32))


def invalidate(mc: MCache, ospn: jnp.ndarray) -> MCache:
    s = _set_index(ospn, mc.tags.shape[0])
    tags = mc.tags[s]
    match = tags == jnp.asarray(ospn, jnp.int32)
    new_tags = jnp.where(match, -1, tags)
    new_age = jnp.where(match, mc.age.shape[1] - 1, mc.age[s])
    return MCache(mc.tags.at[s].set(new_tags), mc.age.at[s].set(new_age))
