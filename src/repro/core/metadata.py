"""IBEX compression metadata: compacted 32B entries (§4.6 co-location +
§4.7 compaction), plus the 4B page-activity entry format (§4.4).

Entry = uint32[8]:

word0 header
  bits  0..19 : 4 x (block_type 2b | block_sz 3b)     [co-location, §4.6]
  bits 20..23 : num_chunks (0..8)
  bits 24..27 : wr_cntr                                [incompressible retry]
  bit  28     : shadow_valid                           [shadowed promotion §4.5]
  bit  29     : dirty      (promoted copy modified)
  bit  30     : promoted   (P-chunk allocated)
  bit  31     : valid      (entry allocated)
words 1..6    : C-chunk pointers (28-bit, sub-region compacted, §4.7)
word  7       : C-chunk pointer OR P-chunk pointer when promoted (the paper's
                29-bit "last pointer"; §4.7)

block_type values follow the paper (§4.1.2 types, per-block under co-location):
  BT_ZERO / BT_COMP / BT_PROM / BT_INCOMP
block_sz s encodes (s+1)*128B. Our rate codes map bijectively:
  zero   <-> (BT_ZERO , s=0)
  4-bit  <-> (BT_COMP , s=2)   3 quanta
  8-bit  <-> (BT_COMP , s=4)   5 quanta
  raw    <-> (BT_INCOMP, s=7)  8 quanta
An all-raw page (num_chunks would be 8 > 7 pointer slots) becomes an
INCOMPRESSIBLE page stored in one aligned 8-chunk group behind a single
pointer — this is how the 32B compacted entry keeps full addressability.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.common.utils import get_bits, set_bits
from repro.core.bitpack import RATE_4BIT, RATE_8BIT, RATE_RAW, RATE_ZERO

ENTRY_WORDS = 8

BT_ZERO = 0
BT_COMP = 1
BT_PROM = 2
BT_INCOMP = 3

_RATE_TO_SZ = jnp.array([0, 2, 4, 7], dtype=jnp.uint32)      # indexed by rate
_RATE_TO_BT = jnp.array([BT_ZERO, BT_COMP, BT_COMP, BT_INCOMP], dtype=jnp.uint32)
# sz -> rate (valid sz values 0,2,4,7; others map to zero)
_SZ_TO_RATE = jnp.array([RATE_ZERO, RATE_ZERO, RATE_4BIT, RATE_ZERO,
                         RATE_8BIT, RATE_ZERO, RATE_ZERO, RATE_RAW], dtype=jnp.int32)


def empty_entry() -> jnp.ndarray:
    return jnp.zeros((ENTRY_WORDS,), jnp.uint32)


def empty_table(n_pages: int) -> jnp.ndarray:
    return jnp.zeros((n_pages, ENTRY_WORDS), jnp.uint32)


# -- header field accessors (operate on word0, vectorized over leading dims) --

def get_block_type(w0: jnp.ndarray, i) -> jnp.ndarray:
    return get_bits(w0, 5 * _as_int(i), 2) if isinstance(i, int) else \
        get_bits(w0, (jnp.asarray(i) * 5).astype(jnp.uint32), 2)


def _as_int(i: int) -> int:
    return i


def get_block_type_dyn(w0: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
    return (w0 >> (jnp.asarray(i, jnp.uint32) * 5)) & jnp.uint32(0x3)


def set_block_type(w0: jnp.ndarray, i: int, v) -> jnp.ndarray:
    return set_bits(w0, 5 * i, 2, v)


def get_block_sz(w0: jnp.ndarray, i: int) -> jnp.ndarray:
    return get_bits(w0, 5 * i + 2, 3)


def get_block_sz_dyn(w0: jnp.ndarray, i: jnp.ndarray) -> jnp.ndarray:
    return (w0 >> (jnp.asarray(i, jnp.uint32) * 5 + 2)) & jnp.uint32(0x7)


def set_block_sz(w0: jnp.ndarray, i: int, v) -> jnp.ndarray:
    return set_bits(w0, 5 * i + 2, 3, v)


def get_num_chunks(w0: jnp.ndarray) -> jnp.ndarray:
    return get_bits(w0, 20, 4)


def set_num_chunks(w0: jnp.ndarray, v) -> jnp.ndarray:
    return set_bits(w0, 20, 4, v)


def get_wr_cntr(w0: jnp.ndarray) -> jnp.ndarray:
    return get_bits(w0, 24, 4)


def set_wr_cntr(w0: jnp.ndarray, v) -> jnp.ndarray:
    return set_bits(w0, 24, 4, v)


def get_shadow_valid(w0: jnp.ndarray) -> jnp.ndarray:
    return get_bits(w0, 28, 1)


def set_shadow_valid(w0: jnp.ndarray, v) -> jnp.ndarray:
    return set_bits(w0, 28, 1, v)


def get_dirty(w0: jnp.ndarray) -> jnp.ndarray:
    return get_bits(w0, 29, 1)


def set_dirty(w0: jnp.ndarray, v) -> jnp.ndarray:
    return set_bits(w0, 29, 1, v)


def get_promoted(w0: jnp.ndarray) -> jnp.ndarray:
    return get_bits(w0, 30, 1)


def set_promoted(w0: jnp.ndarray, v) -> jnp.ndarray:
    return set_bits(w0, 30, 1, v)


def get_valid(w0: jnp.ndarray) -> jnp.ndarray:
    return get_bits(w0, 31, 1)


def set_valid(w0: jnp.ndarray, v) -> jnp.ndarray:
    return set_bits(w0, 31, 1, v)


# -- pointer slots ---------------------------------------------------------

PTR_MASK = jnp.uint32((1 << 29) - 1)


def get_ptr(entry: jnp.ndarray, slot) -> jnp.ndarray:
    return entry[..., 1 + slot] & PTR_MASK if isinstance(slot, int) else \
        jnp.take_along_axis(entry, jnp.asarray(slot)[..., None] + 1, axis=-1)[..., 0] & PTR_MASK


def set_ptr(entry: jnp.ndarray, slot: int, v) -> jnp.ndarray:
    return entry.at[..., 1 + slot].set(jnp.asarray(v).astype(jnp.uint32) & PTR_MASK)


PCHUNK_SLOT = ENTRY_WORDS - 2  # word7 == slot 6 (the paper's "last pointer")


# -- rate <-> (type, sz) mapping -------------------------------------------

def header_from_rates(rates: jnp.ndarray) -> jnp.ndarray:
    """Build word0 block fields from per-block rate codes (page not promoted,
    not dirty, wr_cntr=0, valid=1)."""
    w0 = jnp.uint32(0)
    nblocks = rates.shape[0]
    for i in range(nblocks):
        w0 = set_block_type(w0, i, _RATE_TO_BT[rates[i]])
        w0 = set_block_sz(w0, i, _RATE_TO_SZ[rates[i]])
    w0 = set_valid(w0, 1)
    return w0


def rates_from_header(w0: jnp.ndarray, nblocks: int = 4) -> jnp.ndarray:
    """Recover per-block rate codes from (type, sz) fields. Works for both
    resident-compressed and promoted-with-shadow pages (sz is preserved)."""
    rates = []
    for i in range(nblocks):
        bt = get_block_type(w0, i)
        sz = get_block_sz(w0, i)
        r = _SZ_TO_RATE[sz]
        r = jnp.where(bt == BT_ZERO, RATE_ZERO, r)
        rates.append(r)
    return jnp.stack(rates).astype(jnp.int32)


def quanta_from_header(w0: jnp.ndarray, nblocks: int = 4) -> jnp.ndarray:
    """Per-block quanta counts (0 for zero blocks, else sz+1)."""
    qs = []
    for i in range(nblocks):
        bt = get_block_type(w0, i)
        sz = get_block_sz(w0, i)
        qs.append(jnp.where(bt == BT_ZERO, 0, sz.astype(jnp.int32) + 1))
    return jnp.stack(qs)


# -- page activity entries (§4.4) -------------------------------------------

ACT_ALLOCATED_BIT = 31
ACT_REFERENCED_BIT = 30
ACT_OSPN_MASK = jnp.uint32((1 << 30) - 1)


def act_pack(allocated, referenced, ospn) -> jnp.ndarray:
    a = jnp.asarray(allocated).astype(jnp.uint32) << jnp.uint32(ACT_ALLOCATED_BIT)
    r = jnp.asarray(referenced).astype(jnp.uint32) << jnp.uint32(ACT_REFERENCED_BIT)
    return a | r | (jnp.asarray(ospn).astype(jnp.uint32) & ACT_OSPN_MASK)


def act_allocated(e: jnp.ndarray) -> jnp.ndarray:
    return (e >> jnp.uint32(ACT_ALLOCATED_BIT)) & jnp.uint32(1)


def act_referenced(e: jnp.ndarray) -> jnp.ndarray:
    return (e >> jnp.uint32(ACT_REFERENCED_BIT)) & jnp.uint32(1)


def act_ospn(e: jnp.ndarray) -> jnp.ndarray:
    return e & ACT_OSPN_MASK


def act_set_referenced(e: jnp.ndarray, v) -> jnp.ndarray:
    cleared = e & ~(jnp.uint32(1) << jnp.uint32(ACT_REFERENCED_BIT))
    return cleared | (jnp.asarray(v).astype(jnp.uint32) << jnp.uint32(ACT_REFERENCED_BIT))
