"""Rate-adaptive block compressor ("qpack") producing IBEX's chunked layout.

A 4KB page = 4 x 1KB blocks (co-location, §4.6). Each block is independently
encoded at one of four rates (zero / 4-bit / 8-bit / raw) and its stream is
compacted at 128B quanta granularity; the per-page quanta total determines
``num_chunks`` (512B C-chunks, §4.1.1). ``block_sz[i]`` is the paper's 3-bit
(s+1)*128B size code.

Block stream layout (this repo's TPU-native format):
  RATE_ZERO : 0 quanta
  RATE_4BIT : 3 quanta  = f32 scale (4B) + 256B packed int4 + pad
  RATE_8BIT : 5 quanta  = f32 scale (4B) + 512B int8 + pad
  RATE_RAW  : 8 quanta  = 1024B raw bf16

4KB-block mode (co-location disabled; paper baseline in Fig. 13) treats the
page as a single 2048-value block: sizes {0, 9, 17, 32} quanta.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.common.utils import bytes_to_f32, f32_to_bytes
from repro.core.bitpack import (RATE_4BIT, RATE_8BIT, RATE_RAW, RATE_ZERO,
                                bytes_to_raw, dequantize_block, pack4, pack8,
                                quantize_block, raw_to_bytes, unpack4, unpack8)

QUANTUM = 128


def resolve_impl(cfg: PoolConfig) -> str:
    """Resolve ``cfg.compress_impl``: "auto" picks the fused Pallas kernels
    on TPU and the pure-jnp oracle elsewhere (the interpreter would put a
    per-op Python loop on the hot path); "kernel"/"jnp" force a path (tests
    force "kernel" in interpret mode to assert bit-identity)."""
    impl = getattr(cfg, "compress_impl", "auto")
    if impl == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "jnp"
    return impl


def quanta_per_rate(vals_per_block: int) -> Tuple[int, int, int, int]:
    """Static (python-int) quanta per rate code for a ``vals_per_block``
    block — the fused kernel's static size table."""
    b4 = -(-(4 + vals_per_block // 2) // QUANTUM)
    b8 = -(-(4 + vals_per_block) // QUANTUM)
    braw = (2 * vals_per_block) // QUANTUM
    return (0, b4, b8, braw)


def block_quanta_table(vals_per_block: int) -> jnp.ndarray:
    """quanta per rate code for a block of ``vals_per_block`` bf16 values."""
    return jnp.array(quanta_per_rate(vals_per_block), dtype=jnp.int32)


def select_rate(x: jnp.ndarray, cfg: PoolConfig) -> jnp.ndarray:
    """Pick the cheapest admissible rate for block(s) ``x[..., vals]``."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    q4, s4 = quantize_block(x, 4)
    q8, s8 = quantize_block(x, 8)
    if cfg.lossless:
        ok4 = jnp.all(dequantize_block(q4, s4) == x.astype(jnp.bfloat16), axis=-1)
        ok8 = jnp.all(dequantize_block(q8, s8) == x.astype(jnp.bfloat16), axis=-1)
    else:
        err4 = jnp.max(jnp.abs(dequantize_block(q4, s4).astype(jnp.float32) - xf), axis=-1)
        err8 = jnp.max(jnp.abs(dequantize_block(q8, s8).astype(jnp.float32) - xf), axis=-1)
        safe = jnp.where(amax > 0, amax, 1.0)
        ok4 = err4 / safe <= cfg.tol4
        ok8 = err8 / safe <= cfg.tol8
    rate = jnp.where(ok8, RATE_8BIT, RATE_RAW)
    rate = jnp.where(ok4, RATE_4BIT, rate)
    rate = jnp.where(amax == 0, RATE_ZERO, rate)
    return rate.astype(jnp.int32)


def _encode_block_dense(x: jnp.ndarray, rate: jnp.ndarray) -> jnp.ndarray:
    """Encode one block at ``rate`` into a dense worst-case uint8 buffer
    (2*vals bytes); only the first ``quanta*128`` bytes are meaningful."""
    vals = x.shape[-1]
    nbytes = 2 * vals
    q4, s4 = quantize_block(x, 4)
    q8, s8 = quantize_block(x, 8)

    def enc_zero() -> jnp.ndarray:
        return jnp.zeros((nbytes,), jnp.uint8)

    def enc4() -> jnp.ndarray:
        buf = jnp.zeros((nbytes,), jnp.uint8)
        buf = jax.lax.dynamic_update_slice(buf, f32_to_bytes(s4[None]), (0,))
        return jax.lax.dynamic_update_slice(buf, pack4(q4), (4,))

    def enc8() -> jnp.ndarray:
        buf = jnp.zeros((nbytes,), jnp.uint8)
        buf = jax.lax.dynamic_update_slice(buf, f32_to_bytes(s8[None]), (0,))
        return jax.lax.dynamic_update_slice(buf, pack8(q8), (4,))

    def enc_raw() -> jnp.ndarray:
        return raw_to_bytes(x.astype(jnp.bfloat16))

    return jax.lax.switch(rate, [enc_zero, enc4, enc8, enc_raw])


def _decode_block_dense(buf: jnp.ndarray, rate: jnp.ndarray, vals: int) -> jnp.ndarray:
    """Inverse of ``_encode_block_dense``; ``buf`` is the dense 2*vals buffer."""
    def dec_zero() -> jnp.ndarray:
        return jnp.zeros((vals,), jnp.bfloat16)

    def dec4() -> jnp.ndarray:
        scale = bytes_to_f32(jax.lax.dynamic_slice(buf, (0,), (4,)))[0]
        codes = jax.lax.dynamic_slice(buf, (4,), (vals // 2,))
        return (unpack4(codes, vals).astype(jnp.float32) * scale).astype(jnp.bfloat16)

    def dec8() -> jnp.ndarray:
        scale = bytes_to_f32(jax.lax.dynamic_slice(buf, (0,), (4,)))[0]
        codes = jax.lax.dynamic_slice(buf, (4,), (vals,))
        return (unpack8(codes).astype(jnp.float32) * scale).astype(jnp.bfloat16)

    def dec_raw() -> jnp.ndarray:
        return bytes_to_raw(buf[: 2 * vals])

    return jax.lax.switch(rate, [dec_zero, dec4, dec8, dec_raw])


def _compact_page(dense: jnp.ndarray, quanta: jnp.ndarray,
                  cfg: PoolConfig) -> jnp.ndarray:
    """Compact dense per-block buffers [B, 2*vals] into one page stream at
    quanta granularity (shared by the jnp and kernel encode paths)."""
    nblocks = dense.shape[0]
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(quanta)[:-1]])
    buf = jnp.zeros((cfg.page_bytes,), jnp.uint8)
    pos = jnp.arange(cfg.page_bytes, dtype=jnp.int32)
    for i in range(nblocks):          # static trip count (4 or 1)
        # write the dense worst-case buffer at the compacted offset; overlap
        # with later blocks is fine because later writes overwrite pad bytes.
        start = offsets[i] * QUANTUM
        shifted = jax.lax.dynamic_update_slice(
            jnp.zeros((cfg.page_bytes,), jnp.uint8), dense[i], (start,))
        live = (pos >= start) & (pos < start + quanta[i] * QUANTUM)
        buf = jnp.where(live, shifted, buf)
    return buf


def _encode_page_jnp(x: jnp.ndarray, cfg: PoolConfig):
    nblocks = cfg.blocks_per_page if cfg.coloc else 1
    vals = x.shape[-1] // nblocks
    blocks = x.reshape(nblocks, vals)
    rates = select_rate(blocks, cfg)
    if not cfg.zero_elision:
        rates = jnp.maximum(rates, RATE_4BIT)
    qt = block_quanta_table(vals)
    quanta = qt[rates]
    dense = jnp.stack([_encode_block_dense(blocks[i], rates[i])
                       for i in range(nblocks)])
    buf = _compact_page(dense, quanta, cfg)
    total_quanta = jnp.sum(quanta)
    qpc = cfg.chunk_bytes // QUANTUM
    num_chunks = -(-total_quanta // qpc)
    return buf, rates, quanta, num_chunks.astype(jnp.int32)


def encode_pages(xs: jnp.ndarray, cfg: PoolConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Batched page compression: xs [P, vals_per_page] -> (bufs uint8
    [P, page_bytes], rates i32[P, B], quanta i32[P, B], num_chunks i32[P]).

    On the kernel path all P*B blocks go through ONE fused Pallas launch
    (rate-select + quantize + pack + quanta emit in a single grid pass);
    the jnp path vmaps the oracle. Both are bit-identical per page to
    ``encode_page`` (tests/test_qpack_fused.py)."""
    nblocks = cfg.blocks_per_page if cfg.coloc else 1
    vals = xs.shape[-1] // nblocks
    npages = xs.shape[0]
    if resolve_impl(cfg) == "kernel":
        from repro.kernels import ops as kops
        dense, rates, quanta = kops.qpack_fused_encode(
            xs.reshape(npages * nblocks, vals), tol4=cfg.tol4, tol8=cfg.tol8,
            lossless=cfg.lossless, zero_elision=cfg.zero_elision,
            quanta=quanta_per_rate(vals))
        dense = dense.reshape(npages, nblocks, 2 * vals)
        rates = rates.reshape(npages, nblocks)
        quanta = quanta.reshape(npages, nblocks)
        bufs = jax.vmap(lambda d, q: _compact_page(d, q, cfg))(dense, quanta)
        qpc = cfg.chunk_bytes // QUANTUM
        nchunks = (-(-jnp.sum(quanta, axis=-1) // qpc)).astype(jnp.int32)
        return bufs, rates, quanta, nchunks
    return jax.vmap(lambda x: _encode_page_jnp(x, cfg))(xs)


def encode_page(x: jnp.ndarray, cfg: PoolConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Compress a page of ``vals_per_page`` bf16 values.

    Returns (buf uint8[page_bytes] with compacted streams, rates i32[B],
    quanta i32[B], num_chunks i32[]) where B = blocks_per_page (co-location)
    or 1 (4KB-block mode). Dispatches on ``cfg.compress_impl``: the fused
    Pallas kernel on TPU, the jnp oracle elsewhere."""
    if resolve_impl(cfg) == "kernel":
        bufs, rates, quanta, nchunks = encode_pages(x[None], cfg)
        return bufs[0], rates[0], quanta[0], nchunks[0]
    return _encode_page_jnp(x, cfg)


def _page_dense_blocks(buf: jnp.ndarray, rates: jnp.ndarray,
                       vals: int) -> jnp.ndarray:
    """Slice a compacted page stream back into dense per-block buffers."""
    qt = block_quanta_table(vals)
    quanta = qt[rates]
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(quanta)[:-1]])
    return jnp.stack([
        jax.lax.dynamic_slice(buf, (offsets[i] * QUANTUM,), (2 * vals,))
        for i in range(rates.shape[0])])


def _decode_page_jnp(buf: jnp.ndarray, rates: jnp.ndarray,
                     cfg: PoolConfig) -> jnp.ndarray:
    nblocks = rates.shape[0]
    vals = cfg.vals_per_page // nblocks
    dense = _page_dense_blocks(buf, rates, vals)
    outs = [_decode_block_dense(dense[i], rates[i], vals)
            for i in range(nblocks)]
    return jnp.concatenate(outs, axis=0)


def decode_pages(bufs: jnp.ndarray, rates: jnp.ndarray,
                 cfg: PoolConfig) -> jnp.ndarray:
    """Batched page decompression: (bufs [P, page_bytes], rates [P, B]) ->
    bf16 [P, vals_per_page]. Kernel path: one fused promote launch over all
    P*B blocks (unpack + dequant for every rate in one grid pass)."""
    npages, nblocks = rates.shape
    vals = cfg.vals_per_page // nblocks
    if resolve_impl(cfg) == "kernel":
        from repro.kernels import ops as kops
        dense = jax.vmap(lambda b, r: _page_dense_blocks(b, r, vals))(
            bufs, rates)
        out = kops.qpack_fused_decode(dense.reshape(npages * nblocks, 2 * vals),
                                      rates.reshape(npages * nblocks))
        return out.reshape(npages, nblocks * vals)
    return jax.vmap(lambda b, r: _decode_page_jnp(b, r, cfg))(bufs, rates)


def decode_page(buf: jnp.ndarray, rates: jnp.ndarray, cfg: PoolConfig) -> jnp.ndarray:
    """Decompress all blocks of a page buffer back to bf16 values."""
    if resolve_impl(cfg) == "kernel":
        return decode_pages(buf[None], rates[None], cfg)[0]
    return _decode_page_jnp(buf, rates, cfg)


def decode_block(buf: jnp.ndarray, rates: jnp.ndarray, idx: jnp.ndarray,
                 cfg: PoolConfig) -> jnp.ndarray:
    """Decompress a single co-located block ``idx`` (uses block_sz prefix sums
    exactly as the metadata format intends)."""
    nblocks = rates.shape[0]
    vals = cfg.vals_per_page // nblocks
    qt = block_quanta_table(vals)
    quanta = qt[rates]
    prefix = jnp.cumsum(quanta) - quanta
    start = prefix[idx] * QUANTUM
    dense = jax.lax.dynamic_slice(buf, (start,), (2 * vals,))
    return _decode_block_dense(dense, rates[idx], vals)


# ---------------------------------------------------------------------------
# Flat fixed-rate tensor quantization (KV cache / optimizer-state fast path).
# ---------------------------------------------------------------------------

def quantize_blocks(x: jnp.ndarray, bits: int, block: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x[..., N] -> (packed codes uint8[..., N*bits/8], scales f32[..., N/block])."""
    lead = x.shape[:-1]
    n = x.shape[-1]
    xb = x.reshape(lead + (n // block, block))
    q, s = quantize_block(xb, bits)
    if bits == 4:
        codes = pack4(q).reshape(lead + (n // 2,))
    elif bits == 8:
        codes = pack8(q).reshape(lead + (n,))
    else:
        raise ValueError(f"bits={bits}")
    return codes, s


def dequantize_blocks(codes: jnp.ndarray, scales: jnp.ndarray, bits: int,
                      block: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    lead = scales.shape[:-1]
    nb = scales.shape[-1]
    if bits == 4:
        cb = codes.reshape(lead + (nb, block // 2))
        q = unpack4(cb, block)
    elif bits == 8:
        cb = codes.reshape(lead + (nb, block))
        q = unpack8(cb)
    else:
        raise ValueError(f"bits={bits}")
    return dequantize_block(q, scales, dtype).reshape(lead + (nb * block,))


def quantize_blocks_fast(x: jnp.ndarray, bits: int, block: int,
                         impl: str = "auto") -> Tuple[jnp.ndarray, jnp.ndarray]:
    """``quantize_blocks`` with an impl switch: "kernel" routes through the
    Pallas qpack encode kernel (bit-identical to the jnp path), "jnp" stays
    pure jnp, "auto" picks kernel only on TPU."""
    if impl == "auto":
        impl = "kernel" if jax.default_backend() == "tpu" else "jnp"
    if impl == "kernel":
        from repro.kernels import ops as kops
        return kops.qpack_encode(x, bits=bits, block=block)
    return quantize_blocks(x, bits, block)


def page_compressed_bytes(rates: jnp.ndarray, vals_per_block: int) -> jnp.ndarray:
    """Actual bytes a page occupies in the compressed region (quanta-rounded)."""
    qt = block_quanta_table(vals_per_block)
    return jnp.sum(qt[rates], axis=-1) * QUANTUM
