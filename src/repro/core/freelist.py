"""Functional free-chunk lists (§4.1.1).

The paper tracks free C-chunks and P-chunks with linked lists plus a head
register each. A functional array-stack is the JAX-native equivalent: ``items``
holds free chunk indices, ``top`` is the head register. Pop returns the head;
push writes back. All ops are O(1) and jit-safe; popping an empty list returns
sentinel -1 (callers must check, mirroring the hardware's watermark logic that
prevents true exhaustion).

Compaction (§4.7) splits the compressed region into sub-regions so chunk
pointers share MSBs. We model S sub-regions as S independent stacks laid out in
one array; the allocator round-robins pages across sub-regions ("all C-chunks
allocated to a single OSPA page must belong to the same sub-region").
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


class FreeList(NamedTuple):
    items: jnp.ndarray      # int32[capacity]
    top: jnp.ndarray        # int32[] — number of free items (head register)

    @property
    def capacity(self) -> int:
        return self.items.shape[0]


def make_freelist(n: int, reverse: bool = False) -> FreeList:
    idx = jnp.arange(n, dtype=jnp.int32)
    if reverse:
        idx = idx[::-1]
    return FreeList(items=idx, top=jnp.asarray(n, jnp.int32))


def free_count(fl: FreeList) -> jnp.ndarray:
    return fl.top


def pop(fl: FreeList) -> Tuple[FreeList, jnp.ndarray]:
    """Pop one index; returns -1 if empty."""
    has = fl.top > 0
    idx = jnp.where(has, fl.items[jnp.maximum(fl.top - 1, 0)], -1)
    new_top = jnp.where(has, fl.top - 1, fl.top)
    return FreeList(fl.items, new_top), idx.astype(jnp.int32)


def push(fl: FreeList, idx: jnp.ndarray) -> FreeList:
    """Push one index; idx < 0 is a no-op (makes masked pushes trivial)."""
    do = idx >= 0
    pos = jnp.clip(fl.top, 0, fl.capacity - 1)
    items = jax.lax.select(do, fl.items.at[pos].set(idx.astype(jnp.int32)), fl.items)
    top = jnp.where(do, fl.top + 1, fl.top)
    return FreeList(items, top)


def pop_n(fl: FreeList, k: int, valid_n: jnp.ndarray) -> Tuple[FreeList, jnp.ndarray]:
    """Pop up to ``k`` (static) indices, of which only the first ``valid_n``
    (dynamic) are actually consumed. Returns int32[k] with -1 padding."""
    def body(i, carry):
        fl_c, out = carry
        take = i < valid_n
        fl2, idx = pop(fl_c)
        fl_c = jax.tree_util.tree_map(
            lambda a, b: jax.lax.select(take, a, b), fl2, fl_c)
        out = out.at[i].set(jnp.where(take, idx, -1))
        return fl_c, out
    out0 = jnp.full((k,), -1, jnp.int32)
    fl, out = jax.lax.fori_loop(0, k, body, (fl, out0))
    return fl, out


def push_n(fl: FreeList, idxs: jnp.ndarray) -> FreeList:
    """Push all non-negative entries of ``idxs`` (static length)."""
    def body(i, fl_c):
        return push(fl_c, idxs[i])
    return jax.lax.fori_loop(0, idxs.shape[0], body, fl)
