"""Production training launcher: --arch selectable, mesh-aware, fault
tolerant (retry-from-checkpoint), deterministic data replay.

  PYTHONPATH=src python -m repro.launch.train --arch llama3_8b --reduced \
      --steps 50 --seq-len 128 --global-batch 8

Full-scale flags mirror the dry-run cells; on this CPU container use
--reduced (the full configs only lower/compile via repro.launch.dryrun).
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.common.types import OptimizerConfig, TrainConfig
from repro.configs import describe, get_config, get_reduced
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train import elastic
from repro.train.trainer import make_train_step
from repro.launch.mesh import make_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--compress-state", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--max-retries", type=int, default=2)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(describe(cfg))
    tcfg = TrainConfig(
        steps=args.steps, seq_len=args.seq_len,
        global_batch=args.global_batch, microbatches=args.microbatches,
        checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir or f"/tmp/repro_{args.arch}_ckpt",
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                  compress_state=args.compress_state))

    n_dev = len(jax.devices())
    mesh = None
    shardings = None
    box = {}

    def init():
        p, a = T.init_params(jax.random.PRNGKey(tcfg.seed), cfg)
        box["axes"] = a
        return p

    params = init()
    opt = adamw.init(params, tcfg.optimizer)
    if n_dev > 1:
        mesh = make_mesh(elastic.plan_mesh(n_dev, prefer_model=2))
        print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")
    step_fn, shardings = make_train_step(cfg, tcfg, mesh=mesh,
                                         param_axes=box.get("axes"))
    if shardings is not None:
        params = jax.device_put(params, shardings["params"])
        opt = jax.device_put(opt, shardings["opt"])

    start = 0
    latest = ckpt.latest(tcfg.checkpoint_dir)
    if latest is not None:
        tree, _ = ckpt.restore(tcfg.checkpoint_dir, latest,
                               {"params": params, "opt": opt},
                               None if shardings is None else
                               {"params": shardings["params"],
                                "opt": shardings["opt"]})
        params, opt = tree["params"], tree["opt"]
        start = latest
        print(f"resumed from step {latest}")

    retries = 0
    step = start
    t0 = time.time()
    while step < tcfg.steps:
        try:
            batch = make_batch(cfg, step, global_batch=tcfg.global_batch,
                               seq_len=tcfg.seq_len)
            if shardings is not None:
                batch = {k: jax.device_put(v, shardings["batch"].get(
                    k, shardings["batch"]["tokens"]))
                    for k, v in batch.items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if step % 10 == 0 or step == tcfg.steps - 1:
                dt = (time.time() - t0) / max(step - start + 1, 1)
                print(f"step {step:4d}  loss={float(metrics['loss']):.4f}  "
                      f"gnorm={float(metrics['grad_norm']):.3f}  "
                      f"{dt * 1e3:.0f} ms/step", flush=True)
            if (step + 1) % tcfg.checkpoint_every == 0:
                ckpt.save_async(tcfg.checkpoint_dir, step + 1,
                                {"params": params, "opt": opt},
                                keep=tcfg.keep_checkpoints)
            step += 1
        except Exception as e:   # step-level retry from the last checkpoint
            retries += 1
            if retries > args.max_retries:
                raise
            print(f"step {step} failed ({e}); retrying from last checkpoint")
            latest = ckpt.latest(tcfg.checkpoint_dir)
            if latest is not None:
                tree, _ = ckpt.restore(tcfg.checkpoint_dir, latest,
                                       {"params": params, "opt": opt})
                params, opt = tree["params"], tree["opt"]
                step = latest
    ckpt.wait_pending()
    print("training complete")


if __name__ == "__main__":
    main()
