"""Production mesh construction + per-shape sharding rule tables.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — the 512-device dry-run
sets XLA_FLAGS before any jax init, and smoke tests see the single real CPU.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

from repro.common import sharding as SH
from repro.common.types import MeshConfig, ModelConfig, ShapeConfig


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(cfg: MeshConfig):
    return jax.make_mesh(cfg.shape, cfg.axes)


# ---------------------------------------------------------------------------
# Rule tables per shape kind (the hillclimb lever; see EXPERIMENTS.md §Perf).
# ---------------------------------------------------------------------------

TRAIN_RULES = SH.DEFAULT_RULES

# decode: batch carries the data parallelism; KV seq local; heads on model.
DECODE_RULES: Tuple[Tuple[str, object], ...] = tuple(
    dict(SH.DEFAULT_RULES, **{
        "batch": ("pod", "data"),
        "kv_seq": None,
    }).items())

# long-context decode (global_batch=1): the *sequence* carries the data
# parallelism — chunk-parallel attention partials merge via all-reduce.
LONG_RULES: Tuple[Tuple[str, object], ...] = tuple(
    dict(SH.DEFAULT_RULES, **{
        "batch": None,
        "kv_seq": ("pod", "data"),
        "fsdp": None,              # batch=1: keep params on "model" only
    }).items())


def rules_for(shape: ShapeConfig, mesh_axes: Sequence[str],
              cfg: Optional[ModelConfig] = None, model_size: int = 16):
    if shape.kind == "train":
        return TRAIN_RULES
    base = LONG_RULES if shape.name.startswith("long") else DECODE_RULES
    if cfg is None:
        return base
    # archs whose KV head count does not divide the model axis shard the KV
    # *sequence* over "model" instead — the chunk-parallel decode attention
    # merges per-shard partials with a small all-reduce either way.
    kv_ok = cfg.attn_kind != "mla" and cfg.num_kv_heads % model_size == 0
    if shape.kind != "train" and not kv_ok:
        d = dict(base)
        d["kv_heads"] = None
        prev = d.get("kv_seq")
        d["kv_seq"] = (prev or ()) + ("model",)
        d["kv_hot"] = ("model",)   # ring W axis takes the model shards
        return tuple(d.items())
    return base


def batch_shards(shape: ShapeConfig, mesh) -> int:
    """How many ways the global batch is split on this mesh."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = sizes.get("data", 1) * sizes.get("pod", 1)
    return n
