import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# (No `from __future__ import` here — it would have to precede the XLA_FLAGS
# lines, and nothing below needs it.)
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent end-to-end:
``jax.jit(step, in_shardings, out_shardings).lower(**ShapeDtypeStructs)``
must compile for the single-pod (16,16) mesh and the 2-pod (2,16,16) mesh.
Outputs per cell: memory_analysis (fits?), cost_analysis (FLOPs/bytes),
collective-bytes by op kind (parsed from HLO) -> results/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
"""

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common import sharding as SH
from repro.common.types import (ALL_SHAPES, ModelConfig, OptimizerConfig,
                                ServeConfig, ShapeConfig, SHAPES_BY_NAME,
                                TrainConfig, replace)
from repro.configs import ARCH_IDS, get_config
from repro.launch import mesh as M
from repro.models import decode as D
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import trainer

RESULTS_DIR = "results/dryrun"

# hillclimb variant knobs (set by CLI; defaults = paper-faithful baseline)
VARIANT = {
    "paper_mode": False,        # serve: promote-then-read vs fused dequant
    "microbatches": None,       # train: override grad-accum microbatches
    "serve_replicate_params": False,  # decode/prefill: fsdp -> replicated
    "kv_bits": 4,
    "tag": "",
}


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input.
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {"tokens": _sds((B, S), jnp.int32),
             "labels": _sds((B, S), jnp.int32)}
    if cfg.frontend != "none":
        specs["embeds"] = _sds((B, S, cfg.d_model), jnp.bfloat16)
    return specs


def serve_cfg_for(cfg: ModelConfig, shape: ShapeConfig) -> ServeConfig:
    # chunk must divide the per-shard sequence (long: 524288/32 = 16384)
    chunk = 2048
    return ServeConfig(hot_window=256, attn_chunk=chunk,
                       kv_rate_bits=VARIANT["kv_bits"],
                       fused_dequant_attention=not VARIANT["paper_mode"])


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, logical-axes tree) with no allocation —
    init runs under eval_shape; the (static, string-leaved) axes tree escapes
    via a side channel since eval_shape outputs must be arrays."""
    box = {}

    def f():
        p, a = T.init_params(jax.random.PRNGKey(0), cfg)
        box["axes"] = a
        return p

    shapes = jax.eval_shape(f)
    return shapes, box["axes"]


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                tcfg: Optional[TrainConfig] = None) -> Dict[str, Any]:
    """Abstract inputs for the lowered step of this cell (no allocation)."""
    params = abstract_params(cfg)[0]
    if shape.kind == "train":
        tcfg = tcfg or train_cfg_for(cfg, shape)
        opt = jax.eval_shape(lambda: adamw.init(params, tcfg.optimizer))
        return {"params": params, "opt": opt,
                "batch": batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params, "batch": batch_specs(cfg, shape)}
    scfg = serve_cfg_for(cfg, shape)
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: D.init_cache(cfg, scfg, B, S))
    specs = {"params": params, "cache": cache,
             "tokens": _sds((B,), jnp.int32), "pos": _sds((B,), jnp.int32)}
    if cfg.frontend != "none":
        specs["embeds"] = _sds((B, cfg.d_model), jnp.bfloat16)
    return specs


def train_cfg_for(cfg: ModelConfig, shape: ShapeConfig) -> TrainConfig:
    # big models: bf16 moments keep optimizer HBM within a v5e (16GB)
    big = cfg.param_count() > 3e10
    mb = VARIANT["microbatches"]
    return TrainConfig(
        seq_len=shape.seq_len, global_batch=shape.global_batch,
        microbatches=(mb if mb else 8) if shape.kind == "train" else 1,
        optimizer=OptimizerConfig(
            moment_dtype="bfloat16" if big else "float32"))


# ---------------------------------------------------------------------------
# Step builders (jit-with-shardings per cell).
# ---------------------------------------------------------------------------

def _axes_tree(tree_axes, mesh, rules):
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(
            mesh, SH.logical_to_spec(axes, rules, mesh.axis_names)),
        tree_axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def _param_axes(cfg: ModelConfig):
    return abstract_params(cfg)[1]


def _model_size(mesh) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)


def make_train_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh):
    rules = M.rules_for(shape, mesh.axis_names, cfg, _model_size(mesh))
    tcfg = train_cfg_for(cfg, shape)
    axes = _param_axes(cfg)
    fn, shardings = trainer.make_train_step(cfg, tcfg, mesh=mesh, rules=rules,
                                            param_axes=axes)
    specs = input_specs(cfg, shape, tcfg)
    return fn, (specs["params"], specs["opt"], specs["batch"])


def make_prefill_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh):
    rules = _maybe_replicate_serve(
        M.rules_for(shape, mesh.axis_names, cfg, _model_size(mesh)))
    scfg = serve_cfg_for(cfg, shape)
    axes = _param_axes(cfg)
    p_shard = _axes_tree(axes, mesh, rules)
    bspec = NamedSharding(mesh, SH.logical_to_spec(("batch", "seq"), rules,
                                                   mesh.axis_names))
    bshard = {"tokens": bspec, "labels": bspec}
    if cfg.frontend != "none":
        bshard["embeds"] = NamedSharding(mesh, SH.logical_to_spec(
            ("batch", "seq", "embed"), rules, mesh.axis_names))
    cache_ax = D.cache_axes(cfg, scfg)
    cache_shard = _axes_tree(cache_ax, mesh, rules)
    logit_shard = NamedSharding(mesh, SH.logical_to_spec(
        ("batch", "vocab"), rules, mesh.axis_names))

    def step(params, batch):
        return D.prefill(params, batch, cfg, scfg, max_len=shape.seq_len)

    specs = input_specs(cfg, shape)
    bsp = dict(specs["batch"])
    bsp.pop("labels", None)
    bshard.pop("labels", None)
    fn = jax.jit(step, in_shardings=(p_shard, bshard),
                 out_shardings=(logit_shard, cache_shard))
    return fn, (specs["params"], bsp)


def _maybe_replicate_serve(rules):
    if not VARIANT["serve_replicate_params"]:
        return rules
    d = dict(rules)
    d["fsdp"] = None
    return tuple(d.items())


def make_decode_lowerable(cfg: ModelConfig, shape: ShapeConfig, mesh):
    rules = _maybe_replicate_serve(
        M.rules_for(shape, mesh.axis_names, cfg, _model_size(mesh)))
    scfg = serve_cfg_for(cfg, shape)
    axes = _param_axes(cfg)
    p_shard = _axes_tree(axes, mesh, rules)
    cache_shard = _axes_tree(D.cache_axes(cfg, scfg), mesh, rules)
    tok_shard = NamedSharding(mesh, SH.logical_to_spec(
        ("batch",), rules, mesh.axis_names))
    logit_shard = NamedSharding(mesh, SH.logical_to_spec(
        ("batch", "vocab"), rules, mesh.axis_names))
    specs = input_specs(cfg, shape)
    has_embeds = "embeds" in specs

    if has_embeds:
        emb_shard = NamedSharding(mesh, SH.logical_to_spec(
            ("batch", "embed"), rules, mesh.axis_names))

        def step(params, cache, tokens, pos, embeds):
            return D.decode_step(params, cache, tokens, pos, cfg, scfg,
                                 embeds=embeds)
        fn = jax.jit(step,
                     in_shardings=(p_shard, cache_shard, tok_shard, tok_shard,
                                   emb_shard),
                     out_shardings=(logit_shard, cache_shard))
        args = (specs["params"], specs["cache"], specs["tokens"],
                specs["pos"], specs["embeds"])
    else:
        def step(params, cache, tokens, pos):
            return D.decode_step(params, cache, tokens, pos, cfg, scfg)
        fn = jax.jit(step,
                     in_shardings=(p_shard, cache_shard, tok_shard, tok_shard),
                     out_shardings=(logit_shard, cache_shard))
        args = (specs["params"], specs["cache"], specs["tokens"], specs["pos"])
    return fn, args


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention (DESIGN.md skip)")
    return True, ""


# ---------------------------------------------------------------------------
# Collective-byte extraction from HLO text.
# ---------------------------------------------------------------------------

from repro.roofline.analyze import collective_bytes_from_hlo  # noqa: E402


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = applicable(cfg, shape)
    cell = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}" + \
        (f"__{VARIANT['tag']}" if VARIANT["tag"] else "")
    if not ok:
        rec = {"cell": cell, "status": "skipped", "reason": why}
        _write(out_dir, cell, rec)
        return rec
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with mesh:
        if shape.kind == "train":
            fn, args = make_train_lowerable(cfg, shape, mesh)
        elif shape.kind == "prefill":
            fn, args = make_prefill_lowerable(cfg, shape, mesh)
        else:
            fn, args = make_decode_lowerable(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1
        # collectives only exist in the post-SPMD module
        coll = collective_bytes_from_hlo(compiled.as_text())
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
    rec = {
        "cell": cell, "status": "ok", "arch": arch, "shape": shape_name,
        "mesh": list(mesh.devices.shape), "axes": list(mesh.axis_names),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": float(cost.get("flops", -1)),
        "bytes_accessed": float(cost.get("bytes accessed", -1)),
        "collective_bytes": coll,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        },
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    _write(out_dir, cell, rec)
    return rec


def _write(out_dir: str, cell: str, rec: Dict[str, Any]) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"{cell}.json"), "w") as f:
        json.dump(rec, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--paper-mode", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--serve-replicate-params", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=4)
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    VARIANT.update(paper_mode=args.paper_mode,
                   microbatches=args.microbatches or None,
                   serve_replicate_params=args.serve_replicate_params,
                   kv_bits=args.kv_bits, tag=args.tag)

    pods = []
    if args.multi_pod or not args.single_pod:
        pods.append(True)
    if args.single_pod or not args.multi_pod:
        pods.insert(0, False)

    cells = []
    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                try:
                    rec = run_cell(arch, shape, mp, args.out)
                    status = rec["status"]
                    extra = "" if status != "ok" else \
                        f" flops={rec['flops']:.3g} compile={rec['compile_s']}s"
                    print(f"[{status:7s}] {rec['cell']}{extra}", flush=True)
                except Exception as e:
                    failures += 1
                    print(f"[FAIL   ] {arch}__{shape}__"
                          f"{'pod2' if mp else 'pod1'}: {e}", flush=True)
                    traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
