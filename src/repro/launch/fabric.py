"""Multi-expander fabric launcher: replay a paper workload through a fabric
of N simulated expanders with a chosen placement mode (DESIGN.md §11/§13).

  PYTHONPATH=src python -m repro.launch.fabric --workload mcf --expanders 4 \
      --placement interleave --accesses 4096 --seed 0

``--skew`` forces a weighted placement that sends that fraction of pages to
expander 0 (migration stress); ``--migration {spill,rebalance,off}`` picks
the MigrationPolicy (spill = freelist pressure, rebalance = pressure +
traffic-imbalance trigger); ``--sync-migration`` forces the synchronous
reference driver (PR 3 semantics: migration on the critical path);
``--pipeline-depth 1`` runs the pipelined scheduler degenerately (plan and
apply at the same boundary). ``--verify-depth1`` replays the same trace
through BOTH and asserts final pool state + counters are bit-identical
(the refactor's parity pin — the CI smoke). ``--check-parity``
additionally replays every expander's partition through the single-pool
engine and asserts the summed counters match the fabric exactly.

``--devices N`` runs the sharded driver (DESIGN.md §17): the stacked
pool pytree is placed on an N-device ``expander`` mesh and replayed
shard_map-ed, with migration planned and applied inside the jit (one
fused host fetch per boundary). The forced host-device count must reach
XLA before its backend initializes — the repro imports below pull in
jax — so the flag is pre-scanned from argv and merged into XLA_FLAGS as
this module's first executable statements (same idiom as
launch/dryrun.py). On sharded runs ``--check-parity`` asserts the
sharded end state is bit-identical to the vmap synchronous reference
(every pool leaf, counters included) — the shard_map-vs-vmap contract —
and falls through to the per-shard single-pool check when no migration
fired.
"""
import os
import sys

# --devices N must reach XLA before the backend initializes, and every
# repro import below pulls in jax: pre-scan argv, merge the flag first.
# (Mirrors common.sharding.force_host_device_count, inlined so nothing
# jax-adjacent is imported before the env var is set.)
for _i, _a in enumerate(sys.argv):
    _n = None
    if _a == "--devices" and _i + 1 < len(sys.argv):
        _n = sys.argv[_i + 1]
    elif _a.startswith("--devices="):
        _n = _a.split("=", 1)[1]
    if _n and _n.isdigit() and int(_n) > 1:
        _kept = [f for f in os.environ.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        _kept.append(f"--xla_force_host_platform_device_count={_n}")
        os.environ["XLA_FLAGS"] = " ".join(_kept)

# (no `from __future__ import` — it would have to precede the XLA_FLAGS
# bootstrap above; same trade as launch/dryrun.py)
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import POLICIES
from repro.fabric import Fabric, make_placement
from repro.simx import time as TM
from repro.simx.engine import TRAFFIC_KEYS, pool_cfg_for
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="mcf", choices=sorted(WORKLOADS))
    ap.add_argument("--scheme", default="ibex", choices=sorted(POLICIES))
    ap.add_argument("--expanders", type=int, default=4)
    ap.add_argument("--placement", default="interleave",
                    choices=("interleave", "capacity", "locality"))
    ap.add_argument("--skew", type=float, default=0.0,
                    help="page share forced onto expander 0 (>0 overrides "
                         "--placement with a weighted interleave)")
    ap.add_argument("--accesses", type=int, default=4096)
    ap.add_argument("--pages", type=int, default=512)
    ap.add_argument("--prom", type=int, default=32,
                    help="promoted P-chunks per expander")
    ap.add_argument("--window", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--migration", default="spill",
                    choices=("spill", "rebalance", "off"),
                    help="MigrationPolicy: freelist-pressure spill, "
                         "pressure + traffic-imbalance rebalancing, or off")
    ap.add_argument("--no-spill", action="store_true",
                    help="back-compat alias for --migration off")
    ap.add_argument("--sync-migration", action="store_true",
                    help="force the synchronous reference driver (plan and "
                         "apply at every boundary, migration on the "
                         "critical path — the parity anchor)")
    ap.add_argument("--pipeline-depth", type=int, default=2, choices=(1, 2),
                    help="segment-scheduler depth: 2 overlaps migration "
                         "behind the next segment's replay, 1 degenerates "
                         "to the synchronous schedule")
    ap.add_argument("--verify-depth1", action="store_true",
                    help="replay the trace through the depth-1 pipeline AND "
                         "the synchronous driver and assert bit-identical "
                         "final state (the CI overlapped-migration smoke)")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="run the sharded driver on an N-device expander "
                         "mesh (forces N XLA host devices before backend "
                         "init via the argv pre-scan at module top; "
                         "requires --expanders divisible by N)")
    ap.add_argument("--check-parity", action="store_true")
    ap.add_argument("--trace", default=None, metavar="OUT.trace.json",
                    help="attach a repro.obs.Recorder (piggybacked on the "
                         "existing per-segment/per-epoch fetches — zero "
                         "extra syncs, asserted below), write the Perfetto "
                         "trace_event export there plus a metrics.json "
                         "sibling, and print the per-segment summary table")
    ap.add_argument("--device-profile", default="default",
                    help="comma-separated simx.time.DEVICE_PROFILES names "
                         f"({', '.join(sorted(TM.DEVICE_PROFILES))}) or "
                         "'calibrated' (engine constants from the measured "
                         "BENCH_kernels.json; paper constants if absent), "
                         "cycled across expanders — e.g. 'default,gen4' "
                         "makes an alternating mixed-generation fleet")
    args = ap.parse_args()

    profiles = [p.strip() for p in args.device_profile.split(",") if p.strip()]
    unknown = [p for p in profiles
               if p != "calibrated" and p not in TM.DEVICE_PROFILES]
    if unknown:
        ap.error(f"unknown device profile(s) {unknown}; choose from "
                 f"{sorted(TM.DEVICE_PROFILES) + ['calibrated']}")
    if len(profiles) > args.expanders:
        ap.error(f"{len(profiles)} device profiles for "
                 f"{args.expanders} expanders")
    devices = [TM.calibrated_device() if p == "calibrated"
               else TM.DEVICE_PROFILES[p] for p in profiles]

    policy = POLICIES[args.scheme]
    cfg = pool_cfg_for(policy, n_pages=args.pages, n_pchunks=args.prom,
                       n_cchunks=2 * args.pages * 4)
    spec = WORKLOADS[args.workload]
    rates = make_rates_table(spec, args.pages, seed=args.seed)
    ospn, wr, blk = make_trace(spec, n_accesses=args.accesses,
                               n_pages=args.pages, seed=args.seed)
    n = args.expanders

    def new_placement():
        if args.skew > 0:
            rest = (1.0 - args.skew) / max(n - 1, 1)
            return make_placement("weighted", n, args.pages,
                                  weights=[args.skew] + [rest] * (n - 1))
        return make_placement(args.placement, n, args.pages)

    placement = new_placement()
    migration = "off" if args.no_spill else args.migration

    def make_fabric(pl, **kw):
        return Fabric(cfg, policy, pl, seed=args.seed,
                      rates_table=jnp.asarray(rates), window=args.window,
                      migration=migration, devices=devices, **kw)

    if args.devices is not None:
        from repro.fabric import shard as FS
        if jax.device_count() < args.devices:
            ap.error(f"--devices {args.devices} but only "
                     f"{jax.device_count()} XLA devices visible")
        owners = FS.device_of_expander(n, args.devices)
        print(f"mesh: {args.devices} forced host device(s), axis "
              f"'expander', {n} expanders "
              f"({n // args.devices} per device)")
        for d in range(args.devices):
            owned = np.nonzero(owners == d)[0]
            print(f"  device {d} ({jax.devices()[d].platform}): "
                  f"expanders {owned.tolist()}")

    rec = None
    if args.trace:
        from repro.obs import Recorder
        rec = Recorder()
    fab = make_fabric(placement, sync_migration=args.sync_migration,
                      pipeline_depth=args.pipeline_depth, obs=rec,
                      shard_devices=args.devices)
    t0 = time.time()
    fab.replay(ospn, wr, blk)
    dt = time.time() - t0
    agg = fab.counters()
    print(f"fabric: {n} expanders, placement="
          f"{'weighted' if args.skew > 0 else args.placement}, "
          f"profiles={','.join(profiles)}, "
          f"{args.accesses} accesses in {dt:.1f}s "
          f"({args.accesses / max(dt, 1e-9):,.0f} acc/s, compile included)")
    per = fab.counters_by_expander()
    delivered = fab.delivered_time()
    for e, c in enumerate(per):
        host = c["host_reads"] + c["host_writes"]
        internal = sum(c[k] for k in TRAFFIC_KEYS)
        print(f"  expander {e} ({profiles[e % len(profiles)]}): "
              f"host={host} internal={internal} "
              f"promotions={c['promotions']} "
              f"demotions={c['demotions_clean'] + c['demotions_dirty']} "
              f"delivered={delivered[e] * 1e6:.1f}us")
    print(f"  aggregate: host={agg['host_reads'] + agg['host_writes']} "
          f"internal={sum(agg[k] for k in TRAFFIC_KEYS)}")
    bottleneck = float(delivered.max())
    print(f"  delivered time (bottleneck expander "
          f"{int(delivered.argmax())}): {bottleneck * 1e6:.1f}us "
          f"({args.accesses / bottleneck:,.0f} modeled acc/s)")
    print(f"  migration ({fab.migration_policy.name}): {fab.spill_stats()}")
    ss = fab.sync_stats()
    if args.devices is not None:
        assert ss["segment_syncs"] == 0 and ss["epoch_syncs"] == 0, ss
        assert ss["boundary_syncs"] == ss["boundaries"], ss
        print(f"  syncs: {ss} (sharded: one fused fetch per boundary, "
              f"asserted)")
    else:
        assert ss["segment_syncs"] == ss["segments"], ss
        assert ss["epoch_syncs"] == ss["epochs"], ss
        print(f"  syncs: {ss} (one per segment + one per epoch, asserted)")
    pt = fab.pipeline_times()
    if pt is not None and fab.epochs_applied:
        over = float(np.max(pt["overlapped_s"]))
        sync = float(np.max(pt["sync_s"]))
        print(f"  pipeline pricing ({pt['mode']}): overlapped={over * 1e6:.1f}us "
              f"sync={sync * 1e6:.1f}us "
              f"(migration overlap hides {(sync - over) * 1e6:.2f}us)")

    if rec is not None:
        from repro.obs import export as OBX
        # the contract held with recording ON (sync asserts above); the
        # exported tracks must reconcile with the pipeline pricing exactly
        totals = OBX.fabric_track_totals(rec)
        if pt is not None:
            assert np.allclose(totals["overlapped_s"], pt["overlapped_s"],
                               rtol=1e-9), "trace drifted from pipeline_times"
        dev_totals = OBX.fabric_device_totals(rec)
        if dev_totals is not None:
            dts = fab.device_times()
            assert np.allclose(dev_totals["device_s"], dts["device_s"],
                               rtol=1e-9), \
                "device tracks drifted from Fabric.device_times"
            print(f"  device tracks: "
                  f"{[f'{t * 1e6:.1f}us' for t in dts['device_s']]} "
                  f"(reconcile with device_times at rtol=1e-9, asserted)")
        OBX.write_trace(rec, args.trace)
        mpath = (args.trace[: -len(".trace.json")] if
                 args.trace.endswith(".trace.json") else args.trace) \
            + ".metrics.json"
        OBX.write_metrics(rec, mpath, seed=args.seed)
        print(f"  trace: {args.trace} (+ {mpath}); per-expander track "
              f"totals reconcile with pipeline_times (asserted)")
        print(OBX.fabric_summary_table(rec))

    if args.verify_depth1:
        f1 = make_fabric(new_placement(), pipeline_depth=1)
        fs = make_fabric(new_placement(), sync_migration=True)
        f1.replay(ospn, wr, blk)
        fs.replay(ospn, wr, blk)
        assert f1.state_identical(fs), \
            "depth-1 pipeline drifted from the synchronous driver"
        print(f"  verify-depth1: depth-1 pipeline == synchronous driver "
              f"(bit-identical; {fs.epochs_applied} epochs)")

    if args.check_parity:
        if args.devices is not None:
            # the shard_map-vs-vmap contract: the sharded end state is
            # bit-identical (every pool leaf, counters included) to the
            # vmap synchronous reference on the same trace — migration
            # live included, since the collective apply replays the host
            # planner's exact move sequence
            ref = make_fabric(new_placement(), sync_migration=True)
            ref.replay(ospn, wr, blk)
            assert fab.state_identical(ref), \
                "sharded driver drifted from the vmap reference"
            print(f"parity: sharded (D={args.devices}) == vmap synchronous "
                  f"driver (bit-identical; {ref.epochs_applied} epochs; "
                  f"sharded used {ss['host_syncs']} host syncs vs "
                  f"{ref.sync_stats()['host_syncs']})")
        eids = placement.route(ospn)
        if (placement.overrides >= 0).any():
            print("parity check skipped: migration fired (re-run with "
                  "--migration off for the exact contract)")
            return
        stack0 = S.make_pool_stack(cfg, n, seed=args.seed,
                                   rates_table=jnp.asarray(rates))
        total = {k: 0 for k in S.COUNTER_NAMES}
        for e in range(n):
            sel = eids == e
            ref = B.replay_trace(S.pool_slice(stack0, e), cfg, policy,
                                 ospn[sel], wr[sel], blk[sel],
                                 window=args.window)
            for k, v in S.counters_dict(ref).items():
                total[k] += v
        assert fab.counters() == total, "fabric drifted from single-pool"
        print("parity: summed fabric counters == per-shard single-pool "
              "replays (exact)")


if __name__ == "__main__":
    main()
