"""Serving launcher: batched synthetic request workload through the IBEX
paged-KV engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
      --requests 8 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.common.types import ServeConfig
from repro.configs import describe, get_config, get_reduced
from repro.models import transformer as T
from repro.serve.engine import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--kv-bits", type=int, default=8, choices=(4, 8))
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--paper-mode", action="store_true",
                    help="promote-then-read instead of fused dequant attn")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(describe(cfg))
    scfg = ServeConfig(max_running=args.lanes, hot_window=16, attn_chunk=32,
                       kv_rate_bits=args.kv_bits,
                       fused_dequant_attention=not args.paper_mode)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, scfg, params, max_len=args.max_len)

    rng = np.random.default_rng(0)
    rids = [eng.submit(list(rng.integers(1, cfg.vocab_size, args.prompt_len)),
                       args.new_tokens) for _ in range(args.requests)]
    t0 = time.time()
    eng.run_until_done(max_steps=5000)
    dt = time.time() - t0
    done = sum(eng.requests[r].state == "done" for r in rids)
    print(f"served {done}/{len(rids)} requests, "
          f"{eng.counters['tokens']} tokens in {dt:.1f}s "
          f"({eng.counters['tokens'] / max(dt, 1e-9):.1f} tok/s)")
    print(f"pool: promotions={eng.counters['promotions']} "
          f"demotions={eng.counters['demotions']} "
          f"preempt_bytes={eng.counters['preempt_bytes']}")
    for rid in rids[:3]:
        print(f"  req {rid}: {eng.result(rid)}")


if __name__ == "__main__":
    main()
