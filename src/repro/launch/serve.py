"""Serving launcher: batched synthetic request workload through the IBEX
paged-KV engine (device-resident batched scheduler by default; ``--serial``
runs the per-lane baseline for comparison).

  PYTHONPATH=src python -m repro.launch.serve --arch llama3_8b --reduced \
      --requests 8 --new-tokens 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.common.types import ServeConfig
from repro.configs import describe, get_config, get_reduced
from repro.models import transformer as T
from repro.serve import Engine, SerialEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--vary-prompts", action="store_true",
                    help="mix prompt lengths (exercises length bucketing)")
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--kv-bits", type=int, default=8, choices=(4, 8))
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--serial", action="store_true",
                    help="per-lane baseline engine instead of the batched "
                         "scheduler")
    ap.add_argument("--paper-mode", action="store_true",
                    help="promote-then-read instead of fused dequant attn")
    ap.add_argument("--trace", default=None, metavar="OUT.trace.json",
                    help="attach a repro.obs.Recorder (samples ride the "
                         "engine's single per-step fetch — zero extra "
                         "syncs, asserted below), write the Perfetto "
                         "trace_event export there plus a metrics.json "
                         "sibling")
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    print(describe(cfg))
    scfg = ServeConfig(max_running=args.lanes, hot_window=16, attn_chunk=32,
                       kv_rate_bits=args.kv_bits,
                       fused_dequant_attention=not args.paper_mode)
    params, _ = T.init_params(jax.random.PRNGKey(0), cfg)
    engine_cls = SerialEngine if args.serial else Engine
    rec = None
    if args.trace:
        from repro.obs import Recorder
        rec = Recorder()
    eng = engine_cls(cfg, scfg, params, max_len=args.max_len, obs=rec)

    rng = np.random.default_rng(0)
    def plen(i):
        return (8 + 4 * (i % 5)) if args.vary_prompts else args.prompt_len
    rids = [eng.submit(list(rng.integers(1, cfg.vocab_size, plen(i))),
                       args.new_tokens) for i in range(args.requests)]
    t0 = time.time()
    eng.run_until_done(max_steps=5000)
    dt = time.time() - t0
    done = sum(eng.requests[r].state == "done" for r in rids)
    c = eng.counters
    print(f"served {done}/{len(rids)} requests, "
          f"{c['tokens']} tokens in {dt:.1f}s "
          f"({c['tokens'] / max(dt, 1e-9):.1f} tok/s) "
          f"[{'serial' if args.serial else 'batched'}]")
    print(f"pool: promotions={c['promotions']} demotions={c['demotions']} "
          f"preempt_bytes={c['preempt_bytes']} "
          f"shadow_repreempts={c['shadow_repreempts']}")
    print(f"host: step_syncs={c['step_syncs']}/{c['steps']} steps, "
          f"admit_syncs={c['admit_syncs']}, "
          f"prefill_batches={c['prefill_batches']}")
    mt = eng.modeled_time()
    print(f"modeled (DESIGN.md §12): {mt['modeled_s'] * 1e3:.3f}ms total, "
          f"{mt['modeled_s_per_step'] * 1e6:.2f}us/step "
          f"(sync={mt['sync_s'] * 1e3:.3f}ms, motion bottleneck="
          f"{max(mt['motion_s_per_expander']) * 1e6:.2f}us)")
    if rec is not None:
        from repro.obs import export as OBX
        if not args.serial:   # serial baseline syncs once per lane per step
            assert c["step_syncs"] == c["steps"], \
                "recording changed the per-step sync budget"
        OBX.write_trace(rec, args.trace)
        mpath = (args.trace[: -len(".trace.json")] if
                 args.trace.endswith(".trace.json") else args.trace) \
            + ".metrics.json"
        OBX.write_metrics(rec, mpath)
        print(f"trace: {args.trace} (+ {mpath}); "
              f"{len(rec.steps)} steps, {len(rec.serve_events)} events "
              f"recorded at zero extra syncs (asserted)")
    for rid in rids[:3]:
        print(f"  req {rid}: {eng.result(rid)}")


if __name__ == "__main__":
    main()
