"""Placement layer: OSPA page -> expander routing (DESIGN.md §11).

Hyperscale CXL deployments interleave pages across several expanders, and
delivered bandwidth is dominated by how well that placement spreads traffic.
A ``Placement`` owns the page->expander map the fabric routes with:

  * ``StaticInterleave``  — stateless interleave by multiplicative page
    hash (the OS's random page allocation makes this near-uniform);
  * ``CapacityAware``     — sticky greedy: a page is pinned on first sight
    to the expander with the fewest pages assigned so far;
  * ``LocalityAffinity``  — contiguous OSPN ranges per expander (NUMA-style
    affinity: pages of one tenant/zone land together);
  * ``WeightedInterleave`` — hash interleave with per-expander weights; the
    skew knob for the fabric bench's sensitivity sweep.

All placements carry an *override* table written by the spill/migration
path (fabric/ops.py): once a page migrates, routing follows the override,
not the base rule. Routing is host-side numpy — partitioning happens before
the jitted vmapped replay.
"""
from __future__ import annotations

import numpy as np

# Knuth multiplicative hash constant; OSPNs carry no spatial locality
# (random OS page placement) but the hash makes interleave robust to
# structured page-id patterns from synthetic traces too.
_HASH_MULT = np.uint64(2654435761)


class Placement:
    """Base: override table + routing; subclasses define ``assign``."""

    def __init__(self, n_expanders: int, n_pages: int):
        if n_expanders < 1:
            raise ValueError("n_expanders must be >= 1")
        self.n_expanders = n_expanders
        self.n_pages = n_pages
        # spill/migration overrides: -1 = follow the base rule
        self.overrides = np.full((n_pages,), -1, np.int32)
        # committed migration epochs (one per apply_epoch batch; the
        # segment scheduler's "one scatter per epoch" contract)
        self.epoch = 0

    def assign(self, ospns: np.ndarray) -> np.ndarray:
        """Base page->expander rule (int32[len(ospns)])."""
        raise NotImplementedError

    def route(self, ospns: np.ndarray) -> np.ndarray:
        """Effective routing: overrides first, base rule otherwise."""
        ospns = np.asarray(ospns, np.int64)
        base = self.assign(ospns)
        ov = self.overrides[ospns]
        return np.where(ov >= 0, ov, base).astype(np.int32)

    def override(self, ospns: np.ndarray, expander: int) -> None:
        """Pin migrated pages to their new expander (one destination)."""
        self.apply_epoch(ospns, np.full(len(np.atleast_1d(ospns)),
                                        expander, np.int32))

    def apply_epoch(self, ospns: np.ndarray, dests: np.ndarray) -> None:
        """Commit one migration epoch: pin each page to its destination in
        a SINGLE batched scatter (no per-page host writes — the segment
        scheduler's override-update contract, DESIGN.md §13). Bumps the
        epoch counter even for empty batches so the scheduler's
        epoch/sync accounting stays 1:1 with committed applies."""
        ospns = np.atleast_1d(np.asarray(ospns, np.int64))
        if len(ospns):
            self.overrides[ospns] = np.asarray(dests, np.int32)
        self.epoch += 1


class StaticInterleave(Placement):
    """Stateless interleave by page hash."""

    def assign(self, ospns: np.ndarray) -> np.ndarray:
        h = (np.asarray(ospns, np.uint64) * _HASH_MULT) >> np.uint64(16)
        return (h % np.uint64(self.n_expanders)).astype(np.int32)


class WeightedInterleave(Placement):
    """Hash interleave into per-expander probability buckets — the skew
    knob: ``weights=[0.8, 0.2/…]`` sends 80% of pages to expander 0."""

    def __init__(self, n_expanders: int, n_pages: int, weights):
        super().__init__(n_expanders, n_pages)
        w = np.asarray(weights, np.float64)
        if w.shape != (n_expanders,) or w.min() < 0 or w.sum() <= 0:
            raise ValueError(f"bad weights {weights}")
        self.cum = np.cumsum(w / w.sum())

    def assign(self, ospns: np.ndarray) -> np.ndarray:
        h = (np.asarray(ospns, np.uint64) * _HASH_MULT) >> np.uint64(16)
        u = (h % np.uint64(1 << 20)).astype(np.float64) / float(1 << 20)
        return np.searchsorted(self.cum, u, side="right").clip(
            0, self.n_expanders - 1).astype(np.int32)


class LocalityAffinity(Placement):
    """Contiguous OSPN ranges: expander = ospn * N // n_pages."""

    def assign(self, ospns: np.ndarray) -> np.ndarray:
        o = np.asarray(ospns, np.int64).clip(0, self.n_pages - 1)
        return (o * self.n_expanders // self.n_pages).astype(np.int32)


class CapacityAware(Placement):
    """Sticky greedy: first sight of a page pins it to the expander with
    the fewest pages assigned so far (deterministic: ties break to the
    lowest expander id). Models capacity-aware OS/fabric page allocation."""

    def __init__(self, n_expanders: int, n_pages: int):
        super().__init__(n_expanders, n_pages)
        self.page_to_exp = np.full((n_pages,), -1, np.int32)
        self.load = np.zeros((n_expanders,), np.int64)

    def assign(self, ospns: np.ndarray) -> np.ndarray:
        ospns = np.asarray(ospns, np.int64)
        # only each page's FIRST occurrence needs the sequential greedy
        # step; everything else is a table lookup
        uniq, first = np.unique(ospns, return_index=True)
        for o in uniq[np.argsort(first)]:
            if self.page_to_exp[o] < 0:
                e = int(np.argmin(self.load))
                self.page_to_exp[o] = e
                self.load[e] += 1
        return self.page_to_exp[ospns]


def make_placement(mode: str, n_expanders: int, n_pages: int,
                   weights=None) -> Placement:
    """CLI/bench factory: interleave | capacity | locality | weighted."""
    if mode == "interleave":
        return StaticInterleave(n_expanders, n_pages)
    if mode == "capacity":
        return CapacityAware(n_expanders, n_pages)
    if mode == "locality":
        return LocalityAffinity(n_expanders, n_pages)
    if mode == "weighted":
        return WeightedInterleave(n_expanders, n_pages, weights)
    raise ValueError(f"unknown placement mode {mode!r}")
