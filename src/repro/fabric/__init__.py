"""Multi-expander pool fabric (DESIGN.md §11).

Runs N independent ``engine.state.Pool``s as one stacked pytree and routes
OSPA pages to expanders through a pluggable placement layer:

  * ``placement`` — static interleave by page hash, capacity-aware greedy,
    locality-affinity range partition, weighted interleave (skew studies);
    all carry a spill-override table;
  * ``ops``       — cross-expander page migration (the spill path), built
    from the same §4 mechanism ops as demotion;
  * ``replay``    — trace partitioning + vmapped replay over the stacked
    state (reusing ``engine.batch``'s window bodies unchanged), per-expander
    watermark demotion, and the spill orchestrator.
"""
from repro.fabric import ops, placement, replay
from repro.fabric.ops import spill_pages
from repro.fabric.placement import (CapacityAware, LocalityAffinity,
                                    Placement, StaticInterleave,
                                    WeightedInterleave, make_placement)
from repro.fabric.replay import Fabric, partition_trace

__all__ = [
    "ops", "placement", "replay",
    "Placement", "StaticInterleave", "CapacityAware", "LocalityAffinity",
    "WeightedInterleave", "make_placement",
    "Fabric", "partition_trace", "spill_pages",
]
