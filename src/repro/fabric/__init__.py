"""Multi-expander pool fabric (DESIGN.md §11/§13).

Runs N independent ``engine.state.Pool``s as one stacked pytree and routes
OSPA pages to expanders through a pluggable placement layer:

  * ``placement`` — static interleave by page hash, capacity-aware greedy,
    locality-affinity range partition, weighted interleave (skew studies);
    all carry a migration-override table with a batched epoch-apply API;
  * ``ops``       — cross-expander page migration mechanism: in-jit
    per-segment stats (headroom / eligibility / referenced bits) and the
    batched epoch apply, built from the same §4 mechanism ops as demotion;
  * ``migration`` — the MigrationPolicy layer (mirrors
    ``core/engine/policy.Policy``): freelist-pressure spill,
    traffic-imbalance rebalancing, off;
  * ``replay``    — the segment scheduler: trace partitioning + vmapped
    replay over the stacked state (reusing ``engine.batch``'s window
    bodies unchanged), double-buffered overlapped migration with a
    carried pending-page mask, and the synchronous reference driver;
  * ``shard``     — the same fabric on a *real* device mesh (DESIGN.md
    §17): ``shard_map``-ed replay over the ``expander`` axis, the
    MigrationPolicy plan step as a pure jittable function, and epochs
    applied as collective page motion (psum metadata broadcast +
    ppermute payload ring) — bit-identical per expander to the vmap
    drivers, one fused host sync per boundary.
"""
from repro.fabric import migration, ops, placement, replay, shard
from repro.fabric.migration import (MigrationPlan, MigrationPolicy,
                                    NoMigration, SegmentView, SpillPressure,
                                    TrafficRebalance, make_migration_policy)
from repro.fabric.ops import apply_migrations, segment_stats, spill_pages
from repro.fabric.placement import (CapacityAware, LocalityAffinity,
                                    Placement, StaticInterleave,
                                    WeightedInterleave, make_placement)
from repro.fabric.replay import Fabric, partition_trace

__all__ = [
    "migration", "ops", "placement", "replay", "shard",
    "Placement", "StaticInterleave", "CapacityAware", "LocalityAffinity",
    "WeightedInterleave", "make_placement",
    "MigrationPolicy", "MigrationPlan", "SegmentView", "NoMigration",
    "SpillPressure", "TrafficRebalance", "make_migration_policy",
    "Fabric", "partition_trace", "spill_pages", "apply_migrations",
    "segment_stats",
]
