"""Sharded fabric execution: the stacked pool pytree on a real device
mesh (DESIGN.md §17).

The vmapped drivers in ``fabric/replay.py`` simulate "N expanders" as one
stacked array on ONE device — modeled delivered time scales, wall-clock
does not. This module runs the same computation ``shard_map``-ed over the
``expander`` mesh axis (``common.sharding.expander_mesh``): each device
owns an equal block of ``L = N / D`` expanders and replays its shard with
the SAME vmapped ``batch._replay_windows_masked`` window bodies, so
per-expander counters are bit-identical to the single-device vmap oracle
(all pool state is integer; asserted by tests/test_fabric_sharded.py and
every benchmarks/fabric_bench.py sharded point).

Three pieces:

  * ``plan_in_jit``      — the ``MigrationPolicy`` plan step as a pure
    jittable function over the in-jit ``segment_stats`` facts, mirroring
    ``SpillPressure._pressure_moves`` / ``TrafficRebalance.plan`` move
    for move (same candidate order, same donor accounting, same urgency
    rule), so the per-segment ``_fetch_view`` host fetch becomes optional
    telemetry instead of a control dependency;
  * ``collective_apply`` — one migration epoch as collective page motion:
    per move, ONE ``lax.psum`` broadcasts the source's metadata entry and
    the destination's live allocation-headroom bit (dynamic src/dst ranks
    cannot use ``ppermute``'s static permutations), and the compressed
    payload rides a ``lax.ppermute`` ring — log2(D) unconditional
    rotation stages selected by the bits of the replicated (dst - src)
    rotation amount. All collectives sit OUTSIDE ``lax.cond``; the conds
    guard only local slice updates (the ``migrate_src`` / ``migrate_dst``
    halves ``fabric.ops.migrate_page`` itself is composed from), keeping
    the apply bit-identical to the host-planned ``apply_migrations``;
  * ``replay_step`` / ``boundary_step`` — lru-cached jitted
    ``shard_map`` builders the ``Fabric`` sharded driver calls: a plain
    sharded segment replay (migration off), and the fused
    replay → all_gather stats → plan → collective-apply boundary whose
    outcome the host fetches in ONE sync (``Fabric._commit_boundary``).

Planner parity note: all pool state and spill logic is integer, so the
``spill`` policy plans bit-identically to the host planner. The
``rebalance`` time trigger compares float32 device times where the host
compares float64 promotions of the same float32 values — equivalent
except at exact ties of ``time_ratio * times[cold]``, which the parity
tests script away from.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common.sharding import EXPANDER_AXIS
from repro.common.types import PoolConfig
from repro.core.engine import batch as B
from repro.core.engine import ops
from repro.core.engine.policy import Policy
from repro.core.engine.state import C_HOST_RD, C_HOST_WR, Pool
from repro.fabric import migration as MG
from repro.fabric import ops as fops
from repro.simx import time as TM


def plan_params(policy: "MG.MigrationPolicy") -> Tuple:
    """Hashable planner parameters for the jit cache (``MigrationPolicy``
    dataclasses are unhashable). ``kind`` selects the in-jit planner."""
    if isinstance(policy, MG.TrafficRebalance):
        return ("rebalance", policy.k, policy.low, policy.proactive,
                policy.trigger, policy.time_ratio, policy.min_delta)
    if isinstance(policy, MG.SpillPressure):
        return ("spill", policy.k, policy.low, policy.proactive)
    raise ValueError(f"no in-jit planner for {policy.name!r}")


def plan_rows(params: Tuple, n_expanders: int) -> int:
    """Plan rows: one per potential pressure source, plus the rebalance
    row. Row-major flattening preserves the host planner's move order
    (ascending starved expander, rebalance last)."""
    return n_expanders + (1 if params[0] == "rebalance" else 0)


def plan_in_jit(params: Tuple, free_units, free_singles, free_groups,
                eligible, referenced, delta, times, blocked):
    """The MigrationPolicy plan step, jittable: mirrors
    ``SpillPressure._pressure_moves`` (+ the ``TrafficRebalance``
    traffic trigger) over the in-jit stats. Returns ``(pages, srcs,
    dsts, urgent)`` with pages int32[R, k] -1-padded per row — a row per
    potential source expander in ascending order (the host loop's order)
    plus the rebalance row, so the flattened real moves sequence exactly
    as the host plan's concatenation.

    ``recent`` is omitted: the synchronous scheduling the sharded driver
    uses never carries recently-moved pages (``_replay_sync`` passes
    zeros), and ``blocked`` plays the livelock-guard role."""
    kind, k = params[0], int(params[1])
    low, proactive = int(params[2]), float(params[3])
    n, n_pages = eligible.shape
    free0 = free_units.astype(jnp.int32)
    donor_ok = (free_singles >= 7) & (free_groups >= 1)
    # the trigger set is fixed from the ORIGINAL headroom (the host loop
    # computes np.nonzero before any donor decrement)
    trig = free0 < proactive * low
    cand_all = eligible & ~blocked[None, :]
    rows = plan_rows(params, n)
    pages0 = jnp.full((rows, k), -1, jnp.int32)
    srcs0 = jnp.zeros((rows, k), jnp.int32)
    dsts0 = jnp.zeros((rows, k), jnp.int32)
    lane = jnp.arange(k, dtype=jnp.int32)

    def body(e, carry):
        free, urgent, pages, srcs, dsts = carry
        donor = jnp.argmax(free).astype(jnp.int32)
        cand = cand_all[e]
        cnt = jnp.minimum(cand.sum(), k).astype(jnp.int32)
        ok = trig[e] & (donor != e) & (free[donor] >= 2 * low) & \
            donor_ok[donor] & (cnt > 0)
        idx = jnp.nonzero(cand, size=k, fill_value=n_pages)[0] \
            .astype(jnp.int32)
        urgent = urgent | (ok & (free[e] < low))
        pages = pages.at[e].set(jnp.where(ok & (lane < cnt), idx, -1))
        srcs = srcs.at[e].set(jnp.full((k,), e, jnp.int32))
        dsts = dsts.at[e].set(jnp.full((k,), donor, jnp.int32))
        # conservative donor accounting within one plan (8 units/page)
        free = free.at[donor].add(jnp.where(ok, -8 * cnt, 0))
        return free, urgent, pages, srcs, dsts

    free, urgent, pages, srcs, dsts = lax.fori_loop(
        0, n, body, (free0, jnp.asarray(False), pages0, srcs0, dsts0))

    if kind == "rebalance" and n > 1:
        trigger, time_ratio = float(params[4]), float(params[5])
        min_delta = int(params[6])
        host_d = delta[:, C_HOST_RD] + delta[:, C_HOST_WR]
        total = host_d.sum()
        hot = jnp.argmax(host_d).astype(jnp.int32)
        ok_d = (free >= 2 * low) & donor_ok
        ok_d = ok_d.at[hot].set(False)
        fire = (total >= min_delta) & ok_d.any() & \
            (host_d[hot] * n > trigger * total)
        cold = jnp.argmin(jnp.where(ok_d, times, jnp.inf)).astype(jnp.int32)
        fire = fire & (times[hot] > time_ratio * times[cold])
        # pages the pressure moves already claimed are off the table
        claimed = jnp.zeros((n_pages + 1,), bool).at[
            jnp.where(pages >= 0, pages, n_pages).reshape(-1)].set(True)
        cand = cand_all[hot] & ~claimed[:n_pages]
        refd = cand & referenced[hot]
        # referenced-first, then remaining candidates, each in page order:
        # a stable argsort over the 3-level rank reproduces the host's
        # concatenated np.nonzero ordering exactly
        rank = jnp.where(refd, 0, jnp.where(cand, 1, 2)).astype(jnp.int32)
        order = jnp.argsort(rank, stable=True).astype(jnp.int32)[:k]
        cnt = jnp.minimum(cand.sum(), k).astype(jnp.int32)
        fire = fire & (cnt > 0)
        pages = pages.at[n].set(jnp.where(fire & (lane < cnt), order, -1))
        srcs = srcs.at[n].set(jnp.full((k,), hot, jnp.int32))
        dsts = dsts.at[n].set(jnp.full((k,), cold, jnp.int32))
    return pages, srcs, dsts, urgent


def collective_apply(stack_l: Pool, cfg: PoolConfig, policy: Policy,
                     pages, srcs, dsts, n_local: int, n_devices: int
                     ) -> Tuple[Pool, jnp.ndarray]:
    """One migration epoch on the LOCAL pool shard [L, ...] inside a
    ``shard_map`` over the expander axis; ``pages``/``srcs``/``dsts``
    are the replicated flattened plan (int32[K], pages -1-padded).

    Per move: the source's metadata entry and the destination's live
    headroom bit cross the mesh in ONE fused psum of masked
    contributions; the payload rides the ppermute ring (skipped entirely
    when ``cfg.store_payload`` is off — the simx pools carry no bytes);
    the eligibility / headroom / guard conjunction is exactly
    ``apply_migrations``', and the serial fori order is preserved, so
    the result is bit-identical to the host-planned apply. Returns the
    updated shard plus the replicated int32[K] moved OSPNs (-1 where
    skipped)."""
    rank = lax.axis_index(EXPANDER_AXIS).astype(jnp.int32)
    mw = stack_l.meta.shape[-1]

    def body(i, carry):
        stack, moved = carry
        p, s, d = pages[i], srcs[i], dsts[i]
        pc = jnp.maximum(p, 0)
        sdev, sloc = s // n_local, s % n_local
        ddev, dloc = d // n_local, d % n_local
        is_src = sdev == rank
        is_dst = ddev == rank
        entry_l = stack.meta[sloc, pc]
        head_l = (stack.cfree.top[dloc] >= 7) & (stack.gfree.top[dloc] >= 1)
        # one psum broadcasts entry (from src) + headroom bit (from dst)
        vec = jnp.concatenate([
            jnp.where(is_src, entry_l, jnp.zeros_like(entry_l)),
            jnp.where(is_dst & head_l, jnp.uint32(1), jnp.uint32(0))[None]])
        vec = lax.psum(vec, EXPANDER_AXIS)
        entry, headroom = vec[:mw], vec[mw] > 0
        eligible, nchunks = fops.page_eligible(entry)
        ok = (p >= 0) & (s != d) & headroom & eligible
        if cfg.store_payload:
            src_pool = jax.tree_util.tree_map(lambda a: a[sloc], stack)
            buf = ops._gather_page_buf(src_pool, cfg, entry)
            buf = jnp.where(is_src, buf, jnp.zeros_like(buf))
            # ppermute needs a STATIC permutation; the dynamic src->dst
            # route decomposes into log2(D) fixed +2^b ring rotations,
            # each taken iff that bit of the replicated rotation is set
            rot = jnp.mod(ddev - sdev, n_devices)
            for b in range((n_devices - 1).bit_length()):
                perm = [(j, (j + (1 << b)) % n_devices)
                        for j in range(n_devices)]
                shifted = lax.ppermute(buf, EXPANDER_AXIS, perm)
                take = ((rot >> b) & 1).astype(bool)
                buf = jnp.where(take, shifted, buf)
        else:
            buf = jnp.zeros((cfg.page_bytes,), jnp.uint8)

        def upd_src(sl):
            sp = jax.tree_util.tree_map(lambda a: a[sloc], sl)
            sp = fops.migrate_src(sp, cfg, policy, pc, entry, nchunks)
            return jax.tree_util.tree_map(
                lambda a, x: a.at[sloc].set(x), sl, sp)

        def upd_dst(sl):
            dp = jax.tree_util.tree_map(lambda a: a[dloc], sl)
            dp = fops.migrate_dst(dp, cfg, policy, pc, entry, nchunks, buf)
            return jax.tree_util.tree_map(
                lambda a, x: a.at[dloc].set(x), sl, dp)

        stack = lax.cond(ok & is_src, upd_src, lambda sl: sl, stack)
        stack = lax.cond(ok & is_dst, upd_dst, lambda sl: sl, stack)
        moved = moved.at[i].set(jnp.where(ok, p, -1))
        return stack, moved

    moved0 = jnp.full(pages.shape, -1, jnp.int32)
    return lax.fori_loop(0, pages.shape[0], body, (stack_l, moved0))


def _local_replay(pools_l, cfg, policy, o, w, b, v, lanes_l, pending):
    """The per-shard segment replay: the SAME vmap composition the
    single-device ``_replay_stacked`` runs over all N expanders, over
    the local L — hence bit-identity per expander."""
    pools_l = jax.vmap(
        lambda p, oo, ww, bb, vv: B._replay_windows_masked(
            p, cfg, policy, oo, ww, bb, vv, pending,
            # XLA:CPU miscompiles the fori/while slow drain inside
            # shard_map manual regions on devices != 0 (batch._window_step)
            unroll_slow=True)
    )(pools_l, o, w, b, v)
    times_l = jax.vmap(TM.exec_time_vec)(pools_l.counters, lanes_l)
    return pools_l, times_l


@functools.lru_cache(maxsize=None)
def replay_step(mesh: Mesh, cfg: PoolConfig, policy: Policy,
                need_free: bool):
    """Jitted shard_map segment replay (migration off): returns
    ``(pools, times[, free_units])``, every output sharded over the
    expander axis — the host fetches nothing per segment (the deferred
    ``Fabric._drain_deferred`` fetch prices the run afterwards)."""
    ax = P(EXPANDER_AXIS)

    def local(pools_l, o, w, b, v, lanes_l, pending):
        pools_l, times_l = _local_replay(pools_l, cfg, policy,
                                         o, w, b, v, lanes_l, pending)
        if not need_free:
            return pools_l, times_l
        stats_l = jax.vmap(lambda p: fops.segment_stats(p, cfg))(pools_l)
        return pools_l, times_l, stats_l.free_units

    outs = (ax, ax) if not need_free else (ax, ax, ax)
    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(ax, ax, ax, ax, ax, ax, P()),
        out_specs=outs, check_rep=False))


@functools.lru_cache(maxsize=None)
def boundary_step(mesh: Mesh, cfg: PoolConfig, policy: Policy,
                  mparams: Tuple, n_expanders: int):
    """Jitted shard_map replay + in-jit plan + collective apply: one
    segment boundary in ONE dispatch, no host round-trip between the
    stats and the epoch. Outputs, in order:

      pools       sharded   post-apply stack
      times       sharded   float32[N] post-replay delivered seconds
      ctrs_mid    sharded   [N, C] post-replay / pre-apply counters
      free_pre    sharded   int32[N] post-replay headroom (chunk units)
      fc, fg      sharded   int32[N] post-apply freelist tops
      pages/srcs/dsts  replicated  the flattened plan (pages -1-padded)
      urgent      replicated  bool
      moved       replicated  int32[K] applied OSPNs (-1 where skipped)

    The host commit (``Fabric._commit_boundary``) fetches the lot —
    plus the returned pools' counters — in one ``jax.device_get``: one
    sync per boundary, versus the pipelined driver's one per segment
    PLUS one per epoch."""
    n_dev = mesh.devices.size
    if n_expanders % n_dev:
        raise ValueError(f"{n_expanders} expanders not divisible by "
                         f"{n_dev} devices")
    n_local = n_expanders // n_dev
    ax = P(EXPANDER_AXIS)

    def local(pools_l, o, w, b, v, lanes_l, pending, blocked):
        ctrs_prev_l = pools_l.counters
        pools_l, times_l = _local_replay(pools_l, cfg, policy,
                                         o, w, b, v, lanes_l, pending)
        stats_l = jax.vmap(lambda p: fops.segment_stats(p, cfg))(pools_l)
        ctrs_mid_l = pools_l.counters
        delta_l = ctrs_mid_l - ctrs_prev_l

        def gather(x):
            return lax.all_gather(x, EXPANDER_AXIS, tiled=True)

        # replicate the planner's view: every device plans identically
        pages, srcs, dsts, urgent = plan_in_jit(
            mparams, gather(stats_l.free_units),
            gather(stats_l.free_singles), gather(stats_l.free_groups),
            gather(stats_l.eligible), gather(stats_l.referenced),
            gather(delta_l), gather(times_l), blocked)
        pools_l, moved = collective_apply(
            pools_l, cfg, policy, pages.reshape(-1), srcs.reshape(-1),
            dsts.reshape(-1), n_local, n_dev)
        return (pools_l, times_l, ctrs_mid_l, stats_l.free_units,
                pools_l.cfree.top, pools_l.gfree.top,
                pages, srcs, dsts, urgent, moved)

    return jax.jit(shard_map(
        local, mesh=mesh,
        in_specs=(ax, ax, ax, ax, ax, ax, P(), P()),
        out_specs=(ax, ax, ax, ax, ax, ax, P(), P(), P(), P(), P()),
        check_rep=False))


def shard_pools(pools: Pool, mesh: Mesh) -> Pool:
    """Place a stacked pool pytree with its leading expander axis sharded
    over the mesh (host->device placement, not a sync)."""
    sh = NamedSharding(mesh, P(EXPANDER_AXIS))
    return jax.tree_util.tree_map(lambda a: jax.device_put(a, sh), pools)


def device_of_expander(n_expanders: int, n_devices: int) -> np.ndarray:
    """int [N]: which mesh device owns each expander (block layout)."""
    return np.arange(n_expanders) // (n_expanders // n_devices)
