"""Fabric replay: partition traces by expander, advance all expanders in
parallel with ``vmap`` over the stacked pool state (DESIGN.md §11).

A merged (ospn, is_write, block) trace is split into spill *segments*; each
segment is partitioned by the placement's current routing (base rule +
spill overrides), padded per expander to a common window-aligned length,
and replayed through ``engine.batch._replay_windows_masked`` vmapped over
the expander axis — the window bodies are the single-pool ones, unchanged,
so per-expander counters are bit-identical to replaying that expander's
partition through ``batch.replay_trace`` on a single pool (the fabric's
parity contract, asserted by tests/test_fabric.py and
benchmarks/fabric_bench.py). Per-expander watermark demotion runs inside
each expander's own windows exactly as on a single pool.

Between segments the host performs one freelist-occupancy sync; if an
expander's compressed-region freelists fall below the spill watermark while
another has headroom, ``fabric.ops.spill_pages`` migrates compressed pages
to the most-free donor and the placement override table pins them there.

Padded window counts are bucketed to powers of two so a whole skew sweep
compiles a handful of shapes per expander count.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import PoolConfig
from repro.common.utils import next_pow2
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import Policy
from repro.fabric import ops as fops
from repro.fabric.placement import Placement


def partition_trace(placement: Placement, ospns, writes, blocks,
                    window: int) -> Tuple[np.ndarray, ...]:
    """Route a trace and pack it per expander: [N, n_win, W] arrays plus a
    validity mask. Each expander's partition keeps the merged trace's
    relative order and sits as a prefix before the padding, so the masked
    replay walks full windows, then one partial window, then no-ops — the
    exact shapes ``batch.replay_trace`` produces on a single pool."""
    n = placement.n_expanders
    ospns = np.asarray(ospns, np.int32)
    writes = np.asarray(writes, bool)
    blocks = np.asarray(blocks, np.int32)
    eids = placement.route(ospns)
    counts = np.bincount(eids, minlength=n)
    n_win = next_pow2(-(-max(int(counts.max()), 1) // window))
    L = n_win * window
    o = np.zeros((n, L), np.int32)
    w = np.zeros((n, L), bool)
    b = np.zeros((n, L), np.int32)
    v = np.zeros((n, L), bool)
    for e in range(n):
        sel = eids == e
        k = int(counts[e])
        o[e, :k] = ospns[sel]
        w[e, :k] = writes[sel]
        b[e, :k] = blocks[sel]
        v[e, :k] = True
    shp = (n, n_win, window)
    return (o.reshape(shp), w.reshape(shp), b.reshape(shp), v.reshape(shp),
            eids)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _replay_stacked(pools: S.Pool, cfg: PoolConfig, policy: Policy,
                    ospns, writes, blocks, valid) -> S.Pool:
    return jax.vmap(
        lambda p, o, w, b, v: B._replay_windows_masked(p, cfg, policy,
                                                       o, w, b, v)
    )(pools, ospns, writes, blocks, valid)


class Fabric:
    """N expanders as one stacked pool state + a placement + spill policy.

    ``spill_low`` is the compressed-region watermark in *chunks* (singles +
    8x groups): an expander below it is starved; a donor must clear
    ``2 * spill_low``. ``spill_k`` pages move per event. ``spill_interval``
    is the segment length between occupancy checks — one host sync each.
    """

    def __init__(self, cfg: PoolConfig, policy: Policy, placement: Placement,
                 *, seed: int = 0, rates_table=None, window: Optional[int] = None,
                 spill: bool = True, spill_interval: int = 2048,
                 spill_k: int = 16, spill_low: Optional[int] = None):
        if placement.n_pages != cfg.n_pages:
            raise ValueError("placement/page-space mismatch")
        self.cfg = cfg
        self.policy = policy
        self.placement = placement
        self.n_expanders = placement.n_expanders
        self.window = B.DEFAULT_WINDOW if window is None else window
        self.spill_enabled = spill and self.n_expanders > 1
        self.spill_interval = spill_interval
        self.spill_k = spill_k
        self.spill_low = (max(16, cfg.n_cchunks // 16)
                          if spill_low is None else spill_low)
        self.pools = S.make_pool_stack(cfg, self.n_expanders, seed=seed,
                                       rates_table=rates_table)
        n = self.n_expanders
        self.spill_events = 0
        self.spill_pages_out = np.zeros((n,), np.int64)
        self.spill_pages_in = np.zeros((n,), np.int64)
        self.spill_syncs = 0

    # -- replay --------------------------------------------------------------

    def replay(self, ospns, writes, blocks) -> "Fabric":
        """Replay a merged trace through all expanders.

        The trace is partitioned ONCE and replayed in window-aligned chunks
        of ``spill_interval`` accesses per expander, so each expander's
        window boundaries are exactly those of ``batch.replay_trace`` over
        its partition — if no spill fires, per-expander counters are
        bit-identical to single-pool replays of the partitions (the parity
        contract). When a spill fires, the unconsumed tail of every
        expander's partition is re-merged and re-partitioned so accesses to
        migrated pages follow their page to the donor expander."""
        rem = (np.asarray(ospns, np.int32), np.asarray(writes, bool),
               np.asarray(blocks, np.int32))
        while rem is not None and len(rem[0]):
            o, w, b, v, eids = partition_trace(self.placement, *rem,
                                               self.window)
            counts = np.bincount(eids, minlength=self.n_expanders)
            n_win = o.shape[1]
            if self.spill_enabled:
                seg = next_pow2(max(self.spill_interval // self.window, 1))
                seg = min(seg, n_win)
            else:
                seg = n_win
            rem = None
            for lo in range(0, n_win, seg):
                sl = slice(lo, lo + seg)
                self.pools = _replay_stacked(
                    self.pools, self.cfg, self.policy,
                    jnp.asarray(o[:, sl]), jnp.asarray(w[:, sl]),
                    jnp.asarray(b[:, sl]), jnp.asarray(v[:, sl]))
                if not self.spill_enabled:
                    continue
                fired = self._maybe_spill()
                more = v[:, lo + seg:].any() if lo + seg < n_win else False
                if fired and more:
                    # rebuild the unconsumed per-expander tails in original
                    # merged-trace order (after re-routing, one expander may
                    # merge accesses from several old streams — interleaving
                    # them by trace position keeps its replay order faithful)
                    done = (lo + seg) * self.window
                    tails = [np.nonzero(eids == e)[0][done:]
                             for e in range(self.n_expanders)]
                    perm = np.argsort(np.concatenate(tails), kind="stable")
                    rem = tuple(
                        np.concatenate([
                            a.reshape(self.n_expanders, -1)[e,
                                                            done:counts[e]]
                            for e in range(self.n_expanders)])[perm]
                        for a in (o, w, b))
                    break
        return self

    # -- spill ---------------------------------------------------------------

    def _chunk_headroom(self) -> np.ndarray:
        """Per-expander free compressed capacity in single-chunk units
        (one host sync)."""
        ct, gt = jax.device_get((self.pools.cfree.top, self.pools.gfree.top))
        self.spill_syncs += 1
        return np.asarray(ct, np.int64) + 8 * np.asarray(gt, np.int64)

    def _maybe_spill(self) -> bool:
        """One occupancy check; migrate from each starved expander to the
        most-free donor. Returns True when any page actually moved."""
        free = self._chunk_headroom()
        fired = False
        for e in np.nonzero(free < self.spill_low)[0]:
            donor = int(np.argmax(free))
            if donor == int(e) or free[donor] < 2 * self.spill_low:
                continue
            src = S.pool_slice(self.pools, int(e))
            dst = S.pool_slice(self.pools, donor)
            src, dst, moved = fops.spill_pages(src, dst, self.cfg,
                                               self.policy, self.spill_k)
            moved = np.asarray(jax.device_get(moved))
            self.spill_syncs += 1
            moved = moved[moved >= 0]
            if not len(moved):
                continue
            self.pools = S.pool_unslice(self.pools, int(e), src)
            self.pools = S.pool_unslice(self.pools, donor, dst)
            self.placement.override(moved, donor)
            self.spill_events += 1
            self.spill_pages_out[int(e)] += len(moved)
            self.spill_pages_in[donor] += len(moved)
            free[donor] -= 8 * len(moved)   # stay conservative within a pass
            fired = True
        return fired

    # -- metrics -------------------------------------------------------------

    def counters(self) -> Dict[str, int]:
        """Summed traffic counters across expanders."""
        return S.stacked_counters_dict(self.pools)

    def counters_by_expander(self) -> List[Dict[str, int]]:
        return S.per_expander_counters(self.pools)

    def spill_stats(self) -> Dict[str, object]:
        return {
            "events": self.spill_events,
            "pages_out": self.spill_pages_out.tolist(),
            "pages_in": self.spill_pages_in.tolist(),
            "syncs": self.spill_syncs,
        }
