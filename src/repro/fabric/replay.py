"""Fabric replay: partition traces by expander, advance all expanders in
parallel with ``vmap`` over the stacked pool state (DESIGN.md §11).

A merged (ospn, is_write, block) trace is split into spill *segments*; each
segment is partitioned by the placement's current routing (base rule +
spill overrides), padded per expander to a common window-aligned length,
and replayed through ``engine.batch._replay_windows_masked`` vmapped over
the expander axis — the window bodies are the single-pool ones, unchanged,
so per-expander counters are bit-identical to replaying that expander's
partition through ``batch.replay_trace`` on a single pool (the fabric's
parity contract, asserted by tests/test_fabric.py and
benchmarks/fabric_bench.py). Per-expander watermark demotion runs inside
each expander's own windows exactly as on a single pool.

Between segments the host performs one freelist-occupancy sync; if an
expander's compressed-region freelists fall below the spill watermark while
another has headroom, ``fabric.ops.spill_pages`` migrates compressed pages
to the most-free donor and the placement override table pins them there.

Padded window counts are bucketed to powers of two so a whole skew sweep
compiles a handful of shapes per expander count.

Delivered time (DESIGN.md §12): each fabric carries a stacked
``simx.time.DeviceLanes`` — per-expander timing parameters, possibly
mixed-generation — and every replayed segment prices each expander's
cumulative counters *inside the vmapped replay*; ``Fabric.delivered_time``
/ ``bottleneck_time`` expose the per-expander and fabric-level seconds the
benches record. ``track_segments`` records per-segment counter deltas
(``state.counters_delta``), the hook for async migration overlap and
traffic-imbalance rebalancing.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import PoolConfig
from repro.common.utils import next_pow2
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import Policy
from repro.fabric import ops as fops
from repro.fabric.placement import Placement
from repro.simx import time as TM


def partition_trace(placement: Placement, ospns, writes, blocks,
                    window: int) -> Tuple[np.ndarray, ...]:
    """Route a trace and pack it per expander: [N, n_win, W] arrays plus a
    validity mask. Each expander's partition keeps the merged trace's
    relative order and sits as a prefix before the padding, so the masked
    replay walks full windows, then one partial window, then no-ops — the
    exact shapes ``batch.replay_trace`` produces on a single pool."""
    n = placement.n_expanders
    ospns = np.asarray(ospns, np.int32)
    writes = np.asarray(writes, bool)
    blocks = np.asarray(blocks, np.int32)
    eids = placement.route(ospns)
    counts = np.bincount(eids, minlength=n)
    n_win = next_pow2(-(-max(int(counts.max()), 1) // window))
    L = n_win * window
    o = np.zeros((n, L), np.int32)
    w = np.zeros((n, L), bool)
    b = np.zeros((n, L), np.int32)
    v = np.zeros((n, L), bool)
    for e in range(n):
        sel = eids == e
        k = int(counts[e])
        o[e, :k] = ospns[sel]
        w[e, :k] = writes[sel]
        b[e, :k] = blocks[sel]
        v[e, :k] = True
    shp = (n, n_win, window)
    return (o.reshape(shp), w.reshape(shp), b.reshape(shp), v.reshape(shp),
            eids)


@functools.partial(jax.jit, static_argnums=(1, 2))
def _replay_stacked(pools: S.Pool, cfg: PoolConfig, policy: Policy,
                    ospns, writes, blocks, valid,
                    lanes: TM.DeviceLanes):
    """Advance all expanders one segment AND price their cumulative traffic:
    ``lanes`` is the stacked per-expander DeviceLanes pytree (mixed
    generations = different field values per lane), vmapped alongside the
    pools so each expander's delivered time is computed on device from its
    own counter vector — no host sync, no dict round-trip."""
    pools = jax.vmap(
        lambda p, o, w, b, v: B._replay_windows_masked(p, cfg, policy,
                                                       o, w, b, v)
    )(pools, ospns, writes, blocks, valid)
    times = jax.vmap(TM.exec_time_vec)(pools.counters, lanes)
    return pools, times


class Fabric:
    """N expanders as one stacked pool state + a placement + spill policy.

    ``spill_low`` is the compressed-region watermark in *chunks* (singles +
    8x groups): an expander below it is starved; a donor must clear
    ``2 * spill_low``. ``spill_k`` pages move per event. ``spill_interval``
    is the segment length between occupancy checks — one host sync each.

    ``devices`` is the expander fleet's timing model: ``None`` (default
    ``DeviceConfig`` everywhere), one ``DeviceConfig`` (homogeneous), or a
    sequence — shorter sequences cycle, so ``[gen5, gen4]`` on N=4 makes an
    alternating mixed-generation fleet. The stacked ``DeviceLanes`` rides
    into the vmapped replay, so per-expander delivered time (including
    spill traffic, charged on the expander where it physically occurs) is
    computed on device every segment. ``track_segments=True`` additionally
    records per-segment, per-expander counter deltas
    (``state.counters_delta``) — one extra host sync per segment; the hook
    async migration and traffic-imbalance rebalancing build on.
    """

    def __init__(self, cfg: PoolConfig, policy: Policy, placement: Placement,
                 *, seed: int = 0, rates_table=None, window: Optional[int] = None,
                 spill: bool = True, spill_interval: int = 2048,
                 spill_k: int = 16, spill_low: Optional[int] = None,
                 devices=None, track_segments: bool = False):
        if placement.n_pages != cfg.n_pages:
            raise ValueError("placement/page-space mismatch")
        self.cfg = cfg
        self.policy = policy
        self.placement = placement
        self.n_expanders = placement.n_expanders
        self.window = B.DEFAULT_WINDOW if window is None else window
        self.spill_enabled = spill and self.n_expanders > 1
        self.spill_interval = spill_interval
        self.spill_k = spill_k
        self.spill_low = (max(16, cfg.n_cchunks // 16)
                          if spill_low is None else spill_low)
        self.devices = TM.resolve_fleet(devices, self.n_expanders)
        self.lanes = TM.stack_devices(self.devices)
        self.pools = S.make_pool_stack(cfg, self.n_expanders, seed=seed,
                                       rates_table=rates_table)
        n = self.n_expanders
        self.spill_events = 0
        self.spill_pages_out = np.zeros((n,), np.int64)
        self.spill_pages_in = np.zeros((n,), np.int64)
        self.spill_syncs = 0
        self.track_segments = track_segments
        # per-segment, per-expander counter deltas (int64 [N, NUM_COUNTERS]
        # each) when track_segments; delivered time per expander (device
        # float32 [N]) refreshed by every replayed segment
        self.segment_deltas: List[np.ndarray] = []
        self.segment_syncs = 0
        self._modeled_times = None

    # -- replay --------------------------------------------------------------

    def replay(self, ospns, writes, blocks) -> "Fabric":
        """Replay a merged trace through all expanders.

        The trace is partitioned ONCE and replayed in window-aligned chunks
        of ``spill_interval`` accesses per expander, so each expander's
        window boundaries are exactly those of ``batch.replay_trace`` over
        its partition — if no spill fires, per-expander counters are
        bit-identical to single-pool replays of the partitions (the parity
        contract). When a spill fires, the unconsumed tail of every
        expander's partition is re-merged and re-partitioned so accesses to
        migrated pages follow their page to the donor expander."""
        rem = (np.asarray(ospns, np.int32), np.asarray(writes, bool),
               np.asarray(blocks, np.int32))
        while rem is not None and len(rem[0]):
            o, w, b, v, eids = partition_trace(self.placement, *rem,
                                               self.window)
            counts = np.bincount(eids, minlength=self.n_expanders)
            n_win = o.shape[1]
            if self.spill_enabled:
                seg = next_pow2(max(self.spill_interval // self.window, 1))
                seg = min(seg, n_win)
            else:
                seg = n_win
            rem = None
            for lo in range(0, n_win, seg):
                sl = slice(lo, lo + seg)
                before = S.counters_snapshot(self.pools)
                self.pools, self._modeled_times = _replay_stacked(
                    self.pools, self.cfg, self.policy,
                    jnp.asarray(o[:, sl]), jnp.asarray(w[:, sl]),
                    jnp.asarray(b[:, sl]), jnp.asarray(v[:, sl]),
                    self.lanes)
                if self.track_segments:
                    delta = S.counters_delta(before,
                                             S.counters_snapshot(self.pools))
                    self.segment_deltas.append(
                        np.asarray(jax.device_get(delta), np.int64))
                    self.segment_syncs += 1
                if not self.spill_enabled:
                    continue
                fired = self._maybe_spill()
                more = v[:, lo + seg:].any() if lo + seg < n_win else False
                if fired and more:
                    # rebuild the unconsumed per-expander tails in original
                    # merged-trace order (after re-routing, one expander may
                    # merge accesses from several old streams — interleaving
                    # them by trace position keeps its replay order faithful)
                    done = (lo + seg) * self.window
                    tails = [np.nonzero(eids == e)[0][done:]
                             for e in range(self.n_expanders)]
                    perm = np.argsort(np.concatenate(tails), kind="stable")
                    rem = tuple(
                        np.concatenate([
                            a.reshape(self.n_expanders, -1)[e,
                                                            done:counts[e]]
                            for e in range(self.n_expanders)])[perm]
                        for a in (o, w, b))
                    break
        return self

    # -- spill ---------------------------------------------------------------

    def _chunk_headroom(self) -> np.ndarray:
        """Per-expander free compressed capacity in single-chunk units
        (one host sync)."""
        ct, gt = jax.device_get((self.pools.cfree.top, self.pools.gfree.top))
        self.spill_syncs += 1
        return np.asarray(ct, np.int64) + 8 * np.asarray(gt, np.int64)

    def _maybe_spill(self) -> bool:
        """One occupancy check; migrate from each starved expander to the
        most-free donor. Returns True when any page actually moved.

        A spill charges migration traffic to the pool counters AFTER the
        segment's in-jit delivered times were computed, so those go stale;
        they are invalidated here and either refreshed by the next segment
        or recomputed host-side by ``delivered_time``."""
        free = self._chunk_headroom()
        fired = False
        for e in np.nonzero(free < self.spill_low)[0]:
            donor = int(np.argmax(free))
            if donor == int(e) or free[donor] < 2 * self.spill_low:
                continue
            src = S.pool_slice(self.pools, int(e))
            dst = S.pool_slice(self.pools, donor)
            src, dst, moved = fops.spill_pages(src, dst, self.cfg,
                                               self.policy, self.spill_k)
            moved = np.asarray(jax.device_get(moved))
            self.spill_syncs += 1
            moved = moved[moved >= 0]
            if not len(moved):
                continue
            self.pools = S.pool_unslice(self.pools, int(e), src)
            self.pools = S.pool_unslice(self.pools, donor, dst)
            self.placement.override(moved, donor)
            self._modeled_times = None     # spill traffic not yet priced
            self.spill_events += 1
            self.spill_pages_out[int(e)] += len(moved)
            self.spill_pages_in[donor] += len(moved)
            free[donor] -= 8 * len(moved)   # stay conservative within a pass
            fired = True
        return fired

    # -- metrics -------------------------------------------------------------

    def counters(self) -> Dict[str, int]:
        """Summed traffic counters across expanders."""
        return S.stacked_counters_dict(self.pools)

    def delivered_time(self, exact: bool = True) -> np.ndarray:
        """Per-expander delivered seconds for the traffic replayed so far,
        each priced by that expander's own ``DeviceConfig`` — spill traffic
        included on the expander where it physically occurred (the source's
        demotion-reads, the donor's writes + compression stores land in
        those pools' counters).

        ``exact=True`` (default, host-side) recomputes in float64 through
        the same ``exec_time_vec`` — the parity-grade numbers benches
        record. ``exact=False`` returns the float32 values the vmapped
        replay computed on device (zero extra device work; one fetch) —
        or, when a trailing spill invalidated them, re-prices the current
        counters through the same float32 device path, never the float64
        one (the float32-vs-float64 parity asserts stay meaningful)."""
        if not exact:
            times = self._modeled_times
            if times is None:
                times = TM.exec_time_vec(self.pools.counters, self.lanes)
            return np.asarray(jax.device_get(times), np.float64)
        counters = np.asarray(jax.device_get(self.pools.counters),
                              np.float64)
        return TM.exec_time_vec(counters, TM.stack_devices(self.devices,
                                                           xp=np))

    def bottleneck_time(self, exact: bool = True) -> float:
        """Delivered time of the fabric serving one merged trace: expanders
        run in parallel, so the bottleneck expander governs."""
        return float(np.max(self.delivered_time(exact=exact)))

    def counters_by_expander(self) -> List[Dict[str, int]]:
        return S.per_expander_counters(self.pools)

    def spill_stats(self) -> Dict[str, object]:
        return {
            "events": self.spill_events,
            "pages_out": self.spill_pages_out.tolist(),
            "pages_in": self.spill_pages_in.tolist(),
            "syncs": self.spill_syncs,
        }
