"""Fabric segment scheduler: pipelined vmapped replay with overlapped
asynchronous migration (DESIGN.md §11/§13).

A merged (ospn, is_write, block) trace is partitioned by the placement's
current routing (base rule + migration overrides), padded per expander to
a common window-aligned length, and replayed through
``engine.batch._replay_windows_masked`` vmapped over the expander axis —
the window bodies are the single-pool ones, unchanged, so per-expander
counters are bit-identical to replaying that expander's partition through
``batch.replay_trace`` on a single pool (the fabric's parity contract,
asserted by tests/test_fabric.py and benchmarks/fabric_bench.py).

The replay advances in *segments* (``spill_interval`` accesses per
expander, window-aligned). Each segment is one pipeline stage:

  stage A (device)  the segment's vmapped replay, which ALSO computes —
                    in-jit, no extra sync — the per-expander delivered
                    times, freelist headroom, page eligibility, and
                    referenced bits (``fabric.ops.segment_stats``);
  stage B (host)    while the next segment replays, the previous
                    segment's migration plan (a pluggable
                    ``fabric.migration.MigrationPolicy``) is computed
                    from those stats, applied as ONE jitted batch
                    (``fabric.ops.apply_migrations``), and its
                    override-table updates committed as ONE scatter
                    (``Placement.apply_epoch``).

Double-buffering (``pipeline_depth=2``, the default): the plan computed
off segment N's stats applies after segment N+1's replay — migration
cost is hidden behind foreground traffic, exactly the shadowed-promotion
argument at fabric scale. Accesses landing on a page whose plan is in
flight are masked to no-ops by the carried pending-migration mask
(``batch._replay_windows_masked``'s ``pending``) and replayed after the
epoch commits, routed to the page's final home. ``pipeline_depth=1``
degenerates to plan-and-apply at the same boundary and is bit-identical
to the synchronous reference driver (``sync_migration=True``, the PR 3
semantics: migration on the critical path) — the refactor's parity pin.

Host-sync contract (machine-checked by benchmarks/fabric_bench.py,
mirroring serve's ``step_syncs == steps``): exactly ONE host sync per
replayed segment (the fused stats fetch) plus ONE per committed
migration epoch (the moved-pages fetch) — no per-page host writes, no
separate occupancy probe, no extra ``track_segments`` fetch.

Delivered time (DESIGN.md §12/§13): per-segment replay deltas and
per-epoch migration deltas are recorded host-side from the same fetches;
``Fabric.pipeline_times`` prices them through
``simx.time.pipeline_delivered_time`` — overlapped pricing
``max(replay, migration)`` per segment for the pipelined scheduler, the
``replay + migration`` sum for the synchronous reference.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.common import sharding as shd
from repro.common.contracts import sync_contract
from repro.common.types import PoolConfig
from repro.common.utils import next_pow2
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import Policy
from repro.fabric import migration as MG
from repro.fabric import ops as fops
from repro.fabric import shard as FS
from repro.fabric.placement import Placement
from repro.simx import time as TM


def partition_trace(placement: Placement, ospns, writes, blocks,
                    window: int) -> Tuple[np.ndarray, ...]:
    """Route a trace and pack it per expander: [N, n_win, W] arrays plus a
    validity mask. Each expander's partition keeps the merged trace's
    relative order and sits as a prefix before the padding, so the masked
    replay walks full windows, then one partial window, then no-ops — the
    exact shapes ``batch.replay_trace`` produces on a single pool."""
    n = placement.n_expanders
    ospns = np.asarray(ospns, np.int32)
    writes = np.asarray(writes, bool)
    blocks = np.asarray(blocks, np.int32)
    eids = placement.route(ospns)
    counts = np.bincount(eids, minlength=n)
    n_win = next_pow2(-(-max(int(counts.max()), 1) // window))
    L = n_win * window
    o = np.zeros((n, L), np.int32)
    w = np.zeros((n, L), bool)
    b = np.zeros((n, L), np.int32)
    v = np.zeros((n, L), bool)
    for e in range(n):
        sel = eids == e
        k = int(counts[e])
        o[e, :k] = ospns[sel]
        w[e, :k] = writes[sel]
        b[e, :k] = blocks[sel]
        v[e, :k] = True
    shp = (n, n_win, window)
    return (o.reshape(shp), w.reshape(shp), b.reshape(shp), v.reshape(shp),
            eids)


@functools.partial(jax.jit, static_argnums=(1, 2, 9))
def _replay_stacked(pools: S.Pool, cfg: PoolConfig, policy: Policy,
                    ospns, writes, blocks, valid,
                    lanes: TM.DeviceLanes, pending, need_stats: bool):
    """Advance all expanders one segment AND compute everything the
    scheduler needs from it in-jit: per-expander delivered time (``lanes``
    is the stacked per-expander DeviceLanes pytree) and — when a
    migration policy will consume them — the migration stats (headroom /
    eligibility / referenced bits), one fused output, one host fetch, no
    dict round-trips. ``pending`` is the carried pending-migration page
    mask (bool[n_pages], shared across expanders); all-False reduces to
    the plain replay bit-for-bit. ``need_stats=False`` (migration off)
    skips the per-page stats so parity/scaling runs don't pay for facts
    no policy reads."""
    pools = jax.vmap(
        lambda p, o, w, b, v: B._replay_windows_masked(p, cfg, policy,
                                                       o, w, b, v, pending)
    )(pools, ospns, writes, blocks, valid)
    times = jax.vmap(TM.exec_time_vec)(pools.counters, lanes)
    stats = jax.vmap(lambda p: fops.segment_stats(p, cfg))(pools) \
        if need_stats else None
    return pools, times, stats


@functools.partial(jax.jit, static_argnums=(1,))
def _stacked_stats(pools: S.Pool, cfg: PoolConfig) -> fops.SegmentStats:
    """Post-apply migration facts for the whole stack (fetched with the
    epoch's moved pages in one sync — keeps the planner's view current)."""
    return jax.vmap(lambda p: fops.segment_stats(p, cfg))(pools)


class Fabric:
    """N expanders as one stacked pool state + placement + segment
    scheduler with pluggable migration.

    ``migration`` selects the ``fabric.migration.MigrationPolicy``:
    ``"spill"`` (freelist-pressure, default when ``spill=True``),
    ``"rebalance"`` (pressure + traffic-imbalance trigger fed by segment
    counter deltas and in-jit delivered times), ``"off"``, or a policy
    instance. ``spill_low`` is the compressed-region watermark in
    *chunks* (singles + 8x groups): an expander below it is starved; a
    donor must clear ``2 * spill_low``. ``spill_k`` pages move per
    (src, dst) pair per epoch. ``spill_interval`` is the segment length
    between migration decisions.

    ``pipeline_depth=2`` (default) overlaps: segment N's plan applies
    after segment N+1's replay, with in-flight pages' accesses deferred
    via the pending mask. ``pipeline_depth=1`` plans and applies at the
    same boundary. ``sync_migration=True`` forces the synchronous
    reference driver (PR 3 semantics, bit-identical to depth 1).

    ``obs`` is an optional ``repro.obs.Recorder``: when attached, the
    per-segment/per-epoch samples it accumulates are drained from the SAME
    single fetches the sync contract already budgets (DESIGN.md §16) —
    recording changes neither the sync counts nor one bit of pool state
    (with migration off, only the already-fused in-jit ``segment_stats``
    output is additionally computed, read-only over the pool).

    ``devices`` is the expander fleet's timing model: ``None`` (default
    ``DeviceConfig`` everywhere), one ``DeviceConfig`` (homogeneous), or
    a sequence — shorter sequences cycle, so ``[gen5, gen4]`` on N=4
    makes an alternating mixed-generation fleet. ``track_segments`` is
    accepted for PR 4 API compatibility but no longer changes behavior:
    per-segment counter deltas are ALWAYS recorded in ``segment_deltas``
    (the pipeline pricing needs them, and they fall out of the fused
    per-segment fetch at no extra sync — the flag used to buy an extra
    sync that no longer exists). ``on_epoch(fabric, plan, moved_pages)``
    is called after every committed migration epoch (tests hook
    invariant checks here)."""

    def __init__(self, cfg: PoolConfig, policy: Policy, placement: Placement,
                 *, seed: int = 0, rates_table=None, window: Optional[int] = None,
                 spill: bool = True, spill_interval: int = 2048,
                 spill_k: int = 16, spill_low: Optional[int] = None,
                 devices=None, track_segments: bool = False,
                 migration: Union[str, MG.MigrationPolicy, None] = None,
                 pipeline_depth: int = 2, sync_migration: bool = False,
                 shard_devices: Optional[int] = None,
                 on_epoch: Optional[Callable] = None, obs=None):
        if placement.n_pages != cfg.n_pages:
            raise ValueError("placement/page-space mismatch")
        if pipeline_depth not in (1, 2):
            raise ValueError("pipeline_depth must be 1 or 2")
        if shard_devices is not None and \
                placement.n_expanders % shard_devices:
            raise ValueError(f"{placement.n_expanders} expanders not "
                             f"divisible by shard_devices={shard_devices}")
        self.cfg = cfg
        self.policy = policy
        self.placement = placement
        self.n_expanders = placement.n_expanders
        self.window = B.DEFAULT_WINDOW if window is None else window
        self.spill_interval = spill_interval
        self.spill_k = spill_k
        self.spill_low = (max(16, cfg.n_cchunks // 16)
                          if spill_low is None else spill_low)
        if migration is None:
            migration = "spill" if spill else "off"
        if isinstance(migration, str):
            migration = MG.make_migration_policy(migration, k=spill_k,
                                                 low=self.spill_low)
        self.migration_policy = migration
        self.migration_enabled = (self.n_expanders > 1 and
                                  not isinstance(migration, MG.NoMigration))
        # back-compat alias only (the PR 3 name); the scheduler itself
        # reads migration_enabled
        self.spill_enabled = self.migration_enabled
        self.pipeline_depth = pipeline_depth
        self.sync_migration = sync_migration
        self.on_epoch = on_epoch
        self.obs = obs
        self.devices = TM.resolve_fleet(devices, self.n_expanders)
        self.lanes = TM.stack_devices(self.devices)
        self.pools = S.make_pool_stack(cfg, self.n_expanders, seed=seed,
                                       rates_table=rates_table)
        # sharded mode (DESIGN.md §17): the stacked pytree lives on a
        # device mesh, replayed shard_map-ed by the sharded driver with
        # synchronous migration scheduling collapsed into one jit dispatch
        # + one fetch per boundary
        self.shard_devices = shard_devices
        self.mesh = None
        if shard_devices is not None:
            self.mesh = shd.expander_mesh(shard_devices)
            if self.migration_enabled:
                FS.plan_params(self.migration_policy)  # fail fast: needs
                # an in-jit planner (spill / rebalance); custom host
                # policies must use the vmap drivers
            self.pools = FS.shard_pools(self.pools, self.mesh)
            self.lanes = FS.shard_pools(self.lanes, self.mesh)
        n = self.n_expanders
        self.spill_events = 0
        self.spill_pages_out = np.zeros((n,), np.int64)
        self.spill_pages_in = np.zeros((n,), np.int64)
        self.track_segments = track_segments
        # pipeline bookkeeping: per-segment replay counter deltas (int64
        # [N, NUM_COUNTERS] each) and per-epoch migration deltas, each
        # tagged (segment index whose replay it overlapped, delta,
        # genuinely-overlapped?) — urgent/sync/drain epochs carry False
        # and are priced on the critical path by pipeline_times
        self.segment_deltas: List[np.ndarray] = []
        self.migration_deltas: List[Tuple[int, np.ndarray, bool]] = []
        self.segments_replayed = 0
        self.segment_syncs = 0
        self.epochs_applied = 0
        self.epoch_syncs = 0
        self.spill_syncs = 0          # back-compat alias of epoch_syncs
        # sharded-driver sync bookkeeping: one fused fetch per boundary
        # (migration on), one deferred drain fetch per replay() call
        # (migration off — device refs accumulate, nothing blocks)
        self.boundaries = 0
        self.boundary_syncs = 0
        self.drain_syncs = 0
        self._deferred_refs: List[Tuple] = []
        self._last_counters = np.zeros((n, S.NUM_COUNTERS), np.int64)
        self._last_free: Optional[np.ndarray] = None
        self._pending_plan: Optional[MG.MigrationPlan] = None
        self._no_pending = jnp.zeros((cfg.n_pages,), bool)
        # livelock guard: pages whose last planned epoch moved NOTHING
        # (e.g. the donor's allocation guard refused every move) are
        # barred from re-planning until some epoch makes progress —
        # otherwise an un-appliable plan + its deferred accesses can
        # recur round after round with the trace never advancing
        self._blocked = np.zeros((cfg.n_pages,), bool)
        self._modeled_times = None
        if obs is not None:
            obs.attach_fabric(self)

    # -- pipeline stages -----------------------------------------------------

    def _dispatch_segment(self, o, w, b, v, sl,
                          pending_pages: Optional[np.ndarray]):
        """Stage A: dispatch one segment's vmapped replay (async). Returns
        the device-resident (times, stats, counters) of the post-replay
        state — fetched later in ONE sync."""
        if pending_pages is not None and len(pending_pages):
            pend = np.zeros((self.cfg.n_pages,), bool)
            pend[pending_pages] = True
            pend = jnp.asarray(pend)
        else:
            pend = self._no_pending
        self.pools, times, stats = _replay_stacked(
            self.pools, self.cfg, self.policy,
            jnp.asarray(o[:, sl]), jnp.asarray(w[:, sl]),
            jnp.asarray(b[:, sl]), jnp.asarray(v[:, sl]),
            self.lanes, pend,
            self.migration_enabled or self.obs is not None)
        self._modeled_times = times
        self.segments_replayed += 1
        return times, stats, self.pools.counters

    @sync_contract(syncs_per="segment", fetches=1)
    def _fetch_view(self, times, stats, counters,
                    recent: np.ndarray) -> Optional[MG.SegmentView]:
        """The ONE host sync per segment: fused fetch of delivered times,
        migration stats, and the counter snapshot; the replay delta falls
        out against the previous snapshot. With migration off the stats
        were never computed — ``None`` rides through the single fetch as
        an empty pytree, only the delta bookkeeping runs and no view is
        built (no policy would read it)."""
        stats, ctrs, t = jax.device_get((stats, counters, times))
        self.segment_syncs += 1
        ctrs = np.asarray(ctrs, np.int64)
        delta = ctrs - self._last_counters
        self._last_counters = ctrs
        self.segment_deltas.append(delta)
        if stats is not None:
            self._last_free = np.asarray(stats.free_units, np.int64)
        if self.obs is not None:
            # telemetry drain: the Recorder consumes the host values this
            # single contracted fetch already produced — zero extra syncs
            self.obs.record_segment(self.segments_replayed - 1, delta,
                                    np.asarray(t, np.float64),
                                    self._last_free)
        if stats is None:
            return None
        return MG.SegmentView(free_units=self._last_free,
                              free_singles=np.asarray(stats.free_singles,
                                                      np.int64),
                              free_groups=np.asarray(stats.free_groups,
                                                     np.int64),
                              eligible=np.asarray(stats.eligible),
                              referenced=np.asarray(stats.referenced),
                              counters=ctrs, delta=delta,
                              times=np.asarray(t, np.float64),
                              recent=recent, blocked=self._blocked.copy())

    def _plan(self, view: Optional[MG.SegmentView]
              ) -> Optional[MG.MigrationPlan]:
        """Ask the migration policy for an epoch, dropping pages the
        livelock guard barred (their last planned epoch moved nothing)."""
        if view is None:
            return None
        plan = self._plan_filtered(view)
        if plan is not None and self.obs is not None:
            self.obs.record_plan(self.segments_replayed - 1, plan,
                                 self.migration_policy.name)
        return plan

    def _plan_filtered(self, view: MG.SegmentView
                       ) -> Optional[MG.MigrationPlan]:
        plan = self.migration_policy.plan(view)
        if plan is None or not self._blocked.any():
            return plan
        keep = ~self._blocked[plan.pages]
        if keep.all():
            return plan
        if not keep.any():
            return None
        return MG.MigrationPlan(plan.pages[keep], plan.srcs[keep],
                                plan.dsts[keep], urgent=plan.urgent)

    def _dispatch_apply(self, plan: MG.MigrationPlan):
        """Stage B: dispatch one epoch's batched migration apply (async,
        sequenced after the in-flight segment's replay by data flow).
        Pages pad to a power of two so epochs compile a handful of
        shapes."""
        k = next_pow2(max(len(plan), 1))
        pages = np.full((k,), -1, np.int32)
        srcs = np.zeros((k,), np.int32)
        dsts = np.zeros((k,), np.int32)
        pages[:len(plan)] = plan.pages
        srcs[:len(plan)] = plan.srcs
        dsts[:len(plan)] = plan.dsts
        self.pools, moved = fops.apply_migrations(
            self.pools, self.cfg, self.policy,
            jnp.asarray(pages), jnp.asarray(srcs), jnp.asarray(dsts))
        return plan, srcs, dsts, moved

    @sync_contract(syncs_per="epoch", fetches=1)
    def _commit_epoch(self, plan: MG.MigrationPlan, srcs, dsts, moved,
                      overlapping_seg: int,
                      view: Optional[MG.SegmentView] = None,
                      overlapped: bool = False,
                      kind: str = "sync") -> np.ndarray:
        """Fetch the epoch's outcome (the ONE sync per epoch), commit the
        override-table updates as ONE batched scatter, and record the
        migration counter delta against the segment it overlapped.

        When the pipelined driver is about to plan at this same boundary,
        it passes the segment ``view`` and the commit REFRESHES its
        migration facts (headroom / eligibility / referenced) from the
        post-apply state — fetched in the same sync — so the planner never
        acts on pre-apply freelists (which over-spill and ping-pong).
        The replay delta and delivered times stay the segment's own.
        With no view to refresh (sync driver, urgent/depth-1 applies,
        drain) only the freelist tops ride along — no planner will read
        per-page facts, so none are computed."""
        if view is not None:
            extra = _stacked_stats(self.pools, self.cfg)
        else:
            # no planner will read per-page facts — only the freelist
            # tops ride along in the same single fetch
            extra = (self.pools.cfree.top, self.pools.gfree.top)
        moved, ctrs, extra = jax.device_get(
            (moved, self.pools.counters, extra))
        if view is not None:
            stats = extra
            free_units = np.asarray(stats.free_units, np.int64)
        else:
            stats = None
            ct, gt = extra
            free_units = (np.asarray(ct, np.int64) +
                          8 * np.asarray(gt, np.int64))
        self.epoch_syncs += 1
        self.spill_syncs = self.epoch_syncs
        ctrs = np.asarray(ctrs, np.int64)
        delta = ctrs - self._last_counters
        self.migration_deltas.append((overlapping_seg, delta, overlapped))
        self._last_counters = ctrs
        self._last_free = free_units
        moved = np.asarray(moved)
        sel = moved >= 0
        pages_moved = moved[sel].astype(np.int64)
        self.placement.apply_epoch(pages_moved, dsts[sel])
        self.epochs_applied += 1
        if len(pages_moved):
            np.add.at(self.spill_pages_out, srcs[sel], 1)
            np.add.at(self.spill_pages_in, dsts[sel], 1)
            pairs = {(int(s), int(d)) for s, d in zip(srcs[sel], dsts[sel])}
            self.spill_events += len(pairs)
            self._modeled_times = None    # migration traffic not yet priced
            self._blocked[:] = False      # progress: conditions changed
        else:
            # nothing moved: every move was refused at apply time. Bar the
            # plan's pages from re-planning until some epoch succeeds, or
            # an un-appliable plan recurs forever (livelock guard)
            self._blocked[plan.pages] = True
        if self.obs is not None:
            # telemetry drain: same single per-epoch fetch, zero extra syncs
            self.obs.record_epoch(overlapping_seg, delta, kind=kind,
                                  overlapped=overlapped, planned=len(plan),
                                  moved=len(pages_moved), urgent=plan.urgent,
                                  free_units=free_units)
        if view is not None:
            view.free_units = self._last_free
            view.free_singles = np.asarray(stats.free_singles, np.int64)
            view.free_groups = np.asarray(stats.free_groups, np.int64)
            view.eligible = np.asarray(stats.eligible)
            view.referenced = np.asarray(stats.referenced)
            view.recent[pages_moved] = True
            view.blocked = self._blocked.copy()
        if self.on_epoch is not None:
            self.on_epoch(self, plan, pages_moved)
        return pages_moved

    # -- drivers -------------------------------------------------------------

    def replay(self, ospns, writes, blocks) -> "Fabric":
        """Replay a merged trace through all expanders.

        The trace is partitioned ONCE and replayed in window-aligned
        segments of ``spill_interval`` accesses per expander, so each
        expander's window boundaries are exactly those of
        ``batch.replay_trace`` over its partition — with no migration,
        per-expander counters are bit-identical to single-pool replays of
        the partitions (the parity contract). When a migration epoch
        commits, the unconsumed tails (plus any accesses deferred by the
        pending mask) re-merge in original trace order and re-partition,
        so accesses follow migrated pages to their new expander."""
        rem = (np.asarray(ospns, np.int32), np.asarray(writes, bool),
               np.asarray(blocks, np.int32))
        if self.shard_devices is not None:
            driver = self._replay_sharded
        elif self.sync_migration:
            driver = self._replay_sync
        else:
            driver = self._replay_pipelined
        while rem is not None and len(rem[0]):
            rem = driver(rem)
        if self._deferred_refs:
            # sharded migration-off: nothing forced a fetch mid-run; the
            # per-segment bookkeeping drains in ONE deferred sync now
            self._drain_deferred()
        if self._pending_plan is not None:
            # drain: the plan computed off the final segment's stats has
            # nothing left to overlap — apply and commit it now (the
            # synchronous path would have applied it at the same boundary)
            applied = self._dispatch_apply(self._pending_plan)
            self._pending_plan = None
            self._commit_epoch(*applied, self.segments_replayed,
                               kind="drain")
        return self

    def _segments(self, n_win: int) -> int:
        if not self.migration_enabled:
            return n_win
        seg = next_pow2(max(self.spill_interval // self.window, 1))
        return min(seg, n_win)

    def _rebuild(self, cur, pos_by_exp, hi: int, deferred: np.ndarray):
        """Re-merge the unconsumed per-expander tails (plus deferred
        accesses) in original merged-trace order for re-partitioning —
        after re-routing, one expander may merge accesses from several
        old streams, and sorting by trace position keeps its replay order
        faithful."""
        done = hi * self.window
        tails = [p[done:] for p in pos_by_exp]
        pos = np.sort(np.concatenate([deferred.astype(np.int64)] +
                                     [t.astype(np.int64) for t in tails]))
        if not len(pos):
            return None
        return tuple(a[pos] for a in cur)

    def _replay_pipelined(self, cur):
        """One partition round of the double-buffered scheduler. Returns
        the re-merged remainder when an epoch commit re-routes pages (or
        deferred accesses must replay), ``None`` when the round consumed
        everything."""
        o, w, b, v, eids = partition_trace(self.placement, *cur, self.window)
        n = self.n_expanders
        n_win = o.shape[1]
        seg = self._segments(n_win)
        pos_by_exp = [np.nonzero(eids == e)[0] for e in range(n)]
        none = np.empty((0,), np.int64)
        for lo in range(0, n_win, seg):
            hi = min(lo + seg, n_win)
            in_flight, self._pending_plan = self._pending_plan, None
            times, stats, ctrs = self._dispatch_segment(
                o, w, b, v, slice(lo, hi),
                in_flight.pages if in_flight is not None else None)
            applied = None
            if in_flight is not None:
                # overlap: the previous segment's plan applies behind this
                # segment's replay, one jit call, overrides batched below
                applied = self._dispatch_apply(in_flight)
            view = self._fetch_view(times, stats, ctrs,
                                    np.zeros((self.cfg.n_pages,), bool))
            moved_pages, deferred = none, none
            if applied is not None:
                moved_pages = self._commit_epoch(
                    *applied, self.segments_replayed - 1, view,
                    overlapped=True, kind="overlapped")
                # accesses this segment deferred by the pending mask —
                # replayed after the commit, routed to the final home
                defer = []
                for e in range(n):
                    seg_pos = pos_by_exp[e][lo * self.window:
                                            hi * self.window]
                    dsel = np.isin(cur[0][seg_pos], in_flight.pages)
                    defer.append(seg_pos[dsel])
                deferred = np.concatenate(defer) if defer else none
            if self.migration_enabled:
                plan = self._plan(view)
                if plan is not None and (self.pipeline_depth == 1 or
                                         plan.urgent):
                    # apply at the same boundary: depth-1 degenerates to
                    # the synchronous reference driver bit-for-bit, and
                    # an URGENT plan (source already below the hard
                    # watermark) must not wait a segment — relief that
                    # lands after the freelists run dry is corruption,
                    # not overlap
                    m1 = self._commit_epoch(
                        *self._dispatch_apply(plan),
                        self.segments_replayed - 1,
                        kind="urgent" if plan.urgent else "sync")
                    moved_pages = np.concatenate([moved_pages, m1])
                elif plan is not None:
                    self._pending_plan = plan
            if len(moved_pages) or len(deferred):
                rem = self._rebuild(cur, pos_by_exp, hi, deferred)
                if rem is not None:
                    return rem
        return None

    def _replay_sync(self, cur):
        """The synchronous reference driver (PR 3 semantics): plan and
        apply at every segment boundary, migration cost on the critical
        path, no pending mask, no deferral. Kept as the parity anchor the
        depth-1 pipeline is pinned against (tests/test_fabric.py)."""
        o, w, b, v, eids = partition_trace(self.placement, *cur, self.window)
        n = self.n_expanders
        n_win = o.shape[1]
        seg = self._segments(n_win)
        pos_by_exp = [np.nonzero(eids == e)[0] for e in range(n)]
        for lo in range(0, n_win, seg):
            hi = min(lo + seg, n_win)
            times, stats, ctrs = self._dispatch_segment(
                o, w, b, v, slice(lo, hi), None)
            view = self._fetch_view(times, stats, ctrs,
                                    np.zeros((self.cfg.n_pages,), bool))
            if not self.migration_enabled:
                continue
            plan = self._plan(view)
            if plan is None:
                continue
            moved = self._commit_epoch(*self._dispatch_apply(plan),
                                       self.segments_replayed - 1)
            if len(moved):
                rem = self._rebuild(cur, pos_by_exp, hi,
                                    np.empty((0,), np.int64))
                if rem is not None:
                    return rem
        return None

    def _replay_sharded(self, cur):
        """The sharded driver (DESIGN.md §17): each segment boundary is
        ONE jit dispatch of the shard_map-ed replay + in-jit plan +
        collective apply (``fabric.shard.boundary_step``), committed with
        ONE fused fetch (``_commit_boundary``) — synchronous migration
        scheduling (the ``_replay_sync`` semantics, bit-identical for the
        integer ``spill`` planner) at one host sync per boundary instead
        of the pipelined driver's one per segment plus one per epoch.
        With migration off nothing is fetched at all: per-segment device
        references accumulate and drain in one deferred sync at the end
        of ``replay()``."""
        o, w, b, v, eids = partition_trace(self.placement, *cur, self.window)
        n = self.n_expanders
        n_win = o.shape[1]
        seg = self._segments(n_win)
        pos_by_exp = [np.nonzero(eids == e)[0] for e in range(n)]
        for lo in range(0, n_win, seg):
            hi = min(lo + seg, n_win)
            sl = slice(lo, hi)
            args = (self.pools, jnp.asarray(o[:, sl]), jnp.asarray(w[:, sl]),
                    jnp.asarray(b[:, sl]), jnp.asarray(v[:, sl]),
                    self.lanes, self._no_pending)
            if not self.migration_enabled:
                step = FS.replay_step(self.mesh, self.cfg, self.policy,
                                      self.obs is not None)
                outs = step(*args)
                self.pools, times = outs[0], outs[1]
                self._modeled_times = times
                self.segments_replayed += 1
                self._deferred_refs.append(
                    (times, self.pools.counters,
                     outs[2] if len(outs) > 2 else None))
                continue
            step = FS.boundary_step(self.mesh, self.cfg, self.policy,
                                    FS.plan_params(self.migration_policy),
                                    self.n_expanders)
            (self.pools, times, ctrs_mid, free_pre, fc, fg,
             pages, srcs, dsts, urgent, moved) = step(
                *args, jnp.asarray(self._blocked))
            self._modeled_times = times
            self.segments_replayed += 1
            self.boundaries += 1
            moved_pages = self._commit_boundary(
                times, ctrs_mid, free_pre, fc, fg, pages, srcs, dsts,
                urgent, moved)
            if len(moved_pages):
                rem = self._rebuild(cur, pos_by_exp, hi,
                                    np.empty((0,), np.int64))
                if rem is not None:
                    return rem
        return None

    @sync_contract(syncs_per="boundary", fetches=1)
    def _commit_boundary(self, times, ctrs_mid, free_pre, fc, fg,
                         pages, srcs, dsts, urgent, moved) -> np.ndarray:
        """The sharded driver's ONE host sync per segment boundary: fetch
        the boundary dispatch's whole outcome — post-replay times and
        counters (the segment's replay delta), the in-jit plan, the
        applied moves, and the post-apply counters/freelists (the epoch's
        migration delta) — in a single fused ``device_get``, then run the
        same host bookkeeping ``_fetch_view`` + ``_commit_epoch`` split
        across two syncs on the vmap drivers."""
        (t, ctrs_mid, free_pre, fc, fg, pages, srcs, dsts, urgent, moved,
         ctrs_post) = jax.device_get(
            (times, ctrs_mid, free_pre, fc, fg, pages, srcs, dsts,
             urgent, moved, self.pools.counters))
        self.boundary_syncs += 1
        ctrs_mid = np.asarray(ctrs_mid, np.int64)
        delta_replay = ctrs_mid - self._last_counters
        self.segment_deltas.append(delta_replay)
        self._last_free = np.asarray(free_pre, np.int64)
        if self.obs is not None:
            # telemetry drain: host values from this single fused fetch
            self.obs.record_segment(self.segments_replayed - 1,
                                    delta_replay, np.asarray(t, np.float64),
                                    self._last_free)
        pages = np.asarray(pages).reshape(-1)
        srcs = np.asarray(srcs).reshape(-1)
        dsts = np.asarray(dsts).reshape(-1)
        psel = pages >= 0
        if not psel.any():
            # empty plan: no epoch happened (the collective apply was a
            # bit-exact no-op); the snapshot advances to post-replay
            self._last_counters = ctrs_mid
            return np.empty((0,), np.int64)
        plan = MG.MigrationPlan(pages[psel].astype(np.int32),
                                srcs[psel].astype(np.int32),
                                dsts[psel].astype(np.int32),
                                urgent=bool(urgent))
        if self.obs is not None:
            self.obs.record_plan(self.segments_replayed - 1, plan,
                                 self.migration_policy.name)
        ctrs_post = np.asarray(ctrs_post, np.int64)
        delta_mig = ctrs_post - ctrs_mid
        self.migration_deltas.append(
            (self.segments_replayed - 1, delta_mig, False))
        self._last_counters = ctrs_post
        free_units = np.asarray(fc, np.int64) + 8 * np.asarray(fg, np.int64)
        self._last_free = free_units
        moved = np.asarray(moved)
        msel = moved >= 0
        pages_moved = moved[msel].astype(np.int64)
        self.placement.apply_epoch(pages_moved, dsts[msel])
        self.epochs_applied += 1
        if len(pages_moved):
            np.add.at(self.spill_pages_out, srcs[msel], 1)
            np.add.at(self.spill_pages_in, dsts[msel], 1)
            pairs = {(int(s), int(d)) for s, d in zip(srcs[msel],
                                                      dsts[msel])}
            self.spill_events += len(pairs)
            self._modeled_times = None    # migration traffic not yet priced
            self._blocked[:] = False      # progress: conditions changed
        else:
            self._blocked[plan.pages] = True
        if self.obs is not None:
            self.obs.record_epoch(self.segments_replayed - 1, delta_mig,
                                  kind="sync", overlapped=False,
                                  planned=len(plan), moved=len(pages_moved),
                                  urgent=plan.urgent, free_units=free_units)
        if self.on_epoch is not None:
            self.on_epoch(self, plan, pages_moved)
        return pages_moved

    @sync_contract(syncs_per="drain", fetches=1)
    def _drain_deferred(self) -> None:
        """Drain the sharded migration-off driver's accumulated device
        references — per-segment times, counter snapshots, and (with obs
        attached) freelist headroom — in ONE deferred fetch per
        ``replay()`` call, after the whole trace replayed. Nothing
        host-side depended on any of it mid-run, so the per-segment sync
        of the vmap drivers goes to zero."""
        fetched = jax.device_get(self._deferred_refs)
        self.drain_syncs += 1
        self._deferred_refs = []
        seg0 = self.segments_replayed - len(fetched)
        for i, (t, ctrs, free) in enumerate(fetched):
            ctrs64 = np.asarray(ctrs, np.int64)
            delta = ctrs64 - self._last_counters
            self._last_counters = ctrs64
            self.segment_deltas.append(delta)
            if free is not None:
                self._last_free = np.asarray(free, np.int64)
            if self.obs is not None:
                self.obs.record_segment(seg0 + i, delta,
                                        np.asarray(t, np.float64),
                                        self._last_free
                                        if free is not None else None)

    # -- metrics -------------------------------------------------------------

    def counters(self) -> Dict[str, int]:
        """Summed traffic counters across expanders."""
        return S.stacked_counters_dict(self.pools)

    @sync_contract(syncs_per="call", fetches=1)
    def delivered_time(self, exact: bool = True) -> np.ndarray:
        """Per-expander delivered seconds for the traffic replayed so far,
        each priced by that expander's own ``DeviceConfig`` — migration
        traffic included on the expander where it physically occurred
        (the source's demotion-reads, the donor's writes + compression
        stores land in those pools' counters).

        ``exact=True`` (default, host-side) recomputes in float64 through
        the same ``exec_time_vec`` — the parity-grade numbers benches
        record. ``exact=False`` returns the float32 values the vmapped
        replay computed on device (zero extra device work) — or, when a
        trailing migration invalidated them, re-prices the current
        counters through the same float32 device path, never the float64
        one (the float32-vs-float64 parity asserts stay meaningful).

        Both flavors cost exactly ONE fused fetch (the declared
        contract), so calling it mid-run composes with the schedulers'
        sync budgets instead of quietly doubling them."""
        times = self._modeled_times
        if times is None:
            times = TM.exec_time_vec(self.pools.counters, self.lanes)
        times, counters = jax.device_get((times, self.pools.counters))
        if not exact:
            return np.asarray(times, np.float64)
        return TM.exec_time_vec(np.asarray(counters, np.float64),
                                TM.stack_devices(self.devices, xp=np))

    def bottleneck_time(self, exact: bool = True) -> float:
        """Delivered time of the fabric serving one merged trace: expanders
        run in parallel, so the bottleneck expander governs."""
        return float(np.max(self.delivered_time(exact=exact)))

    def pipeline_times(self) -> Optional[Dict[str, object]]:
        """Pipeline-model delivered seconds from the recorded per-segment
        replay deltas + per-epoch migration deltas (DESIGN.md §13):
        ``overlapped_s`` prices each segment as max(replay, migration)
        (the double-buffered scheduler), ``sync_s`` as their sum (the
        synchronous reference). Both are per-expander float64 arrays over
        the SAME deltas, so overlapped <= sync holds by construction;
        ``delivered_s`` picks the pricing matching how this fabric
        actually ran. Epochs that did NOT physically overlap a segment's
        replay — urgent emergency spills, depth-1/synchronous applies,
        and drain epochs — get their own zero-replay rows, so both
        pricings charge them in full on the critical path; only epochs
        the scheduler genuinely hid behind a foreground segment are
        eligible for the max() discount."""
        rows = self._pipeline_rows()
        if rows is None:
            return None
        replay, mig = rows
        lanes = TM.stack_devices(self.devices, xp=np)
        over = TM.pipeline_delivered_time(replay, mig, lanes, overlapped=True)
        sync = TM.pipeline_delivered_time(replay, mig, lanes,
                                          overlapped=False)
        overlapped_run = (not self.sync_migration and
                          self.pipeline_depth > 1 and
                          self.shard_devices is None)
        return {"overlapped_s": over, "sync_s": sync,
                "mode": "overlapped" if overlapped_run else "sync",
                "delivered_s": over if overlapped_run else sync}

    def _pipeline_rows(self) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """(replay [R,N,C], mig [R,N,C]) — the pipeline row matrices
        shared by ``pipeline_times`` and ``device_times`` (and rebuilt
        independently by obs/export.py from the Recorder's samples; the
        rtol=1e-9 track reconciliation pins the two constructions)."""
        if not self.segment_deltas:
            return None
        n, c = self.n_expanders, S.NUM_COUNTERS
        n_seg = len(self.segment_deltas)
        sync_epochs = [d for _, d, over in self.migration_deltas
                       if not over]
        rows = n_seg + len(sync_epochs)
        replay = np.zeros((rows, n, c), np.float64)
        replay[:n_seg] = np.stack(self.segment_deltas)
        mig = np.zeros_like(replay)
        for i, d, over in self.migration_deltas:
            if over:
                mig[min(i, n_seg - 1)] += d
        for j, d in enumerate(sync_epochs):
            mig[n_seg + j] += d
        return replay, mig

    def device_times(self) -> Optional[Dict[str, object]]:
        """Per-XLA-device delivered seconds on the sharded driver: the
        expanders a device owns execute inside one jit dispatch, so the
        device finishes pipeline row ``r`` when its slowest owned
        expander does — ``device_s[d] = sum_r max_{e in d} max(replay,
        mig)``. Built from the SAME ``_pipeline_rows`` matrices as
        ``pipeline_times`` (on the sharded driver every epoch is a
        zero-replay sync row, so the per-row max degenerates to the sync
        pricing and ``device_s[d] >= max_{e in d} delivered_s[e]``).
        None on vmap drivers or before any segment has replayed."""
        if self.shard_devices is None:
            return None
        rows = self._pipeline_rows()
        if rows is None:
            return None
        replay, mig = rows
        lanes = TM.stack_devices(self.devices, xp=np)
        cell = np.maximum(np.atleast_2d(TM.exec_time_vec(replay, lanes,
                                                         xp=np)),
                          np.atleast_2d(TM.exec_time_vec(mig, lanes,
                                                         xp=np)))
        owners = FS.device_of_expander(self.n_expanders, self.shard_devices)
        device_s = np.asarray([cell[:, owners == d].max(axis=1).sum()
                               for d in range(self.shard_devices)],
                              np.float64)
        return {"device_s": device_s, "owners": owners}

    def park_capacity(self) -> np.ndarray:
        """Per-expander compressed-region headroom in chunk units, straight
        from the last replayed segment's in-jit stats (no host sync when a
        segment has run) — the hook per-expander park-capacity limits for
        fabric-aware serving build on (ROADMAP)."""
        if self._last_free is None:
            ct, gt = jax.device_get((self.pools.cfree.top,
                                     self.pools.gfree.top))
            return np.asarray(ct, np.int64) + 8 * np.asarray(gt, np.int64)
        return self._last_free

    def state_identical(self, other: "Fabric") -> bool:
        """Bit-identity of two fabrics' end states: every leaf of the
        stacked pool pytree (so counters included), plus the placement
        override tables. THE parity predicate — the depth-1-vs-sync pin
        in tests, bench, and the CI smoke all call this one definition."""
        pools_equal = jax.tree_util.tree_all(jax.tree_util.tree_map(
            lambda a, b: bool(jnp.array_equal(a, b)), self.pools,
            other.pools))
        return bool(pools_equal and
                    (self.placement.overrides ==
                     other.placement.overrides).all())

    def counters_by_expander(self) -> List[Dict[str, int]]:
        return S.per_expander_counters(self.pools)

    def spill_stats(self) -> Dict[str, object]:
        return {
            "events": self.spill_events,
            "pages_out": self.spill_pages_out.tolist(),
            "pages_in": self.spill_pages_in.tolist(),
            "syncs": self.epoch_syncs,
        }

    def sync_stats(self) -> Dict[str, int]:
        """The host-sync contract (asserted by benchmarks/fabric_bench.py):
        on the vmap drivers one fused stats fetch per replayed segment
        plus one moved-pages fetch per committed migration epoch; on the
        sharded driver one fused fetch per boundary (migration on) or
        one deferred drain per ``replay()`` call (migration off) —
        nothing else."""
        return {
            "segments": self.segments_replayed,
            "segment_syncs": self.segment_syncs,
            "epochs": self.epochs_applied,
            "epoch_syncs": self.epoch_syncs,
            "boundaries": self.boundaries,
            "boundary_syncs": self.boundary_syncs,
            "drain_syncs": self.drain_syncs,
            "host_syncs": self.segment_syncs + self.epoch_syncs +
            self.boundary_syncs + self.drain_syncs,
        }
