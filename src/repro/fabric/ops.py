"""Cross-expander spill/migration (DESIGN.md §11).

When one expander's freelists run dry while others have headroom, the
fabric migrates compressed pages from the starved expander to a donor:
the page's chunks are read on the source (charged as demotion-read
traffic there), freed, and the page is re-stored on the destination
(allocation + demotion-write + compression-store bookkeeping charged
there) — the same §4 mechanism ops demotion uses, so invariants I1–I5
hold on both expanders after every migration. Only *non-promoted*
chunk-backed pages are eligible: hot pages stay where their traffic is,
and zero pages occupy no chunks so moving them frees nothing.

Traffic is charged per expander on the pool the access physically
touches; fabric-level event counts (pages/bytes moved, spill events)
live on the host ``Fabric`` object (fabric/replay.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.core import metadata as md
from repro.core.engine import ops
from repro.core.engine.policy import Policy
from repro.core.engine.state import (C_DEMO_RD, C_DEMO_WR, C_META_RD,
                                     C_META_WR, CTR_DTYPE, Pool, bump)


def migrate_page(src: Pool, dst: Pool, cfg: PoolConfig, policy: Policy,
                 ospn) -> Tuple[Pool, Pool, jnp.ndarray]:
    """Move one page's compressed copy from ``src`` to ``dst``.

    Eligible pages are valid, non-promoted, and chunk-backed; anything else
    is a no-op (returns moved=False). The metadata word travels unchanged
    (rates, sizes, num_chunks, wr_cntr); only the chunk pointers are
    rewritten for the destination's allocation."""
    entry = src.meta[ospn]
    w0 = entry[0]
    nchunks = md.get_num_chunks(w0).astype(jnp.int32)
    eligible = (md.get_valid(w0) == 1) & (md.get_promoted(w0) == 0) & \
        (nchunks > 0)

    def do(carry):
        s, d = carry
        # source: read the compressed payload (nchunks * 512B), free the
        # chunks, invalidate the entry
        buf = ops._gather_page_buf(s, cfg, entry)
        moved_units = (nchunks * (cfg.chunk_bytes // 64)).astype(CTR_DTYPE)
        sc = policy.charge_migration(s.counters, C_DEMO_RD, moved_units)
        sc = bump(sc, C_META_RD, ops.meta_width(cfg, ospn))
        s = ops.free_chunks(s._replace(counters=sc), cfg, entry)
        s = s._replace(meta=s.meta.at[ospn].set(md.empty_entry()),
                       counters=bump(s.counters, C_META_WR,
                                     ops.meta_width(cfg, ospn)))
        # destination: allocate, store, write the travelled metadata word
        d, ptrs, is_group = ops.alloc_chunks(d, cfg, nchunks)
        d = ops._scatter_page_buf(d, cfg, buf, ptrs, nchunks, is_group)
        new_entry = entry
        for i in range(7):
            new_entry = md.set_ptr(new_entry, i, jnp.maximum(ptrs[i], 0))
        dc = policy.charge_migration(d.counters, C_DEMO_WR, moved_units)
        dc = bump(dc, C_META_WR, ops.meta_width(cfg, ospn))
        dc = policy.on_compress_store(dc)
        d = d._replace(meta=d.meta.at[ospn].set(new_entry), counters=dc)
        return s, d

    src, dst = jax.lax.cond(eligible, do, lambda c: c, (src, dst))
    return src, dst, eligible


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def spill_pages(src: Pool, dst: Pool, cfg: PoolConfig, policy: Policy,
                k: int) -> Tuple[Pool, Pool, jnp.ndarray]:
    """Migrate up to ``k`` eligible pages from ``src`` to ``dst``.

    Candidates are taken in OSPN order (deterministic; the clock engine
    already provides recency-aware victimization for *demotion* — spill
    relieves capacity, it does not rank hotness). A migration is skipped
    when the donor lacks a safe allocation margin (7 singles + 1 group),
    so spill can never corrupt the donor's freelists. Returns the updated
    pools plus int32[k] migrated OSPNs, -1-padded — the host pins those
    pages to the destination in the placement override table."""
    w0s = src.meta[:, 0]
    cand = (md.get_valid(w0s) == 1) & (md.get_promoted(w0s) == 0) & \
        (md.get_num_chunks(w0s) > 0)
    # stable order: candidate OSPNs first, in page order
    order = jnp.argsort(~cand).astype(jnp.int32)

    def body(i, carry):
        s, d, moved = carry
        ospn = order[i]
        headroom = (d.cfree.top >= 7) & (d.gfree.top >= 1)
        ok = cand[ospn] & headroom

        def do(c):
            s2, d2, m2 = c
            s2, d2, did = migrate_page(s2, d2, cfg, policy, ospn)
            m2 = m2.at[i].set(jnp.where(did, ospn, -1))
            return s2, d2, m2

        return jax.lax.cond(ok, do, lambda c: c, (s, d, moved))

    moved0 = jnp.full((k,), -1, jnp.int32)
    return jax.lax.fori_loop(0, k, body, (src, dst, moved0))
