"""Cross-expander migration mechanism (DESIGN.md §11/§13).

When pages move between expanders — freelist-pressure spill or
traffic-imbalance rebalancing (fabric/migration.py decides) — the
mechanism is the same: the page's chunks are read on the source (charged
as demotion-read traffic there), freed, and the page is re-stored on the
destination (allocation + demotion-write + compression-store bookkeeping
charged there) — the same §4 mechanism ops demotion uses, so invariants
I1–I5 hold on both expanders after every migration. Only *non-promoted*
chunk-backed pages are eligible: hot pages stay where their traffic is,
and zero pages occupy no chunks so moving them frees nothing.

The plan/apply split (§13): ``segment_stats`` computes the per-expander
facts a ``MigrationPolicy`` plans from — freelist headroom, per-page
eligibility, per-page referenced bits (metadata-cache residency, the
§4.4 lazy-reference live set) — *inside* the vmapped segment replay, so
planning costs no extra host sync. ``apply_migrations`` applies one
epoch's explicit (page, src, dst) moves on the stacked pool state in a
single jit call, re-checking eligibility and donor headroom per move —
a page that promoted or invalidated while its plan was in flight is
skipped, never corrupted. ``spill_pages`` (in-jit candidate selection on
a sliced pool pair) is the PR 3 API, kept for compatibility.

Traffic is charged per expander on the pool the access physically
touches; fabric-level event counts (pages/bytes moved, epochs, syncs)
live on the host ``Fabric`` object (fabric/replay.py).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.core import mcache as mcc
from repro.core import metadata as md
from repro.core.engine import ops
from repro.core.engine.policy import Policy
from repro.core.engine.state import (C_DEMO_RD, C_DEMO_WR, C_META_RD,
                                     C_META_WR, CTR_DTYPE, Pool, bump)


class SegmentStats(NamedTuple):
    """Per-expander migration facts, computed in-jit each segment (one
    leading expander axis under the fabric's vmap). The singles/groups
    split is exposed so the PLANNER's donor rule can use the same safe
    allocation margin the APPLY enforces (7 singles + 1 group) — a plan
    whose every move the apply would skip is a livelock, not a plan."""
    free_units: jnp.ndarray   # int32[]  cfree + 8*gfree, in chunk units
    free_singles: jnp.ndarray  # int32[] cfree.top
    free_groups: jnp.ndarray  # int32[]  gfree.top
    eligible: jnp.ndarray     # bool[P]  valid & ~promoted & chunk-backed
    referenced: jnp.ndarray   # bool[P]  metadata-cache-resident (§4.4)


def segment_stats(pool: Pool, cfg: PoolConfig) -> SegmentStats:
    """One expander's migration-planning facts. Referenced bits at page
    granularity for *compressed* pages are metadata-cache residency — the
    same recency signal the demotion engine probes to protect hot pages
    (the activity-region referenced bits cover only promoted pages, which
    never migrate)."""
    w0s = pool.meta[:, 0]
    eligible = (md.get_valid(w0s) == 1) & (md.get_promoted(w0s) == 0) & \
        (md.get_num_chunks(w0s) > 0)
    free_units = pool.cfree.top + 8 * pool.gfree.top
    ids = jnp.arange(cfg.n_pages, dtype=jnp.int32)
    sets = mcc._set_index(ids, pool.cache.tags.shape[0])
    referenced = jnp.any(pool.cache.tags[sets] == ids[:, None], axis=1)
    return SegmentStats(free_units=free_units, free_singles=pool.cfree.top,
                        free_groups=pool.gfree.top, eligible=eligible,
                        referenced=referenced)


def page_eligible(entry) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(eligible, nchunks) from a metadata entry: valid, non-promoted,
    chunk-backed — the per-move re-check every apply path shares."""
    w0 = entry[0]
    nchunks = md.get_num_chunks(w0).astype(jnp.int32)
    eligible = (md.get_valid(w0) == 1) & (md.get_promoted(w0) == 0) & \
        (nchunks > 0)
    return eligible, nchunks


def migrate_src(s: Pool, cfg: PoolConfig, policy: Policy, ospn, entry,
                nchunks) -> Pool:
    """Source half of one page move (the payload gather happens at the
    caller — the collective apply routes it over the mesh between the
    halves): charge the demotion-read + metadata traffic, free the
    chunks, invalidate the entry."""
    moved_units = (nchunks * (cfg.chunk_bytes // 64)).astype(CTR_DTYPE)
    sc = policy.charge_migration(s.counters, C_DEMO_RD, moved_units)
    sc = bump(sc, C_META_RD, ops.meta_width(cfg, ospn))
    s = ops.free_chunks(s._replace(counters=sc), cfg, entry)
    return s._replace(meta=s.meta.at[ospn].set(md.empty_entry()),
                      counters=bump(s.counters, C_META_WR,
                                    ops.meta_width(cfg, ospn)))


def migrate_dst(d: Pool, cfg: PoolConfig, policy: Policy, ospn, entry,
                nchunks, buf) -> Pool:
    """Destination half: allocate, store the routed payload, write the
    travelled metadata word with the pointers rewritten for the
    destination's allocation."""
    moved_units = (nchunks * (cfg.chunk_bytes // 64)).astype(CTR_DTYPE)
    d, ptrs, is_group = ops.alloc_chunks(d, cfg, nchunks)
    d = ops._scatter_page_buf(d, cfg, buf, ptrs, nchunks, is_group)
    new_entry = entry
    for i in range(7):
        new_entry = md.set_ptr(new_entry, i, jnp.maximum(ptrs[i], 0))
    dc = policy.charge_migration(d.counters, C_DEMO_WR, moved_units)
    dc = bump(dc, C_META_WR, ops.meta_width(cfg, ospn))
    dc = policy.on_compress_store(dc)
    return d._replace(meta=d.meta.at[ospn].set(new_entry), counters=dc)


def migrate_page(src: Pool, dst: Pool, cfg: PoolConfig, policy: Policy,
                 ospn) -> Tuple[Pool, Pool, jnp.ndarray]:
    """Move one page's compressed copy from ``src`` to ``dst``.

    Eligible pages are valid, non-promoted, and chunk-backed; anything else
    is a no-op (returns moved=False). The metadata word travels unchanged
    (rates, sizes, num_chunks, wr_cntr); only the chunk pointers are
    rewritten for the destination's allocation. Composed from the same
    ``migrate_src`` / ``migrate_dst`` halves the sharded collective apply
    uses, so the two paths stay bit-identical per move."""
    entry = src.meta[ospn]
    eligible, nchunks = page_eligible(entry)

    def do(carry):
        s, d = carry
        # source: read the compressed payload (nchunks * 512B), free the
        # chunks, invalidate the entry
        buf = ops._gather_page_buf(s, cfg, entry)
        s = migrate_src(s, cfg, policy, ospn, entry, nchunks)
        # destination: allocate, store, write the travelled metadata word
        d = migrate_dst(d, cfg, policy, ospn, entry, nchunks, buf)
        return s, d

    src, dst = jax.lax.cond(eligible, do, lambda c: c, (src, dst))
    return src, dst, eligible


@functools.partial(jax.jit, static_argnums=(2, 3, 4))
def spill_pages(src: Pool, dst: Pool, cfg: PoolConfig, policy: Policy,
                k: int) -> Tuple[Pool, Pool, jnp.ndarray]:
    """Migrate up to ``k`` eligible pages from ``src`` to ``dst``.

    Candidates are taken in OSPN order (deterministic; the clock engine
    already provides recency-aware victimization for *demotion* — spill
    relieves capacity, it does not rank hotness). A migration is skipped
    when the donor lacks a safe allocation margin (7 singles + 1 group),
    so spill can never corrupt the donor's freelists. Returns the updated
    pools plus int32[k] migrated OSPNs, -1-padded — the host pins those
    pages to the destination in the placement override table."""
    w0s = src.meta[:, 0]
    cand = (md.get_valid(w0s) == 1) & (md.get_promoted(w0s) == 0) & \
        (md.get_num_chunks(w0s) > 0)
    # stable order: candidate OSPNs first, in page order
    order = jnp.argsort(~cand).astype(jnp.int32)

    def body(i, carry):
        s, d, moved = carry
        ospn = order[i]
        headroom = (d.cfree.top >= 7) & (d.gfree.top >= 1)
        ok = cand[ospn] & headroom

        def do(c):
            s2, d2, m2 = c
            s2, d2, did = migrate_page(s2, d2, cfg, policy, ospn)
            m2 = m2.at[i].set(jnp.where(did, ospn, -1))
            return s2, d2, m2

        return jax.lax.cond(ok, do, lambda c: c, (s, d, moved))

    moved0 = jnp.full((k,), -1, jnp.int32)
    return jax.lax.fori_loop(0, k, body, (src, dst, moved0))


@functools.partial(jax.jit, static_argnums=(1, 2))
def apply_migrations(pools: Pool, cfg: PoolConfig, policy: Policy,
                     pages, srcs, dsts) -> Tuple[Pool, jnp.ndarray]:
    """Apply one migration epoch on the STACKED pool state in one jit call.

    ``pages``/``srcs``/``dsts`` are int32[k] (pages -1-padded): explicit
    moves a ``MigrationPolicy`` planned host-side, possibly one segment
    ago. Each move re-checks donor headroom (7 singles + 1 group, the
    safe allocation margin) against the donor's LIVE freelists and page
    eligibility against the LIVE metadata (inside ``migrate_page``), so a
    stale plan skips — never corrupts — a page whose state changed while
    the plan was in flight. Returns the updated stack plus int32[k] of
    the OSPNs that actually moved (-1 where skipped); the host turns that
    into ONE batched override-table scatter (`Placement.apply_epoch`)."""
    def body(i, carry):
        stack, moved = carry
        p, s, d = pages[i], srcs[i], dsts[i]

        def do(c):
            stack, moved = c
            src = jax.tree_util.tree_map(lambda a: a[s], stack)
            dst = jax.tree_util.tree_map(lambda a: a[d], stack)
            headroom = (dst.cfree.top >= 7) & (dst.gfree.top >= 1)

            def go(c2):
                stack2, m2 = c2
                src2, dst2, did = migrate_page(src, dst, cfg, policy, p)
                stack2 = jax.tree_util.tree_map(
                    lambda a, x: a.at[s].set(x), stack2, src2)
                stack2 = jax.tree_util.tree_map(
                    lambda a, x: a.at[d].set(x), stack2, dst2)
                return stack2, m2.at[i].set(jnp.where(did, p, -1))

            return jax.lax.cond(headroom, go, lambda c2: c2, (stack, moved))

        return jax.lax.cond((p >= 0) & (s != d), do, lambda c: c, carry)

    moved0 = jnp.full(pages.shape, -1, jnp.int32)
    return jax.lax.fori_loop(0, pages.shape[0], body, (pools, moved0))
