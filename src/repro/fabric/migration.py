"""Migration policy layer: WHAT moves between expanders, and WHY
(DESIGN.md §13).

The segment scheduler (fabric/replay.py) separates migration *mechanism*
from migration *policy*, mirroring the pool's ``core/engine/policy.Policy``
split: the scheduler owns the pipeline (per-segment stats computed in-jit,
one fetch per stage, batched apply + one override scatter per epoch), and a
``MigrationPolicy`` owns the decision. A policy is a pure host-side
function of a :class:`SegmentView` — the per-segment facts the vmapped
replay already computed on device (freelist headroom, eligibility and
referenced bits per page, counter deltas, in-jit delivered times) — and
returns a :class:`MigrationPlan` (or ``None``): explicit page → expander
moves the scheduler applies in one jitted epoch.

Policies:

  * ``SpillPressure``    — the freelist-pressure spill: an expander whose
    compressed-region headroom falls below the low watermark sheds its
    first ``k`` eligible pages (OSPN order — spill relieves capacity, it
    does not rank hotness) to the most-free donor that clears ``2 * low``.
  * ``TrafficRebalance`` — pressure spill PLUS a traffic-imbalance
    trigger: when one expander's share of the segment's host-access delta
    exceeds ``trigger`` times the fair share AND its in-jit delivered time
    leads the coldest expander's by ``time_ratio``, hot *compressed* pages
    migrate toward the idle expander. The referenced bits pick WHICH pages
    move: only eligible pages whose metadata is cache-resident — the §4.4
    lazy-reference live set; the activity-region referenced bits cover
    promoted pages, which never migrate — are worth moving, because their
    future promotions and reads follow them to the donor.
  * ``NoMigration``      — the off switch (``--migration off``).

Eligibility is always re-checked in-jit at apply time
(``fabric.ops.migrate_page``), so a plan computed one segment ago can
never move a page that promoted or invalidated while in flight.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np

from repro.core.engine.state import C_HOST_RD, C_HOST_WR


@dataclass
class SegmentView:
    """Host-side view of one replayed segment: everything a policy may
    consume, all fetched in the scheduler's single per-segment sync.
    Arrays are numpy; ``N`` expanders, ``P`` OSPA pages, ``C`` counters."""
    free_units: np.ndarray    # int64[N]  compressed headroom (chunk units)
    free_singles: np.ndarray  # int64[N]  free single C-chunks
    free_groups: np.ndarray   # int64[N]  free aligned 8-chunk groups
    eligible: np.ndarray      # bool[N, P] valid & ~promoted & chunk-backed
    referenced: np.ndarray    # bool[N, P] metadata-cache-resident (§4.4)
    counters: np.ndarray      # int64[N, C] cumulative, post-segment
    delta: np.ndarray         # int64[N, C] this segment's replay delta
    times: np.ndarray         # float64[N] in-jit delivered seconds
    recent: np.ndarray        # bool[P] pages moved by the last epoch
    # pages whose last planned epoch moved NOTHING (the scheduler's
    # livelock guard): candidate selection must skip them so the next
    # plan tries DIFFERENT pages — a successful epoch then clears the
    # set. Merely filtering them out post-hoc would leave the policy
    # re-planning the same barred pages forever, with migration dead.
    blocked: np.ndarray       # bool[P]

    @property
    def n_expanders(self) -> int:
        return self.free_units.shape[0]

    def donor_ok(self) -> np.ndarray:
        """bool[N]: expanders holding the apply-time safe allocation
        margin (7 singles + 1 aligned group — exactly the guard
        ``fabric.ops.apply_migrations`` enforces per move). Planning a
        donor without it yields an epoch whose every move is skipped."""
        return (self.free_singles >= 7) & (self.free_groups >= 1)


@dataclass
class MigrationPlan:
    """Explicit page moves for one epoch. Applied by the scheduler in one
    jitted batch; the override-table update is one scatter of the pages
    that actually moved. ``urgent`` marks a plan whose source is ALREADY
    below the hard watermark: the scheduler applies it at this boundary
    (synchronous emergency relief — deferring it one segment risks
    freelist exhaustion mid-replay) instead of overlapping it."""
    pages: np.ndarray         # int32[k]
    srcs: np.ndarray          # int32[k]
    dsts: np.ndarray          # int32[k]
    urgent: bool = False

    def __len__(self) -> int:
        return len(self.pages)

    def pairs(self) -> List[Tuple[int, int]]:
        """Sorted unique (src, dst) expander routes this plan uses — the
        telemetry-facing shape of a plan (``obs.Recorder.record_plan``
        tags each plan event with it, so a trace can show WHERE pages
        were routed without storing every per-page move)."""
        return sorted({(int(s), int(d))
                       for s, d in zip(self.srcs, self.dsts)})


def _plan(moves: List[Tuple[np.ndarray, int, int]],
          urgent: bool = False) -> Optional[MigrationPlan]:
    moves = [(p, s, d) for p, s, d in moves if len(p)]
    if not moves:
        return None
    pages = np.concatenate([p for p, _, _ in moves]).astype(np.int32)
    srcs = np.concatenate([np.full(len(p), s, np.int32)
                           for p, s, _ in moves])
    dsts = np.concatenate([np.full(len(p), d, np.int32)
                           for p, _, d in moves])
    return MigrationPlan(pages, srcs, dsts, urgent=urgent)


class MigrationPolicy:
    """Protocol: ``plan`` maps a segment view to moves (or ``None``)."""

    name = "base"

    def plan(self, view: SegmentView) -> Optional[MigrationPlan]:
        raise NotImplementedError


@dataclass
class NoMigration(MigrationPolicy):
    name: str = "off"

    def plan(self, view: SegmentView) -> Optional[MigrationPlan]:
        return None


@dataclass
class SpillPressure(MigrationPolicy):
    """Freelist-pressure spill (the PR 3 trigger, planned host-side).

    ``low`` is the hard compressed-region watermark in chunk units; ``k``
    pages move per starved expander per epoch; a donor must clear
    ``2 * low``. ``proactive`` widens the trigger to ``proactive * low``
    so the pipelined scheduler can fire a spill one segment EARLY and
    overlap it; an expander already below the hard ``low`` makes the plan
    ``urgent`` (the scheduler applies it synchronously — relief that
    lands a segment late is relief after the freelists ran dry). Donor
    accounting stays conservative within one plan (a planned page may
    occupy a whole 8-chunk group on the donor)."""
    k: int = 16
    low: int = 64
    proactive: float = 1.5
    name: str = "spill"

    def _pressure_moves(self, view: SegmentView, free: np.ndarray
                        ) -> Tuple[List[Tuple[np.ndarray, int, int]], bool]:
        moves: List[Tuple[np.ndarray, int, int]] = []
        urgent = False
        donor_ok = view.donor_ok()
        for e in np.nonzero(free < self.proactive * self.low)[0]:
            donor = int(np.argmax(free))
            if donor == int(e) or free[donor] < 2 * self.low or \
                    not donor_ok[donor]:
                continue
            cand = view.eligible[e] & ~view.recent & ~view.blocked
            pages = np.nonzero(cand)[0][: self.k].astype(np.int32)
            if not len(pages):
                continue
            urgent = urgent or free[e] < self.low
            moves.append((pages, int(e), donor))
            free[donor] -= 8 * len(pages)
        return moves, urgent

    def plan(self, view: SegmentView) -> Optional[MigrationPlan]:
        moves, urgent = self._pressure_moves(view, view.free_units.copy())
        return _plan(moves, urgent)


@dataclass
class TrafficRebalance(SpillPressure):
    """Pressure spill + traffic-imbalance rebalancing.

    The trigger consumes the per-segment counter DELTAS (host-access share
    this segment) and the per-expander in-jit delivered times — both
    computed inside the vmapped replay, no extra sync. When the hottest
    expander's segment host share exceeds ``trigger / N`` and its
    delivered time leads the coldest headroom-bearing expander by
    ``time_ratio``, up to ``k`` referenced (metadata-cache-resident)
    eligible pages move hot → cold."""
    trigger: float = 1.5      # x fair share of the segment's host delta
    time_ratio: float = 1.05  # hot delivered time must lead cold by this
    min_delta: int = 8        # ignore near-empty segments
    name: str = "rebalance"

    def plan(self, view: SegmentView) -> Optional[MigrationPlan]:
        free = view.free_units.copy()
        moves, urgent = self._pressure_moves(view, free)
        host_d = (view.delta[:, C_HOST_RD] +
                  view.delta[:, C_HOST_WR]).astype(np.int64)
        total = int(host_d.sum())
        n = view.n_expanders
        if n > 1 and total >= self.min_delta:
            hot = int(np.argmax(host_d))
            # coldest expander by delivered time among those with donor
            # headroom (never rebalance INTO a pressure-starved expander,
            # nor one the apply-time allocation guard would refuse)
            ok = (free >= 2 * self.low) & view.donor_ok()
            ok[hot] = False
            if ok.any() and host_d[hot] * n > self.trigger * total:
                times = np.where(ok, view.times, np.inf)
                cold = int(np.argmin(times))
                if view.times[hot] > self.time_ratio * view.times[cold]:
                    planned = np.concatenate(
                        [p for p, _, _ in moves]) if moves else \
                        np.empty(0, np.int32)
                    cand = (view.eligible[hot] & ~view.recent &
                            ~view.blocked)
                    cand[planned] = False
                    # referenced bits rank the candidates: recently
                    # referenced compressed pages (metadata-cache
                    # resident) carry the most future traffic, so they
                    # move first; the rest of the budget falls back to
                    # unreferenced eligible pages in page order
                    refd = cand & view.referenced[hot]
                    order = np.concatenate([np.nonzero(refd)[0],
                                            np.nonzero(cand & ~refd)[0]])
                    pages = order[: self.k].astype(np.int32)
                    if len(pages):
                        moves.append((pages, hot, cold))
        return _plan(moves, urgent)


def make_migration_policy(mode: str, *, k: int = 16, low: int = 64,
                          proactive: float = 1.5, trigger: float = 1.5,
                          time_ratio: float = 1.05) -> MigrationPolicy:
    """CLI/bench factory: spill | rebalance | off."""
    if mode == "spill":
        return SpillPressure(k=k, low=low, proactive=proactive)
    if mode == "rebalance":
        return TrafficRebalance(k=k, low=low, proactive=proactive,
                                trigger=trigger, time_ratio=time_ratio)
    if mode == "off":
        return NoMigration()
    raise ValueError(f"unknown migration mode {mode!r}")
