"""Core AST machinery for the jit-hygiene static analyzer (DESIGN.md §15).

Pure stdlib (``ast`` + ``re``) — this package must run in CI and
pre-commit contexts with no jax installed, so nothing here imports the
runtime stack. Three layers:

  * **ModuleInfo** — one parsed source file: parent links, function
    qualnames, lexical scope tables for resolving a ``Name`` to the local
    function it references, ``# lint: host-ok(reason)`` suppressions, and
    the resolved jit regions.
  * **Region resolution** — a *jit region* is code that executes under
    tracing, where a hidden host sync is a per-access CXL round trip
    rather than a one-time cost. Regions are found syntactically:
    functions decorated ``@jax.jit`` / ``@functools.partial(jax.jit,
    ...)``; functions wrapped at a call site ``jax.jit(f)`` /
    ``jax.jit(functools.partial(f, kw=...))`` (partial-bound kwargs are
    closure constants → static); bodies passed to ``lax.scan`` /
    ``cond`` / ``while_loop`` / ``fori_loop`` / ``switch`` / ``vmap``;
    and Pallas kernels (first argument of ``pl.pallas_call``).
  * **Taint walk** — a lightweight traced-value dataflow over one
    region: non-static parameters seed the taint set; assignments
    propagate it; ``.shape``/``.dtype``-style metadata access drops it
    (static at trace time); structural tests (``x is None``,
    ``"key" in pytree``, ``isinstance``/``len``) are exempt.  The walk
    emits *events* (host cast, ``.item()``, numpy call on a traced
    value, ``print``, Python branch on a traced value) that rule R1
    turns into findings.  Local calls resolve one module deep
    (call-site argument taint maps onto callee parameters), so helpers
    like ``batch._window_step`` — jitted only through their callers —
    are still covered.

The walk is deliberately conservative in BOTH directions: unknown names
(imports, closures from non-region scopes) are untainted — a false
positive costs developer trust, a false negative is caught by the
runtime sync counters the benches already assert — and every finding is
suppressible inline with ``# lint: host-ok(reason)``.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import re
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

SUPPRESS_RE = re.compile(r"#\s*lint:\s*host-ok\(([^)#]*)\)")

JIT_NAMES = {"jax.jit", "jit", "jax.pjit", "pjit"}
PARTIAL_NAMES = {"functools.partial", "partial"}
PALLAS_NAMES = {"pl.pallas_call", "pallas_call", "pallas.pallas_call"}
TRACER_WRAPPERS = {"jax.vmap", "vmap", "jax.pmap", "pmap", "shard_map",
                   "jax.checkpoint", "jax.remat", "checkpoint", "remat",
                   "jax.grad", "grad", "jax.value_and_grad",
                   "value_and_grad"}
LAX_COMBINATORS = {"scan", "cond", "while_loop", "fori_loop", "switch",
                   "map", "associative_scan"}

# attribute access that yields trace-time-static metadata, not a traced value
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "itemsize", "nbytes",
                "aval", "weak_type"}

HOST_CASTS = {"int", "float", "bool", "complex"}
NUMPY_ROOTS = {"np", "numpy", "onp"}
# roots whose calls produce traced values inside a region
TRACED_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu"}
DEVICE_GET_NAMES = {"jax.device_get", "device_get"}

_TAINT_DEPTH = 3    # local-call propagation depth (module-local only)


# ---------------------------------------------------------------------------
# Findings.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Finding:
    rule: str
    path: str          # posix-style path relative to the scan root's parent
    line: int
    col: int
    func: str          # enclosing function qualname, or "<module>"
    message: str
    suppressed: bool = False
    suppress_reason: str = ""

    def fingerprint(self) -> str:
        """Line-number-free identity used by the baseline ratchet: findings
        survive unrelated edits above them, and a *new* instance of an
        already-baselined (rule, func, message) in the same file still
        counts as new (baselines are multisets)."""
        key = f"{self.rule}|{self.path}|{self.func}|{self.message}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d

    def render(self) -> str:
        sup = f"  [host-ok: {self.suppress_reason}]" if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{self.func}] {self.message}{sup}")


# ---------------------------------------------------------------------------
# Small AST helpers.
# ---------------------------------------------------------------------------

def dotted(node: Optional[ast.AST]) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _literal(node: Optional[ast.AST]):
    if node is None:
        return None
    try:
        return ast.literal_eval(node)
    except (ValueError, TypeError, SyntaxError, MemoryError):
        return None


def is_lax_combinator(name: Optional[str]) -> bool:
    if not name:
        return False
    return any(name in (f"jax.lax.{c}", f"lax.{c}") for c in LAX_COMBINATORS)


FuncNode = (ast.FunctionDef, ast.AsyncFunctionDef)
FuncLike = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def param_names(node) -> List[str]:
    """Positional-capable parameter names in order (posonly + args)."""
    a = node.args
    return [p.arg for p in a.posonlyargs + a.args]


def all_param_names(node) -> List[str]:
    a = node.args
    names = [p.arg for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        names.append(a.vararg.arg)
    if a.kwarg:
        names.append(a.kwarg.arg)
    return names


# ---------------------------------------------------------------------------
# Jit-decorator / call-site parsing.
# ---------------------------------------------------------------------------

def _parse_jit_kwargs(call: ast.Call) -> dict:
    meta = {"static_argnums": None, "static_argnames": None, "node": call}
    for kw in call.keywords:
        if kw.arg == "static_argnums":
            v = _literal(kw.value)
            if isinstance(v, int):
                v = (v,)
            if isinstance(v, (tuple, list)):
                meta["static_argnums"] = tuple(x for x in v
                                               if isinstance(x, int))
        elif kw.arg == "static_argnames":
            v = _literal(kw.value)
            if isinstance(v, str):
                v = (v,)
            if isinstance(v, (tuple, list)):
                meta["static_argnames"] = tuple(x for x in v
                                                if isinstance(x, str))
    return meta


def jit_decorator_info(dec: ast.AST) -> Optional[dict]:
    """``@jax.jit`` / ``@jax.jit(...)`` / ``@functools.partial(jax.jit,
    static_arg...=...)`` → jit metadata, else None."""
    if dotted(dec) in JIT_NAMES:
        return {"static_argnums": None, "static_argnames": None, "node": dec}
    if isinstance(dec, ast.Call):
        fd = dotted(dec.func)
        if fd in JIT_NAMES:
            return _parse_jit_kwargs(dec)
        if fd in PARTIAL_NAMES and dec.args and \
                dotted(dec.args[0]) in JIT_NAMES:
            return _parse_jit_kwargs(dec)
    return None


def unwrap_partial(node: ast.AST) -> Tuple[Optional[ast.AST], Tuple[str, ...]]:
    """``functools.partial(f, kw=...)`` → (f, bound kwarg names); anything
    else passes through with no bound names. Partial-bound kwargs become
    closure constants of the traced callable → static."""
    if isinstance(node, ast.Call) and dotted(node.func) in PARTIAL_NAMES \
            and node.args:
        return node.args[0], tuple(kw.arg for kw in node.keywords
                                   if kw.arg is not None)
    return node, ()


def static_names_for(node, meta: dict,
                     extra: Sequence[str] = ()) -> frozenset:
    """Resolve static_argnums/static_argnames metadata against a concrete
    signature into a set of static parameter names."""
    names = set(extra)
    names.update(meta.get("static_argnames") or ())
    pos = param_names(node)
    for i in meta.get("static_argnums") or ():
        if 0 <= i < len(pos):
            names.add(pos[i])
    return frozenset(names)


# ---------------------------------------------------------------------------
# Regions.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Region:
    node: ast.AST                  # FunctionDef / AsyncFunctionDef / Lambda
    kind: str                      # "jit" (real jit boundary) | "traced"
    static_names: frozenset
    qualname: str
    reason: str                    # how it was discovered (for messages)
    jit_meta: Optional[dict] = None


class ModuleInfo:
    """One parsed source file plus everything the rules need from it."""

    def __init__(self, path, src: Optional[str] = None,
                 relpath: Optional[str] = None):
        self.path = Path(path)
        self.src = self.path.read_text() if src is None else src
        self.relpath = (relpath if relpath is not None
                        else self.path.name).replace("\\", "/")
        self.lines = self.src.splitlines()
        self.suppressions: Dict[int, str] = {}
        for i, line in enumerate(self.lines, 1):
            m = SUPPRESS_RE.search(line)
            if m:
                self.suppressions[i] = m.group(1).strip()
        self.tree = ast.parse(self.src, filename=str(self.path))
        self._index()
        self.regions = self._discover_regions()

    # -- indexing -----------------------------------------------------------

    def _index(self) -> None:
        self.parent: Dict[int, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parent[id(child)] = node
        self.qualnames: Dict[int, str] = {}
        self.functions: List[Tuple[ast.AST, str]] = []
        # scope tables: enclosing function of each function, and the
        # functions defined directly within each scope (None = module)
        self._scope_of: Dict[int, Optional[ast.AST]] = {}
        self._local_defs: Dict[Optional[int], Dict[str, ast.AST]] = {None: {}}

        def visit(node, scope, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, FuncNode):
                    qn = prefix + child.name
                    self.qualnames[id(child)] = qn
                    self.functions.append((child, qn))
                    self._scope_of[id(child)] = scope
                    key = id(scope) if scope is not None else None
                    self._local_defs.setdefault(key, {})[child.name] = child
                    self._local_defs.setdefault(id(child), {})
                    visit(child, child, qn + ".")
                elif isinstance(child, ast.Lambda):
                    qn = f"{prefix}<lambda:{child.lineno}>"
                    self.qualnames[id(child)] = qn
                    self._scope_of[id(child)] = scope
                    visit(child, scope, prefix)
                elif isinstance(child, ast.ClassDef):
                    visit(child, scope, prefix + child.name + ".")
                else:
                    visit(child, scope, prefix)

        visit(self.tree, None, "")

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        cur = self.parent.get(id(node))
        while cur is not None and not isinstance(cur, FuncLike):
            cur = self.parent.get(id(cur))
        return cur

    def func_qualname(self, node: ast.AST) -> str:
        fn = node if isinstance(node, FuncLike) else \
            self.enclosing_function(node)
        if fn is None:
            return "<module>"
        return self.qualnames.get(id(fn), "<?>")

    def get_function(self, qualname: str) -> Optional[ast.AST]:
        for node, qn in self.functions:
            if qn == qualname:
                return node
        return None

    def resolve_function(self, name: str,
                         at: ast.AST) -> Optional[ast.AST]:
        """Resolve a bare Name reference to a function defined in an
        enclosing lexical scope of this module (nearest scope wins)."""
        scope = self.enclosing_function(at)
        while True:
            key = id(scope) if scope is not None else None
            table = self._local_defs.get(key, {})
            if name in table:
                return table[name]
            if scope is None:
                return None
            scope = self._scope_of.get(id(scope))

    # -- suppression / finding construction ---------------------------------

    def suppression_at(self, node: ast.AST) -> Optional[str]:
        """Inline suppression covering ``node``: same line, the closing
        line of a multi-line construct, or a comment-only line directly
        above."""
        for ln in {getattr(node, "lineno", 0),
                   getattr(node, "end_lineno", 0) or 0,
                   max(getattr(node, "lineno", 1) - 1, 1)}:
            if ln in self.suppressions:
                if ln == getattr(node, "lineno", 0) - 1:
                    text = self.lines[ln - 1].strip()
                    if not text.startswith("#"):
                        continue          # code line above: not ours
                return self.suppressions[ln]
        return None

    def finding(self, rule: str, node: ast.AST, message: str) -> Finding:
        reason = self.suppression_at(node)
        return Finding(
            rule=rule, path=self.relpath,
            line=getattr(node, "lineno", 1),
            col=getattr(node, "col_offset", 0),
            func=self.func_qualname(node), message=message,
            suppressed=reason is not None,
            suppress_reason=reason or "")

    # -- region discovery ---------------------------------------------------

    def _discover_regions(self) -> List[Region]:
        regions: Dict[int, Region] = {}

        def add(node, kind, static=frozenset(), reason="", jit_meta=None):
            if node is None or not isinstance(node, FuncLike):
                return
            cur = regions.get(id(node))
            if cur is None:
                regions[id(node)] = Region(
                    node=node, kind=kind, static_names=frozenset(static),
                    qualname=self.qualnames.get(id(node), "<?>"),
                    reason=reason, jit_meta=jit_meta)
            else:   # merge: a real jit boundary outranks a traced body
                cur.static_names = cur.static_names | frozenset(static)
                if kind == "jit" and cur.kind != "jit":
                    cur.kind, cur.reason, cur.jit_meta = kind, reason, jit_meta

        def resolve_callable(arg, at):
            """A function-valued argument: Lambda inline, or a Name
            resolved against local scopes. Returns (node, partial-bound
            static names)."""
            target, bound = unwrap_partial(arg)
            if isinstance(target, ast.Lambda):
                return target, bound
            if isinstance(target, ast.Name):
                return self.resolve_function(target.id, at), bound
            return None, ()

        for node, _qn in self.functions:
            for dec in node.decorator_list:
                meta = jit_decorator_info(dec)
                if meta is not None:
                    add(node, "jit", static_names_for(node, meta),
                        reason="jit decorator", jit_meta=meta)

        for call in ast.walk(self.tree):
            if not isinstance(call, ast.Call):
                continue
            d = dotted(call.func)
            if d in JIT_NAMES and call.args:
                fn, bound = resolve_callable(call.args[0], call)
                if fn is not None and isinstance(fn, FuncNode):
                    meta = _parse_jit_kwargs(call)
                    add(fn, "jit", static_names_for(fn, meta, extra=bound),
                        reason="jit call site", jit_meta=meta)
                elif isinstance(fn, ast.Lambda):
                    add(fn, "jit", frozenset(bound), reason="jit call site")
            elif d in PALLAS_NAMES and call.args:
                fn, bound = resolve_callable(call.args[0], call)
                add(fn, "traced", frozenset(bound), reason="pallas kernel")
            elif d in TRACER_WRAPPERS or is_lax_combinator(d):
                cands: List[ast.AST] = []
                for arg in call.args:
                    cands.extend(arg.elts if isinstance(
                        arg, (ast.List, ast.Tuple)) else [arg])
                for arg in cands:
                    fn, bound = resolve_callable(arg, call)
                    if fn is not None:
                        add(fn, "traced", frozenset(bound),
                            reason=d or "combinator body")

        # a root lexically nested inside another root is analyzed as part
        # of its ancestor's walk — keep only the outermost
        out = []
        for r in regions.values():
            anc = self.enclosing_function(r.node)
            nested = False
            while anc is not None:
                if id(anc) in regions:
                    nested = True
                    break
                anc = self.enclosing_function(anc)
            if not nested:
                out.append(r)
        out.sort(key=lambda r: r.node.lineno)
        return out


# ---------------------------------------------------------------------------
# Traced-value taint walk.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TaintEvent:
    node: ast.AST
    category: str       # cast | item | numpy | print | branch | host_fetch
    message: str


def _is_structural_test(node: ast.AST) -> bool:
    """Tests that are resolved at TRACE time even on traced operands:
    identity against None, constant-key pytree membership, isinstance /
    hasattr / len (static structure and shape), and boolean combinations
    thereof."""
    if isinstance(node, ast.Compare):
        if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
            return True
        if all(isinstance(op, (ast.In, ast.NotIn)) for op in node.ops) \
                and isinstance(node.left, ast.Constant):
            return True
        return False
    if isinstance(node, ast.Call):
        return dotted(node.func) in {"isinstance", "hasattr", "callable",
                                     "len", "getattr"}
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
        return _is_structural_test(node.operand)
    if isinstance(node, ast.BoolOp):
        return all(_is_structural_test(v) for v in node.values)
    return False


class _TaintWalk:
    """One region's traced-value walk (see module docstring)."""

    def __init__(self, module: ModuleInfo, region: Region):
        self.module = module
        self.region = region
        self.events: List[TaintEvent] = []
        self._callstack: List[int] = []
        self._memo: set = set()

    def run(self) -> List[TaintEvent]:
        node = self.region.node
        env = {}
        for p in all_param_names(node):
            env[p] = p not in self.region.static_names
        self._walk_func(node, env, depth=0)
        seen, out = set(), []
        for ev in self.events:
            key = (getattr(ev.node, "lineno", 0),
                   getattr(ev.node, "col_offset", 0), ev.category)
            if key not in seen:
                seen.add(key)
                out.append(ev)
        return out

    def _emit(self, node, category, message):
        self.events.append(TaintEvent(node, category, message))

    # -- function / statement walking ---------------------------------------

    def _walk_func(self, node, env, depth):
        if isinstance(node, ast.Lambda):
            self._expr(node.body, env, depth)
        else:
            self._block(node.body, env, depth)

    def _block(self, stmts, env, depth):
        for st in stmts:
            self._stmt(st, env, depth)

    def _bind(self, target, taint, env):
        if isinstance(target, ast.Name):
            env[target.id] = taint
        elif isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._bind(el, taint, env)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, taint, env)
        # attribute / subscript stores don't introduce local names

    def _stmt(self, st, env, depth):
        if isinstance(st, ast.Assign):
            t = self._expr(st.value, env, depth)
            for tgt in st.targets:
                self._bind(tgt, t, env)
        elif isinstance(st, ast.AnnAssign):
            if st.value is not None:
                self._bind(st.target, self._expr(st.value, env, depth), env)
        elif isinstance(st, ast.AugAssign):
            t = self._expr(st.value, env, depth)
            if isinstance(st.target, ast.Name):
                env[st.target.id] = env.get(st.target.id, False) or t
        elif isinstance(st, (ast.If, ast.While)):
            structural = _is_structural_test(st.test)
            t = self._expr(st.test, env, depth)
            if t and not structural:
                word = "if" if isinstance(st, ast.If) else "while"
                self._emit(st, "branch",
                           f"Python `{word}` on a traced value inside a jit "
                           f"region — forces a host sync per trace (use "
                           f"lax.cond/jnp.where or mark the operand static)")
            self._block(st.body, env, depth)
            self._block(st.orelse, env, depth)
        elif isinstance(st, ast.For):
            self._bind(st.target, self._expr(st.iter, env, depth), env)
            self._block(st.body, env, depth)
            self._block(st.orelse, env, depth)
        elif isinstance(st, FuncNode):
            # a def inside a jit region is itself traced when called —
            # walk it with every parameter tainted over the closure env
            env2 = dict(env)
            for p in all_param_names(st):
                env2[p] = True
            self._walk_func(st, env2, depth)
        elif isinstance(st, (ast.Return, ast.Expr)):
            if st.value is not None:
                self._expr(st.value, env, depth)
        elif isinstance(st, ast.With):
            for item in st.items:
                self._expr(item.context_expr, env, depth)
            self._block(st.body, env, depth)
        elif isinstance(st, ast.Try):
            self._block(st.body, env, depth)
            for h in st.handlers:
                self._block(h.body, env, depth)
            self._block(st.orelse, env, depth)
            self._block(st.finalbody, env, depth)
        else:
            for child in ast.iter_child_nodes(st):
                if isinstance(child, ast.expr):
                    self._expr(child, env, depth)
                elif isinstance(child, ast.stmt):
                    self._stmt(child, env, depth)

    # -- expression taint ---------------------------------------------------

    def _expr(self, node, env, depth) -> bool:
        if node is None:
            return False
        if isinstance(node, ast.Name):
            return env.get(node.id, False)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            base = self._expr(node.value, env, depth)
            return False if node.attr in STATIC_ATTRS else base
        if isinstance(node, ast.Subscript):
            tv = self._expr(node.value, env, depth)
            ts = self._expr(node.slice, env, depth)
            return tv or ts
        if isinstance(node, ast.Call):
            return self._call(node, env, depth)
        if isinstance(node, ast.Lambda):
            env2 = dict(env)
            for p in all_param_names(node):
                env2[p] = True
            self._expr(node.body, env2, depth)
            return False
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any([self._expr(e, env, depth) for e in node.elts])
        if isinstance(node, ast.Dict):
            parts = [self._expr(k, env, depth) for k in node.keys
                     if k is not None]
            parts += [self._expr(v, env, depth) for v in node.values]
            return any(parts)
        if isinstance(node, ast.IfExp):
            parts = [self._expr(node.test, env, depth),
                     self._expr(node.body, env, depth),
                     self._expr(node.orelse, env, depth)]
            return parts[1] or parts[2]
        # generic: BoolOp / BinOp / UnaryOp / Compare / comprehensions /
        # JoinedStr / Starred ... — any tainted child taints the result
        return any([self._expr(c, env, depth)
                    for c in ast.iter_child_nodes(node)
                    if isinstance(c, ast.expr)])

    def _call(self, node: ast.Call, env, depth) -> bool:
        d = dotted(node.func) or ""
        root = d.split(".")[0] if d else ""
        arg_taints = [self._expr(a, env, depth) for a in node.args]
        arg_taints += [self._expr(kw.value, env, depth)
                       for kw in node.keywords]
        any_tainted = any(arg_taints)
        recv_taint = False
        if isinstance(node.func, ast.Attribute):
            recv_taint = self._expr(node.func.value, env, depth)

        if isinstance(node.func, ast.Name) and node.func.id in HOST_CASTS \
                and any_tainted:
            self._emit(node, "cast",
                       f"`{node.func.id}()` on a traced value inside a jit "
                       f"region — a hidden device→host sync per call")
            return False
        if isinstance(node.func, ast.Attribute) and node.func.attr == "item" \
                and recv_taint:
            self._emit(node, "item",
                       "`.item()` on a traced value inside a jit region — "
                       "a hidden device→host sync per call")
            return False
        if d in DEVICE_GET_NAMES or (
                isinstance(node.func, ast.Attribute)
                and node.func.attr == "block_until_ready" and recv_taint):
            self._emit(node, "host_fetch",
                       f"`{d or 'block_until_ready'}` inside a jit region — "
                       f"device→host fetch in traced code")
            return False
        if root in NUMPY_ROOTS and any_tainted:
            self._emit(node, "numpy",
                       f"`{d}()` on a traced value inside a jit region — "
                       f"numpy concretizes the tracer (host sync per call); "
                       f"use the jnp equivalent")
            return False
        if isinstance(node.func, ast.Name) and node.func.id == "print":
            self._emit(node, "print",
                       "`print` inside a jit region — runs at trace time "
                       "only (or syncs under concretization); use "
                       "jax.debug.print")
            return False
        if root in TRACED_ROOTS:
            return True

        # one-module-deep local call propagation
        if isinstance(node.func, ast.Name) and depth < _TAINT_DEPTH:
            fn = self.module.resolve_function(node.func.id, node)
            if fn is not None and id(fn) not in self._callstack:
                env2 = {}
                pos = param_names(fn)
                has_star = any(isinstance(a, ast.Starred) for a in node.args)
                for p in all_param_names(fn):
                    env2[p] = has_star
                for i, a in enumerate(node.args):
                    if i < len(pos) and not has_star:
                        env2[pos[i]] = arg_taints[i]
                for kw, t in zip(node.keywords,
                                 arg_taints[len(node.args):]):
                    if kw.arg is not None:
                        env2[kw.arg] = t
                key = (id(fn), tuple(sorted(env2.items())))
                if key not in self._memo:
                    self._memo.add(key)
                    self._callstack.append(id(fn))
                    try:
                        self._walk_func(fn, env2, depth + 1)
                    finally:
                        self._callstack.pop()
                return any_tainted
        return any_tainted or recv_taint


def taint_events(module: ModuleInfo, region: Region) -> List[TaintEvent]:
    """The region's host-sync-relevant events (rule R1's input)."""
    return _TaintWalk(module, region).run()


def iter_calls(tree: ast.AST) -> Iterator[ast.Call]:
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            yield node
