"""repro.analysis — jit-hygiene static analyzer (DESIGN.md §15).

Stdlib-only AST pass enforcing the repo's tracing and host-sync
contracts at the source level: ``python -m repro.analysis.lint src
--baseline src/repro/analysis/baseline.json``.
"""
from repro.analysis.core import Finding, ModuleInfo, Region  # noqa: F401
