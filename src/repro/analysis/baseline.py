"""Baseline ratchet for the jit-hygiene analyzer (DESIGN.md §15).

A committed ``baseline.json`` is the multiset of finding fingerprints
that existed when the analyzer was adopted (or last deliberately
re-baselined). The lint passes when the fresh run produces no finding
OUTSIDE that multiset — grandfathered debt is allowed, new debt fails.
Fingerprints are line-number-free (rule|path|func|message), so the
baseline survives unrelated edits; fixing a grandfathered finding makes
its entry *stale*, which the self-check test (and ``--format json``
output) reports so the baseline only ever shrinks.
"""
from __future__ import annotations

import json
from collections import Counter
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

from repro.analysis.core import Finding

VERSION = 1


def load(path) -> dict:
    data = json.loads(Path(path).read_text())
    if data.get("version") != VERSION:
        raise ValueError(f"unsupported baseline version in {path}: "
                         f"{data.get('version')!r}")
    return data


def save(path, findings: Sequence[Finding], note: str = "") -> dict:
    """Write a baseline grandfathering the *active* (unsuppressed)
    findings in ``findings``."""
    entries = [
        {"fingerprint": f.fingerprint(), "rule": f.rule, "path": f.path,
         "func": f.func, "message": f.message}
        for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule))
        if not f.suppressed
    ]
    data = {"version": VERSION, "note": note, "findings": entries}
    Path(path).write_text(json.dumps(data, indent=2) + "\n")
    return data


def diff(active: Sequence[Finding],
         baseline: dict) -> Tuple[List[Finding], List[Finding], List[dict]]:
    """(new, grandfathered, stale-entries). Multiset semantics: two
    identical findings need two baseline entries — a *second* instance
    of a grandfathered mistake still counts as new."""
    remaining: Dict[str, int] = Counter(
        e["fingerprint"] for e in baseline.get("findings", []))
    new: List[Finding] = []
    old: List[Finding] = []
    for f in active:
        fp = f.fingerprint()
        if remaining.get(fp, 0) > 0:
            remaining[fp] -= 1
            old.append(f)
        else:
            new.append(f)
    stale_fps = {fp for fp, n in remaining.items() if n > 0}
    stale = [e for e in baseline.get("findings", [])
             if e["fingerprint"] in stale_fps]
    return new, old, stale
