"""R6 — telemetry piggyback contract (``repro.obs``, DESIGN.md §16).

The obs subsystem's whole premise is "zero extra syncs": the Recorder
only ever consumes host values that the hot paths' *already-budgeted*
fetches produced. Two ways code can break that premise, both visible
syntactically:

  * **an obs emission inside a jit region** — ``self.obs.record_*`` /
    ``rec.record_*`` in traced code runs at trace time only (silently
    recording nothing on later invocations) or, worse, concretizes a
    tracer into a host sync per call. Telemetry must be emitted from the
    host side of the boundary, fed by the region's fused outputs.
  * **a device value handed to an obs drain inside a declared sync
    contract** — ``obs.record_*(self.pools.counters, ...)`` inside an
    ``@sync_contract`` method makes the Recorder's ``np.asarray`` a
    second, hidden fetch site the R5 budget never sees. Drain arguments
    must be host names (bound from the contracted ``device_get`` /
    ``self._fetch``) or plain host expressions over them.

Together with R5 this registers the obs drains as the *only* sanctioned
host-side consumers of fetched telemetry payloads inside annotated
methods: the fetch site count stays at the declared budget (R5) and
everything the drains touch is provably post-fetch (R6).

Deliberately conservative: dict-style string subscripts
(``self.counters["steps"]``) are host bookkeeping, not device vectors —
device counter arrays are indexed by named integer constants (R3) — so
they never taint. A miss here is caught at runtime by
``verify_sync_counters`` with the Recorder attached (tests/test_obs.py).
"""
import ast
from typing import List, Optional

from repro.analysis import core
from repro.analysis.rules import r5_sync_contract as r5

RULE = "R6"
TITLE = "obs telemetry piggyback violation"

_ATTACH = {"attach_fabric", "attach_serve"}
_DEVICE_PRODUCER_ROOTS = {"jnp", "jax", "lax", "pl", "pltpu"}


def _obs_emission(call: ast.Call) -> Optional[str]:
    """``<recv>.record_*`` / ``<recv>.attach_*`` where the receiver chain
    names an ``obs`` component, or any ``record_*`` method call — the
    syntactic shape of a Recorder drain. Returns the method name."""
    if not isinstance(call.func, ast.Attribute):
        return None
    attr = call.func.attr
    d = core.dotted(call.func) or ""
    on_obs = "obs" in d.split(".")
    if attr.startswith("record_") or (on_obs and attr in _ATTACH):
        return attr
    return None


def _device_expr(node, device_names) -> Optional[str]:
    """Why ``node`` is (or contains) a device value, or None if it is
    host-safe. String-constant subscripts are dict access → host."""
    if isinstance(node, ast.Name):
        if node.id in device_names:
            return f"device value `{node.id}`"
        return None
    if isinstance(node, ast.Subscript):
        sl = node.slice
        if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
            return None
        return _device_expr(node.value, device_names)
    if isinstance(node, ast.Attribute):
        if node.attr in r5._DEVICE_ATTRS:
            return f"device-state chain `{core.dotted(node) or node.attr}`"
        return _device_expr(node.value, device_names)
    if isinstance(node, ast.Call):
        d = core.dotted(node.func) or ""
        root = d.split(".")[0]
        if d in core.DEVICE_GET_NAMES:
            return None     # an explicit fetch argument is R5's finding
        if root in _DEVICE_PRODUCER_ROOTS:
            return f"`{d}(...)` result"
        for a in node.args:
            why = _device_expr(a, device_names)
            if why:
                return why
        return None
    if isinstance(node, (ast.BinOp, ast.UnaryOp, ast.Tuple, ast.List,
                         ast.IfExp)):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, ast.expr):
                why = _device_expr(child, device_names)
                if why:
                    return why
    return None


def check(module: core.ModuleInfo) -> List[core.Finding]:
    out: List[core.Finding] = []

    # 1. no obs emission from inside a jit/traced region
    for region in module.regions:
        for call in core.iter_calls(region.node):
            attr = _obs_emission(call)
            if attr is None:
                continue
            out.append(module.finding(
                RULE, call,
                f"obs emission `{attr}` inside a jit region "
                f"({region.reason}) — telemetry must ride the piggyback "
                f"payload out of the region and drain host-side, never "
                f"emit from traced code"))

    # 2. drains inside declared sync contracts consume host values only
    for node, qn in module.functions:
        if r5.contract_of(node) is None:
            continue
        _host, device = r5._name_flow(node)
        for call in core.iter_calls(node):
            attr = _obs_emission(call)
            if attr is None:
                continue
            args = list(call.args) + [kw.value for kw in call.keywords]
            for a in args:
                why = _device_expr(a, device)
                if why:
                    out.append(module.finding(
                        RULE, a,
                        f"obs drain `{attr}` in `{qn}` is handed {why} — "
                        f"inside a @sync_contract the drain may only "
                        f"consume host values from the contracted fetch "
                        f"(a device argument is a hidden second sync "
                        f"site)"))
    return out
