"""R5 — declared host-sync contracts (``@sync_contract``).

The static half of ``repro.common.contracts`` (the runtime half is
``verify_sync_counters`` in the benches). For every function annotated
``@sync_contract(syncs_per=..., fetches=N)``:

  * count the lexical device→host *fetch sites* in the body —
    ``jax.device_get``, ``.item()``, ``.block_until_ready()``,
    ``self._fetch(...)``, and ``np.asarray``/``np.array`` whose argument
    is device-sourced (host-side numpy on an already-fetched value is
    free and exempt);
  * a fetch site inside a host ``for``/``while`` loop is a finding
    regardless of count — one sync per *iteration* is how "one sync per
    step" quietly becomes O(n);
  * more than ``N`` loop-free sites is a finding per excess site.

Suppressed sites (``# lint: host-ok(reason)``) do not count against the
budget — that is the designed escape hatch for intentional host work.

Additionally, REQUIRED_CONTRACTS pins the repo's three load-bearing
contracts to their functions: deleting the ``@sync_contract`` annotation
from any of them is itself a finding, so the contract cannot be
silently removed to appease the fetch count.
"""
import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis import core

RULE = "R5"
TITLE = "host-sync contract (@sync_contract) violation"

# path suffix -> {function qualname: required syncs_per}
REQUIRED_CONTRACTS: Dict[str, Dict[str, str]] = {
    "serve/engine.py": {"Engine.step": "step"},
    "fabric/replay.py": {"Fabric._fetch_view": "segment",
                         "Fabric._commit_epoch": "epoch",
                         "Fabric._commit_boundary": "boundary",
                         "Fabric._drain_deferred": "drain",
                         "Fabric.delivered_time": "call"},
}

_DEVICE_GET = {"jax.device_get", "device_get"}
_NP_FETCH = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
# attribute names that denote device-resident state in this repo
_DEVICE_ATTRS = {"pools", "counters", "state", "cache", "cfree", "gfree",
                 "pfree", "meta", "activity", "hand", "times", "stats"}


def contract_of(node) -> Optional[Tuple[str, int, ast.AST]]:
    """(syncs_per, fetches, decorator node) parsed from the source
    decorator, or None."""
    for dec in node.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        d = core.dotted(dec.func) or ""
        if d.split(".")[-1] != "sync_contract":
            continue
        per, fetches = None, 1
        if dec.args:
            v = core._literal(dec.args[0])
            per = v if isinstance(v, str) else None
        if len(dec.args) > 1:
            v = core._literal(dec.args[1])
            fetches = v if isinstance(v, int) else 1
        for kw in dec.keywords:
            v = core._literal(kw.value)
            if kw.arg == "syncs_per" and isinstance(v, str):
                per = v
            elif kw.arg == "fetches" and isinstance(v, int):
                fetches = v
        return per or "?", fetches, dec
    return None


def _name_flow(fn) -> Tuple[Set[str], Set[str]]:
    """(host_names, device_names): a bounded fixpoint over the simple
    assignments in ``fn``. Names bound from a fetch call (device_get /
    self._fetch) or from another host name are HOST; names bound from
    jnp/jax producers or device-attr chains are DEVICE. Host wins ties
    (the ``x = jax.device_get(x)`` rebinding pattern)."""
    host: Set[str] = set()
    device: Set[str] = set()
    assigns: List[Tuple[List[str], ast.AST]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign):
            names: List[str] = []
            for tgt in node.targets:
                names.extend(_flat_names(tgt))
            if names:
                assigns.append((names, node.value))
    for _ in range(5):
        changed = False
        for names, value in assigns:
            kind = _value_kind(value, host)
            pool = host if kind == "host" else (
                device if kind == "device" else None)
            if pool is not None and not set(names) <= pool:
                pool.update(names)
                changed = True
        if not changed:
            break
    return host, device - host


def _flat_names(tgt) -> List[str]:
    if isinstance(tgt, ast.Name):
        return [tgt.id]
    if isinstance(tgt, (ast.Tuple, ast.List)):
        out: List[str] = []
        for el in tgt.elts:
            out.extend(_flat_names(el))
        return out
    if isinstance(tgt, ast.Starred):
        return _flat_names(tgt.value)
    return []


def _value_kind(value, host: Set[str]) -> Optional[str]:
    if isinstance(value, ast.Call):
        d = core.dotted(value.func) or ""
        if d in _DEVICE_GET or _is_self_fetch(value):
            return "host"
        root = d.split(".")[0]
        if root in {"jnp", "jax", "lax"}:
            return "device"
        if root in core.NUMPY_ROOTS:
            return "host"
    if isinstance(value, ast.Name) and value.id in host:
        return "host"
    if _device_chain(value):
        return "device"
    if isinstance(value, (ast.Tuple, ast.List)) and value.elts and \
            all(isinstance(e, (ast.Attribute, ast.Name)) for e in value.elts):
        if any(_device_chain(e) for e in value.elts):
            return "device"
    return None


def _device_chain(node) -> bool:
    """Attribute/subscript chain touching a device-state attribute, e.g.
    ``self.pools.cfree.top``."""
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        if isinstance(node, ast.Attribute) and node.attr in _DEVICE_ATTRS:
            return True
        node = node.value
    return False


def _is_self_fetch(call: ast.Call) -> bool:
    return isinstance(call.func, ast.Attribute) and \
        call.func.attr in {"_fetch", "fetch"}


class _Site:
    def __init__(self, node: ast.AST, in_loop: bool, desc: str):
        self.node, self.in_loop, self.desc = node, in_loop, desc


def _fetch_sites(fn, host: Set[str], device: Set[str]) -> List[_Site]:
    sites: List[_Site] = []

    def walk(node, loop_depth):
        for child in ast.iter_child_nodes(node):
            depth = loop_depth + (1 if isinstance(
                child, (ast.For, ast.While)) else 0)
            if isinstance(child, ast.Call):
                desc = _fetch_desc(child, host, device)
                if desc:
                    sites.append(_Site(child, depth > 0, desc))
            walk(child, depth)

    walk(fn, 0)
    return sites


def _fetch_desc(call: ast.Call, host: Set[str],
                device: Set[str]) -> Optional[str]:
    d = core.dotted(call.func) or ""
    if d in _DEVICE_GET:
        return "jax.device_get"
    if _is_self_fetch(call):
        return f"self.{call.func.attr}"
    if isinstance(call.func, ast.Attribute) and \
            call.func.attr in {"item", "block_until_ready"}:
        v = call.func.value
        if _device_chain(v) or (isinstance(v, ast.Name)
                                and v.id in device) or \
                not (isinstance(v, ast.Name) and v.id in host):
            return f".{call.func.attr}()"
        return None
    if d in _NP_FETCH and call.args:
        a = call.args[0]
        if _device_chain(a):
            return f"{d} on device state"
        root = a
        while isinstance(root, (ast.Attribute, ast.Subscript)):
            root = root.value
        if isinstance(root, ast.Name) and root.id in device:
            return f"{d} on device value `{root.id}`"
    return None


def check(module: core.ModuleInfo) -> List[core.Finding]:
    out: List[core.Finding] = []

    for suffix, reqs in REQUIRED_CONTRACTS.items():
        if not module.relpath.endswith(suffix):
            continue
        for qn, per in reqs.items():
            node = module.get_function(qn)
            missing = node is None or contract_of(node) is None
            if missing:
                out.append(module.finding(
                    RULE, node if node is not None else module.tree,
                    f"required @sync_contract(syncs_per=\"{per}\") is "
                    f"missing on `{qn}` — the {per}-sync contract must stay "
                    f"machine-readable (see common/contracts.py)"))

    for node, qn in module.functions:
        parsed = contract_of(node)
        if parsed is None:
            continue
        per, fetches, _dec = parsed
        host, device = _name_flow(node)
        sites = _fetch_sites(node, host, device)
        budget_sites = []
        for s in sites:
            if module.suppression_at(s.node) is not None:
                # still reported (as suppressed) so the count is visible
                out.append(module.finding(
                    RULE, s.node,
                    f"{s.desc} in `{qn}` excluded from the "
                    f"{fetches}/{per} budget"))
                continue
            if s.in_loop:
                out.append(module.finding(
                    RULE, s.node,
                    f"{s.desc} inside a host loop in `{qn}` — syncs once "
                    f"per iteration, violating the declared one-fetch-per-"
                    f"{per} contract"))
            else:
                budget_sites.append(s)
        for s in budget_sites[fetches:]:
            out.append(module.finding(
                RULE, s.node,
                f"{s.desc} exceeds the declared budget of {fetches} "
                f"fetch site(s) per {per} in `{qn}` "
                f"({len(budget_sites)} found) — fuse fetches into one "
                f"device_get or raise the contract deliberately"))
    return out
