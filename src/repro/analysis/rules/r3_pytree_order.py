"""R3 — pytree/counter order drift.

The counter block (``core/engine/state.py``) is a single device vector
whose layout is defined ONCE by ``C_* = range(NUM_COUNTERS)`` and
mirrored in ``COUNTER_NAMES`` / ``TRAFFIC_IDX``. Indexing that vector
with a bare integer literal re-encodes the layout at the use site: the
next counter insertion silently shifts every magic number. The drift
guard tests catch it at runtime for the paths they cover; this rule
catches it at the source for every path.

Flags ``X[<int literal>]`` where X is a name or attribute chain that
denotes a counter/traffic vector (``counters``, ``ctrs``, ``traffic``,
``tvec``, ``COUNTER_NAMES``, ``TRAFFIC_NAMES``...). Variable indices,
named-constant indices (``ctrs[S.C_DATA_RD]``) and slices are fine.
"""
import ast
from typing import List

from repro.analysis import core

RULE = "R3"
TITLE = "integer-literal index into a counter/traffic vector"

# terminal names that denote the layout-sensitive vectors
_VECTOR_NAMES = {"counters", "ctrs", "traffic", "tvec", "traffic_vec",
                 "counter_vec", "COUNTER_NAMES", "TRAFFIC_NAMES",
                 "TRAFFIC_IDX"}


def _terminal_name(node: ast.AST) -> str:
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return ""


def check(module: core.ModuleInfo) -> List[core.Finding]:
    out: List[core.Finding] = []
    for node in ast.walk(module.tree):
        if not isinstance(node, ast.Subscript):
            continue
        if _terminal_name(node.value) not in _VECTOR_NAMES:
            continue
        idx = node.slice
        if isinstance(idx, ast.Constant) and isinstance(idx.value, int) \
                and not isinstance(idx.value, bool):
            out.append(module.finding(
                RULE, node,
                f"`{_terminal_name(node.value)}[{idx.value}]` hard-codes the "
                f"counter layout — use the named `state.C_*` / "
                f"`state.TRAFFIC_IDX` constants so layout changes can't "
                f"silently shift the meaning"))
    return out
