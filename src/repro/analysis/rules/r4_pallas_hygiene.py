"""R4 — Pallas launch hygiene.

Two classes of launch-site mistakes detectable from literals:

  * ``interpret=True`` written literally at a ``pl.pallas_call`` site.
    Interpret mode is a *platform* decision (off-TPU fallback), not a
    call-site decision — it must route through
    ``kernels.qpack.resolve_interpret`` so TPU runs never silently
    execute the python interpreter path (``interpret=False`` literal is
    equally wrong: it breaks every non-TPU environment).
  * grid/BlockSpec arity mismatches visible from tuple displays: a
    BlockSpec ``index_map`` lambda must take one argument per grid axis
    and return one index per block-shape axis. Wrong arity raises only
    at trace time on the launching platform; the lint catches it on any
    machine.
"""
import ast
from typing import List, Optional

from repro.analysis import core

RULE = "R4"
TITLE = "pallas launch hygiene (interpret literal / BlockSpec arity)"


def _tuple_len(node: Optional[ast.AST]) -> Optional[int]:
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return 1
    return None


def check(module: core.ModuleInfo) -> List[core.Finding]:
    out: List[core.Finding] = []
    for call in core.iter_calls(module.tree):
        if core.dotted(call.func) not in core.PALLAS_NAMES:
            continue
        kwargs = {kw.arg: kw.value for kw in call.keywords
                  if kw.arg is not None}

        interp = kwargs.get("interpret")
        if isinstance(interp, ast.Constant) and isinstance(interp.value, bool):
            out.append(module.finding(
                RULE, interp,
                f"literal `interpret={interp.value}` at a pallas_call site — "
                f"route through kernels.qpack.resolve_interpret so the "
                f"interpreter fallback is a platform decision, not a "
                f"call-site constant"))

        grid_ndim = _tuple_len(kwargs.get("grid"))
        for spec in ast.walk(call):
            if not (isinstance(spec, ast.Call)
                    and (core.dotted(spec.func) or "").endswith("BlockSpec")):
                continue
            spec_args = list(spec.args)
            spec_kw = {kw.arg: kw.value for kw in spec.keywords
                       if kw.arg is not None}
            block_shape = spec_kw.get(
                "block_shape", spec_args[0] if spec_args else None)
            index_map = spec_kw.get(
                "index_map", spec_args[1] if len(spec_args) > 1 else None)
            if not isinstance(index_map, ast.Lambda):
                continue
            n_params = len(core.all_param_names(index_map))
            if grid_ndim is not None and n_params != grid_ndim:
                out.append(module.finding(
                    RULE, index_map,
                    f"BlockSpec index_map takes {n_params} arg(s) but the "
                    f"grid has {grid_ndim} axis(es)"))
            n_block = _tuple_len(block_shape)
            n_ret = _tuple_len(index_map.body)
            if n_block is not None and n_ret is not None \
                    and n_block != n_ret:
                out.append(module.finding(
                    RULE, index_map,
                    f"BlockSpec index_map returns {n_ret} index(es) for a "
                    f"{n_block}-axis block_shape"))
    return out
