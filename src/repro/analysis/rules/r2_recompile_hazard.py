"""R2 — recompile hazards on jitted callables.

Jit caches key on static argument *values* and on argument hashability.
Three syntactically detectable ways to defeat the cache:

  * mutable default arguments (``def f(x, cfg={})``) on a jitted
    callable — unhashable when they land in a static slot, and a shared
    mutable cell either way;
  * ``static_argnames`` naming a parameter that does not exist (jax
    raises only when the name is *passed*, so a typo can sit dormant
    until a call site changes);
  * ``static_argnums`` out of range for the signature;
  * per-call-varying literals (f-strings, dict/list/set displays) passed
    as a *static* keyword at a call through a jit-wrapped name — every
    distinct value is a fresh compile.
"""
import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis import core

RULE = "R2"
TITLE = "recompile hazard on a jitted callable"

_MUTABLE_CALLS = {"dict", "list", "set", "bytearray"}
_VARYING = (ast.JoinedStr, ast.Dict, ast.List, ast.Set, ast.DictComp,
            ast.ListComp, ast.SetComp)


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in _MUTABLE_CALLS
    return False


def _jit_assignments(module: core.ModuleInfo) -> Dict[str, dict]:
    """``name = jax.jit(...)`` bindings (module- or function-local) with
    any literal static metadata from the jit call."""
    out: Dict[str, dict] = {}
    for node in ast.walk(module.tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and core.dotted(node.value.func) in core.JIT_NAMES:
            meta = core._parse_jit_kwargs(node.value)
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    out[tgt.id] = meta
    return out


def check(module: core.ModuleInfo) -> List[core.Finding]:
    out: List[core.Finding] = []

    for region in module.regions:
        if region.kind != "jit" or not isinstance(region.node, core.FuncNode):
            continue
        node, meta = region.node, region.jit_meta or {}
        args = node.args
        # mutable defaults
        for param, default in _iter_defaults(args):
            if default is not None and _is_mutable_literal(default):
                out.append(module.finding(
                    RULE, default,
                    f"mutable default `{param}=...` on jitted "
                    f"`{region.qualname}` — unhashable as a static arg and "
                    f"a shared cell across traces; default to None"))
        # static metadata vs signature
        pos = core.param_names(node)
        known = set(core.all_param_names(node))
        for name in meta.get("static_argnames") or ():
            if name not in known:
                out.append(module.finding(
                    RULE, meta.get("node", node),
                    f"static_argnames references `{name}` which is not a "
                    f"parameter of `{region.qualname}` — dormant typo, "
                    f"recompiles (or raises) when a call site passes it"))
        for num in meta.get("static_argnums") or ():
            if not (0 <= num < len(pos)):
                out.append(module.finding(
                    RULE, meta.get("node", node),
                    f"static_argnums index {num} is out of range for "
                    f"`{region.qualname}` ({len(pos)} positional params)"))

    # per-call-varying static kwargs at calls through jit-wrapped names
    jit_names = _jit_assignments(module)
    for call in core.iter_calls(module.tree):
        if not isinstance(call.func, ast.Name):
            continue
        meta = jit_names.get(call.func.id)
        if meta is None:
            continue
        static = set(meta.get("static_argnames") or ())
        for kw in call.keywords:
            if kw.arg in static and isinstance(kw.value, _VARYING):
                out.append(module.finding(
                    RULE, kw.value,
                    f"per-call-varying literal passed as static arg "
                    f"`{kw.arg}` to jitted `{call.func.id}` — every distinct "
                    f"value compiles a fresh executable"))
    return out


def _iter_defaults(args: ast.arguments) -> List[Tuple[str, Optional[ast.AST]]]:
    pos = args.posonlyargs + args.args
    pairs: List[Tuple[str, Optional[ast.AST]]] = []
    for param, default in zip(pos[len(pos) - len(args.defaults):],
                              args.defaults):
        pairs.append((param.arg, default))
    for param, default in zip(args.kwonlyargs, args.kw_defaults):
        pairs.append((param.arg, default))
    return pairs
