"""Rule registry for the jit-hygiene analyzer (DESIGN.md §15).

Each rule is a module exposing ``RULE`` (its id), ``TITLE`` (one-line
summary used in reports) and ``check(module: ModuleInfo) -> List[Finding]``.
Adding a rule = adding a module here and appending it to ``ALL_RULES``.
"""
from repro.analysis.rules import (
    r1_hidden_host_sync,
    r2_recompile_hazard,
    r3_pytree_order,
    r4_pallas_hygiene,
    r5_sync_contract,
    r6_obs_piggyback,
)

ALL_RULES = [
    r1_hidden_host_sync,
    r2_recompile_hazard,
    r3_pytree_order,
    r4_pallas_hygiene,
    r5_sync_contract,
    r6_obs_piggyback,
]

RULE_TITLES = {m.RULE: m.TITLE for m in ALL_RULES}
