"""R1 — hidden host syncs inside jit regions.

The taint walk in ``core`` does the heavy lifting; this rule converts
its events into findings. Every event category is a construct that, in
traced code, either concretizes a tracer (forcing a device→host round
trip per *call*, the per-access cost the host-sync contracts exist to
amortize) or silently runs at trace time only:

  * ``int()/float()/bool()/complex()`` on a traced value
  * ``.item()`` on a traced value
  * ``np.*`` calls with traced arguments (numpy concretizes)
  * ``jax.device_get`` / ``.block_until_ready()`` in traced code
  * ``print`` (trace-time only; use ``jax.debug.print``)
  * Python ``if``/``while`` branching on a traced value (structural
    tests — ``x is None``, ``"key" in pytree``, ``isinstance``/``len`` —
    are exempt: they resolve at trace time)
"""
from typing import List

from repro.analysis import core

RULE = "R1"
TITLE = "hidden host sync inside a jit region"


def check(module: core.ModuleInfo) -> List[core.Finding]:
    out: List[core.Finding] = []
    seen = set()
    for region in module.regions:
        for ev in core.taint_events(module, region):
            key = (getattr(ev.node, "lineno", 0),
                   getattr(ev.node, "col_offset", 0), ev.category)
            if key in seen:     # overlapping regions report each site once
                continue
            seen.add(key)
            out.append(module.finding(RULE, ev.node, ev.message))
    return out
