"""CLI driver for the jit-hygiene analyzer (DESIGN.md §15).

    python -m repro.analysis.lint [paths...] [--baseline FILE]
                                  [--format text|json]
                                  [--write-baseline FILE]

Exit status 0 when every active finding is grandfathered by the
baseline (or no baseline is given and there are no findings); 1 when
new findings exist; 2 on a parse error in a scanned file. Pure stdlib —
runs in CI before any accelerator stack is installed.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis import baseline as baseline_mod
from repro.analysis.core import Finding, ModuleInfo
from repro.analysis.rules import ALL_RULES, RULE_TITLES


def iter_py_files(paths: Sequence) -> List[Tuple[Path, str]]:
    """(abspath, relpath) for every .py under ``paths``. Relpaths are
    anchored at each scan root's parent (``lint src`` → ``src/repro/...``)
    so fingerprints are stable regardless of the invocation directory."""
    out: List[Tuple[Path, str]] = []
    seen = set()
    for p in paths:
        root = Path(p)
        files = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        anchor = root if root.is_dir() else root.parent
        for f in files:
            f = f.resolve()
            if f in seen:
                continue
            seen.add(f)
            try:
                rel = f.relative_to(anchor.resolve().parent)
            except ValueError:
                rel = Path(f.name)
            out.append((f, rel.as_posix()))
    return out


def lint_file(path, relpath: Optional[str] = None,
              src: Optional[str] = None) -> List[Finding]:
    try:
        module = ModuleInfo(path, src=src, relpath=relpath)
    except SyntaxError as e:
        return [Finding(rule="parse-error", path=relpath or str(path),
                        line=e.lineno or 1, col=e.offset or 0,
                        func="<module>", message=f"syntax error: {e.msg}")]
    findings: List[Finding] = []
    for rule in ALL_RULES:
        findings.extend(rule.check(module))
    findings.sort(key=lambda f: (f.line, f.col, f.rule))
    return findings


def run_lint(paths: Sequence, baseline_path=None) -> dict:
    """Lint ``paths`` and diff against the baseline. Returns a report
    dict (JSON-ready); ``report["ok"]`` is the pass/fail verdict."""
    all_findings: List[Finding] = []
    files = iter_py_files(paths)
    for abspath, rel in files:
        all_findings.extend(lint_file(abspath, relpath=rel))
    active = [f for f in all_findings if not f.suppressed
              and f.rule != "parse-error"]
    suppressed = [f for f in all_findings if f.suppressed]
    parse_errors = [f for f in all_findings if f.rule == "parse-error"]

    base = baseline_mod.load(baseline_path) if baseline_path else \
        {"version": baseline_mod.VERSION, "findings": []}
    new, grandfathered, stale = baseline_mod.diff(active, base)

    by_rule: Dict[str, int] = {}
    for f in active:
        by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "files": len(files),
        "counts": {
            "active": len(active), "suppressed": len(suppressed),
            "new": len(new), "grandfathered": len(grandfathered),
            "stale_baseline": len(stale), "parse_errors": len(parse_errors),
        },
        "by_rule": by_rule,
        "new": [f.to_dict() for f in new],
        "grandfathered": [f.to_dict() for f in grandfathered],
        "suppressed": [f.to_dict() for f in suppressed],
        "stale_baseline": stale,
        "parse_errors": [f.to_dict() for f in parse_errors],
        "rule_titles": RULE_TITLES,
        "ok": not new and not parse_errors,
        "_findings": all_findings,      # stripped before JSON output
    }


def _render_text(report: dict, out) -> None:
    c = report["counts"]
    for f in report["parse_errors"]:
        print(f"{f['path']}:{f['line']}: {f['message']}", file=out)
    for f in report["new"]:
        print(f"{f['path']}:{f['line']}:{f['col']}: {f['rule']} "
              f"[{f['func']}] {f['message']}", file=out)
    for e in report["stale_baseline"]:
        print(f"stale baseline entry (fixed? re-baseline to shrink): "
              f"{e['rule']} {e['path']} [{e['func']}]", file=out)
    print(f"lint: {report['files']} files, {c['active']} active "
          f"({c['grandfathered']} grandfathered, {c['new']} new), "
          f"{c['suppressed']} suppressed host-ok, "
          f"{c['stale_baseline']} stale baseline entries", file=out)
    print("OK" if report["ok"] else "FAIL: new findings", file=out)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="jit-hygiene static analyzer (DESIGN.md §15)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to scan (default: src)")
    ap.add_argument("--baseline", default=None,
                    help="baseline.json to grandfather findings against")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="write a fresh baseline grandfathering the "
                         "current active findings, then exit 0")
    ns = ap.parse_args(argv)

    report = run_lint(ns.paths or ["src"], baseline_path=ns.baseline)
    findings = report.pop("_findings")

    if ns.write_baseline:
        active = [f for f in findings
                  if not f.suppressed and f.rule != "parse-error"]
        baseline_mod.save(ns.write_baseline, active,
                          note="grandfathered findings; shrink, don't grow")
        print(f"wrote {len(active)} entries to {ns.write_baseline}")
        return 0

    if ns.format == "json":
        json.dump(report, sys.stdout, indent=2)
        print()
    else:
        _render_text(report, sys.stdout)
    if report["parse_errors"]:
        return 2
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
