"""Pallas TPU kernels for IBEX hot spots (validated vs ref.py in interpret
mode): qpack compression engine, fused dequant decode-attention, flash
attention prefill."""
from repro.kernels import ops, ref  # noqa: F401
