"""Fused dequantize-attention over compressed KV pages (beyond-paper opt #1).

The paper must *promote* (migrate+decompress) a compressed page before serving
reads from it — two round trips over the scarce internal bandwidth. On TPU the
consumer of a KV page is the attention kernel itself, so we fuse: the kernel
streams *compressed* KV (int4/int8 codes + per-(token,head) scales) from HBM
into VMEM, dequantizes in registers, and runs flash-style online-softmax
attention. HBM bytes moved = compressed bytes — strictly fewer than even an
uncompressed read, eliminating promotion traffic entirely for reads.

Layout: one quantization block per (token, kv-head) spanning the head dim D
(D = 64..256, a multiple of the 128-lane VPU for D>=128).

Grid: (batch, kv_head, S/T). Sequential minor axis accumulates in VMEM scratch
(m, l, acc) — the standard TPU flash decode schedule.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _dequant(c, scale, bits):
    if bits == 4:
        lo = (c & jnp.uint8(0xF)).astype(jnp.int32)
        hi = (c >> jnp.uint8(4)).astype(jnp.int32)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0], c.shape[1] * 2)
    else:
        q = c.astype(jnp.int8).astype(jnp.int32)
    return q.astype(jnp.float32) * scale


def _kvc_kernel(len_ref, q_ref, kc_ref, ks_ref, vc_ref, vs_ref, o_ref,
                m_scr, l_scr, acc_scr, *, bits: int, sm_scale: float,
                t_blk: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)                     # [G, D]
    k = _dequant(kc_ref[0, :, 0, :], ks_ref[0, :, 0, :], bits)   # [T, D]
    v = _dequant(vc_ref[0, :, 0, :], vs_ref[0, :, 0, :], bits)   # [T, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    # context-length mask
    length = len_ref[0]
    col = j * t_blk + jax.lax.broadcasted_iota(jnp.int32, (1, t_blk), 1)
    s = jnp.where(col < length, s, NEG_INF)                 # [G, T]

    m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)                                  # [G, T]
    l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_new = acc_prev * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "sm_scale", "t_blk",
                                             "interpret"))
def kvc_decode_attention(q: jnp.ndarray, k_codes: jnp.ndarray,
                         k_scales: jnp.ndarray, v_codes: jnp.ndarray,
                         v_scales: jnp.ndarray, lengths: jnp.ndarray, *,
                         bits: int = 4, sm_scale: float | None = None,
                         t_blk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q [B,Hq,D]; codes uint8 [B,S,Hkv,D*bits/8]; scales f32 [B,S,Hkv];
    lengths int32[B]. Returns [B,Hq,D] (q.dtype)."""
    B, Hq, D = q.shape
    _, S, Hkv, Dp = k_codes.shape
    G = Hq // Hkv
    assert S % t_blk == 0, (S, t_blk)
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    qg = q.reshape(B, Hkv, G, D)
    ks = k_scales[..., None]
    vs = v_scales[..., None]
    grid = (B, Hkv, S // t_blk)
    out = pl.pallas_call(
        functools.partial(_kvc_kernel, bits=bits, sm_scale=float(sm_scale),
                          t_blk=t_blk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, j: (b,)),                  # lengths
            pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),  # q
            pl.BlockSpec((1, t_blk, 1, Dp), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, t_blk, 1, 1), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, t_blk, 1, Dp), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, t_blk, 1, 1), lambda b, h, j: (b, j, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
        interpret=interpret,
    )(lengths, qg, k_codes, ks, v_codes, vs)
    return out.reshape(B, Hq, D)
