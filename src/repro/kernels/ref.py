"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each kernel in this package must match its oracle to float tolerance across
the shape/dtype sweeps in tests/test_kernels.py (interpret=True on CPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.compressor import dequantize_blocks, quantize_blocks


def qpack_encode_ref(x: jnp.ndarray, bits: int, block: int):
    """x[..., N] -> (codes uint8, scales f32[..., N/block])."""
    return quantize_blocks(x, bits, block)


def qpack_decode_ref(codes, scales, bits: int, block: int, dtype=jnp.bfloat16):
    return dequantize_blocks(codes, scales, bits, block, dtype)


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, sm_scale: float | None = None,
            lengths: jnp.ndarray | None = None) -> jnp.ndarray:
    """Reference attention. q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D] (GQA broadcast).

    Returns [B,Sq,Hq,D] in q.dtype; accumulation in f32."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    kf = jnp.repeat(k.astype(jnp.float32), g, axis=2)
    vf = jnp.repeat(v.astype(jnp.float32), g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), kf) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, -1e30)
    if lengths is not None:
        col = jnp.arange(Sk)[None, None, None, :]
        s = jnp.where(col < lengths[:, None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return o.astype(q.dtype)


def kvc_attn_ref(q: jnp.ndarray, k_codes, k_scales, v_codes, v_scales, *,
                 bits: int, lengths: jnp.ndarray,
                 sm_scale: float | None = None) -> jnp.ndarray:
    """Decode attention over block-quantized KV (oracle = dequantize + mha).

    q [B,Hq,D]; {k,v}_codes uint8 [B,S,Hkv,D*bits/8]; {k,v}_scales f32
    [B,S,Hkv] (one block per (token, head): block == D)."""
    B, S, Hkv, _ = k_codes.shape
    D = q.shape[-1]
    k = dequantize_blocks(k_codes, k_scales[..., None], bits, D)
    v = dequantize_blocks(v_codes, v_scales[..., None], bits, D)
    out = mha_ref(q[:, None], k, v, causal=False, sm_scale=sm_scale,
                  lengths=lengths)
    return out[:, 0]
