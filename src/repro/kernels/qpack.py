"""Pallas TPU kernels for the qpack block compression engine.

The compression engine is the paper's per-device hot path (Fig. 3 steps 2-3).
On TPU we replace the LZ77 sequential matcher with rate-adaptive quantization
(DESIGN.md §3): a VPU-friendly reduction (block amax) + elementwise quantize +
nibble pack. Tiling: ``TILE`` blocks per grid step; each block of ``B`` values
is one VMEM row, hardware-aligned when B is a multiple of 128 (lane width).

Two kernel families:

  * ``qpack_encode_2d``/``qpack_decode_2d`` — fixed-rate quantize+pack (the
    KV-cache / optimizer-state fast path). ``block`` may subdivide a row
    (e.g. rows of 256 values holding four 64-value head-dim blocks) so small
    blocks still fill the 128-lane VPU.
  * ``qpack_fused_encode_2d``/``qpack_fused_decode_2d`` — the demotion /
    promotion engine: per-block rate selection (zero-detect + amax ->
    {zero, 4-bit, 8-bit, raw}, CRAM/BDI-style) + quantize + nibble-pack +
    quanta-size emit in ONE grid pass, producing the dense per-block byte
    layout of ``core.compressor._encode_block_dense`` bit-for-bit. The jnp
    compressor remains the bit-identity oracle (tests/test_qpack_fused.py).

``interpret=None`` auto-detects the backend: compiled kernels on TPU,
the Pallas interpreter elsewhere (satellite fix — the old default forced
interpret mode even on TPU). Pass an explicit bool to override.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.bitpack import RATE_4BIT, RATE_8BIT, RATE_RAW, RATE_ZERO

TILE = 8  # blocks per grid step


def _qmax(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def resolve_interpret(interpret) -> bool:
    """None -> interpret only off-TPU (compiled kernels on real hardware)."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _f32_rowbytes(s: jnp.ndarray) -> jnp.ndarray:
    """f32 [T, 1] -> uint8 [T, 4] little-endian (common.utils.f32_to_bytes)."""
    u = jax.lax.bitcast_convert_type(s, jnp.uint32)
    parts = [((u >> jnp.uint32(k)) & jnp.uint32(0xFF)).astype(jnp.uint8)
             for k in (0, 8, 16, 24)]
    return jnp.concatenate(parts, axis=-1)


def _quantize_rows(xf: jnp.ndarray, bits: int):
    """The oracle's reciprocal-multiply quantization (core.bitpack
    .quantize_block) on [T, B] rows: (codes int32, scale f32[T, 1])."""
    qmax = _qmax(bits)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax * jnp.float32(1.0 / qmax), 1.0)
    recip = jnp.float32(1.0) / scale
    q = jnp.clip(jnp.round(xf * recip), -qmax - 1, qmax).astype(jnp.int32)
    return q, scale


def _encode_kernel(x_ref, codes_ref, scales_ref, *, bits: int, block: int):
    x = x_ref[...].astype(jnp.float32)                  # [TILE, B]
    t, b = x.shape
    g = b // block
    xg = x.reshape(t, g, block)
    amax = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)  # [TILE, g, 1]
    # reciprocal multiplies keep this bit-identical to the ref oracle
    scale = jnp.where(amax > 0, amax * jnp.float32(1.0 / _qmax(bits)), 1.0)
    recip = jnp.float32(1.0) / scale
    q = jnp.clip(jnp.round(xg * recip), -_qmax(bits) - 1, _qmax(bits))
    q = q.astype(jnp.int32).reshape(t, b)
    if bits == 4:
        # block is even, so nibble pairs never straddle a sub-block boundary
        u = (q & 0xF).astype(jnp.uint8)
        codes_ref[...] = u[:, 0::2] | (u[:, 1::2] << jnp.uint8(4))
    else:
        codes_ref[...] = (q & 0xFF).astype(jnp.uint8)
    scales_ref[...] = scale[..., 0]


def _decode_kernel(codes_ref, scales_ref, o_ref, *, bits: int, block: int):
    c = codes_ref[...]                                  # [TILE, Bp]
    scale = scales_ref[...]                             # [TILE, G]
    if bits == 4:
        lo = (c & jnp.uint8(0xF)).astype(jnp.int32)
        hi = (c >> jnp.uint8(4)).astype(jnp.int32)
        q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0], c.shape[1] * 2)
        q = jnp.where(q >= 8, q - 16, q)
    else:
        q = c.astype(jnp.int8).astype(jnp.int32)
    t, b = q.shape
    qg = q.reshape(t, b // block, block)
    og = qg.astype(jnp.float32) * scale[..., None]
    o_ref[...] = og.reshape(t, b).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "block", "interpret"))
def qpack_encode_2d(x: jnp.ndarray, *, bits: int = 4, block: int | None = None,
                    interpret: bool | None = None):
    """x [N, B] -> (codes uint8[N, B*bits/8], scales f32[N, B/block]).

    N must be a multiple of TILE; B a multiple of 256 (nibble pairs stay
    lane-aligned). ``block`` (default B) subdivides each row into
    independently-scaled blocks; it must divide B and be even."""
    interpret = resolve_interpret(interpret)
    n, b = x.shape
    block = block or b
    assert n % TILE == 0 and b % 256 == 0, (n, b)
    assert b % block == 0 and block % 2 == 0, (b, block)
    g = b // block
    bp = b * bits // 8
    grid = (n // TILE,)
    codes, scales = pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, b), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE, bp), lambda i: (i, 0)),
                   pl.BlockSpec((TILE, g), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bp), jnp.uint8),
                   jax.ShapeDtypeStruct((n, g), jnp.float32)],
        interpret=interpret,
    )(x)
    return codes, scales


@functools.partial(jax.jit, static_argnames=("bits", "block", "out_dtype",
                                             "interpret"))
def qpack_decode_2d(codes: jnp.ndarray, scales: jnp.ndarray, *, bits: int = 4,
                    block: int | None = None, out_dtype=jnp.bfloat16,
                    interpret: bool | None = None):
    """(codes uint8[N, Bp], scales f32[N, G]) -> x [N, B]."""
    interpret = resolve_interpret(interpret)
    n, bp = codes.shape
    b = bp * 8 // bits
    g = scales.shape[1]
    block = block or b
    assert n % TILE == 0 and b == g * block, (n, b, g, block)
    grid = (n // TILE,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits, block=block),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, bp), lambda i: (i, 0)),
                  pl.BlockSpec((TILE, g), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), out_dtype),
        interpret=interpret,
    )(codes, scales)


# ---------------------------------------------------------------------------
# Fused demote / promote kernels (rate-adaptive engine, DESIGN.md §14).
# ---------------------------------------------------------------------------

def _fused_encode_kernel(x_ref, dense_ref, rates_ref, quanta_ref, *,
                         tol4: float, tol8: float, lossless: bool,
                         zero_elision: bool, qtab):
    x = x_ref[...]                                      # [TILE, V]
    xf = x.astype(jnp.float32)
    t, v = xf.shape
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)  # [TILE, 1]
    q4, s4 = _quantize_rows(xf, 4)
    q8, s8 = _quantize_rows(xf, 8)
    deq4 = (q4.astype(jnp.float32) * s4).astype(jnp.bfloat16)
    deq8 = (q8.astype(jnp.float32) * s8).astype(jnp.bfloat16)
    xb = x.astype(jnp.bfloat16)

    # rate selection — formula-for-formula core.compressor.select_rate
    if lossless:
        ok4 = jnp.all(deq4 == xb, axis=-1, keepdims=True)
        ok8 = jnp.all(deq8 == xb, axis=-1, keepdims=True)
    else:
        err4 = jnp.max(jnp.abs(deq4.astype(jnp.float32) - xf), axis=-1,
                       keepdims=True)
        err8 = jnp.max(jnp.abs(deq8.astype(jnp.float32) - xf), axis=-1,
                       keepdims=True)
        safe = jnp.where(amax > 0, amax, 1.0)
        ok4 = err4 / safe <= tol4
        ok8 = err8 / safe <= tol8
    rate = jnp.where(ok8, RATE_8BIT, RATE_RAW)
    rate = jnp.where(ok4, RATE_4BIT, rate)
    rate = jnp.where(amax == 0, RATE_ZERO, rate)
    rate = rate.astype(jnp.int32)                        # [TILE, 1]
    if not zero_elision:
        rate = jnp.maximum(rate, RATE_4BIT)

    # quanta emit (static 4-entry table -> where chain, no in-kernel gather)
    quanta = jnp.where(rate == RATE_ZERO, qtab[0],
                       jnp.where(rate == RATE_4BIT, qtab[1],
                                 jnp.where(rate == RATE_8BIT, qtab[2],
                                           qtab[3]))).astype(jnp.int32)

    # dense candidate layouts (core.compressor._encode_block_dense):
    #   4-bit: f32 scale bytes | packed nibbles | zero pad
    #   8-bit: f32 scale bytes | int8 bytes     | zero pad
    #   raw  : little-endian bf16 bytes
    nb = 2 * v
    u4 = (q4 & 0xF).astype(jnp.uint8)
    p4 = u4[:, 0::2] | (u4[:, 1::2] << jnp.uint8(4))
    c4 = jnp.concatenate(
        [_f32_rowbytes(s4), p4, jnp.zeros((t, nb - 4 - v // 2), jnp.uint8)],
        axis=1)
    p8 = (q8 & 0xFF).astype(jnp.uint8)
    c8 = jnp.concatenate(
        [_f32_rowbytes(s8), p8, jnp.zeros((t, nb - 4 - v), jnp.uint8)],
        axis=1)
    u16 = jax.lax.bitcast_convert_type(xb, jnp.uint16)
    lo = (u16 & jnp.uint16(0xFF)).astype(jnp.uint8)
    hi = (u16 >> jnp.uint16(8)).astype(jnp.uint8)
    raw = jnp.stack([lo, hi], axis=-1).reshape(t, nb)

    dense = jnp.where(rate == RATE_4BIT, c4, jnp.zeros((t, nb), jnp.uint8))
    dense = jnp.where(rate == RATE_8BIT, c8, dense)
    dense = jnp.where(rate == RATE_RAW, raw, dense)
    dense_ref[...] = dense
    rates_ref[...] = rate
    quanta_ref[...] = quanta


def _fused_decode_kernel(dense_ref, rates_ref, o_ref):
    d = dense_ref[...]                                  # [TILE, 2V] uint8
    rate = rates_ref[...]                               # [TILE, 1] int32
    t, nb = d.shape
    v = nb // 2
    # per-row f32 scale from the first 4 bytes (common.utils.bytes_to_f32)
    q32 = d[:, 0:4].astype(jnp.uint32)
    u = q32[:, 0:1] | (q32[:, 1:2] << 8) | (q32[:, 2:3] << 16) | \
        (q32[:, 3:4] << 24)
    scale = jax.lax.bitcast_convert_type(u.astype(jnp.uint32), jnp.float32)
    # 4-bit: sign-extended nibbles (core.bitpack.unpack4)
    c4 = d[:, 4:4 + v // 2]
    lo = (c4 & jnp.uint8(0xF)).astype(jnp.int8)
    hi = (c4 >> jnp.uint8(4)).astype(jnp.int8)
    qn = jnp.stack([lo, hi], axis=-1).reshape(t, v)
    qn = jnp.where(qn >= 8, qn - 16, qn)
    out4 = (qn.astype(jnp.float32) * scale).astype(jnp.bfloat16)
    # 8-bit: bit-identity int8 (core.bitpack.unpack8)
    q8 = jax.lax.bitcast_convert_type(d[:, 4:4 + v], jnp.int8)
    out8 = (q8.astype(jnp.float32) * scale).astype(jnp.bfloat16)
    # raw: little-endian bf16 (core.bitpack.bytes_to_raw)
    pairs = d.reshape(t, v, 2).astype(jnp.uint16)
    u16 = pairs[..., 0] | (pairs[..., 1] << jnp.uint16(8))
    raw = jax.lax.bitcast_convert_type(u16, jnp.bfloat16)

    out = jnp.where(rate == RATE_4BIT, out4,
                    jnp.zeros((t, v), jnp.bfloat16))
    out = jnp.where(rate == RATE_8BIT, out8, out)
    out = jnp.where(rate == RATE_RAW, raw, out)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("tol4", "tol8", "lossless",
                                             "zero_elision", "quanta",
                                             "interpret"))
def qpack_fused_encode_2d(x: jnp.ndarray, *, tol4: float = 0.10,
                          tol8: float = 0.01, lossless: bool = False,
                          zero_elision: bool = True,
                          quanta: tuple = (0, 3, 5, 8),
                          interpret: bool | None = None):
    """Fused demote kernel: blocks x [N, V] (bf16/f32 values) ->
    (dense uint8[N, 2V], rates int32[N], quanta int32[N]) in one grid pass.

    ``dense`` rows are byte-identical to ``_encode_block_dense``; ``quanta``
    is the static per-rate size table (core.compressor.block_quanta_table).
    N must be a multiple of TILE; V a multiple of 128."""
    interpret = resolve_interpret(interpret)
    n, v = x.shape
    assert n % TILE == 0 and v % 128 == 0, (n, v)
    grid = (n // TILE,)
    dense, rates, qnt = pl.pallas_call(
        functools.partial(_fused_encode_kernel, tol4=tol4, tol8=tol8,
                          lossless=lossless, zero_elision=zero_elision,
                          qtab=tuple(quanta)),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, v), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE, 2 * v), lambda i: (i, 0)),
                   pl.BlockSpec((TILE, 1), lambda i: (i, 0)),
                   pl.BlockSpec((TILE, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, 2 * v), jnp.uint8),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32)],
        interpret=interpret,
    )(x)
    return dense, rates[:, 0], qnt[:, 0]


@functools.partial(jax.jit, static_argnames=("interpret",))
def qpack_fused_decode_2d(dense: jnp.ndarray, rates: jnp.ndarray, *,
                          interpret: bool | None = None):
    """Fused promote kernel: (dense uint8[N, 2V], rates int32[N]) ->
    bf16 [N, V] (unpack + dequant for all four rates in one pass)."""
    interpret = resolve_interpret(interpret)
    n, nb = dense.shape
    v = nb // 2
    assert n % TILE == 0 and v % 128 == 0, (n, v)
    grid = (n // TILE,)
    return pl.pallas_call(
        _fused_decode_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, nb), lambda i: (i, 0)),
                  pl.BlockSpec((TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE, v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, v), jnp.bfloat16),
        interpret=interpret,
    )(dense, rates.reshape(n, 1).astype(jnp.int32))
