"""Pallas TPU kernels for the qpack block compression engine.

The compression engine is the paper's per-device hot path (Fig. 3 steps 2-3).
On TPU we replace the LZ77 sequential matcher with rate-adaptive quantization
(DESIGN.md §3): a VPU-friendly reduction (block amax) + elementwise quantize +
nibble pack. Tiling: ``TILE`` blocks per grid step; each block of ``B`` values
is one VMEM row, hardware-aligned when B is a multiple of 128 (lane width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 8  # blocks per grid step


def _qmax(bits: int) -> float:
    return float(2 ** (bits - 1) - 1)


def _encode_kernel(x_ref, codes_ref, scales_ref, *, bits: int):
    x = x_ref[...].astype(jnp.float32)                 # [TILE, B]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)  # [TILE, 1]
    # reciprocal multiplies keep this bit-identical to the ref oracle
    scale = jnp.where(amax > 0, amax * jnp.float32(1.0 / _qmax(bits)), 1.0)
    recip = jnp.float32(1.0) / scale
    q = jnp.clip(jnp.round(x * recip), -_qmax(bits) - 1, _qmax(bits))
    q = q.astype(jnp.int32)
    if bits == 4:
        u = (q & 0xF).astype(jnp.uint8)
        codes_ref[...] = u[:, 0::2] | (u[:, 1::2] << jnp.uint8(4))
    else:
        codes_ref[...] = (q & 0xFF).astype(jnp.uint8)
    scales_ref[...] = scale


def _decode_kernel(codes_ref, scales_ref, o_ref, *, bits: int):
    c = codes_ref[...]                                  # [TILE, Bp]
    scale = scales_ref[...]                             # [TILE, 1]
    if bits == 4:
        lo = (c & jnp.uint8(0xF)).astype(jnp.int32)
        hi = (c >> jnp.uint8(4)).astype(jnp.int32)
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        q = jnp.stack([lo, hi], axis=-1).reshape(c.shape[0], c.shape[1] * 2)
    else:
        q = c.astype(jnp.int8).astype(jnp.int32)
    o_ref[...] = (q.astype(jnp.float32) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def qpack_encode_2d(x: jnp.ndarray, *, bits: int = 4,
                    interpret: bool = True):
    """x [N, B] -> (codes uint8[N, B*bits/8], scales f32[N, 1]).

    N must be a multiple of TILE; B a multiple of 256 (nibble pairs stay
    lane-aligned)."""
    n, b = x.shape
    assert n % TILE == 0 and b % 256 == 0, (n, b)
    bp = b * bits // 8
    grid = (n // TILE,)
    codes, scales = pl.pallas_call(
        functools.partial(_encode_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, b), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((TILE, bp), lambda i: (i, 0)),
                   pl.BlockSpec((TILE, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, bp), jnp.uint8),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        interpret=interpret,
    )(x)
    return codes, scales


@functools.partial(jax.jit, static_argnames=("bits", "out_dtype", "interpret"))
def qpack_decode_2d(codes: jnp.ndarray, scales: jnp.ndarray, *, bits: int = 4,
                    out_dtype=jnp.bfloat16, interpret: bool = True):
    """(codes uint8[N, Bp], scales f32[N, 1]) -> x [N, B]."""
    n, bp = codes.shape
    b = bp * 8 // bits
    assert n % TILE == 0, n
    grid = (n // TILE,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((TILE, bp), lambda i: (i, 0)),
                  pl.BlockSpec((TILE, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((TILE, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), out_dtype),
        interpret=interpret,
    )(codes, scales)
