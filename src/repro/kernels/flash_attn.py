"""Flash attention forward (causal, GQA) — the prefill hot path.

Standard TPU schedule: grid (batch, q_head, Sq/Tq, Sk/Tk) with the KV axis
minor (sequential), online-softmax accumulators in VMEM scratch, causal
block-skip via pl.when. Tq/Tk default 128 (MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, tq: int, tk: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = (not causal) or (j * tk <= i * tq + tq - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, :, 0, :].astype(jnp.float32)            # [Tq, D]
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # [Tk, D]
        v = v_ref[0, :, 0, :].astype(jnp.float32)            # [Tk, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        if causal:
            row = i * tq + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            col = j * tk + jax.lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(col <= row, s, NEG_INF)
        m_prev, l_prev, acc_prev = m_scr[...], l_scr[...], acc_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_new = acc_prev * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...], l_scr[...], acc_scr[...] = m_new, l_new, acc_new

    @pl.when(j == pl.num_programs(3) - 1)
    def _finish():
        o_ref[0, :, 0, :] = (acc_scr[...] /
                             jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sm_scale", "tq", "tk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, sm_scale: float | None = None,
                    tq: int = 128, tk: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D] (GQA: Hq % Hkv == 0). -> [B,Sq,Hq,D]."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Sq % tq == 0 and Sk % tk == 0, (Sq, Sk, tq, tk)
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    g = Hq // Hkv
    grid = (B, Hq, Sq // tq, Sk // tk)
    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=float(sm_scale),
                          causal=causal, tq=tq, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, tk, 1, D), lambda b, h, i, j: (b, j, h // g, 0)),
            pl.BlockSpec((1, tk, 1, D), lambda b, h, i, j: (b, j, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Sq, Hq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, 1), jnp.float32),
                        pltpu.VMEM((tq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
