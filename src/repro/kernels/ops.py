"""Public jit'd wrappers around the Pallas kernels.

``INTERPRET`` defaults to True off-TPU (this container validates kernels with
the Pallas interpreter); on a real TPU backend the compiled kernels run. The
wrappers also adapt shapes to/from the flat layouts used elsewhere
(core.compressor.quantize_blocks et al.): blocks smaller than a 256-value
row are grouped (e.g. four 64-value head-dim blocks per row) so the kernels
always see lane-aligned rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _fa
from repro.kernels import kvc_attn as _ka
from repro.kernels import qpack as _qp

INTERPRET = jax.default_backend() != "tpu"

_ROW = 256   # minimum kernel row width (nibble pairs stay lane-aligned)


def _row_blocks(block: int) -> int:
    """Blocks grouped per kernel row (1 for block >= 256)."""
    if block % _ROW == 0:
        return 1
    assert _ROW % block == 0 and block % 2 == 0, block
    return _ROW // block


def qpack_encode(x: jnp.ndarray, bits: int = 4, block: int = 512,
                 interpret: bool | None = None):
    """x[..., N] -> (codes uint8[..., N*bits/8], scales f32[..., N/block]).
    Shape-compatible with core.compressor.quantize_blocks."""
    if interpret is None:
        interpret = INTERPRET
    lead = x.shape[:-1]
    n = x.shape[-1]
    nblk = n // block
    total_blocks = int(jnp.prod(jnp.asarray(lead + (nblk,)))) if lead else nblk
    rb = _row_blocks(block)
    # pad block count to whole kernel tiles of whole rows
    pad = (-total_blocks) % (_qp.TILE * rb)
    x2 = x.reshape(total_blocks, block)
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, block), x.dtype)], axis=0)
    rows = (total_blocks + pad) // rb
    codes, scales = _qp.qpack_encode_2d(x2.reshape(rows, rb * block),
                                        bits=bits, block=block,
                                        interpret=interpret)
    codes = codes.reshape(rows * rb, block * bits // 8)[:total_blocks]
    codes = codes.reshape(lead + (n * bits // 8,))
    scales = scales.reshape(rows * rb)[:total_blocks].reshape(lead + (nblk,))
    return codes, scales


def qpack_decode(codes: jnp.ndarray, scales: jnp.ndarray, bits: int = 4,
                 block: int = 512, dtype=jnp.bfloat16,
                 interpret: bool | None = None) -> jnp.ndarray:
    if interpret is None:
        interpret = INTERPRET
    lead = scales.shape[:-1]
    nblk = scales.shape[-1]
    bp = block * bits // 8
    total_blocks = int(jnp.prod(jnp.asarray(lead + (nblk,)))) if lead else nblk
    rb = _row_blocks(block)
    pad = (-total_blocks) % (_qp.TILE * rb)
    c2 = codes.reshape(total_blocks, bp)
    s2 = scales.reshape(total_blocks, 1)
    if pad:
        c2 = jnp.concatenate([c2, jnp.zeros((pad, bp), jnp.uint8)], axis=0)
        s2 = jnp.concatenate([s2, jnp.ones((pad, 1), jnp.float32)], axis=0)
    rows = (total_blocks + pad) // rb
    x = _qp.qpack_decode_2d(c2.reshape(rows, rb * bp),
                            s2.reshape(rows, rb), bits=bits, block=block,
                            out_dtype=dtype, interpret=interpret)
    x = x.reshape(rows * rb, block)[:total_blocks]
    return x.reshape(lead + (nblk * block,))


def qpack_fused_encode(x: jnp.ndarray, *, tol4: float = 0.10,
                       tol8: float = 0.01, lossless: bool = False,
                       zero_elision: bool = True,
                       quanta: tuple = (0, 3, 5, 8),
                       interpret: bool | None = None):
    """Fused demote over blocks x [T, V]: pads T to the kernel tile and
    returns (dense uint8[T, 2V], rates int32[T], quanta int32[T])."""
    if interpret is None:
        interpret = INTERPRET
    t, v = x.shape
    pad = (-t) % _qp.TILE
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, v), x.dtype)], axis=0)
    dense, rates, qnt = _qp.qpack_fused_encode_2d(
        x, tol4=tol4, tol8=tol8, lossless=lossless,
        zero_elision=zero_elision, quanta=tuple(quanta),
        interpret=interpret)
    return dense[:t], rates[:t], qnt[:t]


def qpack_fused_decode(dense: jnp.ndarray, rates: jnp.ndarray, *,
                       interpret: bool | None = None) -> jnp.ndarray:
    """Fused promote over dense blocks [T, 2V] + rates [T] -> bf16 [T, V]."""
    if interpret is None:
        interpret = INTERPRET
    t, nb = dense.shape
    pad = (-t) % _qp.TILE
    if pad:
        dense = jnp.concatenate(
            [dense, jnp.zeros((pad, nb), jnp.uint8)], axis=0)
        rates = jnp.concatenate(
            [rates, jnp.zeros((pad,), rates.dtype)], axis=0)
    out = _qp.qpack_fused_decode_2d(dense, rates, interpret=interpret)
    return out[:t]


def kvc_decode_attention(q, k_codes, k_scales, v_codes, v_scales, lengths, *,
                         bits: int = 4, sm_scale: float | None = None,
                         t_blk: int = 128) -> jnp.ndarray:
    return _ka.kvc_decode_attention(
        q, k_codes, k_scales, v_codes, v_scales, lengths, bits=bits,
        sm_scale=sm_scale, t_blk=t_blk, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None, tq: int = 128,
                    tk: int = 128) -> jnp.ndarray:
    return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               tq=tq, tk=tk, interpret=INTERPRET)
