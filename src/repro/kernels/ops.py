"""Public jit'd wrappers around the Pallas kernels.

``INTERPRET`` defaults to True off-TPU (this container validates kernels with
the Pallas interpreter); on a real TPU backend the compiled kernels run. The
wrappers also adapt shapes to/from the flat layouts used elsewhere
(core.compressor.quantize_blocks et al.).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attn as _fa
from repro.kernels import kvc_attn as _ka
from repro.kernels import qpack as _qp

INTERPRET = jax.default_backend() != "tpu"


def qpack_encode(x: jnp.ndarray, bits: int = 4, block: int = 512):
    """x[..., N] -> (codes uint8[..., N*bits/8], scales f32[..., N/block]).
    Shape-compatible with core.compressor.quantize_blocks."""
    lead = x.shape[:-1]
    n = x.shape[-1]
    nblk = n // block
    total_blocks = int(jnp.prod(jnp.asarray(lead + (nblk,)))) if lead else nblk
    # pad block count to the kernel tile
    pad = (-total_blocks) % _qp.TILE
    x2 = x.reshape(total_blocks, block)
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, block), x.dtype)], axis=0)
    codes, scales = _qp.qpack_encode_2d(x2, bits=bits, interpret=INTERPRET)
    codes = codes[:total_blocks].reshape(lead + (n * bits // 8,))
    scales = scales[:total_blocks, 0].reshape(lead + (nblk,))
    return codes, scales


def qpack_decode(codes: jnp.ndarray, scales: jnp.ndarray, bits: int = 4,
                 block: int = 512, dtype=jnp.bfloat16) -> jnp.ndarray:
    lead = scales.shape[:-1]
    nblk = scales.shape[-1]
    bp = block * bits // 8
    total_blocks = int(jnp.prod(jnp.asarray(lead + (nblk,)))) if lead else nblk
    pad = (-total_blocks) % _qp.TILE
    c2 = codes.reshape(total_blocks, bp)
    s2 = scales.reshape(total_blocks, 1)
    if pad:
        c2 = jnp.concatenate([c2, jnp.zeros((pad, bp), jnp.uint8)], axis=0)
        s2 = jnp.concatenate([s2, jnp.ones((pad, 1), jnp.float32)], axis=0)
    x = _qp.qpack_decode_2d(c2, s2, bits=bits, out_dtype=dtype,
                            interpret=INTERPRET)
    return x[:total_blocks].reshape(lead + (nblk * block,))


def kvc_decode_attention(q, k_codes, k_scales, v_codes, v_scales, lengths, *,
                         bits: int = 4, sm_scale: float | None = None,
                         t_blk: int = 128) -> jnp.ndarray:
    return _ka.kvc_decode_attention(
        q, k_codes, k_scales, v_codes, v_scales, lengths, bits=bits,
        sm_scale=sm_scale, t_blk=t_blk, interpret=INTERPRET)


def flash_attention(q, k, v, *, causal: bool = True,
                    sm_scale: float | None = None, tq: int = 128,
                    tk: int = 128) -> jnp.ndarray:
    return _fa.flash_attention(q, k, v, causal=causal, sm_scale=sm_scale,
                               tq=tq, tk=tk, interpret=INTERPRET)
