"""The Recorder: host-side accumulator for piggybacked telemetry.

Every ``record_*`` method consumes values that are ALREADY host numpy —
the deltas, times and flags that fell out of the hot paths' single
contracted fetches (``Fabric._fetch_view``'s fused per-segment sync,
``Fabric._commit_epoch``'s per-epoch sync, ``serve.Engine.step``'s one
``(tok, done, ref, pos)`` fetch) plus host-only scheduling facts
(migration plans, admissions, park/resume bookkeeping). Handing the
Recorder a device value is a bug the analyzer's R6 rule flags at the
source level; at runtime the contracts' budgets stay unchanged because
nothing here ever crosses the host/device boundary.

Samples land in two places: a :class:`~repro.obs.registry.MetricsRegistry`
(aggregates; counter metrics keyed by ``state.COUNTER_NAMES`` via zip —
no integer-literal indexing, the R3 layout rule stays clean) and ordered
per-domain event lists (``segments`` / ``plans`` / ``epochs`` for the
fabric, ``steps`` / ``serve_events`` for serving) that the exporters in
``repro.obs.export`` turn into a Perfetto timeline and ``metrics.json``.

The module imports neither jax nor the engine packages at module level
(``repro.obs`` must import on jax-free hosts for ``manifest()``); the
counter-name table is pulled lazily on first fabric/serve attach.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.obs.registry import MetricsRegistry

# microsecond buckets for delivered-time histograms: 1-2-5 decades from
# 1 us to 50 s (modeled per-segment times live in the ms range)
TIME_US_BOUNDS = tuple(m * 10 ** e for e in range(0, 8) for m in (1, 2, 5))


class Recorder:
    """Accumulates piggybacked samples from one run (one fabric and/or
    one serving engine). Opt-in: constructed by the caller and passed as
    ``obs=`` — the ``obs=None`` default everywhere is the recording-off
    path, bit-identical in pool/counter state (tests/test_obs.py)."""

    def __init__(self):
        self.metrics = MetricsRegistry()
        # fabric timeline, in record order
        self.segments: List[Dict[str, Any]] = []   # one per replayed segment
        self.plans: List[Dict[str, Any]] = []      # one per non-empty plan
        self.epochs: List[Dict[str, Any]] = []     # one per committed epoch
        # serving timeline
        self.steps: List[Dict[str, Any]] = []      # one per decode step
        self.serve_events: List[Dict[str, Any]] = []   # admissions/motion
        self.cells: List[Dict[str, Any]] = []      # simx workload cells
        self.fabric_info: Optional[Dict[str, Any]] = None
        self.serve_info: Optional[Dict[str, Any]] = None

    # -- attach ---------------------------------------------------------------

    @staticmethod
    def _delta_dict(delta: np.ndarray) -> Dict[str, int]:
        """Name-keyed counter delta via ``state.counters_delta_dict`` —
        the layout-safe (R3) bridge from fetched vectors to metric names.
        Lazy import: repro.obs must load on jax-free hosts."""
        from repro.core.engine import state as S
        return S.counters_delta_dict(delta)

    def attach_fabric(self, fabric) -> None:
        """Called by ``Fabric.__init__`` when constructed with ``obs=``.
        Captures the run facts the exporters need (fleet for pricing,
        scheduler mode for labeling) — never live device state."""
        self.fabric_info = {
            "n_expanders": fabric.n_expanders,
            "devices": list(fabric.devices),     # DeviceConfig per expander
            "window": fabric.window,
            "spill_interval": fabric.spill_interval,
            "pipeline_depth": fabric.pipeline_depth,
            "sync_migration": fabric.sync_migration,
            "migration": fabric.migration_policy.name,
            "migration_enabled": fabric.migration_enabled,
            # None on vmap drivers; the sharded driver's mesh size. The
            # exporters derive the block expander->device placement from
            # (n_expanders, shard_devices) — the recorder stays jax-free.
            "shard_devices": getattr(fabric, "shard_devices", None),
        }

    def attach_serve(self, engine) -> None:
        """Called by ``serve._EngineBase.__init__`` when constructed with
        ``obs=``."""
        self.serve_info = {
            "lanes": engine.lanes,
            "n_expanders": engine.n_expanders,
            "max_len": engine.max_len,
            "family": engine.cfg.family,
        }

    # -- fabric drains (host values from the contracted fetches) --------------

    def record_segment(self, seg: int, delta: np.ndarray, times: np.ndarray,
                       free_units: Optional[np.ndarray]) -> None:
        """One replayed segment, from ``_fetch_view``'s single fused sync:
        the replay counter delta (int64 [N, C]), the in-jit per-expander
        delivered times (float64 [N] seconds), and the freelist headroom
        (int64 [N] chunk units; None before the first stats fetch)."""
        delta = np.asarray(delta, np.int64)
        times = np.asarray(times, np.float64)
        self.segments.append({
            "seg": int(seg), "delta": delta, "times": times,
            "free_units": None if free_units is None
            else np.asarray(free_units, np.int64).copy(),
        })
        for name, v in self._delta_dict(delta).items():
            self.metrics.counter(f"fabric.{name}").inc(v)
        th = self.metrics.histogram("fabric.segment_time_us", TIME_US_BOUNDS)
        for t in times:
            th.observe(float(t) * 1e6)
        if free_units is not None:
            self.metrics.gauge("fabric.free_units_min").set(
                float(np.min(free_units)))
            self.metrics.histogram("fabric.free_units").observe(
                float(np.min(free_units)))

    def record_plan(self, seg: int, plan, policy: str) -> None:
        """A migration plan the policy produced at segment ``seg``'s
        boundary (pure host data — planning never touches the device)."""
        self.plans.append({
            "seg": int(seg), "policy": policy, "pages": int(len(plan)),
            "urgent": bool(plan.urgent),
            "pairs": plan.pairs(),
        })
        self.metrics.counter("fabric.plans").inc()
        self.metrics.counter("fabric.pages_planned").inc(int(len(plan)))
        if plan.urgent:
            self.metrics.counter("fabric.plans_urgent").inc()

    def record_epoch(self, seg: int, delta: np.ndarray, *, kind: str,
                     overlapped: bool, planned: int, moved: int,
                     urgent: bool, free_units: np.ndarray) -> None:
        """One committed migration epoch, from ``_commit_epoch``'s single
        sync: the migration counter delta (int64 [N, C]) tagged with the
        segment whose replay it overlapped and how it was scheduled
        (``kind``: overlapped | urgent | sync | drain)."""
        delta = np.asarray(delta, np.int64)
        self.epochs.append({
            "seg": int(seg), "delta": delta, "kind": str(kind),
            "overlapped": bool(overlapped), "planned": int(planned),
            "moved": int(moved), "urgent": bool(urgent),
            "free_units": np.asarray(free_units, np.int64).copy(),
        })
        for name, v in self._delta_dict(delta).items():
            self.metrics.counter(f"fabric.migration.{name}").inc(v)
        self.metrics.counter("fabric.epochs").inc()
        self.metrics.counter(f"fabric.epochs_{kind}").inc()
        self.metrics.counter("fabric.pages_moved").inc(int(moved))
        if planned and not moved:
            self.metrics.counter("fabric.epochs_stalled").inc()

    # -- simx drains ------------------------------------------------------------

    def record_cell(self, scheme: str, workload: str,
                    metrics: Dict[str, Any]) -> None:
        """One finished (scheme x workload) simx cell — the metrics dict
        ``run_workload`` assembled is host data already; recording it is
        free. Delivered time lands in the shared time histogram so sweep
        aggregations merge with fabric segment times."""
        self.cells.append({"scheme": str(scheme), "workload": str(workload),
                           "time_s": float(metrics["time_s"]),
                           "normalized_perf":
                               float(metrics["normalized_perf"])})
        self.metrics.counter("simx.cells").inc()
        self.metrics.histogram("simx.cell_time_us", TIME_US_BOUNDS).observe(
            float(metrics["time_s"]) * 1e6)
        self.metrics.gauge(
            f"simx.normalized_perf.{scheme}.{workload}").set(
            float(metrics["normalized_perf"]))

    # -- serving drains --------------------------------------------------------

    def record_step(self, step: int, toks: np.ndarray, done: np.ndarray,
                    pos: np.ndarray, active: Sequence[int]) -> None:
        """One decode step, from ``Engine.step``'s single fetch of the
        ``(tok, done, ref, pos)`` quad: emitted tokens, completion flags
        and per-lane positions for the lanes that were active."""
        active = list(int(a) for a in active)
        self.steps.append({
            "step": int(step), "active": active,
            "done": [int(l) for l in active if bool(np.asarray(done)[l])],
            "max_pos": int(np.max(np.asarray(pos)[active])) if active else 0,
        })
        self.metrics.counter("serve.steps").inc()
        self.metrics.counter("serve.tokens").inc(len(active))
        self.metrics.gauge("serve.active_lanes").set(float(len(active)))
        self.metrics.histogram("serve.active_lanes").observe(len(active))

    def _serve_event(self, kind: str, **fields) -> None:
        ev = {"type": kind, "step": len(self.steps)}
        ev.update(fields)
        self.serve_events.append(ev)

    def record_admission(self, n: int, bucket: int) -> None:
        """One bucketed prefill batch (host scheduling fact)."""
        self._serve_event("admission", n=int(n), bucket=int(bucket))
        self.metrics.counter("serve.admissions").inc(int(n))
        self.metrics.counter("serve.prefill_batches").inc()
        self.metrics.histogram("serve.prefill_bucket").observe(int(bucket))

    def record_preempt(self, lane: int, rid: int, nbytes: int, shadow: bool,
                       expander: int) -> None:
        """One lane preemption: ``nbytes`` parked (0 when the shadow still
        covered every token — the §4.5 zero-byte re-preempt)."""
        self._serve_event("preempt", lane=int(lane), rid=int(rid),
                          bytes=int(nbytes), shadow=bool(shadow),
                          expander=int(expander))
        self.metrics.counter("serve.preemptions").inc()
        self.metrics.counter("serve.preempt_bytes").inc(int(nbytes))
        if shadow:
            self.metrics.counter("serve.shadow_repreempts").inc()
        self.metrics.histogram("serve.preempt_bytes").observe(int(nbytes))

    def record_resume(self, lane: int, rid: int, nbytes: int,
                      cross_expander: bool, expander: int) -> None:
        """One parked-request resume (promotion): compressed payload
        installed without dequantizing."""
        self._serve_event("resume", lane=int(lane), rid=int(rid),
                          bytes=int(nbytes), cross=bool(cross_expander),
                          expander=int(expander))
        self.metrics.counter("serve.resumes").inc()
        self.metrics.counter("serve.resume_bytes").inc(int(nbytes))
        if cross_expander:
            self.metrics.counter("serve.cross_expander_resumes").inc()
        self.metrics.histogram("serve.resume_bytes").observe(int(nbytes))
