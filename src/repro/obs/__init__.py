"""Zero-extra-sync telemetry (DESIGN.md §16).

The observability layer's collection path adds NO host syncs: every
sample rides inside a payload the hot paths already fetch under their
declared ``@sync_contract`` budgets — the fabric's fused per-segment
stats fetch (``Fabric._fetch_view``), the per-epoch moved-pages fetch
(``Fabric._commit_epoch``), and the serving engine's single per-step
``(tok, done, ref, pos)`` fetch (``serve.Engine.step``). The
:class:`Recorder` accumulates those piggybacked samples host-side into a
metrics registry (counters / gauges / histograms keyed by
``state.COUNTER_NAMES`` — never integer literals, R3 stays clean) and a
structured event log; exporters turn them into a Chrome/Perfetto
``trace_event`` timeline, a ``metrics.json`` snapshot, and the run
manifest stamped into every BENCH_*.json.

Instrumentation is opt-in: ``obs=None`` (the default everywhere) is the
recording-off path, bit-identical in pool/counter state to recording-on
(tests/test_obs.py pins this), and the analyzer's R6 rule enforces that
telemetry is emitted ONLY through these piggyback drains.
"""
from repro.obs.manifest import manifest
from repro.obs.recorder import Recorder
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                merge_histograms)
from repro.obs import export

__all__ = [
    "Recorder", "manifest", "export",
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "merge_histograms",
]
