"""Run manifest: the one description of "what produced this artifact".

Every BENCH_*.json writer, the launch ``--trace`` exports and the
``metrics.json`` snapshot stamp the same dict, built here — previously
each bench hand-rolled its own ``meta`` and they had drifted on which
fields they carried. Keys: backend + device count, jax/jaxlib versions,
python/platform, seed, git sha.

Must import (and run) on jax-free hosts — the lint bench and analysis
tooling stamp manifests too — so the jax block is best-effort: missing
accelerator stack degrades to ``backend: None``, never an ImportError.
"""
from __future__ import annotations

import pathlib
import platform
import subprocess
from typing import Any, Dict, Optional

_REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]


def _git_sha() -> Optional[str]:
    try:
        out = subprocess.run(["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
                             capture_output=True, text=True, timeout=10)
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def manifest(seed: Optional[int] = None, **extra: Any) -> Dict[str, Any]:
    """The run manifest stamped into every BENCH meta and obs export.

    ``seed`` is recorded when the producing run has one; ``extra``
    key/values ride along verbatim (a bench's own knobs — sizes, point
    names — belong in its results, not here)."""
    out: Dict[str, Any] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
        "git_sha": _git_sha(),
        "jax": None,
        "jaxlib": None,
        "backend": None,
        "device_count": None,
    }
    try:
        import jax
        import jaxlib
        out["jax"] = jax.__version__
        out["jaxlib"] = jaxlib.__version__
        out["backend"] = jax.default_backend()
        out["device_count"] = jax.device_count()
    except Exception:        # no accelerator stack: manifest still valid
        pass
    if seed is not None:
        out["seed"] = int(seed)
    out.update(extra)
    return out
