"""Metrics registry: counters, gauges, histograms (DESIGN.md §16).

Pure stdlib + numpy-free on purpose — the registry is host-side
bookkeeping fed by the Recorder's piggyback drains, and it must stay
importable (like ``repro.analysis``) on machines with no accelerator
stack. Metric *names* are the single namespace benches and exporters
key on; counter metrics derived from the pool's traffic vector are keyed
by ``state.COUNTER_NAMES`` / ``state.TRAFFIC_NAMES`` entries, never by
integer position — the R3 layout-drift rule stays clean by construction.

Histograms use fixed bucket bounds chosen at creation, so merging two
histograms (multi-run aggregation, per-expander roll-ups) is a plain
bucket-wise add: associative and commutative, with ``sum``/``count``
carried exactly (tests/test_obs.py pins merge associativity).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

# Default bucket upper edges: 1-2-5 decades covering counter deltas
# (accesses per segment) through modeled microseconds. The final +inf
# bucket is implicit (``counts`` has ``len(bounds) + 1`` slots).
DEFAULT_BOUNDS: Tuple[float, ...] = tuple(
    m * 10 ** e for e in range(0, 7) for m in (1, 2, 5))


class Counter:
    """Monotonically increasing value (events, accesses, bytes)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name}: negative increment {n}")
        self.value += int(n)

    def snapshot(self):
        return self.value


class Gauge:
    """Point-in-time value (freelist headroom, parked lanes)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def snapshot(self):
        return self.value


class Histogram:
    """Fixed-bound histogram. ``bounds`` are inclusive upper edges of the
    first ``len(bounds)`` buckets; one overflow bucket follows. Merging
    requires identical bounds and is a bucket-wise add — associative, so
    partial aggregations (per expander, per run) compose in any order."""

    __slots__ = ("name", "bounds", "counts", "total", "n")

    def __init__(self, name: str, bounds: Sequence[float] = DEFAULT_BOUNDS):
        self.name = name
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(set(self.bounds)):
            raise ValueError(f"histogram {name}: bounds must be strictly "
                             f"increasing")
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.total = 0.0
        self.n = 0

    def observe(self, v: float) -> None:
        v = float(v)
        # linear scan is fine: bucket counts are small and this runs on
        # already-fetched host scalars, never on the device path
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                break
        else:
            self.counts[-1] += 1
        self.total += v
        self.n += 1

    def merge(self, other: "Histogram") -> "Histogram":
        """Bucket-wise combine into a NEW histogram (inputs untouched)."""
        if self.bounds != other.bounds:
            raise ValueError(f"histogram merge: bounds differ "
                             f"({self.name} vs {other.name})")
        out = Histogram(self.name, self.bounds)
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.total = self.total + other.total
        out.n = self.n + other.n
        return out

    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def snapshot(self) -> dict:
        return {"bounds": list(self.bounds), "counts": list(self.counts),
                "sum": self.total, "count": self.n, "mean": self.mean()}


def merge_histograms(hists: Sequence[Histogram]) -> Optional[Histogram]:
    """Fold ``merge`` over a sequence (order-independent by associativity
    + commutativity of bucket-wise addition)."""
    out: Optional[Histogram] = None
    for h in hists:
        out = h if out is None else out.merge(h)
    return out


class MetricsRegistry:
    """Get-or-create registry: one flat name → metric namespace. The
    Recorder is the only writer on the hot path; benches and exporters
    read ``snapshot()``."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  bounds: Sequence[float] = DEFAULT_BOUNDS) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(name, bounds)
        return h

    def snapshot(self) -> dict:
        return {
            "counters": {k: c.snapshot()
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.snapshot()
                       for k, g in sorted(self._gauges.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self._histograms.items())},
        }
