"""Exporters over a Recorder: Perfetto timeline, metrics.json, tables.

The fabric trace is the visual proof of the pipeline pricing model
(DESIGN.md §13): each expander gets a ``replay`` track and a
``migration`` track; an overlapped epoch's span sits UNDER the segment
span it hid behind, and each track's cursor advances by
``max(replay, migration)`` per row — so a track's total extent equals
``Fabric.pipeline_times()["overlapped_s"]`` for that expander exactly
(``fabric_track_totals`` calls the same ``pipeline_delivered_time`` on
the same row matrices; benchmarks/fabric_bench.py asserts the
reconciliation). Urgent/sync/drain epochs get their own zero-replay rows,
charged in full on the critical path, exactly as ``pipeline_times``
prices them.

Sharded runs (``fabric_info["shard_devices"]``) additionally get one
track per XLA *device*: the expanders a device owns run inside one jit
dispatch, so the device's span for row ``r`` is the max over its owned
expanders of that row's ``max(replay, migration)`` — the track extent
equals ``fabric_device_totals(rec)["device_s"]``, reconciled against
``Fabric.device_times()`` at rtol=1e-9 exactly like the per-expander
tracks against ``pipeline_times``. All of it is priced from the samples
the contracted boundary/drain fetches already carried — zero extra
syncs.

Events follow the Chrome ``trace_event`` JSON format: ``X`` complete
events (ts/dur in microseconds), ``M`` metadata naming processes and
tracks, ``C`` counter events for freelist headroom, ``i`` instants for
admissions. ``validate_trace`` checks the structural contract tests pin:
required keys per phase, per-track monotone timestamps, and proper span
nesting (overlapping spans on one track must nest).

jax and the timing model are imported lazily — ``repro.obs`` stays
importable on jax-free hosts (manifest stamping from the lint bench).
"""
from __future__ import annotations

import json
import pathlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.obs.manifest import manifest
from repro.obs.recorder import Recorder

_FABRIC_PID = 1
_SERVE_PID = 2
_DEVICE_TID = 1000     # per-XLA-device shard tracks start here


# ---------------------------------------------------------------------------
# Fabric rows: the SAME (replay, migration) delta matrices pipeline_times
# builds, reconstructed from the Recorder's samples.
# ---------------------------------------------------------------------------

def _fabric_rows(rec: Recorder) -> Optional[Tuple[np.ndarray, np.ndarray,
                                                  List[Dict[str, Any]]]]:
    """(replay [R,N,C], mig [R,N,C], row labels) mirroring
    ``Fabric.pipeline_times``: one row per replayed segment (overlapped
    epochs fold into the row of the segment they hid behind), then one
    zero-replay row per urgent/sync/drain epoch."""
    if not rec.segments:
        return None
    n_seg = len(rec.segments)
    deltas = np.stack([s["delta"] for s in rec.segments])
    n, c = deltas.shape[1], deltas.shape[2]
    sync_epochs = [e for e in rec.epochs if not e["overlapped"]]
    rows = n_seg + len(sync_epochs)
    replay = np.zeros((rows, n, c), np.float64)
    replay[:n_seg] = deltas
    mig = np.zeros_like(replay)
    labels: List[Dict[str, Any]] = [
        {"seg": s["seg"], "kinds": [], "moved": 0, "planned": 0}
        for s in rec.segments]
    labels += [{"seg": e["seg"], "kinds": [e["kind"]], "moved": e["moved"],
                "planned": e["planned"]} for e in sync_epochs]
    for e in rec.epochs:
        if e["overlapped"]:
            r = min(e["seg"], n_seg - 1)
            mig[r] += e["delta"]
            labels[r]["kinds"].append(e["kind"])
            labels[r]["moved"] += e["moved"]
            labels[r]["planned"] += e["planned"]
    for j, e in enumerate(sync_epochs):
        mig[n_seg + j] += e["delta"]
    return replay, mig, labels


def _fabric_lanes(rec: Recorder):
    from repro.simx import time as TM
    return TM.stack_devices(rec.fabric_info["devices"], xp=np)


def fabric_track_totals(rec: Recorder) -> Optional[Dict[str, np.ndarray]]:
    """Per-expander delivered seconds of the reconstructed rows, priced
    through the SAME ``pipeline_delivered_time`` call ``pipeline_times``
    uses — the reconciliation anchor: ``overlapped_s[e]`` equals the
    extent of expander ``e``'s tracks in the exported trace."""
    rows = _fabric_rows(rec)
    if rows is None:
        return None
    from repro.simx import time as TM
    replay, mig, _ = rows
    lanes = _fabric_lanes(rec)
    return {
        "overlapped_s": TM.pipeline_delivered_time(replay, mig, lanes,
                                                   overlapped=True),
        "sync_s": TM.pipeline_delivered_time(replay, mig, lanes,
                                             overlapped=False),
    }


def _expander_owners(n_expanders: int, n_devices: int) -> np.ndarray:
    """Block expander->device placement (int [N]) — the same layout as
    ``fabric.shard.device_of_expander``, duplicated here so the obs layer
    stays importable without jax."""
    return np.arange(n_expanders) // (n_expanders // n_devices)


def fabric_device_totals(rec: Recorder) -> Optional[Dict[str, np.ndarray]]:
    """Per-XLA-device delivered seconds on sharded runs: row ``r``'s
    device time is the max over owned expanders of ``max(replay, mig)``,
    summed over rows — the extent of the per-device tracks in the
    exported trace, and the quantity ``Fabric.device_times()`` computes
    from its own bookkeeping (the rtol=1e-9 reconciliation pins both).
    None on vmap runs (no ``shard_devices``) or before any segment."""
    info = rec.fabric_info or {}
    n_dev = info.get("shard_devices")
    rows = _fabric_rows(rec)
    if not n_dev or rows is None:
        return None
    from repro.simx import time as TM
    replay, mig, _ = rows
    lanes = _fabric_lanes(rec)
    cell = np.maximum(np.atleast_2d(TM.exec_time_vec(replay, lanes, xp=np)),
                      np.atleast_2d(TM.exec_time_vec(mig, lanes, xp=np)))
    owners = _expander_owners(info["n_expanders"], n_dev)
    return {
        "device_s": np.asarray([cell[:, owners == d].max(axis=1).sum()
                                for d in range(n_dev)], np.float64),
        "owners": owners,
    }


def _fabric_events(rec: Recorder) -> List[Dict[str, Any]]:
    rows = _fabric_rows(rec)
    if rows is None:
        return []
    from repro.core.engine import state as S
    from repro.simx import time as TM
    replay, mig, labels = rows
    n_seg = len(rec.segments)
    n = replay.shape[1]
    lanes = _fabric_lanes(rec)
    t_replay = np.atleast_2d(TM.exec_time_vec(replay, lanes, xp=np))
    t_mig = np.atleast_2d(TM.exec_time_vec(mig, lanes, xp=np))
    ev: List[Dict[str, Any]] = [
        {"ph": "M", "pid": _FABRIC_PID, "tid": 0, "name": "process_name",
         "args": {"name": "fabric"}}]
    for e in range(n):
        ev.append({"ph": "M", "pid": _FABRIC_PID, "tid": 2 * e,
                   "name": "thread_name",
                   "args": {"name": f"expander{e}/replay"}})
        ev.append({"ph": "M", "pid": _FABRIC_PID, "tid": 2 * e + 1,
                   "name": "thread_name",
                   "args": {"name": f"expander{e}/migration"}})
    n_dev = (rec.fabric_info or {}).get("shard_devices")
    owners = None
    if n_dev:
        owners = _expander_owners(n, n_dev)
        for d in range(n_dev):
            owned = np.nonzero(owners == d)[0]
            ev.append({"ph": "M", "pid": _FABRIC_PID, "tid": _DEVICE_TID + d,
                       "name": "thread_name",
                       "args": {"name": f"device{d}/shard "
                                f"(e{owned[0]}..e{owned[-1]})"}})
    cursor = np.zeros((n,), np.float64)        # per-expander clock, us
    dev_cursor = np.zeros((n_dev or 0,), np.float64)  # per-device clock, us
    for r in range(len(replay)):
        lab = labels[r]
        internal = S.traffic_vector(replay[r]).sum(axis=-1)
        host = replay[r][..., S.C_HOST_RD] + replay[r][..., S.C_HOST_WR]
        for e in range(n):
            tr_us = float(t_replay[r, e]) * 1e6
            tm_us = float(t_mig[r, e]) * 1e6
            if r < n_seg:
                ev.append({
                    "ph": "X", "pid": _FABRIC_PID, "tid": 2 * e,
                    "ts": float(cursor[e]), "dur": tr_us,
                    "name": f"seg {lab['seg']}",
                    "args": {"internal_64B": int(internal[e]),
                             "host_64B": int(host[e])}})
            if tm_us > 0.0:
                kinds = "+".join(lab["kinds"]) or "overlapped"
                ev.append({
                    "ph": "X", "pid": _FABRIC_PID, "tid": 2 * e + 1,
                    "ts": float(cursor[e]), "dur": tm_us,
                    "name": f"epoch[{kinds}]@seg{lab['seg']}",
                    "args": {"moved": lab["moved"],
                             "planned": lab["planned"]}})
            cursor[e] += max(tr_us, tm_us)
        if owners is not None:
            row_us = np.maximum(t_replay[r], t_mig[r]) * 1e6
            kinds = "+".join(lab["kinds"])
            name = f"seg {lab['seg']}" if r < n_seg else \
                f"epoch[{kinds}]@seg{lab['seg']}"
            for d in range(n_dev):
                dur = float(np.max(row_us[owners == d]))
                ev.append({
                    "ph": "X", "pid": _FABRIC_PID, "tid": _DEVICE_TID + d,
                    "ts": float(dev_cursor[d]), "dur": dur, "name": name,
                    "args": {"moved": lab["moved"],
                             "planned": lab["planned"]}})
                dev_cursor[d] += dur
        if r < n_seg and rec.segments[r]["free_units"] is not None:
            ev.append({
                "ph": "C", "pid": _FABRIC_PID, "tid": 0,
                "ts": float(np.max(cursor)), "name": "free_units",
                "args": {f"e{e}": int(v) for e, v in
                         enumerate(rec.segments[r]["free_units"])}})
    return ev


# ---------------------------------------------------------------------------
# Serving trace: one steps track (span per decode step, duration = the
# step's sync round trip + the motion its admission performed) and one
# motion track per expander (park/resume payload spans priced by
# serve_motion_time on that expander's own DeviceConfig).
# ---------------------------------------------------------------------------

def _serve_events(rec: Recorder) -> List[Dict[str, Any]]:
    if not rec.steps and not rec.serve_events:
        return []
    from repro.simx import time as TM
    n_exp = rec.serve_info["n_expanders"] if rec.serve_info else 1
    devs = TM.resolve_fleet(None, n_exp)
    sync_us = max(d.cxl_lat for d in devs) * 1e6
    ev: List[Dict[str, Any]] = [
        {"ph": "M", "pid": _SERVE_PID, "tid": 0, "name": "process_name",
         "args": {"name": "serve"}},
        {"ph": "M", "pid": _SERVE_PID, "tid": 1, "name": "thread_name",
         "args": {"name": "steps"}}]
    for e in range(n_exp):
        ev.append({"ph": "M", "pid": _SERVE_PID, "tid": 10 + e,
                   "name": "thread_name",
                   "args": {"name": f"expander{e}/motion"}})
    by_step: Dict[int, List[Dict[str, Any]]] = {}
    for s_ev in rec.serve_events:
        by_step.setdefault(int(s_ev["step"]), []).append(s_ev)
    cursor = 0.0
    exp_cursor = [0.0] * n_exp
    for i in range(len(rec.steps) + 1):
        start = cursor
        motion_us = 0.0
        for s_ev in by_step.get(i, ()):
            if s_ev["type"] == "admission":
                ev.append({"ph": "i", "pid": _SERVE_PID, "tid": 1,
                           "ts": start, "s": "t",
                           "name": f"admit x{s_ev['n']} "
                                   f"(bucket {s_ev['bucket']})"})
                continue
            e = int(s_ev["expander"]) % n_exp
            pb = s_ev["bytes"] if s_ev["type"] == "preempt" else 0
            rb = s_ev["bytes"] if s_ev["type"] == "resume" else 0
            dur = float(TM.serve_motion_time(float(pb), float(rb),
                                             devs[e], np)) * 1e6
            ts = max(exp_cursor[e], start)
            ev.append({"ph": "X", "pid": _SERVE_PID, "tid": 10 + e,
                       "ts": ts, "dur": dur, "name": s_ev["type"],
                       "args": {k: v for k, v in s_ev.items()
                                if k not in ("type", "step")}})
            exp_cursor[e] = ts + dur
            motion_us += dur
        if i < len(rec.steps):
            st = rec.steps[i]
            dur = sync_us + motion_us
            ev.append({"ph": "X", "pid": _SERVE_PID, "tid": 1,
                       "ts": start, "dur": dur,
                       "name": f"step {st['step']}",
                       "args": {"active": len(st["active"]),
                                "done": len(st["done"]),
                                "max_pos": st["max_pos"]}})
            cursor = start + dur
    return ev


# ---------------------------------------------------------------------------
# Public API.
# ---------------------------------------------------------------------------

def build_trace(rec: Recorder) -> Dict[str, Any]:
    """Chrome/Perfetto ``trace_event`` JSON for everything recorded."""
    events = _fabric_events(rec) + _serve_events(rec)
    other: Dict[str, Any] = {"manifest": manifest()}
    totals = fabric_track_totals(rec)
    if totals is not None:
        other["fabric_overlapped_s"] = [float(t)
                                        for t in totals["overlapped_s"]]
        other["fabric_sync_s"] = [float(t) for t in totals["sync_s"]]
    dev_totals = fabric_device_totals(rec)
    if dev_totals is not None:
        other["fabric_device_s"] = [float(t)
                                    for t in dev_totals["device_s"]]
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": other}


def write_trace(rec: Recorder, path) -> Dict[str, Any]:
    trace = build_trace(rec)
    errs = validate_trace(trace)
    if errs:
        raise ValueError(f"invalid trace: {errs[:5]}")
    pathlib.Path(path).write_text(json.dumps(trace))
    return trace


def metrics_snapshot(rec: Recorder, **meta: Any) -> Dict[str, Any]:
    """The ``metrics.json`` payload: manifest + registry snapshot + the
    per-domain roll-ups benches fold into BENCH_*.json."""
    out: Dict[str, Any] = {"manifest": manifest(**meta),
                           "metrics": rec.metrics.snapshot()}
    if rec.fabric_info is not None:
        fab: Dict[str, Any] = {
            "n_expanders": rec.fabric_info["n_expanders"],
            "migration": rec.fabric_info["migration"],
            "pipeline_depth": rec.fabric_info["pipeline_depth"],
            "segments": len(rec.segments),
            "epochs": len(rec.epochs),
            "epoch_kinds": sorted({e["kind"] for e in rec.epochs}),
            "pages_moved": sum(e["moved"] for e in rec.epochs),
        }
        totals = fabric_track_totals(rec)
        if totals is not None:
            fab["overlapped_s"] = [float(t) for t in totals["overlapped_s"]]
            fab["sync_s"] = [float(t) for t in totals["sync_s"]]
        if rec.fabric_info.get("shard_devices"):
            fab["shard_devices"] = rec.fabric_info["shard_devices"]
            dev_totals = fabric_device_totals(rec)
            if dev_totals is not None:
                fab["device_s"] = [float(t)
                                   for t in dev_totals["device_s"]]
        out["fabric"] = fab
    if rec.cells:
        out["simx"] = {"cells": rec.cells}
    if rec.serve_info is not None:
        out["serve"] = {
            "lanes": rec.serve_info["lanes"],
            "n_expanders": rec.serve_info["n_expanders"],
            "steps": len(rec.steps),
            "events": len(rec.serve_events),
        }
    return out


def write_metrics(rec: Recorder, path, **meta: Any) -> Dict[str, Any]:
    snap = metrics_snapshot(rec, **meta)
    pathlib.Path(path).write_text(json.dumps(snap, indent=1, sort_keys=True))
    return snap


def fabric_summary_table(rec: Recorder) -> str:
    """Human-readable per-segment summary (the --trace stdout table):
    traffic, migration overlap and pricing per pipeline row."""
    rows = _fabric_rows(rec)
    if rows is None:
        return "(no fabric segments recorded)"
    from repro.core.engine import state as S
    from repro.simx import time as TM
    replay, mig, labels = rows
    n_seg = len(rec.segments)
    lanes = _fabric_lanes(rec)
    t_replay = np.atleast_2d(TM.exec_time_vec(replay, lanes, xp=np))
    t_mig = np.atleast_2d(TM.exec_time_vec(mig, lanes, xp=np))
    lines = [f"{'row':>4} {'seg':>4} {'kind':<12} {'internal64B':>12} "
             f"{'host64B':>10} {'replay_ms':>10} {'mig_ms':>8} "
             f"{'moved':>6}"]
    for r, lab in enumerate(labels):
        internal = int(S.traffic_vector(replay[r]).sum())
        host = int((replay[r][..., S.C_HOST_RD] +
                    replay[r][..., S.C_HOST_WR]).sum())
        kind = "+".join(lab["kinds"]) if lab["kinds"] else \
            ("replay" if r < n_seg else "?")
        lines.append(
            f"{r:>4} {lab['seg']:>4} {kind:<12} {internal:>12} {host:>10} "
            f"{float(t_replay[r].max()) * 1e3:>10.3f} "
            f"{float(t_mig[r].max()) * 1e3:>8.3f} {lab['moved']:>6}")
    totals = fabric_track_totals(rec)
    over = ", ".join(f"e{e}={float(t) * 1e3:.3f}ms"
                     for e, t in enumerate(totals["overlapped_s"]))
    lines.append(f"overlapped totals: {over}")
    return "\n".join(lines)


def validate_trace(trace: Any) -> List[str]:
    """Structural validation of a trace_event JSON dict. Returns error
    strings (empty = valid): known phases, required keys, non-negative
    ts/dur, per-track monotone timestamps, and span nesting (overlapping
    ``X`` spans on one track must be properly contained)."""
    errs: List[str] = []
    if not isinstance(trace, dict) or \
            not isinstance(trace.get("traceEvents"), list):
        return ["traceEvents missing or not a list"]
    eps = 1e-6
    tracks: Dict[Tuple[Any, Any], List[Tuple[float, float, str]]] = {}
    for i, ev in enumerate(trace["traceEvents"]):
        ph = ev.get("ph")
        if ph not in ("X", "M", "C", "i"):
            errs.append(f"event {i}: unknown phase {ph!r}")
            continue
        if ph != "M" and "ts" not in ev:
            errs.append(f"event {i}: missing ts")
            continue
        if ph == "X":
            missing = [k for k in ("pid", "tid", "ts", "dur", "name")
                       if k not in ev]
            if missing:
                errs.append(f"event {i}: missing {missing}")
                continue
            if ev["ts"] < 0 or ev["dur"] < 0:
                errs.append(f"event {i} ({ev['name']}): negative ts/dur")
            tracks.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["dur"]), str(ev["name"])))
    for key, spans in tracks.items():
        last_ts = -np.inf
        stack: List[float] = []          # open-span end timestamps
        for ts, dur, name in spans:      # emitted order == track order
            if ts < last_ts - eps:
                errs.append(f"track {key}: ts not monotone at {name!r}")
            last_ts = max(last_ts, ts)
            while stack and stack[-1] <= ts + eps:
                stack.pop()
            end = ts + dur
            if stack and end > stack[-1] + eps:
                errs.append(f"track {key}: span {name!r} crosses its "
                            f"enclosing span")
            stack.append(end)
    return errs
