"""Deterministic, resumable, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — the property the
fault-tolerance story rests on: any rank (or a replacement after a node
failure) can regenerate exactly its shard of any step with no coordination,
and straggler backup workers can race on the same shard safely.

The token stream is a mixture designed to exercise the IBEX compressor the
way real corpora exercise LZ: zero runs (padding), narrow-range spans
(repetitive text), and full-vocab spans (high entropy) — giving pages with a
realistic mix of zero / 4-bit / 8-bit / raw blocks.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import ModelConfig


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    zero_frac: float = 0.1          # fraction of padding (zero-run) spans
    narrow_frac: float = 0.5        # narrow-range "repetitive" spans
    narrow_width: int = 64
    span: int = 64


@functools.partial(jax.jit, static_argnums=(1, 2, 3, 4))
def _synth_tokens(key, batch: int, seq: int, vocab: int,
                  dcfg: DataConfig) -> jnp.ndarray:
    nspan = -(-seq // dcfg.span)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    kind = jax.random.uniform(k1, (batch, nspan))
    base = jax.random.randint(k2, (batch, nspan), 0, max(vocab - dcfg.narrow_width, 1))
    narrow = base[:, :, None] + jax.random.randint(
        k3, (batch, nspan, dcfg.span), 0, dcfg.narrow_width)
    wide = jax.random.randint(k4, (batch, nspan, dcfg.span), 0, vocab)
    zeros = jnp.zeros_like(wide)
    spans = jnp.where(kind[:, :, None] < dcfg.zero_frac, zeros,
                      jnp.where(kind[:, :, None] < dcfg.zero_frac + dcfg.narrow_frac,
                                narrow, wide))
    return spans.reshape(batch, nspan * dcfg.span)[:, :seq] % vocab


def make_batch(cfg: ModelConfig, step: int, *, global_batch: int, seq_len: int,
               shard: int = 0, num_shards: int = 1,
               dcfg: DataConfig = DataConfig()) -> Dict[str, jnp.ndarray]:
    """Batch for (step, shard). Labels are next-token shifted."""
    assert global_batch % num_shards == 0
    b = global_batch // num_shards
    key = jax.random.fold_in(jax.random.fold_in(
        jax.random.PRNGKey(dcfg.seed), step), shard)
    tokens = _synth_tokens(key, b, seq_len + 1, cfg.vocab_size, dcfg)
    batch = {"tokens": tokens[:, :-1],
             "labels": tokens[:, 1:].astype(jnp.int32)}
    if cfg.frontend != "none":
        ekey = jax.random.fold_in(key, 7)
        batch["embeds"] = (jax.random.normal(
            ekey, (b, seq_len, cfg.d_model)) * 0.02).astype(jnp.bfloat16)
    return batch


def batch_iterator(cfg: ModelConfig, *, start_step: int, global_batch: int,
                   seq_len: int, shard: int = 0, num_shards: int = 1,
                   dcfg: DataConfig = DataConfig()) -> Iterator[Dict[str, jnp.ndarray]]:
    step = start_step
    while True:
        yield make_batch(cfg, step, global_batch=global_batch, seq_len=seq_len,
                         shard=shard, num_shards=num_shards, dcfg=dcfg)
        step += 1
