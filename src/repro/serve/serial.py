"""Per-lane reference serving engine — the batched scheduler's baseline.

This is the pre-batching engine shape, kept deliberately: one prefill
compile+sync per request (no length bucketing), per-lane Python loops in
``step`` with a full-logits fetch every step, and no lane shadowing (resume
drops the parked copy, so every re-preempt pays the full demotion again).
``benchmarks/serve_bench.py`` serves the same workload through this and
through ``serve.engine.Engine`` and records the tokens/sec and preempt-bytes
gap; tests use it as a semantics reference (same model, same decode step —
generations must match).

The correctness fixes are shared with the batched engine (via
``_EngineBase``): prompts are prefilled at their exact length (no
left-padding — short prompts used to attend to garbage KV at the padded
positions), and preemption quantizes the hot ring on device before parking,
counting the compressed bytes honestly.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.engine import (DONE, PREEMPTED, RUNNING, Request,
                                _EngineBase, _lane_install, _lane_slice)


class SerialEngine(_EngineBase):
    """Per-lane host-loop engine (see module docstring)."""

    # -- scheduling ---------------------------------------------------------

    def _admit(self) -> None:
        started = set()
        while self.queue:
            lane = self._free_lane()
            if lane is None:
                break
            started.add(lane)
            self._start(self.queue.pop(0), lane)
        # at most one preemption per engine step (same rule as the batched
        # engine: an unbounded loop would never drain the queue it refills);
        # lanes started this step are not eligible victims — the batched
        # engine's rule, matched here so both engines preempt the same
        # schedule and the token-for-token parity contract holds by
        # construction, not by quantization luck
        if self.queue:
            occupied = np.array([r is not None and i not in started
                                 for i, r in enumerate(self.lane_req)])
            victim, new_ref = self._victim_policy.select_mask(occupied,
                                                              self._ref)
            if victim is not None:
                self._ref = new_ref
                self._preempt(victim)
                self._start(self.queue.pop(0), victim)

    def _start(self, rid: int, lane: int) -> None:
        req = self.requests[rid]
        if req.parked is not None:
            self._resume(req, lane)
            return
        # fresh request: one exact-length prefill per request — a compile
        # per distinct prompt length and a sync per request (the baseline
        # cost the batched engine's bucketing removes)
        S = len(req.prompt)
        batch = {"tokens": jnp.asarray(np.asarray(req.prompt,
                                                  np.int32)[None, :])}
        if self.cfg.frontend != "none":
            batch["embeds"] = jnp.zeros((1, S, self.cfg.d_model), jnp.bfloat16)
        toks, sub = self._prefill_fn(self.params, batch,
                                     jnp.asarray([S], jnp.int32))
        self.cache = _lane_install(self.cache, lane, _lane_slice(sub, 0))
        self.counters["prefill_batches"] += 1
        tok = int(self._fetch(toks, "admit_syncs")[0])   # a sync per request
        req.generated.append(tok)
        req.pos = S
        req.lane = lane
        req.state = RUNNING
        self._ref[lane] = True
        self.lane_req[lane] = rid
        self.counters["promotions"] += 1
        if req.max_new_tokens <= 1 or req.pos >= self.max_len - 1:
            req.state = DONE
            req.lane = -1
            self.lane_req[lane] = None

    def _preempt(self, lane: int) -> None:
        """Demote and park (shared _park_lane). No shadow survives in the
        baseline: parked is dropped on resume, so this always pays the full
        compressed payload."""
        rid = self.lane_req[lane]
        req = self.requests[rid]
        self._park_lane(req, lane)
        self.counters["demotions"] += 1
        req.state = PREEMPTED
        req.lane = -1
        self.lane_req[lane] = None
        self._ref[lane] = False
        self.queue.append(rid)

    def _resume(self, req: Request, lane: int) -> None:
        self._install_parked(req, lane)
        self._drop_park(req)           # no shadow kept: baseline behavior
        req.shadow_pos = 0
        self._ref[lane] = True

    # -- decode step ---------------------------------------------------------

    def step(self) -> bool:
        """One engine iteration, per-lane host bookkeeping: a full-logits
        fetch plus a Python loop with one np.argmax per lane."""
        self._admit()
        active = [(lane, rid) for lane, rid in enumerate(self.lane_req)
                  if rid is not None]
        if not active:
            return bool(self.queue)
        tokens = np.zeros((self.lanes,), np.int32)
        pos = np.zeros((self.lanes,), np.int32)
        for lane, rid in active:
            req = self.requests[rid]
            tokens[lane] = req.generated[-1] if req.generated else 0
            pos[lane] = req.pos
        kwargs = {}
        if self.cfg.frontend != "none":
            kwargs["embeds"] = jnp.zeros((self.lanes, self.cfg.d_model),
                                         jnp.bfloat16)
        logits, self.cache = self._decode_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos),
            **kwargs)
        self.counters["steps"] += 1
        logits = self._fetch(logits, "step_syncs")   # full-logits host sync
        for lane, rid in active:
            req = self.requests[rid]
            req.pos += 1
            self._ref[lane] = True
            tok = int(np.argmax(logits[lane]))
            req.generated.append(tok)
            self.counters["tokens"] += 1
            if len(req.generated) >= req.max_new_tokens or \
                    req.pos >= self.max_len - 1:
                req.state = DONE
                req.lane = -1
                self.lane_req[lane] = None
        return True
