"""Continuous-batching serving engine with IBEX-managed KV residency.

The engine is the request-granular face of the paper's pool:

  * running requests occupy decode *lanes* (batch slots of the jit'd
    decode_step) — their recent tokens sit uncompressed in the hot ring
    (promoted region), older tokens in the quantized region;
  * a **preempted** request is *demoted*: its hot ring is quantized into the
    codes region on device (always a clean demotion — KV is append-only) and
    only the compressed codes + scales are parked on the host;
  * **resume** is a promotion — the lane adopts the parked codes (cold_len =
    full length, empty ring) and decode reads them directly through the fused
    dequant attention: zero KV bytes are ever dequantized on promotion;
  * **shadowed lanes** (§4.5 at request granularity): the parked copy is
    *kept* after resume, and — because KV is append-only — its prefix stays
    valid forever. ``Request.shadow_pos`` records how many tokens it covers;
    a re-preempt moves only the suffix generated since the last park
    (``pos - shadow_pos`` tokens), and an untouched resumed request moves
    **zero bytes**: the shadow is simply re-validated, like ``shadow_valid``
    pages in ``core/engine/ops.py``. Demotion cost is proportional to new
    tokens, never to context length;
  * victim selection is the §4.4 second-chance sweep over lanes (reference
    bit = "generated a token since last sweep"), vectorized over all lanes
    in one pass (``SecondChanceLanes.select_mask``).

**Host-sync contract.** Lane bookkeeping (last token, position, reference
bit, active mask, remaining budget) lives in device arrays and is advanced
*inside* the jitted engine step — argmax, position advance, done detection
and reference-bit updates all happen on device. The host performs exactly
ONE device sync per decode step (``counters["step_syncs"]``): a single
``device_get`` of the (tokens, done, ref, pos) quad that drives per-request
Python bookkeeping (and, when a ``repro.obs.Recorder`` is attached, the
per-step telemetry sample — piggybacked, zero extra syncs). Admission-path
syncs (one per prefill bucket, one per demotion fetch) are counted
separately in ``counters["admit_syncs"]``.

**Prefill batching.** Fresh requests admitted in the same engine step are
prefilled together, grouped into power-of-two length buckets (right-padded;
``models/decode.prefill``'s ``lens`` argument keeps padded positions out of
the cache's valid range, so a padded row decodes identically to an unpadded
one). Attention-family models bucket freely; ssm/hybrid models group by
exact length only (right-padding would pollute the recurrent state).

Scheduling: FIFO admission, at most one preemption per engine step. All
cache motion is counted in ``self.counters`` (bytes and events) for
benchmarks/serve_bench.py: parked bytes are the *compressed* payload
(codes + scales) of the moved tokens — the bf16 hot ring is quantized
before parking, never moved raw.

``serve.serial.SerialEngine`` keeps the per-lane host-loop implementation
(per-request prefill, one sync per lane per step, no shadow) as the
benchmark baseline; both engines share ``_EngineBase``.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.contracts import sync_contract
from repro.common.types import ModelConfig, ServeConfig
from repro.common.utils import next_pow2 as _next_pow2
from repro.core.compressor import quantize_blocks_fast
from repro.core.engine.policy import SecondChanceLanes
from repro.models import decode as D
from repro.models import transformer as T

WAITING, RUNNING, PREEMPTED, DONE = "waiting", "running", "preempted", "done"

# bf16 hot-ring leaves: quantized into the codes region on demotion, zeroed
# on resume — never parked, never moved
HOT_KEYS = ("k_hot", "v_hot", "lat_hot")


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: str = WAITING
    generated: List[int] = field(default_factory=list)
    lane: int = -1
    pos: int = 0                      # next position to write
    parked: Optional[Dict[str, Any]] = None   # demoted KV (codes+scales only)
    # shadow coverage (§4.5): tokens [0, shadow_pos) of ``parked`` match the
    # device KV bit-for-bit — KV is append-only, so the prefix never goes
    # stale. A preempt at pos == shadow_pos moves zero bytes; at
    # pos > shadow_pos it moves only the (pos - shadow_pos)-token suffix.
    shadow_pos: int = 0
    # fabric: expander whose pool region holds the parked payload/shadow
    # (-1 = never parked). A resume onto a lane striped to a different
    # expander moves the payload across the fabric (counted).
    expander: int = -1


# ---------------------------------------------------------------------------
# Device-side engine ops (jitted once per (cfg, scfg, max_len) via
# _compiled_fns; shared by every Engine/SerialEngine instance).
# ---------------------------------------------------------------------------

def _engine_step_impl(params, cache, state, embeds=None, *, cfg: ModelConfig,
                      scfg: ServeConfig, max_len: int):
    """One decode step over all lanes, lane bookkeeping advanced on device.

    state: {tok,pos,remaining int32[lanes]; active,ref bool[lanes]}.
    Returns (cache, new_state, done[lanes]) — the host fetches
    (new_state.tok, done, new_state.ref) in one sync."""
    logits, cache = D.decode_step(params, cache, state["tok"], state["pos"],
                                  cfg, scfg, embeds)
    active = state["active"]
    tok = jnp.where(active, jnp.argmax(logits, axis=-1).astype(jnp.int32),
                    state["tok"])
    pos = state["pos"] + active
    remaining = state["remaining"] - active
    done = active & ((remaining <= 0) | (pos >= max_len - 1))
    new_state = {"tok": tok, "pos": pos, "remaining": remaining,
                 "active": active & ~done, "ref": state["ref"] | active}
    return cache, new_state, done


def _prefill_impl(params, batch, lens, *, cfg: ModelConfig, scfg: ServeConfig,
                  max_len: int):
    """Bucketed prefill: (first tokens int32[B], cache). argmax happens on
    device so admission costs one fetch of B scalars per bucket."""
    logits, cache = D.prefill(params, batch, cfg, scfg, max_len, lens=lens)
    return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache


def _ring_to_codes(codes, scales, hot, cold_len, pos, W: int, bits: int,
                   impl: str = "auto"):
    """Quantize the live ring tokens (positions [max(cold_len, pos-W), pos))
    into the codes region — the device half of a lane demotion. Mirrors the
    streaming eviction in ``models/decode._evict_to_codes`` but for the whole
    ring at once. codes [Lyr,T,...], scales [Lyr,T,...], hot [Lyr,W,...,D].
    ``impl`` routes the quantize through the Pallas qpack kernel on TPU."""
    T_ = codes.shape[1]
    D_ = hot.shape[-1]
    c, s = quantize_blocks_fast(hot.astype(jnp.float32), bits, D_, impl=impl)
    t = jnp.arange(T_)
    sel = (t[None, :] >= cold_len[:, None]) & (t[None, :] >= pos - W) & \
        (t[None, :] < pos)                                     # [Lyr, T]
    slot = t % W
    gc = jnp.take(c, slot, axis=1)                 # slot content per position
    gs = jnp.take(s[..., 0], slot, axis=1)
    selc = sel.reshape(sel.shape + (1,) * (codes.ndim - 2))
    sels = sel.reshape(sel.shape + (1,) * (scales.ndim - 2))
    return jnp.where(selc, gc, codes), jnp.where(sels, gs, scales)


def _demote_lane_impl(lane_cache, pos, *, scfg: ServeConfig):
    """Clean-demote one lane's cache slice: every ring token is quantized
    into the codes region and cold_len advances to ``pos``; the hot ring
    becomes dead weight (dropped by the host before parking). SSM state has
    no compressed form and passes through raw (counted honestly)."""
    W, bits = scfg.hot_window, scfg.kv_rate_bits
    impl = getattr(scfg, "quantize_impl", "auto")
    out = dict(lane_cache)
    if "k_codes" in out:
        out["k_codes"], out["k_scales"] = _ring_to_codes(
            out["k_codes"], out["k_scales"], out["k_hot"], out["cold_len"],
            pos, W, bits, impl)
        out["v_codes"], out["v_scales"] = _ring_to_codes(
            out["v_codes"], out["v_scales"], out["v_hot"], out["cold_len"],
            pos, W, bits, impl)
    if "lat_codes" in out:
        out["lat_codes"], out["lat_scales"] = _ring_to_codes(
            out["lat_codes"], out["lat_scales"], out["lat_hot"],
            out["cold_len"], pos, W, bits, impl)
    if "cold_len" in out:
        out["cold_len"] = jnp.maximum(out["cold_len"], pos)
    return out


@functools.lru_cache(maxsize=32)
def _compiled_fns(cfg: ModelConfig, scfg: ServeConfig, max_len: int):
    """Engine-shared jitted fns, cached on the hashable configs so
    constructing N engines (tests, replicas) compiles once."""
    step = jax.jit(functools.partial(_engine_step_impl, cfg=cfg, scfg=scfg,
                                     max_len=max_len))
    pre = jax.jit(functools.partial(_prefill_impl, cfg=cfg, scfg=scfg,
                                    max_len=max_len))
    demote = jax.jit(functools.partial(_demote_lane_impl, scfg=scfg))
    decode = jax.jit(functools.partial(D.decode_step, cfg=cfg, scfg=scfg))
    return step, pre, demote, decode


# ---------------------------------------------------------------------------
# Lane slice/install (batch axis 1; hybrid ssm leaves carry a period axis
# before batch, so the ssm subtree is sliced on its own axis).
# ---------------------------------------------------------------------------

def _ssm_batch_axis(cache) -> int:
    return 2 if "k_codes" in cache else 1     # hybrid: [G, period, B, ...]


def _lane_slice(cache, lane: int):
    ax = _ssm_batch_axis(cache)
    out = {}
    for k, v in cache.items():
        if k == "ssm":
            out[k] = jax.tree_util.tree_map(
                lambda a: jnp.take(a, lane, axis=ax), v)
        else:
            out[k] = v[:, lane]
    return out


def _lane_install(cache, lane: int, lane_cache):
    ax = _ssm_batch_axis(cache)
    out = {}
    for k, v in cache.items():
        if k == "ssm":
            out[k] = jax.tree_util.tree_map(
                lambda a, s: jnp.moveaxis(
                    jnp.moveaxis(a, ax, 0).at[lane].set(
                        s.astype(a.dtype)), 0, ax),
                v, lane_cache[k])
        else:
            out[k] = v.at[:, lane].set(lane_cache[k].astype(v.dtype))
    return out


def _lanes_install(cache, lanes: jnp.ndarray, sub_cache):
    """Install a prefilled sub-batch (rows aligned with ``lanes``) into the
    engine cache in one batched scatter per leaf."""
    ax = _ssm_batch_axis(cache)
    out = {}
    for k, v in cache.items():
        if k == "ssm":
            out[k] = jax.tree_util.tree_map(
                lambda a, s: jnp.moveaxis(
                    jnp.moveaxis(a, ax, 0).at[lanes].set(
                        jnp.moveaxis(s.astype(a.dtype), ax, 0)), 0, ax),
                v, sub_cache[k])
        else:
            out[k] = v.at[:, lanes].set(sub_cache[k].astype(v.dtype))
    return out


def _moved_bytes(parked: Dict[str, Any], n_tokens: int, max_len: int) -> int:
    """Bytes a park/restore actually moves: the compressed payload (codes +
    scales) of ``n_tokens`` tokens, plus raw recurrent state for ssm/hybrid
    in full (it has no compressed form and no append-only prefix). The
    counter is the modeled CXL traffic of the motion — the full-length host
    buffers are an implementation detail."""
    total = 0
    for k, v in parked.items():
        if k == "ssm":
            total += sum(int(a.nbytes)
                         for a in jax.tree_util.tree_leaves(v))
        elif k == "cold_len":
            continue
        else:
            total += (int(v.nbytes) // max_len) * min(int(n_tokens), max_len)
    return total


# ---------------------------------------------------------------------------
# Shared engine chassis: request/queue/lane bookkeeping, park/restore
# mechanics, sync counting. Subclasses decide scheduling + decode structure.
# ---------------------------------------------------------------------------

class _EngineBase:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params,
                 max_len: int = 2048, seed: int = 0, obs=None):
        self.cfg, self.scfg = cfg, scfg
        self.params = params
        self.max_len = max_len
        self.lanes = scfg.max_running
        self.cache = D.init_cache(cfg, scfg, self.lanes, max_len)
        self.lane_req: List[Optional[int]] = [None] * self.lanes
        self.requests: Dict[int, Request] = {}
        self.queue: List[int] = []
        self._next_rid = 0
        # victim selection goes through the same §4.4 policy shape as the
        # pool's clock engine, vectorized over all lanes (engine/policy.py)
        self._victim_policy = SecondChanceLanes(self.lanes)
        self._ref = np.zeros((self.lanes,), bool)
        self.counters = {"promotions": 0, "demotions": 0, "preempt_bytes": 0,
                         "resume_bytes": 0, "steps": 0, "tokens": 0,
                         "step_syncs": 0, "admit_syncs": 0,
                         "shadow_repreempts": 0, "prefill_batches": 0,
                         "cross_expander_resumes": 0}
        # fabric-aware serving: lanes stripe across the expander pool fabric;
        # preempted payloads park on (and are charged to) their lane's
        # expander, and victim selection balances parked load across
        # expanders (see SecondChanceLanes.select_mask groups)
        self.n_expanders = max(int(getattr(scfg, "n_expanders", 1)), 1)
        self.lane_expander = np.arange(self.lanes) % self.n_expanders
        self.expander_stats = {
            "parked": np.zeros((self.n_expanders,), np.int64),
            "preempt_bytes": np.zeros((self.n_expanders,), np.int64),
            "resume_bytes": np.zeros((self.n_expanders,), np.int64),
        }
        # n_expanders is scheduling-only (never read by the jitted model
        # code): normalize it out of the compile key so a fabric-striped
        # engine shares compiled programs with the single-expander one
        (self._step_fn, self._prefill_fn, self._demote_fn,
         self._decode_fn) = _compiled_fns(
            cfg, dataclasses.replace(scfg, n_expanders=1), max_len)
        # telemetry (repro.obs.Recorder, DESIGN.md §16): samples ride the
        # contracted fetches the engine already performs — attaching a
        # recorder changes neither sync counts nor any device state
        self.obs = obs
        if obs is not None:
            obs.attach_serve(self)

    # -- client API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        if not 1 <= len(prompt) <= self.max_len - 1:
            raise ValueError(f"prompt length {len(prompt)} outside "
                             f"[1, {self.max_len - 1}]")
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new_tokens)
        self.queue.append(rid)
        return rid

    def result(self, rid: int) -> List[int]:
        return self.requests[rid].generated

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                return

    def step(self) -> bool:
        raise NotImplementedError

    # -- host <-> device ----------------------------------------------------

    def _fetch(self, tree, kind: str):
        """The ONLY place device values cross to the host. Each call is one
        blocking sync, counted per path (step vs admission)."""
        self.counters[kind] += 1
        return jax.device_get(tree)

    # -- delivered-time accounting (DESIGN.md §12) ---------------------------

    def modeled_time(self, devices=None) -> Dict[str, Any]:
        """Convert the engine's preempt/resume byte and host-sync counters
        into modeled seconds (simx.time.serve_modeled_time): per-expander
        payload motion priced by each expander's own DeviceConfig
        (bottleneck across the fabric stripe), plus one CXL round trip per
        host sync. ``modeled_s_per_step`` is the figure of merit —
        serial-vs-batched and fabric-striped serving compare in seconds,
        not just tokens/sec."""
        from repro.simx import time as TM
        devs = TM.resolve_fleet(devices, self.n_expanders)
        return TM.serve_modeled_time(self.counters, self.expander_stats,
                                     devs)

    # -- shared mechanics ---------------------------------------------------

    def _free_lane(self) -> Optional[int]:
        for i, r in enumerate(self.lane_req):
            if r is None:
                return i
        return None

    def _drop_park(self, req: Request) -> None:
        """Release a request's parked payload/shadow (done, or baseline
        resume) and its expander's park slot."""
        if req.parked is not None and req.expander >= 0:
            self.expander_stats["parked"][req.expander] -= 1
        req.parked = None

    def _park_lane(self, req: Request, lane: int) -> None:
        """Demote the lane on device (quantize ring -> codes) and park the
        compressed payload on the lane's expander, charging only the suffix
        not already covered by the request's shadow."""
        covered = req.shadow_pos if req.parked is not None else 0
        exp = int(self.lane_expander[lane])
        if req.parked is None or req.expander != exp:
            if req.parked is not None and req.expander >= 0:
                self.expander_stats["parked"][req.expander] -= 1
            self.expander_stats["parked"][exp] += 1
        lane_cache = _lane_slice(self.cache, lane)
        demoted = self._demote_fn(lane_cache, jnp.asarray(req.pos, jnp.int32))
        kept = {k: v for k, v in demoted.items() if k not in HOT_KEYS}
        req.parked = self._fetch(kept, "admit_syncs")
        req.shadow_pos = req.pos
        req.expander = exp
        moved = _moved_bytes(req.parked, req.pos - covered, self.max_len)
        self.counters["preempt_bytes"] += moved
        self.expander_stats["preempt_bytes"][exp] += moved

    def _install_parked(self, req: Request, lane: int) -> None:
        """Promotion: install parked codes into the lane (empty ring, full
        cold_len); no decompression happens (fused attention reads codes
        directly) — zero KV bytes dequantized."""
        lane_tree = {}
        for k, a in self.cache.items():
            if k in HOT_KEYS:
                lane_tree[k] = jnp.zeros(a.shape[:1] + a.shape[2:], a.dtype)
            elif k == "ssm":
                lane_tree[k] = jax.tree_util.tree_map(jnp.asarray,
                                                      req.parked[k])
            else:
                lane_tree[k] = jnp.asarray(req.parked[k])
        self.cache = _lane_install(self.cache, lane, lane_tree)
        moved = _moved_bytes(req.parked, req.pos, self.max_len)
        self.counters["resume_bytes"] += moved
        exp = int(self.lane_expander[lane])
        self.expander_stats["resume_bytes"][exp] += moved
        cross = req.expander >= 0 and req.expander != exp
        if cross:
            # the parked payload crosses the fabric to the new lane's
            # expander; the shadow follows it (its prefix stays valid —
            # append-only KV does not care which expander holds it)
            self.counters["cross_expander_resumes"] += 1
            self.expander_stats["parked"][req.expander] -= 1
            self.expander_stats["parked"][exp] += 1
            req.expander = exp
        self.counters["promotions"] += 1
        if self.obs is not None:
            self.obs.record_resume(lane, req.rid, moved, cross, exp)
        req.lane = lane
        req.state = RUNNING
        self.lane_req[lane] = req.rid


class Engine(_EngineBase):
    """Device-resident batched scheduler (module docstring has the design)."""

    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params,
                 max_len: int = 2048, seed: int = 0, obs=None):
        super().__init__(cfg, scfg, params, max_len, seed, obs=obs)
        # device-resident lane bookkeeping, advanced inside the jitted step
        self.state = {
            "tok": jnp.zeros((self.lanes,), jnp.int32),
            "pos": jnp.zeros((self.lanes,), jnp.int32),
            "remaining": jnp.zeros((self.lanes,), jnp.int32),
            "active": jnp.zeros((self.lanes,), bool),
            "ref": jnp.zeros((self.lanes,), bool),
        }
        # ssm/hybrid recurrent state cannot tolerate right-padding: group by
        # exact length instead of power-of-two buckets
        self._bucketed = cfg.family not in ("ssm", "hybrid")

    def _set_lane_state(self, lane: int, tok: int, pos: int, remaining: int
                        ) -> None:
        st = self.state
        self.state = {
            "tok": st["tok"].at[lane].set(tok),
            "pos": st["pos"].at[lane].set(pos),
            "remaining": st["remaining"].at[lane].set(remaining),
            "active": st["active"].at[lane].set(True),
            "ref": st["ref"].at[lane].set(True),
        }
        self._ref[lane] = True

    def _clear_lane_state(self, lane: int) -> None:
        st = self.state
        self.state = dict(st, active=st["active"].at[lane].set(False),
                          ref=st["ref"].at[lane].set(False))
        self._ref[lane] = False

    # -- scheduling ---------------------------------------------------------

    def _bucket(self, n: int) -> int:
        if not self._bucketed:
            return n
        return min(max(_next_pow2(n), 8), self.max_len)

    def _admit(self) -> None:
        fresh, resumed = [], []

        def claim(rid: int, lane: int) -> None:
            self.lane_req[lane] = rid
            req = self.requests[rid]
            (resumed if req.parked is not None else fresh).append((rid, lane))

        while self.queue:
            lane = self._free_lane()
            if lane is None:
                break
            claim(self.queue.pop(0), lane)
        # time-slicing: at most ONE preemption per engine step — the evicted
        # request rejoins the queue tail and waits its turn. (An unbounded
        # preempt-while-queue-nonempty loop never terminates: every
        # preemption re-fills the queue it is trying to drain.) Lanes claimed
        # this step are not eligible victims (their KV is not installed yet).
        if self.queue:
            claimed = {lane for _, lane in fresh + resumed}
            occupied = np.array([r is not None and i not in claimed
                                 for i, r in enumerate(self.lane_req)])
            # fabric-aware balancing: among sweep candidates prefer the
            # lane whose expander holds the fewest parked payloads, so
            # preemptions spread across the expander fabric
            groups = self.lane_expander if self.n_expanders > 1 else None
            load = (self.expander_stats["parked"]
                    if self.n_expanders > 1 else None)
            victim, new_ref = self._victim_policy.select_mask(
                occupied, self._ref, groups=groups, group_load=load)
            if victim is not None:
                self._ref = new_ref
                self.state = dict(self.state, ref=jnp.asarray(new_ref))
                self._preempt(victim)
                claim(self.queue.pop(0), victim)
        for rid, lane in resumed:
            self._resume(self.requests[rid], lane)
        if fresh:
            self._start_fresh(fresh)

    def _start_fresh(self, items) -> None:
        """Batched prefill of all fresh admissions, grouped into length
        buckets — one compile and one host sync per bucket instead of one
        per request."""
        groups: Dict[int, list] = {}
        for rid, lane in items:
            L = self._bucket(len(self.requests[rid].prompt))
            groups.setdefault(L, []).append((rid, lane))
        for L, grp in sorted(groups.items()):
            k = len(grp)
            Bp = _next_pow2(k)          # pad rows too: fewer compiled shapes
            tokens = np.zeros((Bp, L), np.int32)
            lens = np.ones((Bp,), np.int32)
            for i, (rid, _) in enumerate(grp):
                p = self.requests[rid].prompt
                tokens[i, :len(p)] = p
                lens[i] = len(p)
            batch = {"tokens": jnp.asarray(tokens)}
            if self.cfg.frontend != "none":
                batch["embeds"] = jnp.zeros((Bp, L, self.cfg.d_model),
                                            jnp.bfloat16)
            toks, sub = self._prefill_fn(self.params, batch,
                                         jnp.asarray(lens))
            lanes_arr = jnp.asarray([lane for _, lane in grp])
            ax = _ssm_batch_axis(self.cache)
            real = {kk: (jax.tree_util.tree_map(
                        lambda a: jax.lax.slice_in_dim(a, 0, k, axis=ax), vv)
                        if kk == "ssm" else vv[:, :k])
                    for kk, vv in sub.items()}
            self.cache = _lanes_install(self.cache, lanes_arr, real)
            toks_h = self._fetch(toks[:k], "admit_syncs")
            self.counters["prefill_batches"] += 1
            if self.obs is not None:
                self.obs.record_admission(k, L)
            for i, (rid, lane) in enumerate(grp):
                req = self.requests[rid]
                req.generated.append(int(toks_h[i]))
                req.pos = int(lens[i])
                req.lane = lane
                req.state = RUNNING
                self.counters["promotions"] += 1
                remaining = req.max_new_tokens - 1
                if remaining <= 0 or req.pos >= self.max_len - 1:
                    req.state = DONE
                    req.lane = -1
                    self.lane_req[lane] = None
                else:
                    self._set_lane_state(lane, int(toks_h[i]), req.pos,
                                         remaining)

    def _preempt(self, lane: int) -> None:
        """Demote the lane. A shadow still covering every token short-
        circuits the whole thing: zero bytes move, the shadow is re-validated
        (§4.5); a partially-covering shadow pays only for the uncovered
        suffix (_park_lane). The zero-byte branch is the N=0 limit of the
        suffix charge — in this engine's own loop a resumed lane always
        decodes before it can be re-selected, so the limit case fires only
        when a caller (scheduler churn, tests, serve_bench) preempts between
        resume and decode; the organic payoff is the suffix-only charge."""
        rid = self.lane_req[lane]
        req = self.requests[rid]
        shadow_hit = req.parked is not None and req.shadow_pos >= req.pos
        if shadow_hit:
            self.counters["shadow_repreempts"] += 1
            moved = 0
        else:
            before = self.counters["preempt_bytes"]
            self._park_lane(req, lane)
            moved = self.counters["preempt_bytes"] - before
        if self.obs is not None:
            self.obs.record_preempt(lane, rid, moved, shadow_hit,
                                    int(self.lane_expander[lane]))
        self.counters["demotions"] += 1
        req.state = PREEMPTED
        req.lane = -1
        self.lane_req[lane] = None
        self._clear_lane_state(lane)
        self.queue.append(rid)

    def _resume(self, req: Request, lane: int) -> None:
        """Promotion; the parked copy stays behind as a shadow — its prefix
        (append-only KV) stays valid no matter how many tokens follow."""
        self._install_parked(req, lane)
        self._set_lane_state(lane, req.generated[-1], req.pos,
                             req.max_new_tokens - len(req.generated))

    # -- decode step ---------------------------------------------------------

    @sync_contract(syncs_per="step", fetches=1)
    def step(self) -> bool:
        """One engine iteration. Returns False when no work remains.
        Exactly one host sync per call once lanes are running — declared
        above and checked both by the R5 lint and by the benches via
        ``verify_sync_counters`` (step_syncs == steps)."""
        self._admit()
        active = [(lane, rid) for lane, rid in enumerate(self.lane_req)
                  if rid is not None]
        if not active:
            return bool(self.queue)
        kwargs = {}
        if self.cfg.frontend != "none":
            kwargs["embeds"] = jnp.zeros((self.lanes, self.cfg.d_model),
                                         jnp.bfloat16)
        self.cache, self.state, done = self._step_fn(
            self.params, self.cache, self.state, **kwargs)
        self.counters["steps"] += 1
        # ONE fused fetch: the lane positions ride along unconditionally —
        # a conditional fetch would be a second lexical sync site (R5) —
        # and feed the telemetry drain below at zero extra syncs
        tok_h, done_h, ref_h, pos_h = self._fetch(
            (self.state["tok"], done, self.state["ref"], self.state["pos"]),
            "step_syncs")
        self._ref = np.array(ref_h, bool, copy=True)
        if self.obs is not None:
            self.obs.record_step(self.counters["steps"], tok_h, done_h,
                                 pos_h, [lane for lane, _ in active])
        for lane, rid in active:
            req = self.requests[rid]
            req.pos += 1
            req.generated.append(int(tok_h[lane]))
            self.counters["tokens"] += 1
            if done_h[lane]:
                req.state = DONE
                req.lane = -1
                self._drop_park(req)       # free the shadow's host memory
                self.lane_req[lane] = None
        return True
