"""Continuous-batching serving engine with IBEX-managed KV residency.

The engine is the request-granular face of the paper's pool:

  * running requests occupy decode *lanes* (batch slots of the jit'd
    decode_step) — their recent tokens sit uncompressed in the hot ring
    (promoted region), older tokens in the quantized region;
  * a **preempted** request is *demoted*: its hot ring is quantized into the
    codes region (always a clean demotion — KV is append-only, the compressed
    copy is the only copy needed) and the lane is freed;
  * **resume** is a promotion — and because decode reads compressed pages
    directly (fused dequant attention), promotion moves *zero* KV bytes: the
    lane just adopts the parked codes (cold_len = full length, empty ring).
    This is the serving-level payoff of the paper's shadowed-promotion idea
    taken to its limit for append-only data;
  * victim selection uses a second-chance sweep over lanes (reference bit =
    "generated a token since last sweep"), the paper's §4.4 policy at
    request granularity.

Scheduling: FIFO admission, optional round-robin quantum. All cache motion is
counted in ``self.counters`` (bytes and events) for benchmarks/fig_serve.py.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import ModelConfig, ServeConfig
from repro.core.engine.policy import SecondChanceLanes
from repro.models import decode as D
from repro.models import transformer as T

WAITING, RUNNING, PREEMPTED, DONE = "waiting", "running", "preempted", "done"


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    state: str = WAITING
    generated: List[int] = field(default_factory=list)
    lane: int = -1
    pos: int = 0                      # next position to write
    parked: Optional[Dict[str, np.ndarray]] = None   # demoted KV (codes only)
    ref_bit: bool = True              # second-chance reference bit


@functools.lru_cache(maxsize=32)
def _compiled_steps(cfg: ModelConfig, scfg: ServeConfig, max_len: int):
    """Engine-shared jitted step/prefill fns. Cached on the hashable configs
    so constructing N engines (tests, replicas) compiles once — a fresh
    functools.partial per engine would key a fresh jit cache entry and
    recompile everything."""
    step = jax.jit(functools.partial(D.decode_step, cfg=cfg, scfg=scfg))
    prefill = jax.jit(functools.partial(D.prefill, cfg=cfg, scfg=scfg,
                                        max_len=max_len))
    return step, prefill


def _lane_slice(cache, lane: int):
    """Extract one lane's cache (arrays indexed at batch axis 1)."""
    return jax.tree_util.tree_map(lambda a: a[:, lane], cache)


def _lane_install(cache, lane: int, lane_cache):
    return jax.tree_util.tree_map(
        lambda a, s: a.at[:, lane].set(s.astype(a.dtype)), cache, lane_cache)


class Engine:
    def __init__(self, cfg: ModelConfig, scfg: ServeConfig, params,
                 max_len: int = 2048, seed: int = 0):
        self.cfg, self.scfg = cfg, scfg
        self.params = params
        self.max_len = max_len
        self.lanes = scfg.max_running
        self.cache = D.init_cache(cfg, scfg, self.lanes, max_len)
        self.lane_req: List[Optional[int]] = [None] * self.lanes
        self.requests: Dict[int, Request] = {}
        self.queue: List[int] = []
        self._next_rid = 0
        # victim selection goes through the same §4.4 policy shape as the
        # pool's clock engine, at lane granularity (engine/policy.py)
        self._victim_policy = SecondChanceLanes(self.lanes)
        self.counters = {"promotions": 0, "demotions": 0, "preempt_bytes": 0,
                         "resume_bytes": 0, "steps": 0, "tokens": 0}
        self._step_fn, self._prefill_fn = _compiled_steps(cfg, scfg, max_len)

    # -- client API ---------------------------------------------------------

    def submit(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.requests[rid] = Request(rid, list(prompt), max_new_tokens)
        self.queue.append(rid)
        return rid

    def result(self, rid: int) -> List[int]:
        return self.requests[rid].generated

    def run_until_done(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.step():
                return

    # -- scheduling ---------------------------------------------------------

    def _free_lane(self) -> Optional[int]:
        for i, r in enumerate(self.lane_req):
            if r is None:
                return i
        return None

    def _second_chance_victim(self) -> Optional[int]:
        """Clock sweep over lanes: clear ref bits, pick first un-referenced."""
        def _req(lane: int) -> Request:
            return self.requests[self.lane_req[lane]]

        def _clear(lane: int) -> None:
            _req(lane).ref_bit = False

        return self._victim_policy.select(
            occupied=lambda lane: self.lane_req[lane] is not None,
            referenced=lambda lane: _req(lane).ref_bit,
            clear=_clear)

    def _admit(self) -> None:
        # fill free lanes first
        while self.queue:
            lane = self._free_lane()
            if lane is None:
                break
            self._start(self.queue.pop(0), lane)
        # time-slicing: at most ONE preemption per engine step — the evicted
        # request rejoins the queue tail and waits its turn. (An unbounded
        # preempt-while-queue-nonempty loop never terminates: every
        # preemption re-fills the queue it is trying to drain.)
        if self.queue:
            lane = self._second_chance_victim()
            if lane is not None:
                self._preempt(lane)
                self._start(self.queue.pop(0), lane)

    def _start(self, rid: int, lane: int) -> None:
        req = self.requests[rid]
        if req.parked is not None:
            self._resume(req, lane)
            return
        # fresh request: single-lane prefill, then install codes+ring
        prompt = np.asarray(req.prompt, np.int32)[None, :]
        S = prompt.shape[1]
        W = self.scfg.hot_window
        if S < W:   # pad short prompts to the ring size
            prompt = np.pad(prompt, ((0, 0), (W - S, 0)))
            S = W
        batch = {"tokens": jnp.asarray(prompt)}
        if self.cfg.frontend != "none":
            batch["embeds"] = jnp.zeros((1, S, self.cfg.d_model), jnp.bfloat16)
        logits, lane_cache = self._prefill_fn(self.params, batch)
        lane_cache = jax.tree_util.tree_map(lambda a: a[:, 0], lane_cache)
        self.cache = _lane_install(self.cache, lane, lane_cache)
        req.pos = S
        req.lane = lane
        req.state = RUNNING
        req.ref_bit = True
        self.lane_req[lane] = rid
        tok = int(jnp.argmax(logits[0]))
        req.generated.append(tok)
        self.counters["promotions"] += 1

    def _preempt(self, lane: int) -> None:
        """Demote: the lane's ring tokens are already quantized on aging; the
        remainder (the ring itself) is quantized here — a clean demotion."""
        rid = self.lane_req[lane]
        req = self.requests[rid]
        lane_cache = _lane_slice(self.cache, lane)
        parked = {}
        host = jax.tree_util.tree_map(np.asarray, lane_cache)
        parked["cache"] = host
        req.parked = parked
        bytes_moved = sum(a.nbytes for a in jax.tree_util.tree_leaves(host)
                          if a.dtype == np.uint8)   # codes only: clean demote
        self.counters["preempt_bytes"] += bytes_moved
        self.counters["demotions"] += 1
        req.state = PREEMPTED
        req.lane = -1
        self.lane_req[lane] = None
        self.queue.append(rid)

    def _resume(self, req: Request, lane: int) -> None:
        """Promotion: install parked codes; no decompression happens (fused
        attention reads codes directly) — zero KV bytes dequantized."""
        lane_cache = jax.tree_util.tree_map(jnp.asarray, req.parked["cache"])
        self.cache = _lane_install(self.cache, lane, lane_cache)
        self.counters["resume_bytes"] += sum(
            a.nbytes for a in jax.tree_util.tree_leaves(req.parked["cache"])
            if a.dtype == np.uint8)
        self.counters["promotions"] += 1
        req.parked = None
        req.lane = lane
        req.state = RUNNING
        req.ref_bit = True
        self.lane_req[lane] = req.rid

    # -- decode step ---------------------------------------------------------

    def step(self) -> bool:
        """One engine iteration. Returns False when no work remains."""
        self._admit()
        active = [(lane, rid) for lane, rid in enumerate(self.lane_req)
                  if rid is not None]
        if not active:
            return bool(self.queue)
        tokens = np.zeros((self.lanes,), np.int32)
        pos = np.zeros((self.lanes,), np.int32)
        for lane, rid in active:
            req = self.requests[rid]
            tokens[lane] = req.generated[-1] if req.generated else 0
            pos[lane] = req.pos
        kwargs = {}
        if self.cfg.frontend != "none":
            kwargs["embeds"] = jnp.zeros((self.lanes, self.cfg.d_model),
                                         jnp.bfloat16)
        logits, self.cache = self._step_fn(
            self.params, self.cache, jnp.asarray(tokens), jnp.asarray(pos),
            **kwargs)
        self.counters["steps"] += 1
        logits = np.asarray(logits)
        for lane, rid in active:
            req = self.requests[rid]
            req.pos += 1
            req.ref_bit = True
            tok = int(np.argmax(logits[lane]))
            req.generated.append(tok)
            self.counters["tokens"] += 1
            if len(req.generated) >= req.max_new_tokens or \
                    req.pos >= self.max_len - 1:
                req.state = DONE
                req.lane = -1
                self.lane_req[lane] = None
        return True
