from repro.serve.engine import Engine  # noqa: F401
from repro.serve.serial import SerialEngine  # noqa: F401
