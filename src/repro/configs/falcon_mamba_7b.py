"""falcon-mamba-7b [ssm]: attention-free Mamba1 [arXiv:2410.05355;
unverified]. No KV cache exists -> the paged-KV side of IBEX is inapplicable
(DESIGN.md §Arch-applicability); IBEX still compresses optimizer state in
training. Runs long_500k (O(1) decode state)."""
from repro.common.types import ModelConfig, SSMConfig, replace

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm", num_layers=64, d_model=4096,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=65024, attn_kind="none",
    ssm=SSMConfig(kind="mamba1", d_state=16, d_conv=4, expand=2, chunk=128))

REDUCED = replace(
    CONFIG, num_layers=2, d_model=128, vocab_size=512,
    ssm=SSMConfig(kind="mamba1", d_state=8, d_conv=4, expand=2, chunk=32))
