"""arctic-480b [moe]: 128 experts top-2 + dense residual MLP
[hf:Snowflake/snowflake-arctic-base; hf]."""
from repro.common.types import ModelConfig, MoEConfig, replace

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", num_layers=35, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=4864, vocab_size=32000,
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864,
                  dense_residual=True, dense_d_ff=4864))

REDUCED = replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    d_ff=256, vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=256,
                  dense_residual=True, dense_d_ff=256))
