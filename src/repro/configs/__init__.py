from repro.configs.registry import (ALIASES, ARCH_IDS, all_configs,  # noqa
                                    describe, get_config, get_reduced)
