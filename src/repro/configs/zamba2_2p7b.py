"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks applied
every 6 layers, alternating between 2 shared weight sets
[arXiv:2411.15242; hf]. Runs long_500k (sub-quadratic backbone)."""
from repro.common.types import ModelConfig, SSMConfig, replace

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    attn_period=6, attn_shared_blocks=2,
    ssm=SSMConfig(kind="mamba2", d_state=64, d_conv=4, expand=2, headdim=64,
                  ngroups=1, chunk=128))

REDUCED = replace(
    CONFIG, num_layers=4, d_model=128, num_heads=4, num_kv_heads=4,
    d_ff=256, vocab_size=512, attn_period=2, attn_shared_blocks=2,
    ssm=SSMConfig(kind="mamba2", d_state=16, d_conv=4, expand=2, headdim=32,
                  ngroups=1, chunk=32))
