"""musicgen-medium [audio]: decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. Frontend stub supplies frame embeddings."""
from repro.common.types import ModelConfig, replace

CONFIG = ModelConfig(
    name="musicgen-medium", family="audio", num_layers=48, d_model=1536,
    num_heads=24, num_kv_heads=24, d_ff=6144, vocab_size=2048,
    frontend="encodec_audio")

REDUCED = replace(CONFIG, num_layers=2, d_model=128, num_heads=4,
                  num_kv_heads=4, d_ff=256, vocab_size=256)
