"""chameleon-34b [vlm]: early-fusion decoder over text + VQ image tokens
[arXiv:2405.09818; unverified]. Frontend is a stub: input_specs supplies
precomputed patch embeddings (assignment brief)."""
from repro.common.types import ModelConfig, replace

CONFIG = ModelConfig(
    name="chameleon-34b", family="vlm", num_layers=48, d_model=8192,
    num_heads=64, num_kv_heads=8, d_ff=22016, vocab_size=65536,
    frontend="vq_image", rope_theta=10000.0)

REDUCED = replace(CONFIG, num_layers=2, d_model=256, num_heads=8,
                  num_kv_heads=2, d_ff=512, vocab_size=512)
