"""codeqwen1.5-7b [dense]: qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
from repro.common.types import ModelConfig, replace

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=13440, vocab_size=92416,
    rope_theta=1000000.0)

REDUCED = replace(CONFIG, num_layers=2, d_model=256, num_heads=4,
                  num_kv_heads=4, d_ff=512, vocab_size=512)
