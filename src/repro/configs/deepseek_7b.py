"""deepseek-7b [dense]: llama-arch MHA [arXiv:2401.02954; hf]."""
from repro.common.types import ModelConfig, replace

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense", num_layers=30, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=102400)

REDUCED = replace(CONFIG, num_layers=2, d_model=256, num_heads=4,
                  num_kv_heads=4, d_ff=512, vocab_size=512)
