"""minicpm3-4b [dense]: multi-head latent attention (MLA)
[hf:openbmb/MiniCPM3-4B; hf]. The latent KV cache is itself a learned KV
compression; IBEX block-compresses the latents (DESIGN.md synergy note)."""
from repro.common.types import MLAConfig, ModelConfig, replace

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense", num_layers=62, d_model=2560,
    num_heads=40, num_kv_heads=40, d_ff=6400, vocab_size=73728,  # 73448 (+280 pad to a multiple of 256 for TP)
    attn_kind="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_head_dim=64,
                  qk_rope_head_dim=32, v_head_dim=64))

REDUCED = replace(
    CONFIG, num_layers=2, d_model=256, num_heads=4, num_kv_heads=4,
    d_ff=512, vocab_size=512,
    mla=MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16))
