"""qwen3-moe-235b-a22b [moe]: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf]."""
from repro.common.types import ModelConfig, MoEConfig, replace

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe", num_layers=94, d_model=4096,
    num_heads=64, num_kv_heads=4, head_dim=128, d_ff=1536, vocab_size=151936,
    moe=MoEConfig(num_experts=128, top_k=8, expert_d_ff=1536),
    rope_theta=1000000.0)

REDUCED = replace(
    CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
    head_dim=32, d_ff=256, vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, expert_d_ff=256))
