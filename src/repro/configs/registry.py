"""Architecture registry: one module per assigned arch, each exporting
``CONFIG`` (the exact published configuration) and ``REDUCED`` (a same-family
miniature for CPU smoke tests). Select with ``--arch <id>``."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.common.types import ModelConfig

ARCH_IDS: List[str] = [
    "chameleon_34b", "qwen3_moe_235b_a22b", "arctic_480b", "deepseek_7b",
    "minicpm3_4b", "codeqwen15_7b", "llama3_8b", "zamba2_2p7b",
    "musicgen_medium", "falcon_mamba_7b",
]

# dashes and dots tolerated on the CLI
ALIASES: Dict[str, str] = {
    "chameleon-34b": "chameleon_34b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "arctic-480b": "arctic_480b",
    "deepseek-7b": "deepseek_7b",
    "minicpm3-4b": "minicpm3_4b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "llama3-8b": "llama3_8b",
    "zamba2-2.7b": "zamba2_2p7b",
    "musicgen-medium": "musicgen_medium",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def describe(cfg: ModelConfig) -> str:
    n = cfg.param_count()
    na = cfg.active_param_count()
    extra = f", active {na/1e9:.1f}B" if na != n else ""
    return (f"{cfg.name}: {cfg.family} {cfg.num_layers}L d={cfg.d_model} "
            f"{n/1e9:.1f}B params{extra}")
