"""CXL-device timing model (Table 1) converting traffic counters to time.

Approximation (documented, not cycle-accurate): execution time is the max of
four saturable resources, plus a latency term moderated by memory-level
parallelism —

  t_mem    = internal 64B accesses x 64 / (channels x DDR bw)
  t_cxl    = host accesses x 64 / CXL bw                (PCIe5 x8 = 32 GB/s)
  t_engine = compressions x 256cyc + decompressions x 64cyc at 2 GHz
             (4B/clk compress, 16B/clk decompress for 1KB blocks, §5)
  t_lat    = host accesses x avg service latency / MLP

The model is used for *relative* performance (Fig. 9/12/14/15/16 analogues);
traffic counts (Fig. 11/13) need no model at all.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class DeviceConfig:
    channels: int = 2
    ch_bw: float = 44.8e9          # DDR5-5600 bytes/s per channel
    cxl_bw: float = 32e9           # PCIe Gen5 x8
    cxl_lat: float = 70e-9         # round-trip (Table 1)
    dram_lat: float = 55e-9        # tCL+tRCD-ish
    clock: float = 2.0e9
    comp_cycles: int = 256         # per 1KB block (4B/clk)
    decomp_cycles: int = 64        # per 1KB block (16B/clk)
    mlp: float = 4.0               # outstanding-request parallelism
    block_scale: float = 1.0       # 4KB-block schemes: 4x engine latency


def ideal_bandwidth(dev: DeviceConfig) -> DeviceConfig:
    """Fig. 1's 'unlimited internal bandwidth but same latency' variant."""
    return DeviceConfig(channels=dev.channels, ch_bw=1e15, cxl_bw=dev.cxl_bw,
                        cxl_lat=dev.cxl_lat, dram_lat=dev.dram_lat,
                        clock=dev.clock, comp_cycles=dev.comp_cycles,
                        decomp_cycles=dev.decomp_cycles, mlp=dev.mlp,
                        block_scale=dev.block_scale)


def exec_time(traffic: Dict[str, float], dev: DeviceConfig) -> float:
    host = traffic["host_reads"] + traffic["host_writes"]
    internal = traffic["internal_accesses"]
    t_mem = internal * 64 / (dev.channels * dev.ch_bw)
    t_cxl = host * 64 / dev.cxl_bw
    n_comp = (traffic.get("demotions_dirty", 0)
              + traffic.get("recompress_retry", 0)) * dev.block_scale * 4
    n_decomp = traffic.get("promotions", 0) * dev.block_scale  # per block
    t_engine = (n_comp * dev.comp_cycles + n_decomp * dev.decomp_cycles) \
        / dev.clock
    # average service latency per host access
    zero_frac = traffic.get("zero_served", 0) / max(host, 1)
    accesses_per_host = internal / max(host, 1)
    decomp_lat_frac = traffic.get("promotions", 0) / max(host, 1)
    l_avg = dev.cxl_lat + (1 - zero_frac) * dev.dram_lat \
        + accesses_per_host * dev.dram_lat * 0.25 \
        + decomp_lat_frac * dev.decomp_cycles / dev.clock
    t_lat = host * l_avg / dev.mlp
    return max(t_mem, t_cxl, t_engine, t_lat)


def uncompressed_time(n_host: int, dev: DeviceConfig) -> float:
    traffic = {"host_reads": n_host, "host_writes": 0,
               "internal_accesses": n_host, "zero_served": 0,
               "promotions": 0, "demotions_dirty": 0}
    return exec_time(traffic, dev)
