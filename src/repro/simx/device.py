"""Legacy scalar face of the CXL-device timing model.

The model itself lives in ``repro.simx.time`` (DESIGN.md §12): a frozen
``DeviceConfig`` plus a vectorized ``exec_time_vec`` over counter arrays in
``engine.state.COUNTER_NAMES`` order, usable inside jit/vmap (the fabric's
per-expander delivered time) and on host float64 arrays (sweeps, parity).
This module keeps the original string-keyed-dict API as a thin shim —
``exec_time(traffic_dict, dev)`` is bitwise-identical to the pre-refactor
scalar model (tests/test_time_model.py pins the parity contract).
"""
from __future__ import annotations

from typing import Dict

from repro.simx.time import (DEVICE_PROFILES, DeviceConfig,  # noqa: F401
                             DeviceLanes, exec_time_dict, ideal_bandwidth,
                             stack_devices)
from repro.simx.time import uncompressed_time as _uncompressed_time


def exec_time(traffic: Dict[str, float], dev: DeviceConfig) -> float:
    """Scalar delivered time of a string-keyed traffic dict (legacy API)."""
    return exec_time_dict(traffic, dev)


def uncompressed_time(n_host: int, dev: DeviceConfig) -> float:
    """Uncompressed-device baseline; traffic derived from
    ``state.COUNTER_NAMES`` (zeros except host reads + one internal access
    each) so the baseline and the model share one key set."""
    return _uncompressed_time(n_host, dev)
