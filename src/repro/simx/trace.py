"""Workload trace generators for the paper-evaluation reproduction.

Each paper workload (Table 2) is modeled by four knobs measured from its
published behavior: memory intensity (accesses simulated), write ratio
(WPKI/RPKI), locality (Zipf exponent over the page footprint + streaming
fraction), and a page-content model (zero / 4-bit / 8-bit / raw block mix)
matching the compressibility the paper reports (Fig. 10: IBEX-1KB mean 1.59,
lbm & graphs poorly compressible, mcf/omnetpp highly compressible).

A trace is (ospn[i], is_write[i], block[i]) plus a per-page rates table
consumed by the payload-less pool (pool.rates_table).

Every generator is a deterministic function of its explicit ``seed`` — the
same seed the benches take on the CLI (``benchmarks/run.py --seed``) and
the fabric derives its per-expander RNG streams from
(``engine.state.make_pool_stack``: ``fold_in(seed, expander)``); fabric
trace partitioning itself is a pure page-hash (fabric/placement.py). One
flag therefore reproduces a whole ``BENCH_*.json`` run bit-for-bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np


@dataclass(frozen=True)
class WorkloadSpec:
    name: str
    wpki_ratio: float        # writes / (reads+writes)
    zipf_a: float            # locality: higher = hotter head
    stream_frac: float       # fraction of sequential-scan accesses
    footprint_pages: float   # footprint as a multiple of the promoted region
    zero_frac: float         # fraction of all-zero pages
    mix4: float              # fraction of 4-bit-compressible blocks
    mix8: float              # 8-bit; remainder raw


# Knobs derived from Table 2 RPKI/WPKI + Figs. 9-11 commentary.
WORKLOADS: Dict[str, WorkloadSpec] = {
    "bwaves":  WorkloadSpec("bwaves", 0.14, 0.9, 0.5, 0.8, 0.10, 0.55, 0.25),
    "mcf":     WorkloadSpec("mcf", 0.15, 0.8, 0.1, 2.5, 0.15, 0.60, 0.25),
    "parest":  WorkloadSpec("parest", 0.01, 1.1, 0.3, 0.6, 0.10, 0.55, 0.30),
    "lbm":     WorkloadSpec("lbm", 0.43, 0.7, 0.8, 1.2, 0.30, 0.10, 0.20),
    "omnetpp": WorkloadSpec("omnetpp", 0.32, 0.6, 0.1, 3.0, 0.10, 0.65, 0.25),
    "bfs":     WorkloadSpec("bfs", 0.06, 0.7, 0.3, 2.0, 0.25, 0.35, 0.30),
    "pr":      WorkloadSpec("pr", 0.02, 0.5, 0.2, 4.0, 0.10, 0.40, 0.35),
    "cc":      WorkloadSpec("cc", 0.10, 0.5, 0.2, 4.0, 0.10, 0.40, 0.35),
    "tc":      WorkloadSpec("tc", 0.41, 0.8, 0.3, 1.5, 0.25, 0.35, 0.30),
    "xsbench": WorkloadSpec("xsbench", 0.00, 0.6, 0.2, 2.5, 0.05, 0.45, 0.35),
}


def make_rates_table(spec: WorkloadSpec, n_pages: int, blocks: int = 4,
                     seed: int = 0) -> np.ndarray:
    """Per-page per-block rate codes (0 zero / 1 4-bit / 2 8-bit / 3 raw)."""
    rng = np.random.default_rng(seed)
    zero_page = rng.random(n_pages) < spec.zero_frac
    p_raw = max(0.0, 1.0 - spec.mix4 - spec.mix8)
    rates = rng.choice([1, 2, 3], size=(n_pages, blocks),
                       p=[spec.mix4, spec.mix8, p_raw])
    rates[zero_page] = 0
    # sprinkle zero blocks inside normal pages (stack/padding regions)
    zb = rng.random((n_pages, blocks)) < 0.08
    rates[zb & ~zero_page[:, None]] = 0
    return rates.astype(np.int32)


def make_trace(spec: WorkloadSpec, *, n_accesses: int, n_pages: int,
               seed: int = 0) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(ospn, is_write, block) arrays. Pages are random-placed (paper §5:
    random OS page allocation), so OSPNs carry no spatial locality."""
    rng = np.random.default_rng(seed + 1)
    n_stream = int(n_accesses * spec.stream_frac)
    n_zipf = n_accesses - n_stream
    # zipf over a randomly permuted page ranking
    ranks = rng.zipf(max(spec.zipf_a, 1.01) + 1e-9, size=2 * n_zipf)
    ranks = ranks[ranks <= n_pages][:n_zipf]
    while ranks.shape[0] < n_zipf:
        extra = rng.zipf(max(spec.zipf_a, 1.01))
        ranks = np.append(ranks, extra if extra <= n_pages else 1)
    perm = rng.permutation(n_pages)
    zipf_pages = perm[(ranks - 1).astype(np.int64)]
    # streaming scan wraps the footprint
    start = rng.integers(0, n_pages)
    stream_pages = perm[(start + np.arange(n_stream)) % n_pages]
    pages = np.concatenate([zipf_pages, stream_pages])
    order = rng.permutation(n_accesses)
    pages = pages[order]
    is_write = rng.random(n_accesses) < spec.wpki_ratio
    block = rng.integers(0, 4, size=n_accesses)
    return (pages.astype(np.int32), is_write.astype(bool),
            block.astype(np.int32))


def write_instrumented_trace(base: WorkloadSpec, write_ratio: float,
                             **kw) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Fig. 16: re-instrument a read-only workload with binomial writes."""
    spec = WorkloadSpec(base.name, write_ratio, base.zipf_a, base.stream_frac,
                        base.footprint_pages, base.zero_frac, base.mix4,
                        base.mix8)
    return make_trace(spec, **kw)
