"""Array-native delivered-time accounting (DESIGN.md §12).

The paper's headline numbers (Fig. 9/12/14/15/16) are *delivered time*, not
byte counts, so the timing model is a first-class layer rather than a
post-hoc script:

  * ``DeviceConfig`` — one expander's Table-1 parameters as a frozen
    (hashable) dataclass: usable as a ``jax.jit`` static argument.
  * ``DeviceLanes`` — a *stacked* fleet of expanders: every field an array
    with a leading expander axis. A NamedTuple, hence a pytree — pass it as
    a traced argument into jitted/vmapped code (mixed-generation fleets:
    different ``ch_bw``/``cxl_lat``/``decomp_cycles`` per expander).
  * ``exec_time_vec`` — the vectorized model: operates on counter *arrays*
    in ``engine.state.COUNTER_NAMES`` order (the ``Pool.counters`` vector),
    broadcasting over any leading axes, under ``jnp`` (inside jit/vmap) or
    ``np`` (host-side float64). The legacy string-keyed-dict API survives as
    ``exec_time_dict`` — a thin shim over the same core, bitwise-identical
    to the old scalar model (tests/test_time_model.py pins this).

Model (documented approximation, not cycle-accurate): execution time is the
max of four saturable resources, plus a latency term moderated by
memory-level parallelism —

  t_mem    = internal 64B accesses x 64 / (channels x DDR bw)
  t_cxl    = host accesses x 64 / CXL bw                (PCIe5 x8 = 32 GB/s)
  t_engine = compressions x 256cyc + decompressions x 64cyc at 2 GHz
             (4B/clk compress, 16B/clk decompress for 1KB blocks, §5)
  t_lat    = host accesses x avg service latency / MLP

The serving-side model (``serve_motion_time``/``serve_modeled_time``)
converts the engine's preempt/resume byte and host-sync counters into
seconds: parked payloads cross the CXL link AND the internal channels
(pipelined → max), demotion pays the compression engine, and every host
sync costs one CXL round trip.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
from dataclasses import dataclass
from typing import Dict, List, Mapping, NamedTuple, Sequence, Union

import jax.numpy as jnp
import numpy as np

from repro.core.engine import state as S


@dataclass(frozen=True)
class DeviceConfig:
    """One expander's timing parameters (Table 1). Frozen → hashable →
    usable as a jit static; stack several into ``DeviceLanes`` for a
    traced per-expander fleet."""
    channels: int = 2
    ch_bw: float = 44.8e9          # DDR5-5600 bytes/s per channel
    cxl_bw: float = 32e9           # PCIe Gen5 x8
    cxl_lat: float = 70e-9         # round-trip (Table 1)
    dram_lat: float = 55e-9        # tCL+tRCD-ish
    clock: float = 2.0e9
    comp_cycles: int = 256         # per 1KB block (4B/clk)
    decomp_cycles: int = 64        # per 1KB block (16B/clk)
    mlp: float = 4.0               # outstanding-request parallelism
    block_scale: float = 1.0       # 4KB-block schemes: 4x engine latency


def ideal_bandwidth(dev: DeviceConfig) -> DeviceConfig:
    """Fig. 1's 'unlimited internal bandwidth but same latency' variant."""
    return dataclasses.replace(dev, ch_bw=1e15)


# Named generation profiles for mixed fleets (launch/fabric.py
# --device-profile, benchmarks/fabric_bench.py mixed-fleet rows). "gen4" is
# a previous-generation expander (PCIe4 x8 link, DDR4-ish channels, slower
# engine clock); "far" sits behind a CXL switch (latency only).
DEVICE_PROFILES: Dict[str, DeviceConfig] = {
    "default": DeviceConfig(),
    "gen4": DeviceConfig(ch_bw=25.6e9, cxl_bw=16e9, cxl_lat=110e-9,
                         dram_lat=60e-9, clock=1.5e9),
    "far": DeviceConfig(cxl_lat=250e-9),
    "slow_engine": DeviceConfig(clock=1.0e9, comp_cycles=512,
                                decomp_cycles=128),
}

# Default location of the measured-kernel bench artifact (repo root; written
# by benchmarks/kernel_bench.py).
_BENCH_KERNELS = pathlib.Path(__file__).resolve().parents[3] / "BENCH_kernels.json"


def calibrated_device(path: "str | pathlib.Path | None" = None,
                      base: "DeviceConfig | None" = None) -> DeviceConfig:
    """DeviceConfig whose compression-engine constants are derived from the
    measured kernel throughput in ``BENCH_kernels.json`` instead of the
    paper's assumed 256/64 cycles per block.

    cycles/block = clock * block_bytes / measured_bytes_per_second, i.e. the
    engine is modeled at exactly the GB/s the fused demote/promote kernels
    sustained on this host (benchmarks/kernel_bench.py 'calibration'
    section). Falls back to the paper constants (``base``) when the bench
    file is missing or lacks the calibration section, so delivered-time
    behavior never silently depends on an uncommitted artifact."""
    base = base if base is not None else DeviceConfig()
    p = pathlib.Path(path) if path is not None else _BENCH_KERNELS
    try:
        data = json.loads(p.read_text())
    except (OSError, ValueError):
        return base
    cal = data.get("calibration", {})
    comp_gbps = cal.get("compress_gbps")
    decomp_gbps = cal.get("decompress_gbps")
    if not comp_gbps or not decomp_gbps:
        return base
    blk = float(cal.get("block_bytes", 1024))
    comp_cycles = max(1, int(round(base.clock * blk / (comp_gbps * 1e9))))
    decomp_cycles = max(1, int(round(base.clock * blk / (decomp_gbps * 1e9))))
    return dataclasses.replace(base, comp_cycles=comp_cycles,
                               decomp_cycles=decomp_cycles)


class DeviceLanes(NamedTuple):
    """A stacked expander fleet: ``DeviceConfig`` field-for-field, each a
    float array with a leading expander axis. NamedTuple → pytree → passes
    through jit/vmap as a traced argument (``jax.vmap`` slices one
    expander's scalars per lane). Field names MUST mirror ``DeviceConfig``
    (``stack_devices`` asserts; test_time_model pins the drift guard)."""
    channels: np.ndarray
    ch_bw: np.ndarray
    cxl_bw: np.ndarray
    cxl_lat: np.ndarray
    dram_lat: np.ndarray
    clock: np.ndarray
    comp_cycles: np.ndarray
    decomp_cycles: np.ndarray
    mlp: np.ndarray
    block_scale: np.ndarray


DeviceLike = Union[DeviceConfig, DeviceLanes]


def stack_devices(devs: Sequence[DeviceConfig], xp=jnp) -> DeviceLanes:
    """[DeviceConfig] * N → DeviceLanes with N-length field arrays. Built
    generically from ``dataclasses.fields`` so adding a DeviceConfig field
    without extending DeviceLanes is a loud error, never a silent drop."""
    names = [f.name for f in dataclasses.fields(DeviceConfig)]
    if set(names) != set(DeviceLanes._fields):
        raise TypeError(f"DeviceConfig fields {names} drifted from "
                        f"DeviceLanes fields {list(DeviceLanes._fields)}")
    dtype = jnp.float32 if xp is jnp else np.float64
    return DeviceLanes(**{n: xp.asarray([getattr(d, n) for d in devs],
                                        dtype=dtype) for n in names})


def resolve_fleet(devices, n_expanders: int) -> List[DeviceConfig]:
    """Normalize a fleet spec — None (all-default), one DeviceConfig
    (homogeneous), or a sequence (cycled to length N if shorter) — into a
    list of N DeviceConfigs."""
    if devices is None:
        devices = DeviceConfig()
    if isinstance(devices, DeviceConfig):
        return [devices] * n_expanders
    devices = list(devices)
    if not devices:
        raise ValueError("empty device fleet")
    if len(devices) < n_expanders:
        devices = [devices[i % len(devices)] for i in range(n_expanders)]
    if len(devices) != n_expanders:
        raise ValueError(f"{len(devices)} device configs for "
                         f"{n_expanders} expanders")
    return devices


# ---------------------------------------------------------------------------
# The model core. One implementation serves every caller: python scalars
# (legacy dict shim, float64), numpy arrays (host-side sweeps, float64), and
# jnp arrays inside jit/vmap (fabric replay, float32). Operation order is
# EXACTLY the legacy scalar model's, so the float64 paths are bitwise
# identical to the pre-refactor code.
# ---------------------------------------------------------------------------

def _exec_time_core(host, internal, promotions, demotions_dirty,
                    recompress_retry, zero_served, dev: DeviceLike, xp):
    t_mem = internal * 64 / (dev.channels * dev.ch_bw)
    t_cxl = host * 64 / dev.cxl_bw
    n_comp = (demotions_dirty + recompress_retry) * dev.block_scale * 4
    n_decomp = promotions * dev.block_scale          # per block
    t_engine = (n_comp * dev.comp_cycles + n_decomp * dev.decomp_cycles) \
        / dev.clock
    # average service latency per host access
    host1 = xp.maximum(host, 1)
    zero_frac = zero_served / host1
    accesses_per_host = internal / host1
    decomp_lat_frac = promotions / host1
    l_avg = dev.cxl_lat + (1 - zero_frac) * dev.dram_lat \
        + accesses_per_host * dev.dram_lat * 0.25 \
        + decomp_lat_frac * dev.decomp_cycles / dev.clock
    t_lat = host * l_avg / dev.mlp
    return xp.maximum(xp.maximum(t_mem, t_cxl),
                      xp.maximum(t_engine, t_lat))


def exec_time_vec(counters, dev: DeviceLike, xp=None):
    """Vectorized delivered time over counter *arrays*.

    ``counters``: ``[..., NUM_COUNTERS]`` in ``state.COUNTER_NAMES`` order
    (the ``Pool.counters`` vector, or a stacked/broadcast batch of them);
    ``dev``: a ``DeviceConfig`` (broadcast) or ``DeviceLanes`` whose field
    arrays broadcast against the leading axes. Returns seconds ``[...]``.
    Internal traffic is derived from the ten ``state.TRAFFIC_IDX``
    categories — the model and the counter layout cannot drift on key
    names. Runs under jit/vmap when given jnp inputs; on numpy inputs it
    computes in float64 and is bitwise-identical to the legacy scalar
    model (the parity contract)."""
    if xp is None:
        xp = np if isinstance(counters, np.ndarray) else jnp
    c = (np.asarray(counters, np.float64) if xp is np
         else counters.astype(jnp.float32))
    internal = S.traffic_vector(c).sum(axis=-1)
    host = c[..., S.C_HOST_RD] + c[..., S.C_HOST_WR]
    return _exec_time_core(host, internal, c[..., S.C_PROMOTIONS],
                           c[..., S.C_DEMO_DIRTY], c[..., S.C_RECOMP_RETRY],
                           c[..., S.C_ZERO_SERVED], dev, xp)


def counters_from_dict(traffic: Mapping[str, float]) -> np.ndarray:
    """String-keyed traffic dict → float64 ``[NUM_COUNTERS]`` vector in
    ``state.COUNTER_NAMES`` order (missing keys are zero)."""
    return np.asarray([traffic.get(k, 0) for k in S.COUNTER_NAMES],
                      np.float64)


def exec_time_dict(traffic: Mapping[str, float], dev: DeviceConfig) -> float:
    """The legacy dict API, kept as a thin shim over the vectorized core.

    Honors an explicit ``internal_accesses`` key (fig12's miracle variant
    passes a reduced total that is NOT the category sum); otherwise derives
    it from the ten traffic categories. Float64 throughout — bitwise equal
    to the pre-refactor scalar model."""
    host = traffic["host_reads"] + traffic["host_writes"]
    if "internal_accesses" in traffic:
        internal = traffic["internal_accesses"]
    else:
        internal = sum(traffic.get(k, 0) for k in S.TRAFFIC_NAMES)
    f = np.float64
    return float(_exec_time_core(
        f(host), f(internal), f(traffic.get("promotions", 0)),
        f(traffic.get("demotions_dirty", 0)),
        f(traffic.get("recompress_retry", 0)),
        f(traffic.get("zero_served", 0)), dev, np))


def uncompressed_counters(n_host) -> np.ndarray:
    """Baseline traffic of an uncompressed device serving ``n_host`` host
    reads: derived from ``state.COUNTER_NAMES`` (zeros except host reads
    and one internal access per host read), so the baseline and the model
    can never drift on key names. ``n_host`` may be a scalar or an array
    (leading axes broadcast into the counters batch)."""
    n = np.asarray(n_host, np.float64)
    vec = np.zeros(n.shape + (S.NUM_COUNTERS,), np.float64)
    vec[..., S.C_HOST_RD] = n
    vec[..., S.C_DATA_RD] = n          # internal: one 64B access per read
    return vec


def uncompressed_time(n_host, dev: DeviceLike):
    """Fig-9-style baseline: ``exec_time`` of the uncompressed traffic.
    Scalar in, float out; array in (or ``DeviceLanes``), array out."""
    t = exec_time_vec(uncompressed_counters(n_host), dev, xp=np)
    return float(t) if np.ndim(t) == 0 else t


def pipeline_delivered_time(replay_deltas, migration_deltas, dev: DeviceLike,
                            overlapped: bool = True):
    """Delivered seconds of the fabric's two-stage segment pipeline
    (DESIGN.md §13): per-segment counter DELTAS priced segment by segment,
    then summed per expander.

    ``replay_deltas``/``migration_deltas``: float/int ``[S, N_counters]``
    or ``[S, N, N_counters]`` in ``state.COUNTER_NAMES`` order — segment
    ``s``'s foreground replay delta and the migration-epoch delta the
    scheduler overlapped with it (zeros when no epoch was in flight).

    ``overlapped=True`` prices each segment as
    ``max(replay_s, migration_s)`` — the pipeline hides an epoch's
    migration behind the next segment's foreground replay (an optimistic
    full-overlap bound: real channels would contend). ``False`` prices the
    synchronous path, ``replay_s + migration_s`` — migration on the
    critical path. ``overlapped <= sync`` holds segmentwise by
    construction (max <= sum of non-negatives); benches assert it on the
    same run's deltas. Note the per-segment max is NOT the cumulative
    ``exec_time_vec`` of summed counters — the pipeline model resolves
    the bottleneck resource per segment, the cumulative model once."""
    xp = np if isinstance(replay_deltas, np.ndarray) else jnp
    t_replay = exec_time_vec(replay_deltas, dev, xp=xp)
    t_mig = exec_time_vec(migration_deltas, dev, xp=xp)
    per_seg = xp.maximum(t_replay, t_mig) if overlapped \
        else t_replay + t_mig
    return per_seg.sum(axis=0)


# ---------------------------------------------------------------------------
# Serving-side model: preempt/resume byte + host-sync counters → seconds
# (serve/engine.py counters; DESIGN.md §12).
# ---------------------------------------------------------------------------

def serve_motion_time(preempt_bytes, resume_bytes, dev: DeviceLike, xp=np):
    """Seconds one expander spends moving park/resume payloads: bytes cross
    the CXL link and the internal channels (pipelined → max of the two),
    and every parked 1KB block pays the compression engine (resume installs
    codes without dequantizing — fused attention reads them in place, so
    promotions charge bandwidth only)."""
    moved = preempt_bytes + resume_bytes
    t_link = moved / dev.cxl_bw
    t_mem = moved / (dev.channels * dev.ch_bw)
    t_engine = (preempt_bytes / 1024.0) * dev.block_scale * dev.comp_cycles \
        / dev.clock
    return xp.maximum(xp.maximum(t_link, t_mem), t_engine)


def serve_modeled_time(counters: Mapping[str, int],
                       expander_stats: Mapping[str, np.ndarray],
                       devices: Sequence[DeviceConfig]) -> Dict[str, object]:
    """Modeled serving seconds from an engine's motion/sync counters.

    Expanders move their own parked payloads in parallel (bottleneck =
    max over lanes); host syncs are serialized round trips charged at the
    slowest lane's CXL latency. Returns per-expander motion seconds plus
    the totals the benches record (seconds per decode step is the figure
    of merit: serial-vs-batched and fabric-striped serving compare in
    seconds, not just tokens/sec)."""
    lanes = stack_devices(list(devices), xp=np)
    motion = serve_motion_time(
        np.asarray(expander_stats["preempt_bytes"], np.float64),
        np.asarray(expander_stats["resume_bytes"], np.float64), lanes, np)
    syncs = counters["step_syncs"] + counters["admit_syncs"]
    sync_s = float(syncs * np.max(lanes.cxl_lat))
    modeled_s = sync_s + float(np.max(motion))
    steps = max(int(counters["steps"]), 1)
    return {
        "sync_s": sync_s,
        "motion_s_per_expander": [float(t) for t in motion],
        "modeled_s": modeled_s,
        "modeled_s_per_step": modeled_s / steps,
    }
