"""Trace-driven evaluation engine: runs a workload trace through the Layer-A
pool (payload-less) under each compared scheme, reproducing the paper's
SST-based methodology as traffic counts + the device.py time model.

Schemes (paper §5/§6):
  ibex        full IBEX (shadow + co-location + compaction, clock demotion)
  ibex_base / ibex_s / ibex_sc / ibex_scm   Fig. 13 ablation ladder
  tmcc        4KB blocks, variable-size chunks (zsmalloc bookkeeping +
              fragmentation reclaim traffic), list-based recency, no shadow
  dylect      tmcc + dual metadata tables (2nd probe per mcache miss)
  mxt         4KB promotion cache with on-chip tags (no activity traffic,
              clean evictions free) but page-granular promotion, no zero
              elision
  dmc         32KB migration granularity (promotion/demotion traffic x8)
  compresso   line-level: no promotion machinery at all, low ratio
  uncompressed   the normalization baseline

Post-pool adjustments (documented per scheme) add the traffic that the shared
pool mechanics do not model natively (LRU-list updates, zspage bookkeeping,
second-table probes, migration multipliers).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import PoolConfig, replace
from repro.core import pool as P
from repro.simx import device as DEV
from repro.simx.trace import WorkloadSpec, make_rates_table, make_trace


@dataclass(frozen=True)
class Scheme:
    name: str
    coloc: bool = True
    shadow: bool = True
    compact: bool = True
    zero_elision: bool = True
    lru_list_traffic: bool = False    # +1 access / host access (list recency)
    dual_metadata: bool = False       # +1 metadata access / mcache miss
    frag_bookkeeping: bool = False    # zsmalloc: +2 acc / compression store,
    #                                   +1 reclaim acc / demotion
    migrate_mult: float = 1.0         # DMC: 8x (32KB)
    line_level: bool = False          # compresso fast path
    no_activity_traffic: bool = False  # MXT on-chip tags
    block4k_engine: bool = False      # 4x compression-engine latency


SCHEMES: Dict[str, Scheme] = {
    "ibex": Scheme("ibex"),
    "ibex_base": Scheme("ibex_base", coloc=False, shadow=False, compact=False,
                        block4k_engine=True),
    "ibex_s": Scheme("ibex_s", coloc=False, shadow=True, compact=False,
                     block4k_engine=True),
    "ibex_sc": Scheme("ibex_sc", coloc=True, shadow=True, compact=False),
    "ibex_scm": Scheme("ibex_scm", coloc=True, shadow=True, compact=True),
    "tmcc": Scheme("tmcc", coloc=False, shadow=False, compact=True,
                   lru_list_traffic=True, frag_bookkeeping=True,
                   block4k_engine=True),
    "dylect": Scheme("dylect", coloc=False, shadow=False, compact=True,
                     lru_list_traffic=True, frag_bookkeeping=True,
                     dual_metadata=True, block4k_engine=True),
    "mxt": Scheme("mxt", coloc=False, shadow=True, compact=True,
                  zero_elision=False, no_activity_traffic=True,
                  block4k_engine=True),
    "dmc": Scheme("dmc", coloc=False, shadow=False, compact=True,
                  migrate_mult=8.0, block4k_engine=True),
    "compresso": Scheme("compresso", line_level=True),
}

TRAFFIC_KEYS = ("metadata_rd", "metadata_wr", "data_rd", "data_wr",
                "promo_rd", "promo_wr", "demo_rd", "demo_wr",
                "activity_rd", "activity_wr")


def pool_cfg_for(scheme: Scheme, *, n_pages: int, n_pchunks: int,
                 n_cchunks: int) -> PoolConfig:
    return PoolConfig(
        # mcache MUST be much smaller than the page population (paper:
        # 24MB cache footprint vs GBs of pages) — an oversized cache makes
        # every page probe-hit and forces the clock into pure random
        # fallback, inverting the mechanism being measured
        n_pages=n_pages, n_cchunks=n_cchunks, n_pchunks=n_pchunks,
        mcache_sets=4, mcache_ways=8, demote_watermark=8,
        shadow=scheme.shadow, coloc=scheme.coloc, compact=scheme.compact,
        zero_elision=scheme.zero_elision, store_payload=False)


@functools.partial(jax.jit, static_argnums=(1,))
def _run_scan(pool: P.Pool, cfg: PoolConfig, ospns, writes, blocks):
    zero_block = jnp.zeros((cfg.vals_per_block,), jnp.bfloat16)

    def step(pool, x):
        ospn, w, blk = x

        def do_write(p):
            return P.host_write_block.__wrapped__(p, cfg, ospn, blk, zero_block)

        def do_read(p):
            return P.host_read_block.__wrapped__(p, cfg, ospn, blk)[0]

        return jax.lax.cond(w, do_write, do_read, pool), None

    pool, _ = jax.lax.scan(step, pool, (ospns, writes, blocks))
    return pool


def run_workload(scheme_name: str, spec: WorkloadSpec, *,
                 n_accesses: int = 20000, promoted_pages: int = 128,
                 seed: int = 0, first_touch: bool = True,
                 device: Optional[DEV.DeviceConfig] = None
                 ) -> Dict[str, float]:
    """Run one (scheme x workload) cell; returns traffic + time metrics.

    Pool dimensions are FIXED (4x promoted region) across workloads so the
    jitted scan compiles once per scheme; a workload's footprint is realized
    by restricting which pages its trace touches."""
    scheme = SCHEMES[scheme_name]
    n_pages = 4 * promoted_pages
    n_used = min(max(int(promoted_pages * spec.footprint_pages), 32), n_pages)
    rates = make_rates_table(spec, n_pages, seed=seed)
    ospn, is_write, block = make_trace(spec, n_accesses=n_accesses,
                                       n_pages=n_used, seed=seed)
    dev = device or DEV.DeviceConfig()
    if scheme.block4k_engine:
        dev = replace(dev, block_scale=4.0)

    if scheme.line_level:
        return _run_compresso(spec, rates[:n_used], ospn, is_write, dev)

    cfg = pool_cfg_for(scheme, n_pages=n_pages, n_pchunks=promoted_pages,
                       n_cchunks=2 * n_pages * 8)
    pool = P.make_pool(cfg, seed=seed, rates_table=jnp.asarray(rates))
    if first_touch:
        # populate every used page once (first touch -> promoted; demotes).
        # padded to n_pages (cycling) so the scan length is static per scheme.
        order = np.random.default_rng(seed).permutation(n_used).astype(np.int32)
        order = order[np.arange(n_pages) % n_used]
        pool = _run_scan(pool, cfg, jnp.asarray(order),
                         jnp.ones((n_pages,), bool),
                         jnp.zeros((n_pages,), jnp.int32))
        pool = pool._replace(counters=jnp.zeros_like(pool.counters))
    pool = _run_scan(pool, cfg, jnp.asarray(ospn), jnp.asarray(is_write),
                     jnp.asarray(block))
    c = P.counters_dict(pool)
    return _finalize(scheme, c, dev,
                     ratio=float(P.compression_ratio(pool, cfg)))


def _finalize(scheme: Scheme, c: Dict[str, int], dev: DEV.DeviceConfig,
              ratio: float) -> Dict[str, float]:
    t = {k: float(c[k]) for k in TRAFFIC_KEYS}
    host = c["host_reads"] + c["host_writes"]
    # scheme post-adjustments
    if scheme.no_activity_traffic:
        t["activity_rd"] = t["activity_wr"] = 0.0
    if scheme.lru_list_traffic:
        t["activity_wr"] += host  # list node update per access
    if scheme.dual_metadata:
        t["metadata_rd"] += c["mcache_misses"]
    if scheme.frag_bookkeeping:
        stores = c["demotions_dirty"] + c["recompress_retry"]
        t["metadata_wr"] += 2 * stores
        t["demo_wr"] += c["demotions_clean"] + c["demotions_dirty"]
    if scheme.migrate_mult != 1.0:
        for k in ("promo_rd", "promo_wr", "demo_rd", "demo_wr"):
            t[k] *= scheme.migrate_mult
    internal = sum(t.values())
    traffic = dict(t, internal_accesses=internal,
                   host_reads=c["host_reads"], host_writes=c["host_writes"],
                   zero_served=c["zero_served"],
                   promotions=c["promotions"],
                   demotions_clean=c["demotions_clean"],
                   demotions_dirty=c["demotions_dirty"],
                   recompress_retry=c.get("recompress_retry", 0),
                   random_fallback=c["random_fallback"],
                   mcache_hits=c["mcache_hits"],
                   mcache_misses=c["mcache_misses"])
    time_s = DEV.exec_time(traffic, dev)
    base_s = DEV.uncompressed_time(host, dev)
    return dict(traffic, time_s=time_s, uncompressed_s=base_s,
                normalized_perf=base_s / time_s, compression_ratio=ratio)


def _run_compresso(spec: WorkloadSpec, rates: np.ndarray, ospn: np.ndarray,
                   is_write: np.ndarray, dev: DEV.DeviceConfig
                   ) -> Dict[str, float]:
    """Line-level compression (no promotion machinery): metadata access on
    mcache miss; ~1.05 data accesses per read (lines pack across 64B), ~2.2
    per write (read-modify-write + occasional size-overflow repack)."""
    from repro.core import mcache as MC
    mc = MC.make_mcache(32, 16)
    hits = 0

    # vectorized-ish mcache sim via python loop over unique ospns windows is
    # too slow; use a jitted scan over accesses
    @jax.jit
    def run(mc, pages):
        def step(carry, p):
            mc, h = carry
            mc, hit, _ = MC.access(mc, p)
            return (mc, h + hit.astype(jnp.int32)), None
        (mc, h), _ = jax.lax.scan(step, (mc, jnp.int32(0)), pages)
        return h
    hits = int(run(mc, jnp.asarray(ospn, jnp.int32)))
    n = len(ospn)
    misses = n - hits
    reads = int((~is_write).sum())
    writes = int(is_write.sum())
    t = {k: 0.0 for k in TRAFFIC_KEYS}
    t["metadata_rd"] = float(misses)
    t["metadata_wr"] = float(writes * 0.1)    # size-class changes
    t["data_rd"] = reads * 1.05
    t["data_wr"] = writes * 2.2
    internal = sum(t.values())
    # line-level ratio: a 64B window only captures the *strong* patterns
    # (zero lines + narrow-range data == our rate<=1 blocks) at ~2:1; the
    # 8-bit-class blocks that a 1KB dictionary still compresses are
    # incompressible at line granularity (paper: Compresso mean 1.24,
    # far below block-level)
    comp_frac = float((rates <= 1).mean())
    ratio = 1.0 / (comp_frac * 0.55 + (1 - comp_frac) * 1.0)
    traffic = dict(t, internal_accesses=internal, host_reads=reads,
                   host_writes=writes, zero_served=0, promotions=0,
                   demotions_clean=0, demotions_dirty=0, recompress_retry=0,
                   random_fallback=0, mcache_hits=hits, mcache_misses=misses)
    time_s = DEV.exec_time(traffic, dev)
    base_s = DEV.uncompressed_time(n, dev)
    return dict(traffic, time_s=time_s, uncompressed_s=base_s,
                normalized_perf=base_s / time_s, compression_ratio=ratio)
