"""Trace-driven evaluation engine: runs a workload trace through the pool
engine (payload-less) under each compared scheme, reproducing the paper's
SST-based methodology as traffic counts + the device.py time model.

Schemes are first-class ``Policy`` modules (repro.core.engine.policy): each
scheme's extra traffic — TMCC's LRU-list updates and zsmalloc bookkeeping,
DyLeCT's dual-table probes, MXT's on-chip tags, DMC's 8x migration — is
charged by policy hooks at the access site where it physically occurs; there
are no post-hoc counter adjustments. Traces replay through the batched
front-end (repro.core.engine.batch): a window of W accesses per scan step
with vectorized fast-path accounting, which is what makes the full workload
sweep CPU-tractable (before/after accesses/sec are tracked in
BENCH_simx.json).

Compresso (line-level, no promotion machinery) keeps its dedicated model.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import PoolConfig, replace
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import POLICIES, Policy
from repro.simx import device as DEV
from repro.simx import time as TM
from repro.simx.trace import WorkloadSpec, make_rates_table, make_trace

# name -> Policy; the per-scheme behavior lives in repro.core.engine.policy
SCHEMES: Dict[str, Policy] = POLICIES

# the ten internal-traffic categories, derived from the counter layout so
# the metrics dicts and the delivered-time model share one key set
TRAFFIC_KEYS = S.TRAFFIC_NAMES

DEFAULT_WINDOW = B.DEFAULT_WINDOW


def pool_cfg_for(policy: Policy, *, n_pages: int, n_pchunks: int,
                 n_cchunks: int) -> PoolConfig:
    return PoolConfig(
        # mcache MUST be much smaller than the page population (paper:
        # 24MB cache footprint vs GBs of pages) — an oversized cache makes
        # every page probe-hit and forces the clock into pure random
        # fallback, inverting the mechanism being measured
        n_pages=n_pages, n_cchunks=n_cchunks, n_pchunks=n_pchunks,
        mcache_sets=4, mcache_ways=8, demote_watermark=8,
        shadow=policy.shadow, coloc=policy.coloc, compact=policy.compact,
        zero_elision=policy.zero_elision, store_payload=False)


def first_touch_populate(pool, cfg: PoolConfig, policy: Policy, *,
                         n_used: int, seed: int = 0,
                         window: int = DEFAULT_WINDOW):
    """Write every used page once (first touch -> promoted; demotes), then
    zero the counters. Padded to ``cfg.n_pages`` accesses (cycling) so the
    replay length is static per scheme. Shared by run_workload, the replay
    benchmark, and the parity tests so all warm pools identically."""
    order = np.random.default_rng(seed).permutation(n_used).astype(np.int32)
    order = order[np.arange(cfg.n_pages) % n_used]
    pool = B.replay_trace(pool, cfg, policy, order,
                          np.ones((cfg.n_pages,), bool),
                          np.zeros((cfg.n_pages,), np.int32), window=window)
    return pool._replace(counters=jnp.zeros_like(pool.counters))


def run_workload(scheme_name: str, spec: WorkloadSpec, *,
                 n_accesses: int = 20000, promoted_pages: int = 128,
                 seed: int = 0, first_touch: bool = True,
                 device: Optional[DEV.DeviceConfig] = None,
                 window: int = DEFAULT_WINDOW, obs=None) -> Dict[str, float]:
    """Run one (scheme x workload) cell; returns traffic + time metrics.

    Pool dimensions are FIXED (4x promoted region) across workloads so the
    jitted replay compiles once per scheme; a workload's footprint is
    realized by restricting which pages its trace touches. ``window=1``
    forces the serial one-access-per-step scan (benchmark baseline).
    ``obs`` (a ``repro.obs.Recorder``) records the finished cell's metrics
    — host data the run already produced, zero extra syncs."""
    policy = SCHEMES[scheme_name]
    n_pages = 4 * promoted_pages
    n_used = min(max(int(promoted_pages * spec.footprint_pages), 32), n_pages)
    rates = make_rates_table(spec, n_pages, seed=seed)
    ospn, is_write, block = make_trace(spec, n_accesses=n_accesses,
                                       n_pages=n_used, seed=seed)
    dev = device or DEV.DeviceConfig()
    if policy.block4k_engine:
        dev = replace(dev, block_scale=4.0)

    if policy.line_level:
        out = _run_compresso(spec, rates[:n_used], ospn, is_write, dev)
    else:
        cfg = pool_cfg_for(policy, n_pages=n_pages,
                           n_pchunks=promoted_pages,
                           n_cchunks=2 * n_pages * 8)
        pool = S.make_pool(cfg, seed=seed, rates_table=jnp.asarray(rates))
        if first_touch:
            pool = first_touch_populate(pool, cfg, policy, n_used=n_used,
                                        seed=seed, window=window)
        pool = B.replay_trace(pool, cfg, policy, ospn, is_write, block,
                              window=window)
        c = S.counters_dict(pool)
        out = _finalize(c, dev,
                        ratio=float(S.compression_ratio(pool, cfg)))
    if obs is not None:
        obs.record_cell(scheme_name, spec.name, out)
    return out


def _finalize(c: Dict[str, int], dev: DEV.DeviceConfig, ratio: float
              ) -> Dict[str, float]:
    """Assemble the metrics dict. All scheme-specific traffic was already
    counted in place by policy hooks — nothing is adjusted here. Time comes
    from the vectorized model over the counter vector (float64 host path —
    bitwise what the legacy dict shim computes)."""
    t = {k: float(c[k]) for k in TRAFFIC_KEYS}
    internal = sum(t.values())
    traffic = dict(t, internal_accesses=internal,
                   host_reads=c["host_reads"], host_writes=c["host_writes"],
                   zero_served=c["zero_served"],
                   promotions=c["promotions"],
                   demotions_clean=c["demotions_clean"],
                   demotions_dirty=c["demotions_dirty"],
                   recompress_retry=c.get("recompress_retry", 0),
                   random_fallback=c["random_fallback"],
                   mcache_hits=c["mcache_hits"],
                   mcache_misses=c["mcache_misses"])
    host = c["host_reads"] + c["host_writes"]
    time_s = float(TM.exec_time_vec(TM.counters_from_dict(traffic), dev))
    base_s = TM.uncompressed_time(host, dev)
    return dict(traffic, time_s=time_s, uncompressed_s=base_s,
                normalized_perf=base_s / time_s, compression_ratio=ratio)


def _run_compresso(spec: WorkloadSpec, rates: np.ndarray, ospn: np.ndarray,
                   is_write: np.ndarray, dev: DEV.DeviceConfig
                   ) -> Dict[str, float]:
    """Line-level compression (no promotion machinery): metadata access on
    mcache miss; ~1.05 data accesses per read (lines pack across 64B), ~2.2
    per write (read-modify-write + occasional size-overflow repack)."""
    from repro.core import mcache as MC
    mc = MC.make_mcache(32, 16)

    @jax.jit
    def run(mc, pages):
        def step(carry, p):
            mc, h = carry
            mc, hit, _ = MC.access(mc, p)
            return (mc, h + hit.astype(jnp.int32)), None
        (mc, h), _ = jax.lax.scan(step, (mc, jnp.int32(0)), pages)
        return h
    hits = int(run(mc, jnp.asarray(ospn, jnp.int32)))
    n = len(ospn)
    misses = n - hits
    reads = int((~is_write).sum())
    writes = int(is_write.sum())
    t = {k: 0.0 for k in TRAFFIC_KEYS}
    t["metadata_rd"] = float(misses)
    t["metadata_wr"] = float(writes * 0.1)    # size-class changes
    t["data_rd"] = reads * 1.05
    t["data_wr"] = writes * 2.2
    internal = sum(t.values())
    # line-level ratio: a 64B window only captures the *strong* patterns
    # (zero lines + narrow-range data == our rate<=1 blocks) at ~2:1; the
    # 8-bit-class blocks that a 1KB dictionary still compresses are
    # incompressible at line granularity (paper: Compresso mean 1.24,
    # far below block-level)
    comp_frac = float((rates <= 1).mean())
    ratio = 1.0 / (comp_frac * 0.55 + (1 - comp_frac) * 1.0)
    traffic = dict(t, internal_accesses=internal, host_reads=reads,
                   host_writes=writes, zero_served=0, promotions=0,
                   demotions_clean=0, demotions_dirty=0, recompress_retry=0,
                   random_fallback=0, mcache_hits=hits, mcache_misses=misses)
    time_s = float(TM.exec_time_vec(TM.counters_from_dict(traffic), dev))
    base_s = TM.uncompressed_time(n, dev)
    return dict(traffic, time_s=time_s, uncompressed_s=base_s,
                normalized_perf=base_s / time_s, compression_ratio=ratio)
