"""Layer B: trace-driven reproduction of the paper's SST evaluation."""
from repro.simx import device, engine, time, trace  # noqa: F401
