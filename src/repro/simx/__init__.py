"""Layer B: trace-driven reproduction of the paper's SST evaluation."""
from repro.simx import device, engine, trace  # noqa: F401
