"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), in seconds:
    compute    = HLO_FLOPs / (chips * peak_FLOPs)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

Sources: ``compiled.cost_analysis()`` for FLOPs/bytes; collective bytes are
NOT in cost_analysis — we parse the (post-SPMD) HLO text and sum operand
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops.

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (assignment brief).
"""
from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,4096,128]{2,1,0} all-gather(...)"  or tuple-typed ops
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Sum byte sizes of every tensor literal in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> Dict[str, float]:
    """Per-kind and total collective bytes (result-shape convention).

    MUST be fed *post-SPMD* HLO (``compiled.as_text()``) — collectives only
    exist after partitioning; the pre-compile StableHLO has none. Counts each
    collective once with its result size: sync ops directly; async
    start/done pairs via the ``-done`` op (the start op returns a tuple
    holding both buffers and would double-count)."""
    out: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo.splitlines():
        s = line.strip()
        # result-type prefix form: "<name> = <type> <op>(...)"
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([a-z\-]+(?:-start|-done)?)", s)
        if not m:
            continue
        op = m.group(2)
        if op.endswith("-start"):
            continue
        base = op[:-5] if op.endswith("-done") else op
        for kind in _COLLECTIVES:
            if base == kind:
                out[kind] += _shape_bytes(m.group(1))
                counts[kind] += 1
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts  # type: ignore[assignment]
    return out


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops: float
    useful_ratio: float

    def as_dict(self) -> Dict[str, float]:
        return {"compute_s": self.compute_s, "memory_s": self.memory_s,
                "collective_s": self.collective_s, "dominant": self.dominant,
                "model_flops": self.model_flops, "hlo_flops": self.hlo_flops,
                "useful_ratio": self.useful_ratio}


def model_flops(params: int, active_params: int, tokens: int,
                kind: str) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE); decode: D = batch tokens (1 step).
    Training includes backward (the 6x already counts fwd+bwd); inference
    steps use 2*N*D."""
    n = active_params
    if kind == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def roofline_terms(*, flops: float, bytes_accessed: float,
                   collective: Dict[str, float], chips: int,
                   params: int, active_params: int, tokens: int,
                   kind: str) -> Roofline:
    """All inputs are whole-program (all-chip) quantities from the dry-run.

    cost_analysis flops/bytes are per-partition after SPMD; we treat them as
    per-chip. Collective bytes from HLO are per-chip program bytes; ring
    all-reduce costs ~2x on the wire, others ~1x."""
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    wire = (2.0 * collective.get("all-reduce", 0.0)
            + collective.get("all-gather", 0.0)
            + collective.get("reduce-scatter", 0.0)
            + collective.get("all-to-all", 0.0)
            + collective.get("collective-permute", 0.0))
    collective_s = wire / LINK_BW
    mf = model_flops(params, active_params, tokens, kind)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    hlo_total = flops * chips
    return Roofline(compute_s, memory_s, collective_s, dominant, mf,
                    hlo_total, mf / hlo_total if hlo_total > 0 else 0.0)


def load_dryrun(results_dir: str = "results/dryrun") -> List[Dict]:
    recs = []
    if not os.path.isdir(results_dir):
        return recs
    for name in sorted(os.listdir(results_dir)):
        if name.endswith(".json"):
            with open(os.path.join(results_dir, name)) as f:
                recs.append(json.load(f))
    return recs


def analyze_record(rec: Dict, tokens: int, kind: str) -> Optional[Roofline]:
    if rec.get("status") != "ok":
        return None
    chips = 1
    for s in rec["mesh"]:
        chips *= s
    return roofline_terms(
        flops=rec["flops"], bytes_accessed=rec["bytes_accessed"],
        collective=rec["collective_bytes"], chips=chips,
        params=rec["params"], active_params=rec["active_params"],
        tokens=tokens, kind=kind)


def kernel_roofline(rows: List[Dict], hbm_bw: float = HBM_BW) -> List[Dict]:
    """Distance-from-bandwidth-bound for measured kernel rows (the qpack
    encode/decode/fused-demote kernels are pure streaming: ~0 FLOPs/byte,
    so the HBM roof *is* their speed-of-light). Each input row needs
    ``name``, ``bytes`` (uncompressed bytes moved per call) and ``us``
    (median wall time); emits GB/s, fraction of the HBM roof, and the
    bound classification used by BENCH_kernels.json."""
    out = []
    for r in rows:
        us = float(r.get("us", 0.0))
        nbytes = float(r.get("bytes", 0.0))
        if us <= 0 or nbytes <= 0:
            continue
        gbps = nbytes / (us * 1e-6) / 1e9
        frac = gbps * 1e9 / hbm_bw
        out.append({
            "name": r["name"],
            "gbps": gbps,
            "frac_of_hbm_roof": frac,
            "bound": "bandwidth" if frac >= 0.5 else "overhead",
        })
    return out
