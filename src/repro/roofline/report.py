"""Build the §Roofline table (EXPERIMENTS.md) from results/dryrun/*.json.

  PYTHONPATH=src python -m repro.roofline.report [--dir results/dryrun]

Single-pod (16,16) cells only, per the brief; pod2 cells prove multi-pod
shardability and are listed in §Dry-run.
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

from repro.common.types import SHAPES_BY_NAME
from repro.roofline.analyze import analyze_record


def tokens_for(shape_name: str) -> int:
    s = SHAPES_BY_NAME[shape_name]
    if s.kind in ("train", "prefill"):
        return s.global_batch * s.seq_len
    return s.global_batch          # one decode step


def scan_trips(arch: str, shape_name: str) -> int:
    """XLA's cost_analysis counts a lax.scan body ONCE; the real program runs
    it `trips` times. Correction factor per cell (static, from configs):
    layer-scan trips x grad-accumulation microbatches x (for SSM prefill/
    train) the time-chunk scan. First-order: the non-scanned prologue
    (embed/unembed/optimizer) gets overcounted by the same factor — accepted
    and noted in EXPERIMENTS.md; the three hillclimbed cells are re-derived
    from their actual HLO."""
    from repro.configs import get_config
    cfg = get_config(arch)
    s = SHAPES_BY_NAME[shape_name]
    if cfg.family == "hybrid":
        layers = cfg.num_layers // (cfg.attn_period or cfg.num_layers)
    else:
        layers = cfg.num_layers
    trips = layers
    if s.kind == "train":
        trips *= 8                            # dryrun microbatches
    if cfg.family in ("ssm", "hybrid") and s.kind in ("train", "prefill"):
        chunk = (cfg.ssm.chunk if cfg.ssm else 128)
        trips *= max(s.seq_len // chunk, 1)
    return trips


def build_rows(results_dir: str, pod: str = "pod1") -> List[Dict]:
    rows = []
    for name in sorted(os.listdir(results_dir)):
        if not name.endswith(f"__{pod}.json"):
            continue
        rec = json.load(open(os.path.join(results_dir, name)))
        if rec.get("status") == "skipped":
            rows.append({"cell": rec["cell"], "skipped": True,
                         "reason": rec["reason"]})
            continue
        shape = rec["shape"]
        kind = SHAPES_BY_NAME[shape].kind
        trips = scan_trips(rec["arch"], shape)
        corrected = dict(rec)
        corrected["flops"] = rec["flops"] * trips
        corrected["bytes_accessed"] = rec["bytes_accessed"] * trips
        corrected["collective_bytes"] = {
            k: (v * trips if isinstance(v, (int, float)) else v)
            for k, v in rec["collective_bytes"].items()}
        rl = analyze_record(corrected, tokens_for(shape), kind)
        chips = 1
        for s in rec["mesh"]:
            chips *= s
        ideal_compute_s = rl.model_flops / (chips * 197e12)
        bound = max(rl.compute_s, rl.memory_s, rl.collective_s, 1e-30)
        rows.append({
            "cell": rec["cell"], "arch": rec["arch"], "shape": shape,
            "skipped": False, "chips": chips, "scan_trips": trips,
            "compute_s": rl.compute_s, "memory_s": rl.memory_s,
            "collective_s": rl.collective_s, "dominant": rl.dominant,
            "model_flops": rl.model_flops, "hlo_flops": rl.hlo_flops,
            "useful_ratio": rl.useful_ratio,
            "bound_s": bound,
            # fraction of the peak-FLOP roofline the *useful* model math
            # achieves if the dominant term fully serializes the step
            "roofline_frac": ideal_compute_s / bound,
            "temp_gb": (rec["memory"]["temp_bytes"] or 0) / 1e9,
            "arg_gb": (rec["memory"]["argument_bytes"] or 0) / 1e9,
            "compile_s": rec["compile_s"],
        })
    return rows


def fmt(v: float) -> str:
    if v == 0:
        return "0"
    if v < 1e-6:
        return f"{v * 1e9:.1f}n"
    if v < 1e-3:
        return f"{v * 1e6:.1f}u"
    if v < 1:
        return f"{v * 1e3:.2f}m"
    return f"{v:.2f}"


def markdown(rows: List[Dict]) -> str:
    out = ["| cell | compute | memory | collective | dominant | MODEL_FLOPs/HLO | roofline frac | mem/chip |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["skipped"]:
            out.append(f"| {r['cell']} | — | — | — | skipped | — | — | — |")
            continue
        out.append(
            f"| {r['cell']} | {fmt(r['compute_s'])}s | {fmt(r['memory_s'])}s "
            f"| {fmt(r['collective_s'])}s | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']:.2%} "
            f"| {r['arg_gb'] + r['temp_gb']:.2f} GB |")
    return "\n".join(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--json", default="")
    args = ap.parse_args()
    rows = build_rows(args.dir)
    print(markdown(rows))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
