"""Error-feedback int8 gradient compression for the DP all-reduce.

Distributed-optimization trick (beyond-paper #2, DESIGN.md §5): the data-
parallel all-reduce moves int8 codes + per-block f32 scales instead of f32
gradients — ~3.9x fewer bytes on the interconnect (the collective roofline
term). The per-device quantization residual is carried into the next step
(error feedback), which keeps SGD/Adam convergence unbiased to first order
[Seide et al. 2014; Karimireddy et al. 2019].

Usage inside a shard_map'd train step:
    g_q, new_residual = compress_with_feedback(g, residual, block)
    g_mean = psum(decompress(g_q)) / ndev        # or all-reduce the codes
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressor import dequantize_blocks, quantize_blocks

Tree = Any


def _block_for(n: int, block: int) -> int:
    return block if n % block == 0 and n >= block else n


def compress_leaf(g: jnp.ndarray, block: int):
    flat = g.astype(jnp.float32).reshape(-1)
    b = _block_for(flat.shape[0], block)
    codes, scales = quantize_blocks(flat, 8, b)
    return {"codes": codes, "scales": scales}


def decompress_leaf(c, shape, block: int) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    b = _block_for(n, block)
    return dequantize_blocks(c["codes"], c["scales"], 8, b,
                             jnp.float32).reshape(shape)


def compress_with_feedback(grads: Tree, residual: Tree, block: int = 512
                           ) -> Tuple[Tree, Tree]:
    """Returns (quantized grads tree, new residual tree)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        c = compress_leaf(corrected, block)
        back = decompress_leaf(c, g.shape, block)
        return c, corrected - back
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residual)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return treedef.unflatten([o[0] for o in out]), \
        treedef.unflatten([o[1] for o in out])


def decompress(qgrads: Tree, like: Tree, block: int = 512) -> Tree:
    flat_q, treedef = jax.tree_util.tree_flatten(
        qgrads, is_leaf=lambda x: isinstance(x, dict) and "codes" in x)
    flat_l = treedef.flatten_up_to(like)
    return treedef.unflatten(
        [decompress_leaf(q, l.shape, block) for q, l in zip(flat_q, flat_l)])


def init_residual(params: Tree) -> Tree:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_bytes(qgrads: Tree) -> int:
    import numpy as np
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(qgrads))
