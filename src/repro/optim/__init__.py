from repro.optim import adamw, gradcomp  # noqa: F401
