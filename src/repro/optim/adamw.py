"""AdamW with optional IBEX-compressed optimizer state.

``compress_state=True`` stores both Adam moments block-quantized (8-bit m,
8-bit v on a sqrt-companded scale) with per-block f32 scales — the IBEX
qpack compressor applied to training substrate. HBM for optimizer state drops
from 8 bytes/param (2xf32) to ~2.06 bytes/param, exactly the capacity-
expansion story of the paper turned onto the training side. Error behaves
like stochastic-rounding noise on the moments; wall-clock cost is two extra
qpack codec passes per step (measured in benchmarks/state_compression.py).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import OptimizerConfig
from repro.core.compressor import dequantize_blocks, quantize_blocks

Params = Any


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Params            # raw f32 moments, or (codes, scales) when compressed
    v: Params


def _blk(n: int, block: int) -> int:
    return block if n % block == 0 and n >= block else n


def _compress_leaf(x: jnp.ndarray, block: int):
    flat = x.reshape(-1)
    b = _blk(flat.shape[0], block)
    codes, scales = quantize_blocks(flat, 8, b)
    return {"codes": codes, "scales": scales, "block": jnp.int32(b)}


def _decompress_leaf(c, shape, block: int) -> jnp.ndarray:
    b = int(c["block"])
    return dequantize_blocks(c["codes"], c["scales"], 8, b,
                             jnp.float32).reshape(shape)


def init(params: Params, cfg: OptimizerConfig) -> AdamState:
    mdt = jnp.dtype(cfg.moment_dtype)

    # `+ 0` forces a fresh buffer per leaf — m and v must never alias, or
    # donating the optimizer state trips "donate the same buffer twice"
    def zeros_tree():
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, mdt) + jnp.asarray(0, mdt), params)

    if cfg.compress_state:
        comp = lambda t: jax.tree_util.tree_map(
            lambda z: _compress_leaf(z.astype(jnp.float32), cfg.state_block), t)
        return AdamState(jnp.int32(0), comp(zeros_tree()), comp(zeros_tree()))
    return AdamState(jnp.int32(0), zeros_tree(), zeros_tree())


def _lr_at(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step.astype(jnp.float32) / max(cfg.warmup_steps, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree: Params) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2)
                        for x in jax.tree_util.tree_leaves(tree)))


def update(grads: Params, state: AdamState, params: Params,
           cfg: OptimizerConfig) -> Tuple[Params, AdamState, Dict[str, jnp.ndarray]]:
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _lr_at(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    is_compressed = cfg.compress_state

    def upd(p, g, m_c, v_c):
        g = g.astype(jnp.float32) * clip
        if is_compressed:
            m = _decompress_leaf(m_c, p.shape, cfg.state_block)
            # v stored on a sqrt-companded scale to preserve dynamic range
            v = _decompress_leaf(v_c, p.shape, cfg.state_block) ** 2
        else:
            m, v = m_c.astype(jnp.float32), v_c.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if is_compressed:
            return newp, _compress_leaf(m, cfg.state_block), \
                _compress_leaf(jnp.sqrt(v), cfg.state_block)
        mdt = jnp.dtype(cfg.moment_dtype)
        return newp, m.astype(mdt), v.astype(mdt)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamState(step, new_m, new_v), metrics


def state_bytes(state: AdamState) -> int:
    import numpy as np
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(state))
