"""Fault-tolerant checkpointing: atomic, content-hashed, elastic.

* Atomic: write to ``<dir>/tmp.<step>`` then rename — a crash mid-save never
  corrupts the latest checkpoint.
* Content-hashed: a sha256 over the payload is stored in the manifest and
  verified on restore — silent disk corruption surfaces as a skipped
  checkpoint, and ``latest()`` falls back to the previous valid one.
* Elastic: arrays are saved unsharded (gathered) with their logical-axis
  annotations; ``restore`` re-shards onto *any* mesh via the rule table, so a
  job can resume on a different topology (node failures, pool resizes).
* Async: ``save_async`` hands the host copy to a writer thread — the step
  loop never blocks on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Tree = Any
_SEP = "/"


def jnp_cast(arr: np.ndarray, dtype) -> np.ndarray:
    """Cast through jnp (numpy lacks cast kernels for ml_dtypes)."""
    import jax.numpy as jnp
    if arr.dtype == dtype:
        return arr
    return np.asarray(jnp.asarray(arr).astype(dtype))


def _flatten(tree: Tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":   # npz cannot round-trip ml_dtypes
            arr = arr.view(np.uint16)
            key = key + "@bf16"
        flat[key] = arr
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def _tree_def(tree: Tree):
    return jax.tree_util.tree_structure(tree)


def save(ckpt_dir: str, step: int, tree: Tree, *, keep: int = 3,
         extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    h = hashlib.sha256()
    for key in sorted(flat):
        arr = flat[key]
        h.update(key.encode())
        h.update(arr.tobytes())
    np.savez(os.path.join(tmp, "arrays.npz"),
             **{k.replace("/", "|"): v for k, v in flat.items()})
    manifest = {"step": step, "sha256": h.hexdigest(),
                "keys": sorted(flat), "extra": extra or {}}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic publish
    _gc(ckpt_dir, keep)
    return final


_PENDING: List[threading.Thread] = []


def save_async(ckpt_dir: str, step: int, tree: Tree, *, keep: int = 3,
               extra: Optional[Dict[str, Any]] = None) -> threading.Thread:
    """Device->host copy happens here (cheap); disk I/O on a worker thread."""
    flat_host = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, flat_host),
        kwargs={"keep": keep, "extra": extra}, daemon=True)
    t.start()
    _PENDING.append(t)
    return t


def wait_pending() -> None:
    for t in _PENDING:
        t.join()
    _PENDING.clear()


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(list_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def _verify(path: str) -> bool:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "arrays.npz")) as z:
            h = hashlib.sha256()
            keys = manifest["keys"]
            arrays = {k: z[k.replace("/", "|")] for k in keys}
            for key in sorted(keys):
                h.update(key.encode())
                h.update(arrays[key].tobytes())
        return h.hexdigest() == manifest["sha256"]
    except Exception:
        return False


def latest(ckpt_dir: str) -> Optional[int]:
    """Newest checkpoint that passes integrity verification."""
    for s in reversed(list_steps(ckpt_dir)):
        if _verify(os.path.join(ckpt_dir, f"step_{s:08d}")):
            return s
    return None


def restore(ckpt_dir: str, step: int, like: Tree,
            shardings: Optional[Tree] = None) -> Tuple[Tree, Dict[str, Any]]:
    """Restore into the structure of ``like``; optionally re-shard (elastic).

    ``shardings``, when given, is a pytree of jax.sharding.Sharding matching
    ``like`` — arrays are placed directly onto the (possibly different) mesh."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k.replace("/", "|")] for k in manifest["keys"]}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for path_k, leaf in leaves_like:
        key = _SEP.join(_path_str(p) for p in path_k)
        if key + "@bf16" in flat:
            import ml_dtypes
            arr = flat[key + "@bf16"].view(ml_dtypes.bfloat16)
        else:
            arr = flat[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        out.append(jnp_cast(arr, leaf.dtype))
    tree = jax.tree_util.tree_structure(like).unflatten(out)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, manifest.get("extra", {})
