"""Train-step factory: microbatched, remat'd, sharded, optionally with
error-feedback compressed gradient collectives.

Two gradient-reduction modes:
  * GSPMD (default): params are FSDP-sharded over "data" (logical "fsdp"
    axis); XLA emits the optimal reduce-scatter/all-gather pair per layer,
    overlapped with the scan-over-layers compute.
  * compressed DP (ocfg.compress_grads): for replicated-param data-parallel
    runs, the cross-device mean is done manually inside shard_map as
    psum_scatter(f32) + int8 all-gather with error feedback —
    ~1.8x fewer wire bytes than a ring all-reduce (the collective roofline
    term; benchmarks/fig_gradcomp.py).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.common import sharding as SH
from repro.common.types import ModelConfig, OptimizerConfig, TrainConfig
from repro.models import transformer as T
from repro.optim import adamw, gradcomp

Tree = Any


def _microbatch(batch: Dict[str, jnp.ndarray], k: int) -> Dict[str, jnp.ndarray]:
    return jax.tree_util.tree_map(
        lambda x: x.reshape((k, x.shape[0] // k) + x.shape[1:]), batch)


def grads_and_loss(params: Tree, batch: Dict[str, jnp.ndarray],
                   cfg: ModelConfig, microbatches: int
                   ) -> Tuple[Tree, jnp.ndarray]:
    """Microbatched grad accumulation via lax.scan (constant live memory)."""
    if microbatches <= 1:
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, batch, cfg)[0])(params)
        return grads, loss

    mbs = _microbatch(batch, microbatches)

    def body(carry, mb):
        acc, loss_acc = carry
        loss, g = jax.value_and_grad(
            lambda p: T.loss_fn(p, mb, cfg)[0])(params)
        acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), acc, g)
        return (acc, loss_acc + loss), None

    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    (gsum, lsum), _ = jax.lax.scan(body, (zeros, jnp.float32(0)), mbs)
    inv = 1.0 / microbatches
    return jax.tree_util.tree_map(lambda g: g * inv, gsum), lsum * inv


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    mesh: Optional[Mesh] = None,
                    rules=SH.DEFAULT_RULES,
                    param_axes: Optional[Tree] = None):
    """Returns (train_step, shardings dict). Without a mesh: plain jit."""
    ocfg = tcfg.optimizer

    def step(params: Tree, opt: adamw.AdamState, batch: Dict[str, jnp.ndarray]
             ) -> Tuple[Tree, adamw.AdamState, Dict[str, jnp.ndarray]]:
        grads, loss = grads_and_loss(params, batch, cfg, tcfg.microbatches)
        new_params, new_opt, metrics = adamw.update(grads, opt, params, ocfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    if mesh is None:
        return jax.jit(step, donate_argnums=(0, 1)), None

    assert param_axes is not None
    p_shard = SH.tree_shardings(mesh, param_axes, rules)
    opt_shard = adamw.AdamState(
        step=NamedSharding(mesh, P()),
        m=_opt_tree_shardings(p_shard, ocfg, mesh),
        v=_opt_tree_shardings(p_shard, ocfg, mesh))
    batch_spec = NamedSharding(mesh, SH.logical_to_spec(
        ("batch", "seq"), rules, mesh.axis_names))
    batch_shard = {"tokens": batch_spec, "labels": batch_spec}
    if cfg.frontend != "none":
        batch_shard["embeds"] = NamedSharding(mesh, SH.logical_to_spec(
            ("batch", "seq", "embed"), rules, mesh.axis_names))
    metrics_shard = {k: NamedSharding(mesh, P()) for k in
                     ("loss", "grad_norm", "lr")}
    fn = jax.jit(step,
                 in_shardings=(p_shard, opt_shard, batch_shard),
                 out_shardings=(p_shard, opt_shard, metrics_shard),
                 donate_argnums=(0, 1))
    return fn, {"params": p_shard, "opt": opt_shard, "batch": batch_shard}


def _opt_tree_shardings(p_shard: Tree, ocfg: OptimizerConfig, mesh: Mesh):
    """Moment shardings mirror params; compressed moments are replicated
    blobs (codes/scales flattened — sharded by fsdp is possible but the
    compressed footprint is small enough to keep simple)."""
    if not ocfg.compress_state:
        return p_shard
    rep = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda s: {"codes": rep, "scales": rep, "block": rep}, p_shard)


# ---------------------------------------------------------------------------
# Compressed-collective DP step (replicated params) via shard_map.
# ---------------------------------------------------------------------------

def make_dp_compressed_step(cfg: ModelConfig, tcfg: TrainConfig, mesh: Mesh,
                            axis: str = "data"):
    """Data-parallel step with int8 error-feedback gradient collectives.

    Params replicated; batch sharded on ``axis``. Per step and per device the
    wire traffic is size(f32)·(N-1)/N (psum_scatter) + size/4 (int8
    all-gather) ≈ 1.25x size vs 2x size for a ring all-reduce."""
    from jax.experimental.shard_map import shard_map
    ocfg = tcfg.optimizer
    ndev = 1
    for ax, sz in zip(mesh.axis_names, mesh.devices.shape):
        if ax == axis:
            ndev = sz

    def step(params, opt, residual, batch):
        def inner(params, opt, residual, batch):
            grads, loss = grads_and_loss(params, batch, cfg, tcfg.microbatches)
            loss = jax.lax.pmean(loss, axis)

            def reduce_one(g, r):
                """g leaf; r [1, n] this device's error-feedback residual."""
                gf = g.astype(jnp.float32)
                flat = gf.reshape(-1)
                n = flat.shape[0]
                if n % ndev or n < 4 * ndev:      # tiny leaves: plain psum
                    return jax.lax.pmean(gf, axis), r
                ns = n // ndev
                shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0,
                                             tiled=True) / ndev
                rs = r[0, :ns]
                blk = gradcomp._block_for(ns, 512)
                c = gradcomp.compress_leaf(shard + rs, blk)
                back = gradcomp.decompress_leaf(c, (ns,), blk)
                new_r = r.at[0, :ns].set(shard + rs - back)
                codes = jax.lax.all_gather(c["codes"], axis, tiled=True)
                scales = jax.lax.all_gather(c["scales"], axis, tiled=True)
                full = gradcomp.decompress_leaf(
                    {"codes": codes, "scales": scales}, (n,), blk)
                return full.reshape(g.shape), new_r

            flat_g, treedef = jax.tree_util.tree_flatten(grads)
            flat_r = treedef.flatten_up_to(residual)
            out = [reduce_one(g, r) for g, r in zip(flat_g, flat_r)]
            grads = treedef.unflatten([o[0] for o in out])
            residual = treedef.unflatten([o[1] for o in out])
            new_params, new_opt, metrics = adamw.update(grads, opt, params, ocfg)
            metrics["loss"] = loss
            return new_params, new_opt, residual, metrics

        rep = P()
        return shard_map(
            inner, mesh=mesh,
            in_specs=(rep, rep, P(axis), P(axis)),
            out_specs=(rep, rep, P(axis), rep),
            check_rep=False)(params, opt, residual, batch)

    return jax.jit(step, donate_argnums=(0, 1, 2))


def init_residual_flat(params: Tree, ndev: int) -> Tree:
    """Per-device EF residuals: [ndev, size] leaves, sharded on the DP axis."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros((ndev, p.size), jnp.float32), params)
