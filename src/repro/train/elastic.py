"""Elastic scaling + failure handling (the 1000+-node story).

Mechanisms (all exercised in tests/test_train_stack.py on CPU):
  * mesh planning — ``plan_mesh(n)`` picks (data, model) / (pod, data, model)
    factors for whatever device count survives a failure;
  * elastic restore — checkpoints store arrays unsharded + logical axes, so
    restore re-shards onto the new mesh (checkpoint.restore(shardings=...));
  * deterministic replay — the data pipeline is a pure fn of (step, shard):
    a replacement rank regenerates its shard bit-exactly; a backup rank can
    race a straggler on the same shard with identical results (speculative
    execution is safe);
  * step-level retry — launch/train.py wraps the step in retry-from-last-
    checkpoint; the deterministic pipeline makes replays exact.

At 1000+ nodes the coordinator-free pattern is: every pod runs DP replicas;
on pod loss the job restores the latest verified checkpoint onto
plan_mesh(remaining), re-shards, and continues — no global barrier beyond
the restore itself. Spare-pod hot swap = the same restore path with equal
device count.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

from repro.common.types import MeshConfig


def _best_2d(n: int, prefer_model: int) -> Tuple[int, int]:
    """Factor n into (data, model) with model as close to prefer_model as
    possible (model must divide n)."""
    best = (n, 1)
    for model in range(1, n + 1):
        if n % model:
            continue
        if model <= prefer_model:
            best = (n // model, model)
    return best


def plan_mesh(n_devices: int, *, prefer_model: int = 16,
              pods: int = 1) -> MeshConfig:
    """Mesh for an arbitrary surviving device count."""
    if pods > 1 and n_devices % pods == 0:
        per_pod = n_devices // pods
        d, m = _best_2d(per_pod, prefer_model)
        return MeshConfig(shape=(pods, d, m), axes=("pod", "data", "model"))
    d, m = _best_2d(n_devices, prefer_model)
    return MeshConfig(shape=(d, m), axes=("data", "model"))


def degraded_plan(old: MeshConfig, lost_devices: int) -> MeshConfig:
    """Re-plan after losing ``lost_devices`` (drop to the largest usable
    device count that keeps the model axis intact)."""
    total = old.num_devices - lost_devices
    model = old.shape[-1]
    usable = (total // model) * model
    if usable == 0:
        model, usable = 1, total
    pods = old.shape[0] if len(old.shape) == 3 else 1
    if pods > 1 and usable % pods != 0:
        pods = 1
    return plan_mesh(usable, prefer_model=model, pods=pods)


class StragglerMonitor:
    """EWMA step-time tracker: flags ranks whose step time exceeds
    ``threshold`` x the fleet median — the launcher then reassigns their data
    shard to a backup rank (safe: the pipeline is deterministic per shard)."""

    def __init__(self, n_ranks: int, alpha: float = 0.2,
                 threshold: float = 2.0):
        self.ewma = [0.0] * n_ranks
        self.alpha = alpha
        self.threshold = threshold

    def record(self, rank: int, step_time: float) -> None:
        e = self.ewma[rank]
        self.ewma[rank] = step_time if e == 0 else \
            (1 - self.alpha) * e + self.alpha * step_time

    def stragglers(self) -> list:
        live = sorted(e for e in self.ewma if e > 0)
        if not live:
            return []
        median = live[len(live) // 2]
        return [i for i, e in enumerate(self.ewma)
                if e > self.threshold * median]
