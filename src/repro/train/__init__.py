from repro.train import checkpoint, elastic, trainer  # noqa: F401
