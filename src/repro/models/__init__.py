from repro.models import decode, layers, moe, ssm, transformer  # noqa: F401
