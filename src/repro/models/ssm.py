"""Selective state-space layers: Mamba1 (falcon-mamba) and Mamba2/SSD (zamba2).

Prefill/training uses a chunked scan: lax.scan over time chunks carrying the
[.., d, N] state, with an associative scan inside each chunk — O(chunk) live
memory, exact, differentiable. Decode is the O(1)-state recurrence (these
archs have *no KV cache*; see DESIGN.md §4 arch-applicability).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig, SSMConfig
from repro.models.layers import _init, rms_norm

Params = Dict[str, Any]


def _dt_rank(cfg: ModelConfig) -> int:
    return max(1, cfg.d_model // 16)


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv. x [B,T,C], w [C,K], b [C]. init_state [B,K-1,C]
    supplies the left context (decode); zeros otherwise."""
    B, T, C = x.shape
    K = w.shape[1]
    if init_state is None:
        init_state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + xp[:, i:i + T].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return jax.nn.silu(out + b.astype(jnp.float32)).astype(x.dtype)


def _chunked_ssm_scan(decay: jnp.ndarray, inp: jnp.ndarray, h0: jnp.ndarray,
                      chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = decay_t * h_{t-1} + inp_t along axis 1 (time).

    decay/inp [B, T, ...]; h0 [B, ...]. Returns (h_all [B,T,...], h_T).

    NOTE: materializes the full state history — use only for short T
    (decode steps). Prefill/training must use ``_chunked_ssm_scan_out``,
    which keeps the [chunk, ..., N] states VMEM-transient (§Perf cell B:
    this was the single largest memory-roofline term in the baseline)."""
    B, T = inp.shape[0], inp.shape[1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    dc = decay.reshape((B, nc, chunk) + decay.shape[2:]).swapaxes(0, 1)
    ic = inp.reshape((B, nc, chunk) + inp.shape[2:]).swapaxes(0, 1)

    def combine(a, b):
        (da, ia), (db, ib) = a, b
        return da * db, db * ia + ib

    def body(h, xs):
        d, i = xs                                   # [B, chunk, ...]
        dd, ii = jax.lax.associative_scan(combine, (d, i), axis=1)
        h_all = dd * h[:, None] + ii
        return h_all[:, -1], h_all

    hT, h_all = jax.lax.scan(body, h0, (dc, ic))
    h_all = h_all.swapaxes(0, 1).reshape((B, T) + inp.shape[2:])
    return h_all, hT


def _chunked_ssm_scan_out(ins, h0: jnp.ndarray, make_decay_inp, contract,
                          chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunked selective scan that keeps every [.., N]-expanded tensor
    chunk-local: per chunk, ``decay, inp = make_decay_inp(ins_chunk)`` builds
    the [B, chunk, ..., N] recurrence operands (the dt*x (x) B outer product
    included — materializing it for the full T was the baseline's largest
    memory-roofline term, §Perf cell B), the state recurrence runs as an
    associative scan, and ``y_chunk = contract(h_chunk, ins_chunk)`` reduces
    N away before anything returns to HBM. The scan emits [B, T, out...].

    ins: pytree of [B, T, ...] per-timestep tensors; h0 [B, ..., N]."""
    leaves = jax.tree_util.tree_leaves(ins)
    B, T = leaves[0].shape[0], leaves[0].shape[1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nc = T // chunk
    resh = lambda a: a.reshape((B, nc, chunk) + a.shape[2:]).swapaxes(0, 1)
    ins_c = jax.tree_util.tree_map(resh, ins)

    def combine(a, b):
        (da, ia), (db, ib) = a, b
        return da * db, db * ia + ib

    def body(h, xs):
        d, i = make_decay_inp(xs)
        dd, ii = jax.lax.associative_scan(combine, (d, i), axis=1)
        h_all = dd * h[:, None] + ii                # [B, chunk, ..., N]
        return h_all[:, -1], contract(h_all, xs)

    hT, ys = jax.lax.scan(body, h0, ins_c)
    ys = ys.swapaxes(0, 1).reshape((B, T) + ys.shape[3:])
    return ys, hT


# ---------------------------------------------------------------------------
# Mamba1
# ---------------------------------------------------------------------------

class Mamba1State(NamedTuple):
    h: jnp.ndarray        # [B, d_in, N]
    conv: jnp.ndarray     # [B, K-1, d_in]


def mamba1_init(key, cfg: ModelConfig) -> Tuple[Params, Dict[str, Any]]:
    ssm = cfg.ssm or SSMConfig()
    d = cfg.d_model
    d_in = ssm.expand * d
    r = _dt_rank(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "in_proj": _init(ks[0], (d, 2 * d_in)),
        "conv_w": _init(ks[1], (d_in, ssm.d_conv), scale=0.5),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": _init(ks[2], (d_in, r + 2 * ssm.d_state)),
        "dt_proj": _init(ks[3], (r, d_in), scale=r ** -0.5),
        "dt_bias": jnp.full((d_in,), -4.6, jnp.float32),  # softplus ~ 0.01
        "A_log": jnp.log(jnp.tile(jnp.arange(1, ssm.d_state + 1,
                                             dtype=jnp.float32), (d_in, 1))),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": _init(ks[4], (d_in, d), scale=d_in ** -0.5),
    }
    axes = {"in_proj": ("fsdp", "mlp"), "conv_w": ("mlp", None),
            "conv_b": ("mlp",), "x_proj": ("mlp", None),
            "dt_proj": (None, "mlp"), "dt_bias": ("mlp",),
            "A_log": ("mlp", "state"), "D": ("mlp",),
            "out_proj": ("mlp", "fsdp")}
    return params, axes


def _mamba1_core(p: Params, xconv: jnp.ndarray, z: jnp.ndarray,
                 h0: jnp.ndarray, cfg: ModelConfig,
                 return_all: bool) -> Tuple[jnp.ndarray, jnp.ndarray]:
    ssm = cfg.ssm or SSMConfig()
    r = _dt_rank(cfg)
    dbc = xconv @ p["x_proj"].astype(xconv.dtype)
    dt, Bc, Cc = jnp.split(dbc, [r, r + ssm.d_state], axis=-1)
    dt = jax.nn.softplus((dt @ p["dt_proj"].astype(xconv.dtype))
                         .astype(jnp.float32) + p["dt_bias"])     # [B,T,d_in]
    A = -jnp.exp(p["A_log"])                                      # [d_in, N]

    # decay/inp built per chunk; C contracted per chunk: nothing [T, d, N]
    # ever reaches HBM (§Perf cell B)
    def make_di(xs):
        dtc, xc, bc, _ = xs
        decay = jnp.exp(dtc[..., None] * A)                       # [B,c,d,N]
        inp = (dtc * xc.astype(jnp.float32))[..., None] * \
            bc.astype(jnp.float32)[:, :, None, :]
        return decay, inp

    y, hT = _chunked_ssm_scan_out(
        (dt, xconv, Bc, Cc.astype(jnp.float32)), h0, make_di,
        lambda h, xs: jnp.einsum("btdn,btn->btd", h, xs[3]), ssm.chunk)
    y = y + p["D"] * xconv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(xconv.dtype)
    return y @ p["out_proj"].astype(xconv.dtype), hT


def mamba1_apply_train(p: Params, u: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    ssm = cfg.ssm or SSMConfig()
    B, T, _ = u.shape
    d_in = ssm.expand * cfg.d_model
    xz = u @ p["in_proj"].astype(u.dtype)
    x, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(x, p["conv_w"], p["conv_b"])
    h0 = jnp.zeros((B, d_in, ssm.d_state), jnp.float32)
    y, _ = _mamba1_core(p, xc, z, h0, cfg, return_all=True)
    return y


def mamba1_init_state(cfg: ModelConfig, batch: int) -> Mamba1State:
    ssm = cfg.ssm or SSMConfig()
    d_in = ssm.expand * cfg.d_model
    return Mamba1State(
        h=jnp.zeros((batch, d_in, ssm.d_state), jnp.float32),
        conv=jnp.zeros((batch, ssm.d_conv - 1, d_in), jnp.bfloat16))


def mamba1_decode(p: Params, u: jnp.ndarray, state: Mamba1State,
                  cfg: ModelConfig) -> Tuple[jnp.ndarray, Mamba1State]:
    """u [B,1,d] one token. O(1) state update."""
    xz = u @ p["in_proj"].astype(u.dtype)
    x, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(x, p["conv_w"], p["conv_b"], init_state=state.conv)
    y, hT = _mamba1_core(p, xc, z, state.h, cfg, return_all=False)
    new_conv = jnp.concatenate([state.conv[:, 1:], x.astype(state.conv.dtype)],
                               axis=1)
    return y, Mamba1State(h=hT, conv=new_conv)


# ---------------------------------------------------------------------------
# Mamba2 (SSD, scalar decay per head)
# ---------------------------------------------------------------------------

class Mamba2State(NamedTuple):
    h: jnp.ndarray        # [B, H, P, N]
    conv: jnp.ndarray     # [B, K-1, d_in]


def mamba2_init(key, cfg: ModelConfig) -> Tuple[Params, Dict[str, Any]]:
    ssm = cfg.ssm or SSMConfig(kind="mamba2")
    d = cfg.d_model
    d_in = ssm.expand * d
    nheads = d_in // ssm.headdim
    g, n = ssm.ngroups, ssm.d_state
    ks = jax.random.split(key, 4)
    params = {
        "in_proj": _init(ks[0], (d, 2 * d_in + 2 * g * n + nheads)),
        "conv_w": _init(ks[1], (d_in, ssm.d_conv), scale=0.5),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "dt_bias": jnp.full((nheads,), -4.6, jnp.float32),
        "A_log": jnp.zeros((nheads,), jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "norm_w": jnp.ones((d_in,), jnp.float32),
        "out_proj": _init(ks[2], (d_in, d), scale=d_in ** -0.5),
    }
    axes = {"in_proj": ("fsdp", "mlp"), "conv_w": ("mlp", None),
            "conv_b": ("mlp",), "dt_bias": (None,), "A_log": (None,),
            "D": (None,), "norm_w": ("mlp",), "out_proj": ("mlp", "fsdp")}
    return params, axes


def _mamba2_core(p: Params, xc, Bc, Cc, dt, z, h0, cfg: ModelConfig):
    ssm = cfg.ssm or SSMConfig(kind="mamba2")
    B_, T, d_in = xc.shape
    H = d_in // ssm.headdim
    P, N, g = ssm.headdim, ssm.d_state, ssm.ngroups
    xh = xc.reshape(B_, T, H, P).astype(jnp.float32)
    Bg = Bc.reshape(B_, T, g, N).astype(jnp.float32)
    Cg = Cc.reshape(B_, T, g, N).astype(jnp.float32)
    rep = H // g
    Bh = jnp.repeat(Bg, rep, axis=2)                   # [B,T,H,N]
    Ch = jnp.repeat(Cg, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # [B,T,H]
    A = -jnp.exp(p["A_log"])                                     # [H]

    # decay/inp built per chunk; C contracted per chunk (§Perf cell B):
    # the [T, H, P, N] outer product never reaches HBM
    def make_di(xs):
        dtc, xc, bc, _ = xs
        decay = jnp.exp(dtc * A)[..., None, None]                # [B,c,H,1,1]
        inp = (dtc[..., None] * xc)[..., None] * bc[:, :, :, None, :]
        return decay, inp

    y, hT = _chunked_ssm_scan_out(
        (dt, xh, Bh, Ch), h0, make_di,
        lambda h, xs: jnp.einsum("bthpn,bthn->bthp", h, xs[3]), ssm.chunk)
    y = y + p["D"][:, None] * xh                                 # [B,T,H,P]
    y = y.reshape(B_, T, d_in)
    y = rms_norm((y * jax.nn.silu(z.astype(jnp.float32))).astype(xc.dtype),
                 p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"].astype(xc.dtype), hT


def _mamba2_split(p: Params, u: jnp.ndarray, cfg: ModelConfig):
    ssm = cfg.ssm or SSMConfig(kind="mamba2")
    d_in = ssm.expand * cfg.d_model
    g, n = ssm.ngroups, ssm.d_state
    nheads = d_in // ssm.headdim
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    return jnp.split(zxbcdt, [d_in, 2 * d_in, 2 * d_in + g * n,
                              2 * d_in + 2 * g * n], axis=-1)  # z,x,B,C,dt


def mamba2_apply_train(p: Params, u: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    ssm = cfg.ssm or SSMConfig(kind="mamba2")
    B_, T, _ = u.shape
    d_in = ssm.expand * cfg.d_model
    H = d_in // ssm.headdim
    z, x, Bc, Cc, dt = _mamba2_split(p, u, cfg)
    xc = _causal_conv(x, p["conv_w"], p["conv_b"])
    h0 = jnp.zeros((B_, H, ssm.headdim, ssm.d_state), jnp.float32)
    y, _ = _mamba2_core(p, xc, Bc, Cc, dt, z, h0, cfg)
    return y


def mamba2_init_state(cfg: ModelConfig, batch: int) -> Mamba2State:
    ssm = cfg.ssm or SSMConfig(kind="mamba2")
    d_in = ssm.expand * cfg.d_model
    H = d_in // ssm.headdim
    return Mamba2State(
        h=jnp.zeros((batch, H, ssm.headdim, ssm.d_state), jnp.float32),
        conv=jnp.zeros((batch, ssm.d_conv - 1, d_in), jnp.bfloat16))


def mamba2_decode(p: Params, u: jnp.ndarray, state: Mamba2State,
                  cfg: ModelConfig) -> Tuple[jnp.ndarray, Mamba2State]:
    z, x, Bc, Cc, dt = _mamba2_split(p, u, cfg)
    xc = _causal_conv(x, p["conv_w"], p["conv_b"], init_state=state.conv)
    y, hT = _mamba2_core(p, xc, Bc, Cc, dt, z, state.h, cfg)
    new_conv = jnp.concatenate([state.conv[:, 1:], x.astype(state.conv.dtype)],
                               axis=1)
    return y, Mamba2State(h=hT, conv=new_conv)
