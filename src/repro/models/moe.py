"""Mixture-of-Experts layer (qwen3-moe: 128e top-8; arctic: 128e top-2 +
dense residual).

Sort-based capacity dispatch (the production TPU pattern): tokens are grouped
by expert with a single argsort, truncated at capacity C = ceil(k*N/E * cf),
processed as one [E, C, D] batched einsum (experts sharded on the "expert"
logical axis -> EP over "model"), and gathered back differentiably. No
[N, E, C] one-hot tensors are ever materialized."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig, MoEConfig
from repro.models.layers import _init, mlp_apply, mlp_init

CAPACITY_FACTOR = 1.25


def moe_init(key, cfg: ModelConfig) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    mo = cfg.moe or MoEConfig()
    d, f, e = cfg.d_model, mo.expert_d_ff, mo.num_experts
    ks = jax.random.split(key, 5)
    params = {
        "router": _init(ks[0], (d, e), scale=0.02),
        "wi": _init(ks[1], (e, d, f)),
        "wg": _init(ks[2], (e, d, f)),
        "wo": _init(ks[3], (e, f, d), scale=1.0 / (f ** 0.5)),
    }
    axes = {"router": ("fsdp", None), "wi": ("expert", "fsdp", "expert_mlp"),
            "wg": ("expert", "fsdp", "expert_mlp"),
            "wo": ("expert", "expert_mlp", "fsdp")}
    if mo.dense_residual:
        dp, da = mlp_init(ks[4], d, mo.dense_d_ff or cfg.d_ff)
        params["dense"] = dp
        axes["dense"] = da
    return params, axes


GROUP_TOKENS = 512   # grouped dispatch: tokens per routing group


def moe_apply_grouped(p: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """GShard-style grouped einsum dispatch (the GSPMD-native pattern).

    Tokens are viewed as [G, Sg, D] groups (G inherits the batch sharding);
    dispatch/combine are one-hot [G, Sg, E, C] tensors contracted with
    einsums, so the data->expert movement lowers to a clean all-to-all
    instead of the replicating gathers that index-based dispatch costs under
    GSPMD (§Perf cell C: the sort-based path moved ~8x more collective
    bytes). Dispatch-matmul overhead is ~2*k*Sg*cf/d of the expert compute
    (~4% for arctic at Sg=512). Tokens beyond per-group capacity
    C = ceil(k*Sg*cf/E) are dropped (standard GShard semantics)."""
    mo = cfg.moe or MoEConfig()
    B, S, d = x.shape
    e, k = mo.num_experts, mo.top_k
    n = B * S
    sg = min(GROUP_TOKENS, n)
    g = n // sg
    cap = max(1, int(-(-(k * sg * CAPACITY_FACTOR) // e)))

    xg = x.reshape(g, sg, d)
    logits = (xg @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [G,Sg,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                           # [G,Sg,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    dispatch = jnp.zeros((g, sg, e, cap), jnp.bool_)
    combine = jnp.zeros((g, sg, e, cap), jnp.float32)
    # running per-(group, expert) fill count threads the k choices
    fill = jnp.zeros((g, e), jnp.int32)
    for j in range(k):
        oh = jax.nn.one_hot(top_i[..., j], e, dtype=jnp.int32)       # [G,Sg,E]
        pos = fill[:, None, :] + jnp.cumsum(oh, axis=1) - oh         # excl.
        keep = (oh > 0) & (pos < cap)
        cslot = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                               dtype=jnp.float32)[..., :cap]         # [G,Sg,E,C]
        sel = keep[..., None] & (cslot > 0)
        dispatch = dispatch | sel
        combine = combine + top_p[..., j][..., None, None] * sel
        fill = fill + jnp.sum(oh, axis=1)

    dsp = dispatch.astype(x.dtype)
    xe = jnp.einsum("gsec,gsd->egcd", dsp, xg)                       # [E,G,C,D]
    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["wg"].astype(x.dtype))) \
        * jnp.einsum("egcd,edf->egcf", xe, p["wi"].astype(x.dtype))
    ye = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)
    out = out.reshape(B, S, d)

    if mo.dense_residual and "dense" in p:
        out = out + mlp_apply(p["dense"], x)
    frac = jnp.mean(dispatch.any(-1).astype(jnp.float32), axis=(0, 1))
    mprob = jnp.mean(probs, axis=(0, 1))
    aux = jnp.sum(frac * mprob) * e * mo.load_balance_coef
    return out, aux


def moe_apply(p: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (out [B,S,D], aux load-balance loss scalar).

    Dispatches to the grouped einsum path for multi-token inputs (the
    distributed-friendly default); single-token decode keeps the sort-based
    path (tiny n, no dispatch-matmul overhead)."""
    B, S, _ = x.shape
    if B * S >= 2 * GROUP_TOKENS:
        return moe_apply_grouped(p, x, cfg)
    return moe_apply_sorted(p, x, cfg)


def moe_apply_sorted(p: Dict[str, Any], x: jnp.ndarray, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sort-based capacity dispatch (single-host / decode path)."""
    mo = cfg.moe or MoEConfig()
    B, S, d = x.shape
    e, k = mo.num_experts, mo.top_k
    n = B * S
    cap = max(1, int(-(-(k * n * CAPACITY_FACTOR) // e)))

    xf = x.reshape(n, d)
    logits = (xf @ p["router"].astype(x.dtype)).astype(jnp.float32)  # [N,E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                           # [N,k]
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # group (token, choice) pairs by expert
    flat_e = top_i.reshape(n * k)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e)
    sort_e = flat_e[order]
    sort_tok = flat_tok[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(n * k) - starts[sort_e]
    keep = pos < cap
    slot = jnp.where(keep, sort_e * cap + pos, e * cap)  # drop slot = e*cap

    # dispatch: xe [E*C+1, D] (last row is the drop bin)
    xe = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].add(xf[sort_tok])
    xe = xe[:-1].reshape(e, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(x.dtype))) \
        * jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(x.dtype))
    ye = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))

    # gather back (unsort) with routing weights; dropped pairs contribute 0
    ye_flat = jnp.concatenate([ye.reshape(e * cap, d),
                               jnp.zeros((1, d), x.dtype)], axis=0)
    contrib = ye_flat[slot]                                           # [N*k, D]
    unsorted = jnp.zeros((n * k, d), x.dtype).at[order].set(contrib)
    w = top_p.reshape(n, k).astype(x.dtype)
    out = jnp.einsum("nkd,nk->nd", unsorted.reshape(n, k, d), w).reshape(B, S, d)

    if mo.dense_residual and "dense" in p:
        out = out + mlp_apply(p["dense"], x)
    # switch-style aux loss over the *routed* (pre-drop) assignment
    frac = counts.astype(jnp.float32) / (n * k)
    mprob = jnp.mean(probs, axis=0)
    aux = jnp.sum(frac * mprob) * e * mo.load_balance_coef
    return out, aux
