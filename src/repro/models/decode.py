"""Serving decode path: IBEX-compressed paged KV cache + one-token step.

The KV cache *is* an IBEX pool specialized for append-only data:

  * hot window (promoted region) — last ``W`` tokens per sequence, bf16 ring
    buffer. New K/V lands here (first-touch data is stored hot, §4.1).
  * compressed region — every token older than ``W``, block-quantized
    (one block per (token, kv-head) over the head dim; 4 or 8 bits + f32
    scale). A token is compressed exactly once, when it ages out of the ring
    (its slot is reused) — the streaming analogue of clock demotion for
    append-only data, where *every* demotion is clean (§4.5: no recompression
    ever happens; the paper measures 62% clean on general traffic, KV reaches
    100%).

Two read paths for the compressed prefix (EXPERIMENTS.md §Perf):
  * fused  — dequantize-inside-attention (ops.kvc kernel on TPU; the chunked
    jnp equivalent under GSPMD): HBM bytes = compressed bytes.  [beyond-paper]
  * paper  — promote-then-read: the prefix is materialized to bf16 (an
    optimization_barrier'd buffer = the promoted-region write+read), then
    attended uncompressed.                                       [faithful]
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import MLAConfig, ModelConfig, ServeConfig, SSMConfig
from repro.core.compressor import dequantize_blocks, quantize_blocks
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models import transformer as T

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Online-softmax partials and merging
# ---------------------------------------------------------------------------

class Partial(NamedTuple):
    m: jnp.ndarray     # [B, H, 1]
    l: jnp.ndarray     # [B, H, 1]
    acc: jnp.ndarray   # [B, H, D]


def merge_partials(a: Partial, b: Partial) -> Partial:
    m = jnp.maximum(a.m, b.m)
    ea, eb = jnp.exp(a.m - m), jnp.exp(b.m - m)
    return Partial(m, a.l * ea + b.l * eb, a.acc * ea + b.acc * eb)


def finish(p: Partial, dtype) -> jnp.ndarray:
    return (p.acc / jnp.maximum(p.l, 1e-30)).astype(dtype)


def _attend_partial(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    valid: jnp.ndarray, sm_scale: float) -> Partial:
    """q [B,Hq,D]; k,v [B,T,Hkv,D] f32; valid [B,T] -> partial."""
    B, Hq, D = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qf, k) * sm_scale
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)                       # [B,Hkv,g,1]
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bhgt,bthd->bhgd", p, v)
    return Partial(m.reshape(B, Hq, 1), l.reshape(B, Hq, 1),
                   acc.reshape(B, Hq, D))


def quantized_attention_partial(q: jnp.ndarray, k_codes, k_scales, v_codes,
                                v_scales, length: jnp.ndarray, *, bits: int,
                                chunk: int, sm_scale: float,
                                paper_mode: bool = False) -> Partial:
    """Attention partial over the compressed prefix.

    fused: chunk-*parallel* flash-decode — every KV chunk computes a local
    softmax partial, then partials merge with a max/sum reduction. Two
    properties matter: (1) XLA fuses the int4/8 dequant into the dot-operand
    read, so HBM bytes = compressed bytes [beyond-paper]; (2) the chunk axis
    is born from a reshape of the sequence axis, so a sequence-sharded cache
    (long_500k cells) turns the merge reductions into small cross-device
    all-reduces — sequence-parallel decode attention for free under GSPMD.

    paper: materialize the full bf16 prefix first (the promoted-region write+
    read round trip, optimization_barrier'd so XLA cannot fuse it away), then
    attend uncompressed."""
    B, Hq, D = q.shape
    Sc, Hkv = k_codes.shape[1], k_codes.shape[2]
    chunk = min(chunk, Sc)
    assert Sc % chunk == 0
    nch = Sc // chunk
    g = Hq // Hkv

    if paper_mode:
        k = dequantize_blocks(k_codes, k_scales[..., None], bits, D,
                              jnp.bfloat16)
        v = dequantize_blocks(v_codes, v_scales[..., None], bits, D,
                              jnp.bfloat16)
        # the promoted-region round trip: force materialization
        k, v = jax.lax.optimization_barrier((k, v))
        valid = jnp.arange(Sc)[None, :] < length[:, None]
        return _attend_partial(q, k.astype(jnp.float32),
                               v.astype(jnp.float32), valid, sm_scale)

    resh = lambda a: a.reshape((B, nch, chunk) + a.shape[2:])
    k = dequantize_blocks(resh(k_codes), resh(k_scales)[..., None], bits, D,
                          jnp.float32)                       # [B,n,t,Hkv,D]
    v = dequantize_blocks(resh(v_codes), resh(v_scales)[..., None], bits, D,
                          jnp.float32)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, D)
    s = jnp.einsum("bhgd,bnthd->bnhgt", qf, k) * sm_scale    # [B,n,Hkv,g,t]
    tpos = (jnp.arange(nch)[:, None] * chunk + jnp.arange(chunk)[None, :])
    valid = tpos[None] < length[:, None, None]               # [B,n,t]
    s = jnp.where(valid[:, :, None, None, :], s, -1e30)
    m_i = jnp.max(s, axis=-1, keepdims=True)                 # [B,n,Hkv,g,1]
    p = jnp.exp(s - m_i)
    l_i = jnp.sum(p, axis=-1, keepdims=True)
    acc_i = jnp.einsum("bnhgt,bnthd->bnhgd", p, v)           # [B,n,Hkv,g,D]
    m = jnp.max(m_i, axis=1, keepdims=True)                  # [B,1,Hkv,g,1]
    w = jnp.exp(m_i - m)
    l = jnp.sum(w * l_i, axis=1)                             # [B,Hkv,g,1]
    acc = jnp.sum(w * acc_i, axis=1)
    return Partial(m[:, 0].reshape(B, Hq, 1), l.reshape(B, Hq, 1),
                   acc.reshape(B, Hq, D))


# ---------------------------------------------------------------------------
# Cache containers (stacked on a leading layer/group axis)
# ---------------------------------------------------------------------------

def init_gqa_cache(cfg: ModelConfig, scfg: ServeConfig, batch: int,
                   max_len: int, n_sites: int) -> Dict[str, jnp.ndarray]:
    Hkv, D = cfg.num_kv_heads, cfg.resolved_head_dim
    W = scfg.hot_window
    bits = scfg.kv_rate_bits
    Dp = D * bits // 8
    z = functools.partial(jnp.zeros)
    return {
        "k_codes": z((n_sites, batch, max_len, Hkv, Dp), jnp.uint8),
        "k_scales": z((n_sites, batch, max_len, Hkv), jnp.float32),
        "v_codes": z((n_sites, batch, max_len, Hkv, Dp), jnp.uint8),
        "v_scales": z((n_sites, batch, max_len, Hkv), jnp.float32),
        "k_hot": z((n_sites, batch, W, Hkv, D), jnp.bfloat16),
        "v_hot": z((n_sites, batch, W, Hkv, D), jnp.bfloat16),
        # boundary between compressed region and hot ring per lane: positions
        # < cold_len live in codes (the pool's per-sequence metadata; lets a
        # resumed request start with an empty ring — promotion is free)
        "cold_len": z((n_sites, batch), jnp.int32),
    }


def init_mla_cache(cfg: ModelConfig, scfg: ServeConfig, batch: int,
                   max_len: int) -> Dict[str, jnp.ndarray]:
    m = cfg.mla or MLAConfig()
    R = m.kv_lora_rank + m.qk_rope_head_dim
    W = scfg.hot_window
    bits = scfg.kv_rate_bits
    Lyr = cfg.num_layers
    z = functools.partial(jnp.zeros)
    return {
        "lat_codes": z((Lyr, batch, max_len, R * bits // 8), jnp.uint8),
        "lat_scales": z((Lyr, batch, max_len), jnp.float32),
        "lat_hot": z((Lyr, batch, W, R), jnp.bfloat16),
        "cold_len": z((Lyr, batch), jnp.int32),
    }


def init_cache(cfg: ModelConfig, scfg: ServeConfig, batch: int,
               max_len: int) -> Dict[str, Any]:
    """Decode cache for any family. Leading axis = layer (or group/site)."""
    if cfg.family == "ssm":
        ssm = cfg.ssm or SSMConfig()
        st = SSM.mamba1_init_state(cfg, batch)
        return {"ssm": jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(),
            st._asdict())}
    if cfg.family == "hybrid":
        period = cfg.attn_period or cfg.num_layers
        ngroups = cfg.num_layers // period
        st = SSM.mamba2_init_state(cfg, batch)
        ssm_stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(
                a, (ngroups, period) + a.shape).copy(), st._asdict())
        return {"ssm": ssm_stacked,
                **init_gqa_cache(cfg, scfg, batch, max_len, ngroups)}
    if cfg.attn_kind == "mla":
        return init_mla_cache(cfg, scfg, batch, max_len)
    return init_gqa_cache(cfg, scfg, batch, max_len, cfg.num_layers)


def cache_bytes(cache: Dict[str, Any]) -> int:
    import numpy as np
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(cache))


def cache_axes(cfg: ModelConfig, scfg: ServeConfig) -> Dict[str, Any]:
    """Logical-axis tree mirroring init_cache (for NamedShardings)."""
    gqa = {
        "k_codes": ("layers", "batch", "kv_seq", "kv_heads", None),
        "k_scales": ("layers", "batch", "kv_seq", "kv_heads"),
        "v_codes": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v_scales": ("layers", "batch", "kv_seq", "kv_heads"),
        "k_hot": ("layers", "batch", "kv_hot", "kv_heads", None),
        "v_hot": ("layers", "batch", "kv_hot", "kv_heads", None),
        "cold_len": ("layers", "batch"),
    }
    if cfg.family == "ssm":
        return {"ssm": {"h": ("layers", "batch", "mlp", "state"),
                        "conv": ("layers", "batch", None, "mlp")}}
    if cfg.family == "hybrid":
        # leading axes: [group, period, ...] for ssm; [group, ...] for attn
        return {"ssm": {"h": ("layers", None, "batch", "heads", None, None),
                        "conv": ("layers", None, "batch", None, "mlp")},
                **gqa}
    if cfg.attn_kind == "mla":
        return {"lat_codes": ("layers", "batch", "kv_seq", None),
                "lat_scales": ("layers", "batch", "kv_seq"),
                "lat_hot": ("layers", "batch", "kv_hot", None),
                "cold_len": ("layers", "batch")}
    return gqa


# ---------------------------------------------------------------------------
# Hot-window ring ops
# ---------------------------------------------------------------------------

def _ring_positions(pos: jnp.ndarray, W: int) -> jnp.ndarray:
    """Position stored in each ring slot after inserting token ``pos``:
    p_s = pos - ((pos%W - s) mod W). [B] -> [B, W]."""
    s = jnp.arange(W)[None, :]
    slot_now = (pos % W)[:, None]
    return pos[:, None] - ((slot_now - s) % W)


def _hot_insert(hot: jnp.ndarray, new: jnp.ndarray, pos: jnp.ndarray):
    """hot [B,W,...], new [B,...] inserted at slot pos%W.

    One-hot select instead of scatter: per-batch dynamic scatter indices
    force SPMD into "involuntary full rematerialization" (an all-gather of
    the whole ring per step — measured 640MB/step on llama3 decode, §Perf
    cell A-i3); the masked select partitions cleanly on every axis."""
    W = hot.shape[1]
    onehot = jnp.arange(W)[None, :] == (pos % W)[:, None]        # [B, W]
    m = onehot.reshape(onehot.shape + (1,) * (hot.ndim - 2))
    return jnp.where(m, new[:, None].astype(hot.dtype), hot)


def _hot_read_slot(hot: jnp.ndarray, pos: jnp.ndarray) -> jnp.ndarray:
    """Read slot pos%W per batch row via one-hot contraction (exact: the
    mask is 0/1 and each output element sums exactly one bf16 value)."""
    W = hot.shape[1]
    onehot = (jnp.arange(W)[None, :] == (pos % W)[:, None])
    m = onehot.reshape(onehot.shape + (1,) * (hot.ndim - 2))
    return jnp.sum(jnp.where(m, hot.astype(jnp.float32), 0.0), axis=1)


def _evict_to_codes(codes, scales, hot, pos: jnp.ndarray, cold_len: jnp.ndarray,
                    W: int, bits: int):
    """Compress the token aging out of the ring (position pos-W) into the
    compressed region — the streaming clean demotion. Skipped when the slot
    holds no real token (pos < W, or a resumed lane whose older tokens are
    already compressed: pos-W < cold_len)."""
    B = hot.shape[0]
    evict_pos = pos - W
    do = evict_pos >= cold_len
    old = _hot_read_slot(hot, pos)     # [B, Hkv, D] f32 (pre-overwrite!)
    D = old.shape[-1]
    c, s = quantize_blocks(old, bits, D)           # [B,Hkv,Dp], [B,Hkv,1]
    idx = jnp.where(do, jnp.maximum(evict_pos, 0), 0)
    bsel = jnp.arange(B)
    new_codes = codes.at[bsel, idx].set(
        jnp.where(do[:, None, None], c, codes[bsel, idx]))
    new_scales = scales.at[bsel, idx].set(
        jnp.where(do[:, None], s[..., 0], scales[bsel, idx]))
    return new_codes, new_scales


# ---------------------------------------------------------------------------
# Per-layer decode: GQA
# ---------------------------------------------------------------------------

def gqa_decode_layer(lp: Params, x: jnp.ndarray, cache_l: Dict[str, jnp.ndarray],
                     pos: jnp.ndarray, cfg: ModelConfig, scfg: ServeConfig
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """x [B,1,d]; pos [B] current token positions; cache_l holds this layer's
    slices (no leading layer axis)."""
    B = x.shape[0]
    W = scfg.hot_window
    bits = scfg.kv_rate_bits
    D = cfg.resolved_head_dim
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    q = L.gqa_project_q(lp["attn"], h, pos[:, None], cfg)[:, 0]   # [B,Hq,D]
    k_new, v_new = L.gqa_project_kv(lp["attn"], h, pos[:, None], cfg)
    k_new, v_new = k_new[:, 0], v_new[:, 0]                       # [B,Hkv,D]

    # demote the token aging out of the hot window (clean by construction)
    cold_len = cache_l["cold_len"]
    kc, ks = _evict_to_codes(cache_l["k_codes"], cache_l["k_scales"],
                             cache_l["k_hot"], pos, cold_len, W, bits)
    vc, vs = _evict_to_codes(cache_l["v_codes"], cache_l["v_scales"],
                             cache_l["v_hot"], pos, cold_len, W, bits)
    k_hot = _hot_insert(cache_l["k_hot"], k_new, pos)
    v_hot = _hot_insert(cache_l["v_hot"], v_new, pos)
    new_cold = jnp.maximum(cold_len, jnp.maximum(pos - W + 1, 0))

    sm = 1.0 / (D ** 0.5)
    cold = quantized_attention_partial(
        q, kc, ks, vc, vs, new_cold, bits=bits, chunk=scfg.attn_chunk,
        sm_scale=sm, paper_mode=not scfg.fused_dequant_attention)
    ring_pos = _ring_positions(pos, W)
    hot_valid = ring_pos >= new_cold[:, None]
    hot = _attend_partial(q, k_hot.astype(jnp.float32),
                          v_hot.astype(jnp.float32), hot_valid, sm)
    o = finish(merge_partials(cold, hot), x.dtype)[:, None]       # [B,1,Hq,D]
    x = x + L.gqa_output(lp["attn"], o, cfg)

    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        from repro.models.moe import moe_apply
        y, _ = moe_apply(lp["mlp"], h2, cfg)
    else:
        y = L.mlp_apply(lp["mlp"], h2)
    x = x + y
    new_cache = dict(cache_l, k_codes=kc, k_scales=ks, v_codes=vc,
                     v_scales=vs, k_hot=k_hot, v_hot=v_hot, cold_len=new_cold)
    return x, new_cache


# ---------------------------------------------------------------------------
# Per-layer decode: MLA (absorbed latent attention over compressed latent)
# ---------------------------------------------------------------------------

def mla_decode_layer(lp: Params, x: jnp.ndarray, cache_l: Dict[str, jnp.ndarray],
                     pos: jnp.ndarray, cfg: ModelConfig, scfg: ServeConfig
                     ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    m = cfg.mla or MLAConfig()
    B = x.shape[0]
    W = scfg.hot_window
    bits = scfg.kv_rate_bits
    R = m.kv_lora_rank + m.qk_rope_head_dim
    hD = cfg.num_heads
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    lat_new = L.mla_latent(lp["attn"], h, pos[:, None], cfg)[:, 0]  # [B,R]

    cold_len = cache_l["cold_len"]
    lc, ls = _evict_latent(cache_l, pos, cold_len, W, bits)
    lat_hot = _hot_insert(cache_l["lat_hot"], lat_new, pos)
    new_cold = jnp.maximum(cold_len, jnp.maximum(pos - W + 1, 0))

    # absorbed query: q_lat [B,H,R_c], q_rope [B,H,rope]
    p = lp["attn"]
    qx = L.rms_norm(h @ p["wq_a"].astype(h.dtype), p["q_norm"], cfg.norm_eps)
    q = (qx @ p["wq_b"].astype(h.dtype)).reshape(
        B, 1, hD, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = L.apply_rope(q_rope, pos[:, None], cfg.rope_theta)[:, 0]  # [B,H,r]
    wkv_b = p["wkv_b"].astype(h.dtype).reshape(
        m.kv_lora_rank, hD, m.qk_nope_head_dim + m.v_head_dim)
    w_uk = wkv_b[..., :m.qk_nope_head_dim]                 # [R_c, H, nope]
    w_uv = wkv_b[..., m.qk_nope_head_dim:]                 # [R_c, H, v]
    q_lat = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_uk)  # [B,H,R_c]
    q_eff = jnp.concatenate([q_lat, q_rope], axis=-1)       # [B,H,R]
    sm = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)

    # latent "KV": key = value = latent vector (head-shared, Hkv=1)
    cold = quantized_attention_partial(
        q_eff, lc[:, :, None, :], ls[:, :, None], lc[:, :, None, :],
        ls[:, :, None], new_cold, bits=bits, chunk=scfg.attn_chunk,
        sm_scale=sm, paper_mode=not scfg.fused_dequant_attention)
    ring_pos = _ring_positions(pos, W)
    hot_valid = ring_pos >= new_cold[:, None]
    latf = lat_hot.astype(jnp.float32)[:, :, None, :]       # [B,W,1,R]
    hot = _attend_partial(q_eff, latf, latf, hot_valid, sm)
    ctx = finish(merge_partials(cold, hot), jnp.float32)    # [B,H,R]
    ctx_c = ctx[..., :m.kv_lora_rank]
    o = jnp.einsum("bhr,rhv->bhv", ctx_c, w_uv.astype(jnp.float32))
    o = o.reshape(B, 1, hD * m.v_head_dim).astype(x.dtype)
    x = x + o @ p["wo"].astype(x.dtype)

    h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    x = x + L.mlp_apply(lp["mlp"], h2)
    new_cache = dict(cache_l, lat_codes=lc, lat_scales=ls, lat_hot=lat_hot,
                     cold_len=new_cold)
    return x, new_cache


def _evict_latent(cache_l, pos, cold_len, W, bits):
    """Latent variant of _evict_to_codes (no head axis: Hkv == 1)."""
    B = cache_l["lat_hot"].shape[0]
    evict_pos = pos - W
    do = evict_pos >= cold_len
    old = _hot_read_slot(cache_l["lat_hot"], pos)          # [B, R]
    R = old.shape[-1]
    c, s = quantize_blocks(old, bits, R)                   # [B,Rp], [B,1]
    idx = jnp.where(do, jnp.maximum(evict_pos, 0), 0)
    bsel = jnp.arange(B)
    codes, scales = cache_l["lat_codes"], cache_l["lat_scales"]
    new_codes = codes.at[bsel, idx].set(
        jnp.where(do[:, None], c, codes[bsel, idx]))
    new_scales = scales.at[bsel, idx].set(
        jnp.where(do, s[..., 0], scales[bsel, idx]))
    return new_codes, new_scales


# ---------------------------------------------------------------------------
# Full decode step (all families)
# ---------------------------------------------------------------------------

def decode_step(params: Params, cache: Dict[str, Any], tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig, scfg: ServeConfig,
                embeds: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One decode step. tokens [B] int32 (or embeds [B,d]); pos [B].
    Returns (logits [B,V], new cache)."""
    B = tokens.shape[0]
    if cfg.frontend != "none" and embeds is not None:
        x = embeds[:, None].astype(jnp.dtype(cfg.dtype))
    else:
        x = params["tok_embed"].astype(jnp.dtype(cfg.dtype))[tokens][:, None]

    if cfg.family == "ssm":
        def body(x, inp):
            lp, st = inp
            h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
            y, new_st = SSM.mamba1_decode(lp["mixer"], h, SSM.Mamba1State(**st),
                                          cfg)
            return x + y, new_st._asdict()
        x, new_ssm = jax.lax.scan(body, x, (params["layers"], cache["ssm"]))
        logits = T.unembed(params, x, cfg)[:, 0]
        return logits, {"ssm": new_ssm}

    if cfg.family == "hybrid":
        period = cfg.attn_period or cfg.num_layers
        nshared = cfg.attn_shared_blocks

        def gbody(carry, inp):
            x, g = carry
            glp, gcache = inp

            xx = x
            new_ssm = []
            for j in range(period):
                lp_j = jax.tree_util.tree_map(lambda a: a[j], glp)
                st_j = jax.tree_util.tree_map(lambda a: a[j], gcache["ssm"])
                h = L.rms_norm(xx, lp_j["ln"], cfg.norm_eps)
                y, st = SSM.mamba2_decode(lp_j["mixer"], h,
                                          SSM.Mamba2State(**st_j), cfg)
                xx = xx + y
                new_ssm.append(st._asdict())
            new_ssm = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *new_ssm)
            sid = g % nshared
            sp = jax.tree_util.tree_map(lambda a: a[sid], params["shared"])
            attn_cache = {k: gcache[k] for k in
                          ("k_codes", "k_scales", "v_codes", "v_scales",
                           "k_hot", "v_hot", "cold_len")}
            xx, new_attn = gqa_decode_layer(sp, xx, attn_cache, pos, cfg, scfg)
            return (xx, g + 1), {"ssm": new_ssm, **new_attn}

        (x, _), new_cache = jax.lax.scan(
            gbody, (x, jnp.int32(0)), (params["layers"], cache))
        logits = T.unembed(params, x, cfg)[:, 0]
        return logits, new_cache

    layer_fn = mla_decode_layer if cfg.attn_kind == "mla" else gqa_decode_layer

    def body(x, inp):
        lp, cl = inp
        x, new_cl = layer_fn(lp, x, cl, pos, cfg, scfg)
        return x, new_cl

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    logits = T.unembed(params, x, cfg)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: full forward that also fills the cache
# ---------------------------------------------------------------------------

def prefill(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            scfg: ServeConfig, max_len: int,
            lens: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Run the full prompt [B,S], return (last-token logits, filled cache).

    Prefix tokens older than the hot window are written compressed; the last
    W tokens populate the ring. (The bulk-compression path of the engine.)

    ``lens`` [B] gives each row's true prompt length for right-padded
    batches (length bucketing): the ring holds the last W *real* tokens,
    ``cold_len`` is the real compressed length, and the returned logits are
    each row's last real token's. Padded positions never enter the cache's
    valid range, so a padded row decodes identically to an unpadded one.
    ``lens=None`` means every row is exactly S tokens."""
    x = T.embed(params, batch, cfg)
    B, S, _ = x.shape
    W = scfg.hot_window
    bits = scfg.kv_rate_bits
    pos = jnp.arange(S)[None, :]
    lens_arr = (jnp.full((B,), S, jnp.int32) if lens is None
                else jnp.asarray(lens, jnp.int32))

    def last_logits(x):
        """Per-row logits at the last real token (lens-1)."""
        idx = jnp.clip(lens_arr - 1, 0, S - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)  # [B,1,d]
        return T.unembed(params, x_last, cfg)[:, 0]

    def ring_slots(last):
        """Position held by ring slot s right after token ``last``: the
        largest p <= last with p === s (mod W). [B] -> [B, W]; p < 0 means
        the slot holds no real token (short prompt) — its (clipped-gather)
        content is masked out by decode's hot_valid test."""
        s = jnp.arange(W)[None, :]
        return last[:, None] - ((last[:, None] - s) % W)

    def fill_gqa(k, v):
        """k,v [B,S,Hkv,D] -> cache slices for one site."""
        Hkv, D = k.shape[2], k.shape[3]
        pad = max_len - S
        kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kc, ks = quantize_blocks(kp, bits, D)
        vc, vs = quantize_blocks(vp, bits, D)
        # ring: last W real tokens, right-aligned at slot p % W
        p = ring_slots(lens_arr - 1)                              # [B, W]
        safe = jnp.clip(p, 0, S - 1)[:, :, None, None]
        k_hot = jnp.take_along_axis(k, safe, axis=1).astype(jnp.bfloat16)
        v_hot = jnp.take_along_axis(v, safe, axis=1).astype(jnp.bfloat16)
        return {"k_codes": kc, "k_scales": ks[..., 0], "v_codes": vc,
                "v_scales": vs[..., 0], "k_hot": k_hot, "v_hot": v_hot,
                "cold_len": jnp.maximum(lens_arr - W, 0)}

    if cfg.family == "ssm":
        def body(x, lp):
            h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
            xz = h @ lp["mixer"]["in_proj"].astype(h.dtype)
            xs, z = jnp.split(xz, 2, axis=-1)
            xc = SSM._causal_conv(xs, lp["mixer"]["conv_w"], lp["mixer"]["conv_b"])
            ssm = cfg.ssm or SSMConfig()
            d_in = ssm.expand * cfg.d_model
            h0 = jnp.zeros((B, d_in, ssm.d_state), jnp.float32)
            y, hT = SSM._mamba1_core(lp["mixer"], xc, z, h0, cfg, True)
            conv_tail = xs[:, -(ssm.d_conv - 1):].astype(jnp.bfloat16)
            return x + y, {"h": hT, "conv": conv_tail}
        x, states = jax.lax.scan(body, x, params["layers"])
        return last_logits(x), {"ssm": states}

    if cfg.family == "hybrid":
        period = cfg.attn_period or cfg.num_layers
        nshared = cfg.attn_shared_blocks
        ssm = cfg.ssm or SSMConfig(kind="mamba2")

        def gbody(carry, glp):
            x, g = carry
            hs, convs = [], []
            for j in range(period):
                lp_j = jax.tree_util.tree_map(lambda a: a[j], glp)
                h = L.rms_norm(x, lp_j["ln"], cfg.norm_eps)
                z, xs, Bc, Cc, dt = SSM._mamba2_split(lp_j["mixer"], h, cfg)
                xc = SSM._causal_conv(xs, lp_j["mixer"]["conv_w"],
                                      lp_j["mixer"]["conv_b"])
                d_in = ssm.expand * cfg.d_model
                H = d_in // ssm.headdim
                h0 = jnp.zeros((B, H, ssm.headdim, ssm.d_state), jnp.float32)
                y, hT = SSM._mamba2_core(lp_j["mixer"], xc, Bc, Cc, dt, z, h0, cfg)
                x = x + y
                hs.append(hT)
                convs.append(xs[:, -(ssm.d_conv - 1):].astype(jnp.bfloat16))
            sid = g % nshared
            sp = jax.tree_util.tree_map(lambda a: a[sid], params["shared"])
            h = L.rms_norm(x, sp["ln1"], cfg.norm_eps)
            k, v = L.gqa_project_kv(sp["attn"], h, pos, cfg)
            q = L.gqa_project_q(sp["attn"], h, pos, cfg)
            o = L.chunked_attention(q, k, v, causal=True)
            x = x + L.gqa_output(sp["attn"], o, cfg)
            h2 = L.rms_norm(x, sp["ln2"], cfg.norm_eps)
            x = x + L.mlp_apply(sp["mlp"], h2)
            site = fill_gqa(k, v)
            site["ssm"] = {"h": jnp.stack(hs), "conv": jnp.stack(convs)}
            return (x, g + 1), site

        (x, _), cache = jax.lax.scan(gbody, (x, jnp.int32(0)), params["layers"])
        return last_logits(x), cache

    if cfg.attn_kind == "mla":
        def body(x, lp):
            h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
            lat = L.mla_latent(lp["attn"], h, pos, cfg)        # [B,S,R]
            x = x + L.mla_attend(lp["attn"], h, lat, pos, cfg, causal=True)
            h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
            x = x + L.mlp_apply(lp["mlp"], h2)
            R = lat.shape[-1]
            pad = max_len - S
            latp = jnp.pad(lat, ((0, 0), (0, pad), (0, 0)))
            c, s = quantize_blocks(latp, bits, R)
            p = ring_slots(lens_arr - 1)                          # [B, W]
            safe = jnp.clip(p, 0, S - 1)[:, :, None]
            lat_hot = jnp.take_along_axis(lat, safe, axis=1).astype(
                jnp.bfloat16)
            return x, {"lat_codes": c, "lat_scales": s[..., 0],
                       "lat_hot": lat_hot,
                       "cold_len": jnp.maximum(lens_arr - W, 0)}
        x, cache = jax.lax.scan(body, x, params["layers"])
        return last_logits(x), cache

    def body(x, lp):
        h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
        k, v = L.gqa_project_kv(lp["attn"], h, pos, cfg)
        q = L.gqa_project_q(lp["attn"], h, pos, cfg)
        o = L.chunked_attention(q, k, v, causal=True)
        x = x + L.gqa_output(lp["attn"], o, cfg)
        h2 = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            from repro.models.moe import moe_apply
            y, _ = moe_apply(lp["mlp"], h2, cfg)
        else:
            y = L.mlp_apply(lp["mlp"], h2)
        return x + y, fill_gqa(k, v)

    x, cache = jax.lax.scan(body, x, params["layers"])
    return last_logits(x), cache
