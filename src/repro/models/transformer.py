"""Unified LM covering all 10 assigned architectures.

Families:
  dense  — pre-norm GQA attention + SwiGLU (deepseek/codeqwen/llama3;
           chameleon & musicgen backbones via the frontend stub)
  moe    — GQA attention + sort-dispatch MoE (qwen3-moe, arctic)
  mla    — attn_kind="mla" swaps GQA for latent attention (minicpm3)
  ssm    — attention-free Mamba1 stack (falcon-mamba)
  hybrid — Mamba2 groups with shared attention blocks every attn_period
           layers, alternating between attn_shared_blocks weight sets (zamba2)

Layers are stacked [L, ...] and traversed with lax.scan (hybrid: [G, period,
...] group scan) so the compiled HLO contains ONE layer body — essential for
512-device dry-run compile times. cfg.remat checkpoints the layer body.

Modality frontends (chameleon VQ images, musicgen EnCodec audio) are stubs per
the assignment brief: the batch supplies precomputed embeddings [B,S,D] via
the "embeds" key and token ids only for the text/code path.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import ModelConfig, SSMConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    """vmap a layer init over n keys -> params stacked on a leading axis."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    _, axes = fn(keys[0])
    axes = jax.tree_util.tree_map(
        lambda a: ("layers",) + a, axes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            e is None or isinstance(e, str) for e in x))
    return params, axes


def _layer_init(key, cfg: ModelConfig):
    """One transformer layer (attention archs)."""
    ks = jax.random.split(key, 4)
    if cfg.attn_kind == "mla":
        ap, aa = L.mla_init(ks[0], cfg)
    else:
        ap, aa = L.gqa_init(ks[0], cfg)
    if cfg.family == "moe":
        mp, ma = MOE.moe_init(ks[1], cfg)
    else:
        mp, ma = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    params = {"attn": ap, "mlp": mp,
              "ln1": jnp.ones((cfg.d_model,), jnp.float32),
              "ln2": jnp.ones((cfg.d_model,), jnp.float32)}
    axes = {"attn": aa, "mlp": ma, "ln1": ("embed",), "ln2": ("embed",)}
    return params, axes


def _ssm_layer_init(key, cfg: ModelConfig):
    ssm = cfg.ssm or SSMConfig()
    fn = SSM.mamba2_init if ssm.kind == "mamba2" else SSM.mamba1_init
    mp, ma = fn(key, cfg)
    params = {"mixer": mp, "ln": jnp.ones((cfg.d_model,), jnp.float32)}
    axes = {"mixer": ma, "ln": ("embed",)}
    return params, axes


def init_params(key, cfg: ModelConfig) -> Tuple[Params, Dict[str, Any]]:
    ks = jax.random.split(key, 5)
    params: Params = {
        "tok_embed": L._init(ks[0], (cfg.vocab_size, cfg.d_model), scale=0.02),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    axes: Dict[str, Any] = {"tok_embed": ("vocab", "embed"),
                            "final_norm": ("embed",)}
    if not cfg.tie_embeddings:
        params["lm_head"] = L._init(ks[1], (cfg.d_model, cfg.vocab_size),
                                    scale=0.02)
        axes["lm_head"] = ("embed", "vocab")

    if cfg.family == "ssm":
        params["layers"], axes["layers"] = _stack_init(
            lambda k: _ssm_layer_init(k, cfg), ks[2], cfg.num_layers)
    elif cfg.family == "hybrid":
        period = cfg.attn_period or cfg.num_layers
        ngroups = cfg.num_layers // period
        # mamba layers regrouped [G, period, ...]
        lp, la = _stack_init(lambda k: _ssm_layer_init(k, cfg),
                             ks[2], cfg.num_layers)
        params["layers"] = jax.tree_util.tree_map(
            lambda x: x.reshape((ngroups, period) + x.shape[1:]), lp)
        axes["layers"] = jax.tree_util.tree_map(
            lambda a: ("layers",) + a, la,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                e is None or isinstance(e, str) for e in x))
        params["shared"], axes["shared"] = _stack_init(
            lambda k: _layer_init(k, cfg), ks[3], cfg.attn_shared_blocks)
    else:
        params["layers"], axes["layers"] = _stack_init(
            lambda k: _layer_init(k, cfg), ks[2], cfg.num_layers)
    return params, axes


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed(params: Params, batch: Dict[str, jnp.ndarray],
          cfg: ModelConfig) -> jnp.ndarray:
    if cfg.frontend != "none" and "embeds" in batch:
        return batch["embeds"].astype(jnp.dtype(cfg.dtype))
    tok = batch["tokens"]
    return params["tok_embed"].astype(jnp.dtype(cfg.dtype))[tok]


def unembed(params: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["tok_embed"].T if cfg.tie_embeddings else params["lm_head"]
    return x @ w.astype(x.dtype)


# ---------------------------------------------------------------------------
# Train / prefill forward (full sequence)
# ---------------------------------------------------------------------------

def _attn_block(lp: Params, x: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    h = L.rms_norm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attn_kind == "mla":
        x = x + L.mla_apply_train(lp["attn"], h, cfg)
    else:
        x = x + L.gqa_apply_train(lp["attn"], h, cfg)
    h = L.rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = MOE.moe_apply(lp["mlp"], h, cfg)
    else:
        y, aux = L.mlp_apply(lp["mlp"], h), jnp.float32(0)
    return x + y, aux


def _ssm_block(lp: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    ssm = cfg.ssm or SSMConfig()
    h = L.rms_norm(x, lp["ln"], cfg.norm_eps)
    fn = SSM.mamba2_apply_train if ssm.kind == "mamba2" else SSM.mamba1_apply_train
    return x + fn(lp["mixer"], h, cfg)


def forward(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward. Returns (logits [B,S,V], aux_loss)."""
    x = embed(params, batch, cfg)

    if cfg.family == "ssm":
        def body(carry, lp):
            fn = functools.partial(_ssm_block, cfg=cfg)
            if cfg.remat:
                fn = jax.checkpoint(fn)
            return fn(lp, carry), None
        x, _ = jax.lax.scan(body, x, params["layers"])
        return unembed(params, x, cfg), jnp.float32(0)

    if cfg.family == "hybrid":
        period = cfg.attn_period or cfg.num_layers
        nshared = cfg.attn_shared_blocks

        def group_body(carry, inp):
            x, g = carry[0], carry[1]
            glp = inp

            def inner(x_in):
                xx = x_in
                for j in range(period):
                    lp_j = jax.tree_util.tree_map(lambda a: a[j], glp)
                    xx = _ssm_block(lp_j, xx, cfg)
                # alternating shared attention block
                sid = g % nshared
                sp = jax.tree_util.tree_map(lambda a: a[sid], params["shared"])
                xx, _ = _attn_block(sp, xx, cfg)
                return xx
            fn = jax.checkpoint(inner) if cfg.remat else inner
            return (fn(x), g + 1), None

        (x, _), _ = jax.lax.scan(group_body, (x, jnp.int32(0)), params["layers"])
        return unembed(params, x, cfg), jnp.float32(0)

    def body(carry, lp):
        x, aux = carry
        fn = functools.partial(_attn_block, cfg=cfg)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        x, a = fn(lp, x)
        return (x, aux + a), None
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0)), params["layers"])
    return unembed(params, x, cfg), aux


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: ModelConfig) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    xent = -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return xent + aux, {"xent": xent, "aux": aux}
