"""Core transformer layers: RMSNorm, RoPE, GQA/MLA attention, SwiGLU MLP.

Parameters are plain dict pytrees; every init returns (params, logical_axes)
mirrored trees so the launcher can derive NamedShardings from the rule table
in common/sharding.py (MaxText-style logical axes).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.types import MLAConfig, ModelConfig

Params = Dict[str, Any]
Axes = Dict[str, Any]


def _init(key, shape, scale=None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / (shape[0] ** 0.5)
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    """RMSNorm with f32 *reductions* but bf16 *products*.

    Casting the whole input to f32 first (the naive form) makes every
    backward cotangent upstream of the cast f32 — including the TP dgrad
    partial-sums, which then all-reduce at 2x the bytes (measured 2x3.76GB
    f32 all-reduces per layer on arctic train, §Perf cell C-i3). Keeping the
    [B,S,d]-sized math in the input dtype halves that wire traffic; only the
    [B,S,1] variance runs in f32."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * w.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, D] (D even), positions [..., S] -> rotated x."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos = jnp.cos(angles)[..., None, :]                # [..., S, 1, D/2]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Memory-efficient exact attention (training path; differentiable).
# ---------------------------------------------------------------------------

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, sm_scale: Optional[float] = None,
                      chunk: int = 1024) -> jnp.ndarray:
    """Flash-style online-softmax attention as a lax.scan over KV chunks —
    exact, differentiable, O(S·chunk) live memory (body is rematerialized).

    q [B,Sq,Hq,D]; k,v [B,Sk,Hkv,D]."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    Dv = v.shape[-1]                 # MLA: value dim may differ from qk dim
    g = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / (D ** 0.5)
    chunk = min(chunk, Sk)
    assert Sk % chunk == 0
    nchunks = Sk // chunk
    qf = (q.astype(jnp.float32) * sm_scale).transpose(0, 2, 1, 3)  # [B,Hq,Sq,D]
    kc = k.reshape(B, nchunks, chunk, Hkv, D)
    vc = v.reshape(B, nchunks, chunk, Hkv, Dv)
    rows = jnp.arange(Sq)

    @jax.checkpoint
    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        kj = jnp.repeat(kj.astype(jnp.float32), g, axis=2)     # [B,chunk,Hq,D]
        vj = jnp.repeat(vj.astype(jnp.float32), g, axis=2)
        s = jnp.einsum("bhqd,bkhd->bhqk", qf, kj)
        if causal:
            cols = j * chunk + jnp.arange(chunk)
            mask = cols[None, :] <= (rows + (Sk - Sq))[:, None]
            s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vj)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hq, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hq, Sq, Dv), jnp.float32)
    # note: k chunk axis moved to front for scan
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.arange(nchunks), kc.transpose(1, 0, 2, 3, 4),
         vc.transpose(1, 0, 2, 3, 4)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    d, hq, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    params = {
        "wq": _init(ks[0], (d, hq * hd)),
        "wk": _init(ks[1], (d, hkv * hd)),
        "wv": _init(ks[2], (d, hkv * hd)),
        "wo": _init(ks[3], (hq * hd, d), scale=1.0 / ((hq * hd) ** 0.5)),
    }
    axes = {"wq": ("fsdp", "heads"), "wk": ("fsdp", "heads"),
            "wv": ("fsdp", "heads"), "wo": ("heads", "fsdp")}
    return params, axes


def gqa_apply_train(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, S, d = x.shape
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, hq, hd)
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, S, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, S, hkv, hd)
    pos = jnp.arange(S)[None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    o = chunked_attention(q, k, v, causal=True)
    return o.reshape(B, S, hq * hd) @ p["wo"].astype(x.dtype)


def gqa_project_kv(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                   cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """K/V for new tokens (cache append). x [B,T,d] -> k,v [B,T,Hkv,D]."""
    B, T, _ = x.shape
    hkv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    k = (x @ p["wk"].astype(x.dtype)).reshape(B, T, hkv, hd)
    v = (x @ p["wv"].astype(x.dtype)).reshape(B, T, hkv, hd)
    k = apply_rope(k, positions, cfg.rope_theta)
    return k, v


def gqa_project_q(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
                  cfg: ModelConfig) -> jnp.ndarray:
    B, T, _ = x.shape
    hq, hd = cfg.num_heads, cfg.resolved_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, T, hq, hd)
    return apply_rope(q, positions, cfg.rope_theta)


def gqa_output(p: Params, o: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B = o.shape[0]
    return o.reshape(B, -1, cfg.num_heads * cfg.resolved_head_dim) @ \
        p["wo"].astype(o.dtype)


# ---------------------------------------------------------------------------
# MLA attention (MiniCPM3 / DeepSeek-V2 style latent KV)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig) -> Tuple[Params, Axes]:
    d, h = cfg.d_model, cfg.num_heads
    m = cfg.mla or MLAConfig()
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    params = {
        "wq_a": _init(ks[0], (d, m.q_lora_rank)),
        "wq_b": _init(ks[1], (m.q_lora_rank, h * qk)),
        "wkv_a": _init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim)),
        "wkv_b": _init(ks[3], (m.kv_lora_rank,
                               h * (m.qk_nope_head_dim + m.v_head_dim))),
        "wo": _init(ks[4], (h * m.v_head_dim, d),
                    scale=1.0 / ((h * m.v_head_dim) ** 0.5)),
        "q_norm": jnp.ones((m.q_lora_rank,), jnp.float32),
        "kv_norm": jnp.ones((m.kv_lora_rank,), jnp.float32),
    }
    axes = {"wq_a": ("fsdp", "latent"), "wq_b": ("latent", "heads"),
            "wkv_a": ("fsdp", "latent"), "wkv_b": ("latent", "heads"),
            "wo": ("heads", "fsdp"), "q_norm": ("latent",), "kv_norm": ("latent",)}
    return params, axes


def mla_latent(p: Params, x: jnp.ndarray, positions: jnp.ndarray,
               cfg: ModelConfig) -> jnp.ndarray:
    """Latent KV for new tokens: [B,T, kv_lora_rank + rope_dim] — this *is*
    the cached quantity (a learned KV compression; IBEX then block-compresses
    the latent cache — the two compose, DESIGN.md §4)."""
    m = cfg.mla or MLAConfig()
    ckv = x @ p["wkv_a"].astype(x.dtype)
    c, k_rope = jnp.split(ckv, [m.kv_lora_rank], axis=-1)
    c = rms_norm(c, p["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return jnp.concatenate([c, k_rope], axis=-1)


def mla_attend(p: Params, x: jnp.ndarray, latent: jnp.ndarray,
               positions: jnp.ndarray, cfg: ModelConfig, *,
               causal: bool) -> jnp.ndarray:
    """Attention of x's queries over the latent cache (expanded per head)."""
    m = cfg.mla or MLAConfig()
    h = cfg.num_heads
    B, T, _ = x.shape
    S = latent.shape[1]
    q = rms_norm(x @ p["wq_a"].astype(x.dtype), p["q_norm"], cfg.norm_eps)
    q = (q @ p["wq_b"].astype(x.dtype)).reshape(
        B, T, h, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    c, k_rope = jnp.split(latent, [m.kv_lora_rank], axis=-1)
    kv = (c @ p["wkv_b"].astype(x.dtype)).reshape(
        B, S, h, m.qk_nope_head_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_head_dim], axis=-1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None, :],
                                          (B, S, h, m.qk_rope_head_dim))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    sm = 1.0 / ((m.qk_nope_head_dim + m.qk_rope_head_dim) ** 0.5)
    o = chunked_attention(qq, k, v, causal=causal, sm_scale=sm)
    return o.reshape(B, T, h * m.v_head_dim) @ p["wo"].astype(x.dtype)


def mla_apply_train(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    B, S, _ = x.shape
    pos = jnp.arange(S)[None, :]
    latent = mla_latent(p, x, pos, cfg)
    return mla_attend(p, x, latent, pos, cfg, causal=True)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d: int, f: int) -> Tuple[Params, Axes]:
    ks = jax.random.split(key, 3)
    params = {"wi": _init(ks[0], (d, f)), "wg": _init(ks[1], (d, f)),
              "wo": _init(ks[2], (f, d), scale=1.0 / (f ** 0.5))}
    axes = {"wi": ("fsdp", "mlp"), "wg": ("fsdp", "mlp"), "wo": ("mlp", "fsdp")}
    return params, axes


def mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["wg"].astype(x.dtype)) * (x @ p["wi"].astype(x.dtype))
    return h @ p["wo"].astype(x.dtype)
