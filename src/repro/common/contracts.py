"""Machine-readable host-sync contracts (DESIGN.md §15).

Every speedup layer in this repro rests on a host-sync discipline — ONE
device→host sync per decode step (serve), per replayed segment and per
migration epoch (fabric). Until now those contracts lived in docstrings
and were enforced only at runtime by benches a regressing PR may not run.
``@sync_contract`` turns them into annotations that are checked twice:

  * **statically** — ``repro.analysis`` rule R5 counts the device→host
    fetch sites (``jax.device_get``, ``.item()``, ``block_until_ready``,
    ``self._fetch``, device-sourced ``np.asarray``) lexically present in
    the annotated function and fails the lint when the count exceeds the
    declared budget, or when a fetch site sits inside a host loop (one
    sync per *iteration* is how the one-sync contract quietly becomes
    O(n));
  * **at runtime** — ``verify_sync_counters`` cross-checks the measured
    sync counters (``step_syncs == steps``, ``segment_syncs ==
    segments``, ...) against the declared budget, so the benches assert
    the *declared* contract rather than a magic constant of their own.

The decorator is intentionally a no-op at call time (it only attaches a
``SyncContract`` record): the annotated functions are the hottest host
loops in the repo and must not pay a wrapper frame per step.

This module must stay importable without jax — the static analyzer and
CI lint step run it on machines with no accelerator stack.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

_ATTR = "__sync_contract__"

# The event kinds the repo's runtime counters are keyed on. Free-form
# strings are allowed (the analyzer only needs identity), but sticking to
# these keeps the bench cross-checks uniform. "boundary" is the sharded
# fabric driver's fused per-segment-boundary fetch, "drain" its one
# deferred migration-off fetch per replay() call, "call" a per-invocation
# metric fetch (Fabric.delivered_time).
KNOWN_EVENTS = ("step", "segment", "epoch", "admission", "boundary",
                "drain", "call")


@dataclass(frozen=True)
class SyncContract:
    """Declared host-sync budget: at most ``fetches`` device→host fetch
    sites per ``syncs_per`` event."""
    syncs_per: str
    fetches: int = 1

    def expected_syncs(self, n_events: int) -> int:
        return n_events * self.fetches


def sync_contract(syncs_per: str, fetches: int = 1) -> Callable:
    """Annotate a function with its host-sync contract.

    ``syncs_per`` names the event the contract is counted against
    ("step", "segment", "epoch"); ``fetches`` is the maximum number of
    device→host fetch sites the body may contain per event. Returns the
    function UNCHANGED (no wrapper) with a ``SyncContract`` attached —
    the static analyzer reads the decorator from source, runtime
    cross-checks read the attribute.
    """
    if not isinstance(fetches, int) or fetches < 0:
        raise ValueError(f"fetches must be a non-negative int, got {fetches!r}")

    def attach(fn):
        setattr(fn, _ATTR, SyncContract(syncs_per=syncs_per, fetches=fetches))
        return fn

    return attach


def get_sync_contract(fn) -> Optional[SyncContract]:
    """The contract attached to ``fn`` (bound methods resolve through to
    the underlying function), or None when undeclared."""
    return getattr(fn, _ATTR, None)


def verify_sync_counters(fn, n_events: int, n_syncs: int,
                         what: str = "") -> SyncContract:
    """Runtime half of the contract: assert the measured sync count
    matches the budget ``fn`` declared. Raises AssertionError when ``fn``
    declares no contract (the cross-check exists precisely so the
    annotation cannot be silently deleted) or when the counters disagree.
    Returns the contract so callers can report it."""
    c = get_sync_contract(fn)
    name = getattr(fn, "__qualname__", repr(fn))
    assert c is not None, f"{name} declares no @sync_contract ({what})"
    expected = c.expected_syncs(n_events)
    assert n_syncs == expected, (
        f"{name}: measured {n_syncs} syncs over {n_events} {c.syncs_per}s, "
        f"contract declares {c.fetches} per {c.syncs_per} "
        f"(expected {expected}) {what}")
    return c
