"""Small shared utilities: bit manipulation, tree helpers, timing."""
from __future__ import annotations

import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Bit-field packing helpers (uint32 words).
# ---------------------------------------------------------------------------

def next_pow2(n: int) -> int:
    """Smallest power of two >= n (n >= 1). Shared by the serving engine's
    prefill bucketing and the fabric's window-count bucketing — both bound
    compiled-shape counts to O(log) distinct sizes."""
    return 1 << max(n - 1, 1).bit_length() if n > 1 else 1


def get_bits(word: jnp.ndarray, lo: int, width: int) -> jnp.ndarray:
    """Extract ``width`` bits starting at bit ``lo`` from uint32 word(s)."""
    mask = jnp.uint32((1 << width) - 1)
    return (word >> jnp.uint32(lo)) & mask


def set_bits(word: jnp.ndarray, lo: int, width: int, value: jnp.ndarray) -> jnp.ndarray:
    """Return ``word`` with ``width`` bits at ``lo`` replaced by ``value``."""
    mask = jnp.uint32((1 << width) - 1)
    value = jnp.asarray(value).astype(jnp.uint32) & mask
    cleared = word & ~(mask << jnp.uint32(lo))
    return cleared | (value << jnp.uint32(lo))


def bitcast_bf16_to_u16(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)


def bitcast_u16_to_bf16(x: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(x.astype(jnp.uint16), jnp.bfloat16)


def u16_to_bytes(x: jnp.ndarray) -> jnp.ndarray:
    """uint16[N] -> uint8[2N] little-endian."""
    lo = (x & jnp.uint16(0xFF)).astype(jnp.uint8)
    hi = (x >> jnp.uint16(8)).astype(jnp.uint8)
    return jnp.stack([lo, hi], axis=-1).reshape(x.shape[:-1] + (x.shape[-1] * 2,))


def bytes_to_u16(b: jnp.ndarray) -> jnp.ndarray:
    """uint8[2N] -> uint16[N] little-endian."""
    pairs = b.reshape(b.shape[:-1] + (b.shape[-1] // 2, 2)).astype(jnp.uint16)
    return pairs[..., 0] | (pairs[..., 1] << jnp.uint16(8))


def f32_to_bytes(x: jnp.ndarray) -> jnp.ndarray:
    u = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    out = []
    for s in (0, 8, 16, 24):
        out.append(((u >> jnp.uint32(s)) & jnp.uint32(0xFF)).astype(jnp.uint8))
    return jnp.stack(out, axis=-1).reshape(x.shape[:-1] + (x.shape[-1] * 4,))


def bytes_to_f32(b: jnp.ndarray) -> jnp.ndarray:
    quads = b.reshape(b.shape[:-1] + (b.shape[-1] // 4, 4)).astype(jnp.uint32)
    u = quads[..., 0] | (quads[..., 1] << 8) | (quads[..., 2] << 16) | (quads[..., 3] << 24)
    return jax.lax.bitcast_convert_type(u.astype(jnp.uint32), jnp.float32)


# ---------------------------------------------------------------------------
# Tree / shape helpers.
# ---------------------------------------------------------------------------

def tree_bytes(tree: Any) -> int:
    return sum(np.prod(x.shape) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def tree_count(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree)
               if hasattr(x, "shape"))


def assert_finite(tree: Any, name: str = "tree") -> None:
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))):
                raise AssertionError(f"non-finite values in {name}{jax.tree_util.keystr(path)}")


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


class Timer:
    """Wall-clock timer for benchmark harness (block until ready)."""

    def __init__(self) -> None:
        self.t0 = 0.0

    def __enter__(self) -> "Timer":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc: Any) -> None:
        self.elapsed = time.perf_counter() - self.t0


def time_fn(fn: Callable[[], Any], iters: int = 5, warmup: int = 1) -> float:
    """Median microseconds per call; blocks on all returned arrays."""
    def run() -> None:
        out = fn()
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x, out)
    for _ in range(warmup):
        run()
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        run()
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))
