"""Logical-axis sharding rules (MaxText-style) mapped onto the production mesh.

Every parameter / activation annotates its dims with *logical* axis names;
``logical_to_spec`` resolves them to mesh axes through a rule table. Hillclimb
iterations in EXPERIMENTS.md §Perf swap rule tables, not model code.

The fabric's ``expander`` mesh axis (DESIGN.md §17) also lives here:
``force_host_device_count(n)`` makes N CPU devices exist anywhere (CI
included) via the ``xla_force_host_platform_device_count`` flag, and
``expander_mesh(d)`` builds the 1-D mesh the sharded fabric drivers run
on. The force MUST happen before jax initializes its backend — importing
any ``repro.*`` engine module initializes it (module-level jnp constants),
so launchers set it as their literal first statement (launch/dryrun.py,
launch/fabric.py ``--devices``).
"""
from __future__ import annotations

import os
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# the fabric's device axis: one shard of the stacked pool pytree per device
EXPANDER_AXIS = "expander"


def force_host_device_count(n: int) -> None:
    """Make ``n`` XLA host (CPU) devices exist, the SNIPPETS idiom:
    merge ``--xla_force_host_platform_device_count=n`` into XLA_FLAGS.
    Must run before the jax backend initializes (first trace/device query);
    a later call is silently ineffective, which ``host_device_count`` lets
    callers detect."""
    flags = os.environ.get("XLA_FLAGS", "")
    kept = [f for f in flags.split() if not f.startswith(
        "--xla_force_host_platform_device_count")]
    kept.append(f"--xla_force_host_platform_device_count={n}")
    os.environ["XLA_FLAGS"] = " ".join(kept)


def host_device_count() -> int:
    """Devices actually visible to jax (after any force took effect)."""
    return jax.device_count()


def expander_mesh(n_devices: int) -> Mesh:
    """1-D mesh over the first ``n_devices`` devices, axis ``expander``.
    The sharded fabric requires n_expanders % n_devices == 0 so every
    device owns an equal block of the stacked pool pytree."""
    import numpy as _np
    devs = jax.devices()
    if n_devices > len(devs):
        raise ValueError(
            f"expander_mesh({n_devices}) but only {len(devs)} devices "
            "visible; call force_host_device_count before jax initializes")
    return Mesh(_np.asarray(devs[:n_devices]), (EXPANDER_AXIS,))

# Default rule table: FSDP over "data", tensor parallel over "model",
# batch over ("pod","data"). ``None`` -> replicated.
DEFAULT_RULES: Tuple[Tuple[str, Optional[object]], ...] = (
    ("batch", ("pod", "data")),
    ("seq", None),                   # sequence kept local by default
    ("seq_shard", ("data",)),        # long-context cells shard sequence over data
    ("embed", None),
    ("vocab", ("model",)),
    ("heads", ("model",)),
    ("kv_heads", ("model",)),
    ("head_dim", None),
    ("mlp", ("model",)),
    ("expert", ("model",)),          # expert parallelism
    ("expert_mlp", None),
    ("fsdp", ("data",)),             # parameter FSDP axis
    ("layers", None),
    ("kv_pages", None),
    ("kv_hot", None),   # hot-ring W axis (sharded over model when kv_heads cannot)
    ("latent", None),
    ("state", None),
    ("expander", ("expander",)),     # fabric pool stack: one shard per device
)


def rules_to_dict(rules: Sequence[Tuple[str, Optional[object]]]) -> dict:
    return {k: v for k, v in rules}


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    rules: Sequence[Tuple[str, Optional[object]]] = DEFAULT_RULES,
                    mesh_axes: Sequence[str] = ("data", "model")) -> P:
    """Map a tuple of logical axis names to a PartitionSpec, dropping mesh axes
    that do not exist on the current mesh (e.g. "pod" on the single-pod mesh)."""
    table = rules_to_dict(rules)
    out = []
    used: set = set()
    for name in logical_axes:
        if name is None:
            out.append(None)
            continue
        phys = table.get(name, None)
        if phys is None:
            out.append(None)
            continue
        if isinstance(phys, str):
            phys = (phys,)
        keep = tuple(a for a in phys if a in mesh_axes and a not in used)
        used.update(keep)
        if not keep:
            out.append(None)
        elif len(keep) == 1:
            out.append(keep[0])
        else:
            out.append(keep)
    # trim trailing Nones for cleanliness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(mesh: Mesh, *logical_axes: Optional[str],
                   rules: Sequence[Tuple[str, Optional[object]]] = DEFAULT_RULES) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules, mesh.axis_names))


def tree_shardings(mesh: Mesh, logical_tree,
                   rules: Sequence[Tuple[str, Optional[object]]] = DEFAULT_RULES):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, logical_to_spec(axes, rules, mesh.axis_names)),
        logical_tree, is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, str) for a in x))


def batch_spec(mesh: Mesh, rules=DEFAULT_RULES) -> P:
    return logical_to_spec(("batch", "seq"), rules, mesh.axis_names)
