"""Config system: model / shape / mesh / pool / train / serve configs.

Everything is a frozen dataclass so configs hash and can be closed over by
``jax.jit`` as static arguments.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


# ---------------------------------------------------------------------------
# Model architecture.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    dense_residual: bool = False       # arctic: dense MLP in parallel with experts
    dense_d_ff: int = 0                # width of the parallel dense residual MLP
    router_jitter: float = 0.0
    load_balance_coef: float = 0.01


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class SSMConfig:
    """Mamba1 (falcon-mamba) / Mamba2 (zamba2) configuration."""
    kind: str = "mamba1"               # "mamba1" | "mamba2"
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    headdim: int = 64                  # mamba2 head dim
    ngroups: int = 1                   # mamba2 B/C groups
    chunk: int = 128                   # scan chunk for chunked (SSD) form


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"              # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                  # 0 -> d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 8192
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attn_kind: str = "gqa"             # gqa | mla | none
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid layout: every `attn_period` layers, an attention block is applied,
    # sharing weights among `attn_shared_blocks` alternating shared blocks (zamba2).
    attn_period: int = 0
    attn_shared_blocks: int = 2
    # modality frontend stub: "none" | "vq_image" | "encodec_audio"
    frontend: str = "none"
    dtype: str = "bfloat16"
    # training-time knobs
    remat: bool = True
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, v, L = self.d_model, self.vocab_size, self.num_layers
        hd = self.resolved_head_dim
        n = v * d  # embeddings
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.family == "ssm":
            ssm = self.ssm or SSMConfig()
            d_in = ssm.expand * d
            # in_proj (x,z), conv, x_proj (dt,B,C), dt_proj, out_proj, A,D
            per_layer = d * (2 * d_in) + d_in * ssm.d_conv + \
                d_in * (ssm.d_state * 2 + d_in // 16) + (d_in // 16) * d_in + \
                d_in * d + d_in * ssm.d_state + d_in
            n += L * per_layer
            return n
        # attention
        if self.attn_kind == "mla" and self.mla is not None:
            m = self.mla
            qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_dim
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d)
        else:
            attn = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
        # mlp
        if self.family in ("moe",) and self.moe is not None:
            mo = self.moe
            mlp = mo.num_experts * 3 * d * mo.expert_d_ff + d * mo.num_experts
            if mo.dense_residual:
                mlp += 3 * d * mo.dense_d_ff
        else:
            mlp = 3 * d * self.d_ff
        if self.family == "hybrid":
            # mamba layers carry no MLP; only shared attention blocks do
            ssm = self.ssm or SSMConfig(kind="mamba2")
            d_in = ssm.expand * d
            nheads = d_in // ssm.headdim
            ssm_layer = d * (2 * d_in + 2 * ssm.ngroups * ssm.d_state + nheads) \
                + d_in * ssm.d_conv + d_in * d + nheads
            n_attn_uses = L // max(self.attn_period, 1) if self.attn_period else 0
            n += L * ssm_layer + min(self.attn_shared_blocks, max(n_attn_uses, 1)) * (attn + mlp)
            return n
        n += L * (attn + mlp)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.family != "moe" or self.moe is None:
            return self.param_count()
        mo = self.moe
        d, L = self.d_model, self.num_layers
        total = self.param_count()
        all_experts = L * mo.num_experts * 3 * d * mo.expert_d_ff
        active_experts = L * mo.top_k * 3 * d * mo.expert_d_ff
        return total - all_experts + active_experts


# ---------------------------------------------------------------------------
# Input shapes (the assigned 4 shapes).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                          # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


# ---------------------------------------------------------------------------
# Mesh / distribution.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")
    # logical -> physical axis rules; see common/sharding.py
    pipeline_stages: int = 0           # >0: map "pod" axis to pipeline stages

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


# ---------------------------------------------------------------------------
# IBEX pool configuration (Layer A / B).
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class PoolConfig:
    """Configuration of the IBEX compressed-memory pool.

    Paper constants (§4): 4KB page, 1KB block (co-location: 4/page), 512B
    C-chunk, 4KB P-chunk, 128B size quanta, 32B metadata entries, wr_cntr
    threshold 16, demotion watermark 256 free P-chunks.
    """
    n_pages: int = 1024                # logical (OSPA) pages tracked
    n_cchunks: int = 4096              # 512B chunks in compressed region
    n_pchunks: int = 256               # 4KB chunks in promoted region
    page_bytes: int = 4096
    block_bytes: int = 1024
    chunk_bytes: int = 512
    quantum_bytes: int = 128
    mcache_sets: int = 128             # 16-way 96KB-equivalent model: sets*ways entries
    mcache_ways: int = 16
    wr_thresh: int = 16
    demote_watermark: int = 8
    # scheme toggles (paper ablation S/C/M):
    shadow: bool = True                # shadowed promotion (§4.5)
    coloc: bool = True                 # block co-location (§4.6)
    compact: bool = True               # metadata compaction (§4.7)
    zero_elision: bool = True
    store_payload: bool = True         # Layer A carries real bytes; simx does not
    # background-demotion cadence of the batched front-end (engine/batch.py):
    # "window" (default) tops up the free-P-chunk list once per window to a
    # raised target; "access" reproduces the serial engine's per-access
    # cadence (top up to the bare watermark each window AND re-check before
    # every slow access) so small-pool configs — where the watermark is a
    # large fraction of the promoted region and cadence visibly shifts
    # traffic — can be compared serial-vs-batched tightly
    # (tests/test_simx_schemes.py::test_small_pool_cadence_knob_bounds_divergence)
    demote_cadence: str = "window"     # "window" | "access"
    # quantization tolerances for the rate-adaptive compressor (relative to
    # block amax; int8 of bf16 data carries ~0.4% inherent rounding)
    tol4: float = 0.10
    tol8: float = 0.01
    lossless: bool = False             # exact roundtrip required for 4/8-bit rates
    # compression engine implementation: "auto" runs the fused Pallas kernels
    # on TPU and the bit-identical jnp oracle elsewhere; "kernel"/"jnp" force
    # a path (core/compressor.py::resolve_impl)
    compress_impl: str = "auto"
    # batched multi-victim demotion ("auto" follows compress_impl resolution;
    # "on"/"off" force) — core/engine/ops.py::demote_batch
    fused_demote: str = "auto"

    @property
    def blocks_per_page(self) -> int:
        return self.page_bytes // self.block_bytes

    @property
    def chunks_per_page(self) -> int:
        return self.page_bytes // self.chunk_bytes

    @property
    def quanta_per_block(self) -> int:
        return self.block_bytes // self.quantum_bytes

    @property
    def vals_per_block(self) -> int:
        return self.block_bytes // 2   # bf16 values

    @property
    def vals_per_page(self) -> int:
        return self.page_bytes // 2


# ---------------------------------------------------------------------------
# Train / serve configs.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # IBEX-compressed optimizer state (block-quantized moments)
    compress_state: bool = False
    state_block: int = 512
    # moment dtype for the uncompressed path ("float32" | "bfloat16");
    # bfloat16 halves optimizer HBM at scale while staying shard-aligned
    moment_dtype: str = "float32"
    # error-feedback int8 gradient compression for the DP all-reduce
    compress_grads: bool = False


@dataclass(frozen=True)
class TrainConfig:
    steps: int = 100
    seq_len: int = 512
    global_batch: int = 8
    microbatches: int = 1
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    seed: int = 0
    log_every: int = 10


@dataclass(frozen=True)
class ServeConfig:
    max_running: int = 8               # concurrently decoding requests
    max_resident: int = 32             # requests resident in the KV pool
    page_tokens: int = 64              # tokens per KV page
    max_pages_per_seq: int = 64
    kv_rate_bits: int = 4              # compressed-pool KV rate (4 or 8)
    hot_window: int = 256              # uncompressed recent-token window (the
                                       # promoted region of the KV pool)
    attn_chunk: int = 2048             # kv chunk for the decode attention scan
    fused_dequant_attention: bool = True  # False = paper-faithful promote-then-read
    # fabric-aware serving: lanes are striped across this many expanders;
    # preempted payloads park per-expander and victim selection balances
    # parked load across expanders (serve/engine.py, fabric/)
    n_expanders: int = 1
    # KV lane quantization implementation ("auto"/"kernel"/"jnp"), resolved
    # by core/compressor.py::quantize_blocks_fast at trace time
    quantize_impl: str = "auto"
    pool: PoolConfig = field(default_factory=PoolConfig)


def replace(cfg, **kw):
    return dataclasses.replace(cfg, **kw)
