from repro.common import sharding, types, utils  # noqa: F401
