"""Long-context serving with the IBEX-compressed KV cache.

Demonstrates the paper's capacity story end-to-end on a reduced model:
a context longer than the hot window decodes against 4-bit compressed KV,
and we compare the fused dequant-attention path against the paper-faithful
promote-then-read path — same tokens, very different HBM traffic.

  PYTHONPATH=src python examples/serve_longctx.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import ServeConfig, replace
from repro.configs import get_reduced
from repro.models import decode as D
from repro.models import transformer as T


def main() -> None:
    cfg = get_reduced("llama3_8b")
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    B, prompt_len, new_tokens, max_len = 2, 192, 24, 512

    for fused in (True, False):
        scfg = ServeConfig(hot_window=32, attn_chunk=64, kv_rate_bits=4,
                           fused_dequant_attention=fused)
        tokens = jax.random.randint(key, (B, prompt_len), 1, cfg.vocab_size)
        logits, cache = D.prefill(params, {"tokens": tokens}, cfg, scfg,
                                  max_len=max_len)
        nbytes = D.cache_bytes(cache)
        raw = (cfg.num_layers * B * max_len * cfg.num_kv_heads *
               cfg.resolved_head_dim * 2 * 2)
        step = jax.jit(lambda p, c, t, q: D.decode_step(p, c, t, q, cfg, scfg))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        pos = jnp.full((B,), prompt_len, jnp.int32)
        out = [np.asarray(tok)]
        t0 = time.perf_counter()
        for i in range(new_tokens):
            logits, cache = step(params, cache, tok, pos + i)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(np.asarray(tok))
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / new_tokens * 1e3
        mode = "fused dequant-attn " if fused else "paper promote+read"
        print(f"[{mode}] {dt:6.1f} ms/tok | cache {nbytes / 1e6:.1f} MB "
              f"(uncompressed KV would be {raw / 1e6:.1f} MB) | "
              f"tokens: {np.stack(out)[:6, 0].tolist()}...")


if __name__ == "__main__":
    main()
