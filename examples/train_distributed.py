"""End-to-end distributed training driver (~100M-param model, few hundred
steps) with checkpoints, crash recovery and elastic resume.

On CPU this runs a genuinely multi-device program: set
  XLA_FLAGS=--xla_force_host_platform_device_count=8
to exercise the (data, model) mesh, FSDP sharding, checkpoint/restart and a
mid-run "failure" (restore onto a smaller mesh).

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/train_distributed.py --steps 200
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp

from repro.common.types import (ModelConfig, OptimizerConfig, TrainConfig,
                                replace)
from repro.data.pipeline import make_batch
from repro.launch.mesh import make_mesh
from repro.models import transformer as T
from repro.optim import adamw
from repro.train import checkpoint as ckpt
from repro.train import elastic
from repro.train.trainer import make_train_step


def model_100m() -> ModelConfig:
    return ModelConfig(name="repro-100m", family="dense", num_layers=8,
                       d_model=512, num_heads=8, num_kv_heads=4, d_ff=2048,
                       vocab_size=32000, remat=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--simulate-failure-at", type=int, default=-1)
    args = ap.parse_args()

    cfg = model_100m()
    n_dev = len(jax.devices())
    mesh_cfg = elastic.plan_mesh(n_dev, prefer_model=min(2, n_dev))
    mesh = make_mesh(mesh_cfg)
    print(f"devices={n_dev} mesh={mesh_cfg.shape} {mesh_cfg.axes}")

    tcfg = TrainConfig(steps=args.steps, seq_len=256, global_batch=8,
                       microbatches=2, checkpoint_every=50,
                       checkpoint_dir=args.ckpt_dir,
                       optimizer=OptimizerConfig(lr=3e-4, warmup_steps=20))

    key = jax.random.PRNGKey(0)
    box = {}

    def init():
        p, a = T.init_params(key, cfg)
        box["axes"] = a
        return p

    params = init()
    print(f"params: {sum(x.size for x in jax.tree_util.tree_leaves(params)) / 1e6:.1f}M")
    opt = adamw.init(params, tcfg.optimizer)
    step_fn, shardings = make_train_step(cfg, tcfg, mesh=mesh,
                                         param_axes=box["axes"])
    if shardings is not None:
        params = jax.device_put(params, shardings["params"])
        opt = jax.device_put(opt, shardings["opt"])

    start = 0
    latest = ckpt.latest(tcfg.checkpoint_dir)
    if latest is not None:
        print(f"resuming from checkpoint step {latest}")
        (params, opt), _ = _restore(tcfg.checkpoint_dir, latest, params, opt,
                                    shardings)
        start = latest

    t0 = time.time()
    for step in range(start, tcfg.steps):
        batch = make_batch(cfg, step, global_batch=tcfg.global_batch,
                           seq_len=tcfg.seq_len)
        if shardings is not None:
            batch = jax.device_put(batch, shardings["batch"])
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 20 == 0 or step == tcfg.steps - 1:
            dt = (time.time() - t0) / max(step - start + 1, 1)
            print(f"step {step:4d}  loss={float(metrics['loss']):.3f}  "
                  f"{dt * 1e3:.0f} ms/step")
        if (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_async(tcfg.checkpoint_dir, step + 1,
                            {"params": params, "opt": opt})
        if step + 1 == args.simulate_failure_at:
            print("simulated failure: exiting mid-run "
                  "(rerun to resume from the latest checkpoint)")
            ckpt.wait_pending()
            return
    ckpt.wait_pending()
    print("done.")


def _restore(d, step, params, opt, shardings):
    like = {"params": params, "opt": opt}
    sh = None
    if shardings is not None:
        sh = {"params": shardings["params"], "opt": shardings["opt"]}
    tree, extra = ckpt.restore(d, step, like, sh)
    return (tree["params"], tree["opt"]), extra


if __name__ == "__main__":
    main()
