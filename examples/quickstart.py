"""Quickstart: train a small LM with IBEX-compressed optimizer state, then
serve it with the IBEX paged-KV engine. Runs on CPU in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import OptimizerConfig, ServeConfig, TrainConfig
from repro.configs import get_reduced
from repro.data.pipeline import make_batch
from repro.models import transformer as T
from repro.optim import adamw
from repro.serve.engine import Engine
from repro.train.trainer import make_train_step


def main() -> None:
    cfg = get_reduced("llama3_8b")
    tcfg = TrainConfig(
        steps=20, seq_len=64, global_batch=8, microbatches=2,
        optimizer=OptimizerConfig(lr=1e-3, warmup_steps=5,
                                  compress_state=True))
    key = jax.random.PRNGKey(0)
    params, _ = T.init_params(key, cfg)
    opt = adamw.init(params, tcfg.optimizer)
    print(f"model: {cfg.name} (reduced) | params="
          f"{sum(x.size for x in jax.tree_util.tree_leaves(params)):,}")
    print(f"optimizer state bytes (8-bit moments): {adamw.state_bytes(opt):,}")

    step_fn, _ = make_train_step(cfg, tcfg)
    for step in range(tcfg.steps):
        batch = make_batch(cfg, step, global_batch=tcfg.global_batch,
                           seq_len=tcfg.seq_len)
        params, opt, metrics = step_fn(params, opt, batch)
        if step % 5 == 0 or step == tcfg.steps - 1:
            print(f"step {step:3d}  loss={float(metrics['loss']):.3f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}")

    # serve the trained model with the IBEX KV pool
    scfg = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                       kv_rate_bits=8)
    eng = Engine(cfg, scfg, params, max_len=128)
    rng = np.random.default_rng(0)
    rids = [eng.submit(list(rng.integers(1, cfg.vocab_size, 20)), 8)
            for _ in range(4)]
    eng.run_until_done()
    for rid in rids:
        print(f"request {rid}: {eng.result(rid)}")
    print(f"engine counters: {eng.counters}")


if __name__ == "__main__":
    main()
