"""Benchmark harness entry point: one function per paper table/figure plus
kernel/system micro-benchmarks. Prints ``name,us_per_call,derived`` CSV.

Usage:
  PYTHONPATH=src python -m benchmarks.run           # quick mode (CI-sized)
  PYTHONPATH=src python -m benchmarks.run --full    # full workload sweep
  PYTHONPATH=src python -m benchmarks.run --only fig09,kernel
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    ap.add_argument("--seed", type=int, default=0,
                    help="one seed, threaded end-to-end (trace generation, "
                         "per-expander RNG streams, model params) so every "
                         "BENCH_*.json run is bit-reproducible")
    args = ap.parse_args()
    quick = not args.full
    only = [s.strip() for s in args.only.split(",") if s.strip()]

    from benchmarks import (fabric_bench, kernel_bench, lint_bench,
                            paper_figs, serve_bench, simx_bench,
                            system_bench)

    suites = [(f.__name__, lambda q, s, f=f: f(q)) for f in
              paper_figs.ALL_FIGS]
    # Pallas kernel timings + engine calibration -> BENCH_kernels.json
    suites.append(("kernels", kernel_bench.run))
    suites.append(("system", lambda q, s: system_bench.run(q)))
    # trace-replay throughput; also writes BENCH_simx.json (accesses/sec per
    # scheme, serial-vs-batched) so the perf trajectory is machine-readable
    suites.append(("simx", simx_bench.run))
    # serving engine: per-lane baseline vs batched scheduler -> BENCH_serve.json
    suites.append(("serve", serve_bench.run))
    # multi-expander fabric: 1/2/4/8 scaling + skew + parity -> BENCH_fabric.json
    suites.append(("fabric", fabric_bench.run))
    # jit-hygiene lint over src vs committed baseline -> BENCH_lint.json;
    # runs LAST so its meta.lint stamp lands in every BENCH_*.json above
    suites.append(("lint", lint_bench.run))

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if only and not any(name.startswith(o) or o in name for o in only):
            continue
        try:
            for row in fn(quick, args.seed):
                print(f"{row['name']},{row['us']:.1f},{row['derived']}",
                      flush=True)
        except Exception as e:  # keep the suite running; count failures
            failed += 1
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
