"""Serving-engine benchmark: per-lane baseline vs device-resident batched
scheduler, same model, same workload.

Measures three things the tentpole claims:

  * **tokens/sec** — the batched engine admits fresh requests through
    bucketed prefill (few compiles, one sync per bucket) and advances lane
    bookkeeping on device (one sync per decode step); the serial baseline
    prefills per request (a compile per distinct prompt length, a sync per
    request) and fetches full logits every step. The workload uses mixed
    prompt lengths so the bucketing difference is visible, and the timed run
    *includes* admission — that is where serving latency actually goes.
  * **host-sync contract** — asserted, not just recorded:
    ``step_syncs == steps`` for the batched engine.
  * **preempt/resume bytes** — both engines quantize the ring on demotion
    and count the compressed payload honestly; the batched engine's shadowed
    lanes pay only for the suffix generated since the last park (the serial
    baseline drops its parked copy on resume and re-pays the full context),
    and a re-preempt of an untouched resumed request moves exactly 0 bytes
    (checked by driving resume→preempt directly).
  * **modeled seconds (DESIGN.md §12)** — the byte and sync counters priced
    through ``simx.time.serve_modeled_time``: serial-vs-batched and a
    fabric-striped (n_expanders=2) run compare in modeled seconds per
    decode step, not just simulator tokens/sec.

Writes ``BENCH_serve.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

import jax
import numpy as np

from repro.common.types import ServeConfig
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.obs import manifest as run_manifest
from repro.serve import Engine, SerialEngine

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_serve.json"

ARCH = "llama3_8b"


def _workload(rng, vocab: int, n_requests: int):
    """Mixed prompt lengths (the bucketing story needs length diversity)."""
    lens = [12, 20, 24, 17, 28, 9, 22, 14]
    return [list(rng.integers(1, vocab, lens[i % len(lens)]))
            for i in range(n_requests)]


def _serve(engine_cls, cfg, scfg, params, prompts, new_tokens, max_len,
           obs=None):
    eng = engine_cls(cfg, scfg, params, max_len=max_len, obs=obs)
    rids = [eng.submit(p, new_tokens) for p in prompts]
    t0 = time.perf_counter()
    eng.run_until_done(max_steps=4000)
    dt = time.perf_counter() - t0
    assert all(eng.requests[r].state == "done" for r in rids)
    return eng, dt


def _shadow_repreempt_bytes(cfg, scfg, params, prompts, max_len) -> int:
    """Bytes moved by re-preempting an untouched resumed request (must be 0:
    the shadow is re-validated instead)."""
    eng = Engine(cfg, scfg, params, max_len=max_len)
    rid = eng.submit(prompts[0], 10)
    for _ in range(3):
        eng.step()
    eng._preempt(0)
    req = eng.requests[rid]
    eng.queue.remove(rid)
    eng.lane_req[0] = rid
    eng._resume(req, 0)
    before = eng.counters["preempt_bytes"]
    eng._preempt(0)                     # untouched since resume
    assert eng.counters["shadow_repreempts"] == 1
    return eng.counters["preempt_bytes"] - before


def run(quick: bool, seed: int = 0) -> List[Dict]:
    cfg = get_reduced(ARCH)
    scfg = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                       kv_rate_bits=8)
    max_len = 128
    n_requests = 6 if quick else 12
    new_tokens = 8 if quick else 16
    params, _ = T.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = _workload(rng, cfg.vocab_size, n_requests)

    # warm the jit caches with a tiny run of each engine so the timed pass
    # measures steady-state serving of *new* lengths (the serial engine still
    # pays a prefill compile per unseen length inside the timed region — that
    # per-length cost is exactly its handicap in production)
    warm = prompts[:2]
    _serve(SerialEngine, cfg, scfg, params, warm, 2, max_len)
    _serve(Engine, cfg, scfg, params, warm, 2, max_len)

    se, dt_s = _serve(SerialEngine, cfg, scfg, params, prompts, new_tokens,
                      max_len)
    be, dt_b = _serve(Engine, cfg, scfg, params, prompts, new_tokens, max_len)
    tok_s = se.counters["tokens"] / max(dt_s, 1e-9)
    tok_b = be.counters["tokens"] / max(dt_b, 1e-9)

    # host-sync contract: the measured sync count must match the budget
    # Engine.step DECLARES via @sync_contract (one sync per decode step) —
    # not a constant this bench made up
    from repro.common.contracts import verify_sync_counters
    verify_sync_counters(Engine.step, be.counters["steps"],
                         be.counters["step_syncs"], what=str(be.counters))

    # fabric-striped run (lanes across 2 expanders; compiled programs are
    # shared with the single-expander engine — n_expanders is scheduling-
    # only and normalized out of the jit key)
    import dataclasses
    scfg2 = dataclasses.replace(scfg, n_expanders=2)
    fe, dt_f = _serve(Engine, cfg, scfg2, params, prompts, new_tokens,
                      max_len)

    # modeled seconds: serial vs batched vs fabric-striped in one currency
    ms, mb, mf = (e.modeled_time() for e in (se, be, fe))
    # same counters re-priced with the measurement-calibrated engine (no-op
    # fallback to paper constants when BENCH_kernels.json is absent)
    from repro.simx import time as TM
    cal_dev = TM.calibrated_device()
    mb_cal = be.modeled_time(cal_dev)

    shadow_bytes = _shadow_repreempt_bytes(cfg, scfg, params, prompts,
                                           max_len)
    assert shadow_bytes == 0, shadow_bytes

    # -- telemetry piggyback A/B (DESIGN.md §16): the batched run repeated
    # with an obs.Recorder attached. Asserted: the engine's counters are
    # identical to the recording-off run (the recorder only consumes the
    # host values the step's single fetch already produced), the declared
    # one-sync-per-step contract still holds with the recorder draining
    # every step, and the exported Perfetto trace validates. Wall-clock
    # overhead is recorded (warm A/B) — the ≤5% acceptance number.
    from repro.obs import Recorder
    from repro.obs import export as OBX
    rec = Recorder()
    re_, dt_r = _serve(Engine, cfg, scfg, params, prompts, new_tokens,
                       max_len, obs=rec)
    assert re_.counters == be.counters, \
        "recording changed the engine's counters"
    verify_sync_counters(Engine.step, re_.counters["steps"],
                         re_.counters["step_syncs"],
                         what="recorder attached")
    trace = OBX.build_trace(rec)
    errors = OBX.validate_trace(trace)
    assert not errors, errors
    obs_ab = {
        "counters_identical": True,
        "step_syncs_with_recorder": re_.counters["step_syncs"],
        "steps_recorded": len(rec.steps),
        "events_recorded": len(rec.serve_events),
        "trace_events": len(trace["traceEvents"]),
        "trace_valid": True,
        "wallclock_overhead_ratio": dt_r / max(dt_b, 1e-12),
    }

    payload = {
        "meta": {**run_manifest(seed=seed),
                 "arch": ARCH, "lanes": scfg.max_running,
                 "requests": n_requests, "new_tokens": new_tokens,
                 "max_len": max_len, "quick": quick,
                 "unit": "decode tokens/sec, admission included"},
        "obs": obs_ab,
        "serial_tok_per_sec": tok_s,
        "batched_tok_per_sec": tok_b,
        "speedup_batched_over_serial": tok_b / max(tok_s, 1e-9),
        "serial": {k: se.counters[k] for k in
                   ("steps", "tokens", "step_syncs", "admit_syncs",
                    "prefill_batches", "demotions", "preempt_bytes",
                    "resume_bytes", "shadow_repreempts")},
        "batched": {k: be.counters[k] for k in
                    ("steps", "tokens", "step_syncs", "admit_syncs",
                     "prefill_batches", "demotions", "preempt_bytes",
                     "resume_bytes", "shadow_repreempts")},
        "step_syncs_per_step": be.counters["step_syncs"] /
        max(be.counters["steps"], 1),
        "shadow_repreempt_bytes": shadow_bytes,
        # delivered-time accounting (DESIGN.md §12): one currency (seconds)
        # across serial / batched / fabric-striped scheduling
        "modeled": {
            "unit": "modeled seconds from preempt/resume bytes + host "
                    "syncs (simx.time.serve_modeled_time)",
            "serial": ms,
            "batched": mb,
            "fabric_striped_2x": dict(mf, per_expander_stats={
                k: v.tolist() for k, v in fe.expander_stats.items()}),
            "modeled_speedup_batched_over_serial":
                ms["modeled_s_per_step"] / max(mb["modeled_s_per_step"],
                                               1e-18),
            "batched_calibrated": dict(
                mb_cal,
                device={"comp_cycles": cal_dev.comp_cycles,
                        "decomp_cycles": cal_dev.decomp_cycles,
                        "calibrated": cal_dev != TM.DeviceConfig()}),
        },
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")

    return [
        {"name": "serve.serial_tok_per_sec", "us": dt_s * 1e6,
         "derived": f"{tok_s:,.1f}tok/s;prefills={se.counters['prefill_batches']};"
                    f"admit_syncs={se.counters['admit_syncs']}"},
        {"name": "serve.batched_tok_per_sec", "us": dt_b * 1e6,
         "derived": f"{tok_b:,.1f}tok/s;prefills={be.counters['prefill_batches']};"
                    f"admit_syncs={be.counters['admit_syncs']}"},
        {"name": "serve.speedup", "us": 0.0,
         "derived": f"x{tok_b / max(tok_s, 1e-9):.2f};"
                    f"syncs_per_step={payload['step_syncs_per_step']:.0f};"
                    f"shadow_repreempt_bytes={shadow_bytes};"
                    f"json={JSON_PATH.name}"},
        {"name": "serve.modeled_s_per_step", "us": dt_f * 1e6,
         "derived": f"serial={ms['modeled_s_per_step'] * 1e6:.2f}us;"
                    f"batched={mb['modeled_s_per_step'] * 1e6:.2f}us;"
                    f"striped2x={mf['modeled_s_per_step'] * 1e6:.2f}us;"
                    f"modeled_x={payload['modeled']['modeled_speedup_batched_over_serial']:.2f}"},
        {"name": "serve.obs.ab", "us": dt_r * 1e6,
         "derived": f"overhead=x{obs_ab['wallclock_overhead_ratio']:.3f};"
                    f"counters_identical=True;"
                    f"events={obs_ab['trace_events']}"},
    ]
