"""Hygiene stamp for bench runs: the same lint invocation CI runs
(``python -m repro.analysis.lint src --baseline
src/repro/analysis/baseline.json``) executed as a bench suite, so every
BENCH_*.json produced by a run records whether the code it measured
honored the tracing/host-sync contracts.

Writes ``BENCH_lint.json`` and injects a compact ``meta.lint`` stamp
into every sibling BENCH_*.json present at the repo root (the suite runs
LAST in ``benchmarks.run`` for exactly this reason). Raises on new
findings so ``--only lint`` fails the same way the CI step does.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

ROOT = pathlib.Path(__file__).resolve().parent.parent
JSON_PATH = ROOT / "BENCH_lint.json"
BASELINE = ROOT / "src" / "repro" / "analysis" / "baseline.json"


def _stamp(report: dict) -> dict:
    c = report["counts"]
    return {"ok": report["ok"], "new": c["new"], "active": c["active"],
            "grandfathered": c["grandfathered"],
            "suppressed_host_ok": c["suppressed"],
            "stale_baseline": c["stale_baseline"]}


def run(quick: bool, seed: int = 0) -> List[Dict]:
    from repro.analysis.lint import run_lint

    t0 = time.perf_counter()
    report = run_lint([str(ROOT / "src")], baseline_path=BASELINE)
    report.pop("_findings", None)
    dt_us = (time.perf_counter() - t0) * 1e6

    stamp = _stamp(report)
    # run_manifest degrades gracefully on jax-free hosts (backend/device
    # fields stay None) — the lint suite must run without the jax stack
    from repro.obs import manifest as run_manifest
    payload = {"meta": {**run_manifest(seed=seed),
                        "files": report["files"],
                        "baseline": "src/repro/analysis/baseline.json",
                        "by_rule": report["by_rule"], **stamp},
               "new": report["new"],
               "stale_baseline": report["stale_baseline"],
               "suppressed": report["suppressed"]}
    JSON_PATH.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")

    # stamp every sibling bench JSON from this run with the verdict
    for bench in sorted(ROOT.glob("BENCH_*.json")):
        if bench == JSON_PATH:
            continue
        try:
            data = json.loads(bench.read_text())
        except (ValueError, OSError):
            continue
        if isinstance(data, dict):
            data.setdefault("meta", {})["lint"] = stamp
            bench.write_text(json.dumps(data, indent=2, sort_keys=True)
                             + "\n")

    rows = [{"name": "lint_src", "us": dt_us,
             "derived": (f"files={report['files']} new={stamp['new']} "
                         f"active={stamp['active']} "
                         f"suppressed={stamp['suppressed_host_ok']} "
                         f"ok={stamp['ok']}")}]
    if not report["ok"]:
        raise RuntimeError(
            f"jit-hygiene lint failed: {stamp['new']} new finding(s) — "
            f"see BENCH_lint.json")
    return rows
