"""Measured kernel throughput + compression-engine calibration.

Two kinds of numbers, kept separate on purpose:

  * ``kernels`` rows — Pallas kernel wall time. Off-TPU these run in
    interpret mode (a python grid loop: NOT hardware perf, recorded with
    ``mode=pallas-interpret`` so nobody mistakes them for TPU numbers); on a
    TPU backend they are compiled-kernel timings.
  * ``calibration`` — the *production* compress/decompress path, compiled
    (``jax.jit``): the fused Pallas kernels on TPU, the bit-identical
    jnp/XLA oracle elsewhere.  Measured GB/s of uncompressed bytes is
    converted to engine cycles/block and consumed by
    ``simx.time.calibrated_device()`` so delivered-time curves can be priced
    from measurement instead of the paper's assumed 256/64 cycles.

``fused_vs_unfused`` times one fused demote launch (rate-select + quantize +
pack + quanta emit) against the unfused sequence it replaces — two
fixed-rate qpack launches plus jnp rate-selection/assembly — in the same
execution mode (acceptance: fused >= unfused).

Writes ``BENCH_kernels.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.common.types import PoolConfig
from repro.common.utils import time_fn
from repro.core import compressor as comp
from repro.kernels import ops
from repro.obs import manifest as run_manifest
from repro.roofline import analyze as AN

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_kernels.json"


def _gbps(nbytes: float, us: float) -> float:
    return nbytes / (us * 1e-6) / 1e9 if us > 0 else 0.0


def _unfused_demote(x, quanta):
    """The pre-fusion demote sequence: two fixed-rate kernel launches (4-bit
    and 8-bit quantize+pack) followed by jnp rate selection and dense-stream
    assembly — what ``qpack_fused_encode`` replaces with one grid pass."""
    t, v = x.shape
    c4, s4 = ops.qpack_encode(x, bits=4, block=v)
    c8, s8 = ops.qpack_encode(x, bits=8, block=v)
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    d4 = ops.qpack_decode(c4, s4, bits=4, block=v).astype(jnp.float32)
    d8 = ops.qpack_decode(c8, s8, bits=8, block=v).astype(jnp.float32)
    safe = jnp.where(amax > 0, amax, 1.0)
    ok4 = jnp.max(jnp.abs(d4 - xf), axis=-1) / safe <= 0.10
    ok8 = jnp.max(jnp.abs(d8 - xf), axis=-1) / safe <= 0.01
    rate = jnp.where(ok8, 2, 3)
    rate = jnp.where(ok4, 1, rate)
    rate = jnp.where(amax == 0, 0, rate).astype(jnp.int32)
    from repro.common.utils import f32_to_bytes
    from repro.core.bitpack import raw_to_bytes
    pad4 = jnp.zeros((t, 2 * v - 4 - v // 2), jnp.uint8)
    pad8 = jnp.zeros((t, 2 * v - 4 - v), jnp.uint8)
    b4 = jnp.concatenate([jax.vmap(lambda s: f32_to_bytes(s[None]))(s4[:, 0]),
                          c4, pad4], axis=-1)
    b8 = jnp.concatenate([jax.vmap(lambda s: f32_to_bytes(s[None]))(s8[:, 0]),
                          c8, pad8], axis=-1)
    braw = jax.vmap(raw_to_bytes)(x.astype(jnp.bfloat16))
    dense = jnp.where((rate == 1)[:, None], b4,
                      jnp.where((rate == 2)[:, None], b8, braw))
    dense = jnp.where((rate == 0)[:, None], jnp.zeros_like(dense), dense)
    qtab = jnp.asarray(quanta, jnp.int32)
    return dense, rate, qtab[rate]


def run(quick: bool, seed: int = 0) -> List[Dict]:
    rows = []
    backend = jax.default_backend()
    kmode = "pallas-compiled" if backend == "tpu" else "pallas-interpret"
    cmode = "compiled-pallas" if backend == "tpu" else "compiled-xla"
    key = jax.random.PRNGKey(seed)

    # -- Pallas kernel wall time (interpret mode off-TPU) --------------------
    n = 64 if quick else 512
    x = (jax.random.normal(key, (n, 512))).astype(jnp.bfloat16)
    logical = x.size * 2

    for bits in (4, 8):
        us = time_fn(lambda: ops.qpack_encode(x.reshape(-1), bits=bits,
                                              block=512), iters=3)
        rows.append({"name": f"kernel.qpack_encode_{bits}b", "us": us,
                     "bytes": logical, "mode": kmode,
                     "derived": f"logical_bytes={logical};mode={kmode}"})
        codes, scales = ops.qpack_encode(x.reshape(-1), bits=bits, block=512)
        us = time_fn(lambda: ops.qpack_decode(codes, scales, bits=bits,
                                              block=512), iters=3)
        rows.append({"name": f"kernel.qpack_decode_{bits}b", "us": us,
                     "bytes": logical, "mode": kmode,
                     "derived": f"compressed_bytes="
                                f"{codes.size + scales.size * 4};mode={kmode}"})

    # -- fused demote vs the unfused quantize-then-pack sequence -------------
    tq = 32 if quick else 256
    v = 512
    quanta = comp.quanta_per_rate(v)
    blocks = (jax.random.normal(jax.random.fold_in(key, 1), (tq, v)) *
              0.5).astype(jnp.bfloat16)
    blocks = blocks.at[::4].set(0.0)           # exercise the zero rate too
    fused_us = time_fn(lambda: ops.qpack_fused_encode(
        blocks, quanta=quanta), iters=3)
    unfused_us = time_fn(lambda: _unfused_demote(blocks, quanta), iters=3)
    fbytes = blocks.size * 2
    rows.append({"name": "kernel.fused_demote", "us": fused_us,
                 "bytes": fbytes, "mode": kmode,
                 "derived": f"gbps={_gbps(fbytes, fused_us):.3f};mode={kmode}"})
    rows.append({"name": "kernel.unfused_demote", "us": unfused_us,
                 "bytes": fbytes, "mode": kmode,
                 "derived": f"gbps={_gbps(fbytes, unfused_us):.3f};"
                            f"fused_speedup=x{unfused_us / max(fused_us, 1e-9):.2f}"})
    dense_f, rates_f, _ = ops.qpack_fused_encode(blocks, quanta=quanta)
    prom_us = time_fn(lambda: ops.qpack_fused_decode(dense_f, rates_f),
                      iters=3)
    rows.append({"name": "kernel.fused_promote", "us": prom_us,
                 "bytes": fbytes, "mode": kmode,
                 "derived": f"gbps={_gbps(fbytes, prom_us):.3f};mode={kmode}"})

    # -- attention kernels (unchanged coverage) ------------------------------
    B, S, Hq, Hkv, D = (1, 256, 4, 2, 64) if quick else (2, 1024, 8, 2, 128)
    q = jax.random.normal(key, (B, Hq, D)).astype(jnp.bfloat16)
    k = jax.random.normal(key, (B, S, Hkv, D))
    vv = jax.random.normal(key, (B, S, Hkv, D))
    kc, ks = comp.quantize_blocks(k, 4, D)
    vc, vs = comp.quantize_blocks(vv, 4, D)
    lengths = jnp.full((B,), S, jnp.int32)
    us = time_fn(lambda: ops.kvc_decode_attention(
        q, kc, ks[..., 0], vc, vs[..., 0], lengths, bits=4, t_blk=128),
        iters=3)
    hbm_fused = kc.size + vc.size + ks.size * 4 + vs.size * 4
    hbm_paper = k.size * 2 + vv.size * 2 + hbm_fused  # promote then read bf16
    rows.append({"name": "kernel.kvc_decode_attention", "us": us,
                 "mode": kmode,
                 "derived": f"fused_bytes={hbm_fused};paper_bytes={hbm_paper}"
                            f";saving=x{hbm_paper / hbm_fused:.2f}"})

    Sq = 128 if quick else 256
    q2 = jax.random.normal(key, (1, Sq, 4, 64)).astype(jnp.bfloat16)
    k2 = jax.random.normal(key, (1, Sq, 2, 64)).astype(jnp.bfloat16)
    v2 = jax.random.normal(key, (1, Sq, 2, 64)).astype(jnp.bfloat16)
    us = time_fn(lambda: ops.flash_attention(q2, k2, v2, causal=True,
                                             tq=64, tk=64), iters=3)
    flops = 4 * Sq * Sq * 4 * 64 // 2
    rows.append({"name": "kernel.flash_attention", "us": us, "mode": kmode,
                 "derived": f"logical_flops={flops}"})

    # -- calibration: compiled production encode/decode ----------------------
    cfg = PoolConfig()                        # compress_impl="auto"
    npages = 128 if quick else 1024
    pages = (jax.random.normal(jax.random.fold_in(key, 2),
                               (npages, cfg.vals_per_page)) *
             0.5).astype(jnp.bfloat16)
    enc = jax.jit(lambda xs: comp.encode_pages(xs, cfg))
    bufs, rates, _, _ = enc(pages)            # compile + encoded inputs
    dec = jax.jit(lambda b, r: comp.decode_pages(b, r, cfg))
    dec(bufs, rates)
    enc_us = time_fn(lambda: enc(pages), iters=5)
    dec_us = time_fn(lambda: dec(bufs, rates), iters=5)
    nbytes = npages * cfg.page_bytes
    comp_gbps = _gbps(nbytes, enc_us)
    decomp_gbps = _gbps(nbytes, dec_us)
    base_clock = 2.0e9
    comp_cycles = max(1, int(round(base_clock * 1024 / (comp_gbps * 1e9))))
    decomp_cycles = max(1, int(round(base_clock * 1024 / (decomp_gbps * 1e9))))
    rows.append({"name": "kernel.calibrated_compress", "us": enc_us,
                 "bytes": nbytes, "mode": cmode,
                 "derived": f"gbps={comp_gbps:.3f};"
                            f"cycles_per_1KB={comp_cycles};paper=256"})
    rows.append({"name": "kernel.calibrated_decompress", "us": dec_us,
                 "bytes": nbytes, "mode": cmode,
                 "derived": f"gbps={decomp_gbps:.3f};"
                            f"cycles_per_1KB={decomp_cycles};paper=64"})

    payload = {
        "meta": {**run_manifest(seed=seed), "quick": quick,
                 "kernel_mode": kmode, "calibration_mode": cmode,
                 "unit": "us per call (median); GB/s of uncompressed bytes"},
        "kernels": [{"name": r["name"], "us": r["us"],
                     "derived": r["derived"], "mode": r.get("mode", kmode)}
                    for r in rows],
        "fused_vs_unfused": {
            "fused_us": fused_us, "unfused_us": unfused_us,
            "fused_gbps": _gbps(fbytes, fused_us),
            "unfused_gbps": _gbps(fbytes, unfused_us),
            "speedup": unfused_us / max(fused_us, 1e-9),
            "bytes": fbytes, "mode": kmode,
            "fused_ge_unfused": bool(fused_us <= unfused_us),
        },
        "calibration": {
            "compress_gbps": comp_gbps, "decompress_gbps": decomp_gbps,
            "block_bytes": 1024, "clock": base_clock,
            "comp_cycles": comp_cycles, "decomp_cycles": decomp_cycles,
            "paper_comp_cycles": 256, "paper_decomp_cycles": 64,
            "mode": cmode, "uncompressed_bytes": nbytes,
        },
        # distance-from-bandwidth-bound per kernel (streaming kernels: the
        # HBM roof is the speed of light; interpret-mode rows are python
        # wall time and will sit far from it by construction)
        "roofline": AN.kernel_roofline([r for r in rows if "bytes" in r]),
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    rows.append({"name": "kernel.fused_vs_unfused", "us": 0.0,
                 "derived": f"x{payload['fused_vs_unfused']['speedup']:.2f};"
                            f"json={JSON_PATH.name}"})
    return [{k: r[k] for k in ("name", "us", "derived")} for r in rows]
