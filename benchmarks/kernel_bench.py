"""Kernel micro-benchmarks (interpret mode on CPU: wall time is NOT TPU perf;
``derived`` reports logical bytes/FLOPs so TPU projections use the roofline
constants instead)."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.common.utils import time_fn
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def run(quick: bool) -> List[Dict]:
    rows = []
    n = 64 if quick else 512
    x = (jax.random.normal(KEY, (n, 512))).astype(jnp.bfloat16)

    for bits in (4, 8):
        us = time_fn(lambda: ops.qpack_encode(x.reshape(-1), bits=bits,
                                              block=512), iters=3)
        logical = x.size * 2
        rows.append({"name": f"kernel.qpack_encode_{bits}b", "us": us,
                     "derived": f"logical_bytes={logical}"})
        codes, scales = ops.qpack_encode(x.reshape(-1), bits=bits, block=512)
        us = time_fn(lambda: ops.qpack_decode(codes, scales, bits=bits,
                                              block=512), iters=3)
        rows.append({"name": f"kernel.qpack_decode_{bits}b", "us": us,
                     "derived": f"compressed_bytes={codes.size + scales.size * 4}"})

    B, S, Hq, Hkv, D = (1, 256, 4, 2, 64) if quick else (2, 1024, 8, 2, 128)
    q = jax.random.normal(KEY, (B, Hq, D)).astype(jnp.bfloat16)
    k = jax.random.normal(KEY, (B, S, Hkv, D))
    v = jax.random.normal(KEY, (B, S, Hkv, D))
    from repro.core.compressor import quantize_blocks
    kc, ks = quantize_blocks(k, 4, D)
    vc, vs = quantize_blocks(v, 4, D)
    lengths = jnp.full((B,), S, jnp.int32)
    us = time_fn(lambda: ops.kvc_decode_attention(
        q, kc, ks[..., 0], vc, vs[..., 0], lengths, bits=4, t_blk=128),
        iters=3)
    hbm_fused = kc.size + vc.size + ks.size * 4 + vs.size * 4
    hbm_paper = k.size * 2 + v.size * 2 + hbm_fused  # promote then read bf16
    rows.append({"name": "kernel.kvc_decode_attention", "us": us,
                 "derived": f"fused_bytes={hbm_fused};paper_bytes={hbm_paper}"
                            f";saving=x{hbm_paper / hbm_fused:.2f}"})

    Sq = 128 if quick else 256
    q2 = jax.random.normal(KEY, (1, Sq, 4, 64)).astype(jnp.bfloat16)
    k2 = jax.random.normal(KEY, (1, Sq, 2, 64)).astype(jnp.bfloat16)
    v2 = jax.random.normal(KEY, (1, Sq, 2, 64)).astype(jnp.bfloat16)
    us = time_fn(lambda: ops.flash_attention(q2, k2, v2, causal=True,
                                             tq=64, tk=64), iters=3)
    flops = 4 * Sq * Sq * 4 * 64 // 2
    rows.append({"name": "kernel.flash_attention", "us": us,
                 "derived": f"logical_flops={flops}"})
    return rows
