"""Trace-replay throughput benchmark: the seed's one-access-per-step serial
scan vs the batched window front-end (repro.core.engine.batch), per scheme
and workload.

Writes ``BENCH_simx.json`` at the repo root so the perf trajectory is
tracked from PR 1 onward: ``serial`` is the *before* (the seed engine's
replay structure, ``window=1``), ``batched`` is the *after* (the default
front-end). Steady-state accesses/sec, compile excluded (median of reps).
"""
from __future__ import annotations

import json
import pathlib
import statistics
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.obs import manifest as run_manifest
from repro.simx.engine import SCHEMES, first_touch_populate, pool_cfg_for
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_simx.json"

Q_SCHEMES, F_SCHEMES = ["ibex", "tmcc"], ["ibex", "tmcc", "mxt", "dmc"]
Q_WL, F_WL = ["mcf", "xsbench", "pr"], ["mcf", "xsbench", "pr", "lbm",
                                        "omnetpp"]


def _warmed_pool(policy, cfg, spec, n_pages, prom, seed=0):
    rates = make_rates_table(spec, n_pages, seed=seed)
    n_used = min(max(int(prom * spec.footprint_pages), 32), n_pages)
    pool = S.make_pool(cfg, seed=seed, rates_table=jnp.asarray(rates))
    return first_touch_populate(pool, cfg, policy, n_used=n_used,
                                seed=seed), n_used


def _steady_rates(fn_a, fn_b, n_accesses: int, reps: int):
    """Interleaved A/B steady-state rates — back-to-back pairs so machine
    load hits both variants equally, min-of-reps so preemption noise (large
    on shared boxes) does not land in the estimate."""
    jax.block_until_ready(fn_a().counters)          # compile + warm
    jax.block_until_ready(fn_b().counters)
    ta, tb = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a().counters)
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b().counters)
        tb.append(time.perf_counter() - t0)
    return n_accesses / min(ta), n_accesses / min(tb)


def run(quick: bool, seed: int = 0) -> List[Dict]:
    schemes = Q_SCHEMES if quick else F_SCHEMES
    workloads = Q_WL if quick else F_WL
    # the paper-fig suite's operating point (paper_figs.PROM_Q/N_Q scale)
    n_accesses = 4096
    prom = 64
    reps = 5 if quick else 9
    window = B.DEFAULT_WINDOW

    serial: Dict[str, Dict[str, float]] = {}
    batched: Dict[str, Dict[str, float]] = {}
    rows = []
    for s in schemes:
        policy = SCHEMES[s]
        n_pages = 4 * prom
        cfg = pool_cfg_for(policy, n_pages=n_pages, n_pchunks=prom,
                           n_cchunks=2 * n_pages * 8)
        serial[s], batched[s] = {}, {}
        for wl in workloads:
            spec = WORKLOADS[wl]
            pool, n_used = _warmed_pool(policy, cfg, spec, n_pages, prom,
                                        seed=seed)
            ospn, wr, blk = make_trace(spec, n_accesses=n_accesses,
                                       n_pages=n_used, seed=seed)
            args = (jnp.asarray(ospn), jnp.asarray(wr), jnp.asarray(blk))
            t0 = time.perf_counter()
            serial[s][wl], batched[s][wl] = _steady_rates(
                lambda: B._replay_serial(pool, cfg, policy, *args),
                lambda: B.replay_trace(pool, cfg, policy, ospn, wr, blk,
                                       window=window),
                n_accesses, reps)
            speed = batched[s][wl] / serial[s][wl]
            rows.append({
                "name": f"simx.replay.{s}.{wl}",
                "us": (time.perf_counter() - t0) * 1e6,
                "derived": f"serial={serial[s][wl]:,.0f}acc/s;"
                           f"batched={batched[s][wl]:,.0f}acc/s;"
                           f"speedup=x{speed:.2f}"})
    speedups = [batched[s][w] / serial[s][w] for s in schemes
                for w in workloads]
    gm = float(np.exp(np.mean(np.log(speedups))))
    payload = {
        "meta": {**run_manifest(seed=seed),
                 "n_accesses": n_accesses, "promoted_pages": prom,
                 "window": window, "reps": reps, "quick": quick,
                 "unit": "accesses/sec (steady state, compile excluded)"},
        "serial_acc_per_sec": serial,
        "batched_acc_per_sec": batched,
        "speedup_batched_over_serial": {
            s: {w: batched[s][w] / serial[s][w] for w in workloads}
            for s in schemes},
        "geomean_speedup": gm,
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    rows.append({"name": "simx.replay.geomean_speedup", "us": 0.0,
                 "derived": f"x{gm:.2f};json={JSON_PATH.name}"})
    return rows
