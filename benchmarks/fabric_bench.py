"""Multi-expander fabric benchmark: delivered-time scaling curves + skew
sensitivity + counter-sum and time-model parity (DESIGN.md §11/§12).

  * **scaling** — the same merged trace replayed through fabrics of
    1/2/4/8 expanders (per-expander pool dimensions fixed, so capacity
    scales with N). Two rates per point: simulator wall-clock accesses/sec
    (steady state, compile excluded — NOTE: under vmap both sides of every
    masked-window branch execute for all expanders, so wall-clock carries
    a documented constant and is NOT the delivered-bandwidth story) and
    **delivered** accesses/sec: expanders serve in parallel, so delivered
    time is the *bottleneck* expander's vectorized device-model time
    (`Fabric.delivered_time`, computed inside the vmapped replay) over its
    own traffic — that is the curve that scales with capacity and
    collapses under skew.
  * **mixed fleets** — heterogeneous generations (`simx.time
    DEVICE_PROFILES`: gen5 default + gen4) under skewed placement with
    spill LIVE: per-expander delivered seconds price each expander's own
    traffic — including migration traffic, charged on the expander where
    it physically occurred — through that expander's own DeviceConfig.
  * **skew** — a 4-expander fabric under WeightedInterleave placement with
    a growing expander-0 page share: delivered rate + per-expander host
    traffic share + spill activity (placement skew, not workload locality,
    is the lever that kills delivered bandwidth on real multi-device CXL).
  * **migration pipeline** — the skew-0.8 4-expander point under the
    ``rebalance`` MigrationPolicy, replayed through the overlapped
    segment scheduler (pipeline depth 2) AND the synchronous reference
    driver: per-segment pipeline pricing (``simx.time
    pipeline_delivered_time``) records sync-vs-overlapped delivered time,
    with overlapped <= sync ASSERTED on the overlapped run's own deltas
    (max <= sum per segment) — and the depth-1 degenerate pipeline is
    asserted BIT-IDENTICAL (pools + counters + overrides) to the
    synchronous driver.
  * **host-sync contract (asserted on every fabric run)** — mirroring
    serve's ``step_syncs == steps``: exactly one host sync per replayed
    segment (the fused stats fetch) and one per committed migration epoch
    (the moved-pages fetch); ``segment_syncs == segments`` and
    ``epoch_syncs == epochs`` are checked machine-side on every
    scaling/fleet/skew/migration point, so the "one sync per pipeline
    stage" claim is enforced, not narrated.
  * **parity (asserted)** — an N=1 fabric is counter-for-counter identical
    to ``batch.replay_trace`` on one pool, and an N=2 fabric's summed
    counters equal the sum of single-pool replays of the merged trace's
    per-expander partitions EXACTLY (static interleave, no spill). Against
    ONE merged pool with N× regions + N× metadata cache, total internal
    traffic agrees within the documented tolerance (shared-vs-sharded
    cache and demotion cadence shift counters; see DESIGN.md §11). The
    vectorized time model is additionally asserted against the legacy
    scalar dict path (bitwise, float64) on every expander of every scaling
    point, and against the in-jit float32 value within 1e-4.

Writes ``BENCH_fabric.json`` at the repo root.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import replace
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import POLICIES
from repro.fabric import Fabric, StaticInterleave, WeightedInterleave
from repro.obs import manifest as run_manifest
from repro.simx import device as DEV
from repro.simx import time as TM
from repro.simx.engine import TRAFFIC_KEYS, pool_cfg_for
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_fabric.json"

SCALES = (1, 2, 4, 8)
SKEWS_Q = (0.5, 0.8)           # expander-0 page share at N=4
SKEWS_F = (0.25, 0.5, 0.8)
MERGED_POOL_TOL = 0.35         # documented tolerance vs ONE merged pool
WL = "mcf"

# mixed-generation fleets (profiles cycle across expanders): the gen4
# expanders' slower link/channels/engine make them the delivered-time
# bottleneck even under even placement
FLEETS_Q = {"mixed2": ("default", "gen4")}
FLEETS_F = {"mixed2": ("default", "gen4"),
            "mixed4": ("default", "default", "gen4", "gen4")}


def _fabric(cfg, n, rates, seed, window, placement=None, **kw):
    placement = placement or StaticInterleave(n, cfg.n_pages)
    return Fabric(cfg, POLICIES["ibex"], placement, seed=seed,
                  rates_table=jnp.asarray(rates), window=window, **kw)


def _rate(make, ospn, wr, blk, reps: int):
    """Steady-state accesses/sec: compile+warm once, then min-of-reps on
    fresh fabrics (state shapes identical → jit cache hits). Returns
    (rate, last fabric) so callers read counters without another replay."""
    make().replay(ospn, wr, blk)                  # compile + warm
    best = np.inf
    for _ in range(reps):
        fab = make()
        t0 = time.perf_counter()
        jax.block_until_ready(fab.replay(ospn, wr, blk).pools.counters)
        best = min(best, time.perf_counter() - t0)
    return len(ospn) / best, fab


def _internal(c: Dict[str, int]) -> int:
    return sum(c[k] for k in TRAFFIC_KEYS)


def _sync_contract(fab: Fabric) -> Dict[str, int]:
    """Assert (and record) the segment scheduler's host-sync contract:
    measured syncs must match the budgets `_fetch_view` / `_commit_epoch`
    DECLARE via @sync_contract (one per segment, one per epoch) — the
    bench cross-checks the declaration instead of restating it."""
    from repro.common.contracts import verify_sync_counters
    ss = fab.sync_stats()
    verify_sync_counters(Fabric._fetch_view, ss["segments"],
                         ss["segment_syncs"], what=str(ss))
    verify_sync_counters(Fabric._commit_epoch, ss["epochs"],
                         ss["epoch_syncs"], what=str(ss))
    return ss


def _delivered(fab: Fabric) -> Dict[str, object]:
    """Per-expander + bottleneck delivered seconds, with the time-model
    parity contract asserted: the vectorized float64 path is bitwise what
    the legacy scalar dict model computes per expander, and the in-jit
    float32 values (computed inside the vmapped replay) agree to 1e-4."""
    per = fab.delivered_time()                       # float64, host
    for e, c in enumerate(fab.counters_by_expander()):
        legacy = DEV.exec_time(dict(c, internal_accesses=_internal(c)),
                               fab.devices[e])
        assert per[e] == legacy, \
            f"vectorized time drifted from scalar model on expander {e}"
    in_jit = fab.delivered_time(exact=False)
    assert np.allclose(per, in_jit, rtol=1e-4), (per, in_jit)
    return {"per_expander_s": [float(t) for t in per],
            "bottleneck_s": float(per.max()),
            "bottleneck_expander": int(per.argmax())}


def run(quick: bool, seed: int = 0) -> List[Dict]:
    prom = 32                      # per-expander promoted region
    n_pages = 256                  # shared OSPA page space: N=1 is 8x
    #                                oversubscribed, N=8 fully promotes —
    #                                the capacity side of the scaling story
    n_accesses = 2048 if quick else 8192
    window = 16
    reps = 2 if quick else 4
    cfg = pool_cfg_for(  # per-expander pool dimensions (fixed across N)
        POLICIES["ibex"], n_pages=n_pages, n_pchunks=prom,
        n_cchunks=2 * n_pages * 4)
    spec = WORKLOADS[WL]
    rates = make_rates_table(spec, n_pages, seed=seed)
    ospn, wr, blk = make_trace(spec, n_accesses=n_accesses, n_pages=n_pages,
                               seed=seed)
    rows = []

    # -- delivered-time scaling curve (homogeneous fleets) -------------------
    scaling: Dict[str, Dict[str, float]] = {}
    for n in SCALES:
        t0 = time.perf_counter()
        acc, fab = _rate(lambda n=n: _fabric(cfg, n, rates, seed, window,
                                             spill=False), ospn, wr, blk,
                         reps)
        d = _delivered(fab)
        modeled = n_accesses / d["bottleneck_s"]
        scaling[str(n)] = {
            "wallclock_acc_per_sec": acc,
            "modeled_acc_per_sec": modeled,
            "delivered_time_s": d["bottleneck_s"],
            "delivered_per_expander_s": d["per_expander_s"],
            "internal_accesses": _internal(fab.counters()),
            "sync": _sync_contract(fab),
        }
        rows.append({"name": f"fabric.scale.{n}x",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"wall={acc:,.0f}acc/s;"
                                f"delivered={d['bottleneck_s'] * 1e6:.1f}us;"
                                f"modeled={modeled:,.0f}acc/s;"
                                f"internal={_internal(fab.counters())}"})
        if n == 1:
            c_1x = dict(fab.counters(),
                        internal_accesses=_internal(fab.counters()))

    # -- measurement-calibrated engine pricing (tentpole (b)): the N=1
    # delivered-time point re-priced with engine constants derived from the
    # measured kernel throughput in BENCH_kernels.json (paper constants when
    # the bench artifact is absent)
    cal_dev = TM.calibrated_device()
    paper_dev = TM.DeviceConfig()
    calibration = {
        "source": "BENCH_kernels.json" if cal_dev != paper_dev
                  else "paper-fallback",
        "comp_cycles": cal_dev.comp_cycles,
        "decomp_cycles": cal_dev.decomp_cycles,
        "paper_comp_cycles": paper_dev.comp_cycles,
        "paper_decomp_cycles": paper_dev.decomp_cycles,
        "delivered_time_s_1x_paper": float(DEV.exec_time(c_1x, paper_dev)),
        "delivered_time_s_1x_calibrated":
            float(DEV.exec_time(c_1x, cal_dev)),
    }
    rows.append({"name": "fabric.calibrated_1x", "us": 0.0,
                 "derived": f"paper={calibration['delivered_time_s_1x_paper'] * 1e6:.1f}us;"
                            f"calibrated={calibration['delivered_time_s_1x_calibrated'] * 1e6:.1f}us;"
                            f"src={calibration['source']}"})

    # -- mixed-generation fleets (spill live, skewed placement) --------------
    # the fleet rows shrink the per-expander compressed region so the 0.8
    # page skew genuinely starves expander 0's freelists and the spill path
    # fires — the JSON then shows migration traffic charged per expander
    # (source demo_rd, donor demo_wr) and priced by each expander's own
    # device generation
    fleet_cfg = replace(cfg, n_cchunks=256)
    mixed: Dict[str, Dict[str, object]] = {}
    for name, profiles in (FLEETS_Q if quick else FLEETS_F).items():
        n = len(profiles)
        devices = [TM.DEVICE_PROFILES[p] for p in profiles]
        share = 0.8
        restw = (1.0 - share) / max(n - 1, 1)
        mk = lambda n=n, devices=devices, restw=restw: _fabric(
            fleet_cfg, n, rates, seed, window,
            placement=WeightedInterleave(n, n_pages,
                                         [share] + [restw] * (n - 1)),
            spill=True, spill_interval=512, spill_k=16, spill_low=112,
            devices=devices)
        t0 = time.perf_counter()
        acc, fab = _rate(mk, ospn, wr, blk, reps)
        d = _delivered(fab)
        per = fab.counters_by_expander()
        assert fab.spill_stats()["events"] > 0, \
            f"fleet {name}: spill never fired (deterministic config)"
        mixed[name] = {
            "profiles": list(profiles),
            "wallclock_acc_per_sec": acc,
            "modeled_acc_per_sec": n_accesses / d["bottleneck_s"],
            "delivered_time_s": d["bottleneck_s"],
            "delivered_per_expander_s": d["per_expander_s"],
            "bottleneck_expander": d["bottleneck_expander"],
            "internal_per_expander": [_internal(c) for c in per],
            "host_per_expander": [c["host_reads"] + c["host_writes"]
                                  for c in per],
            # spill traffic is charged on the expander where it occurs:
            # demo_rd on the starved source, demo_wr on the donor
            "spill": fab.spill_stats(),
            "spill_demo_rd_per_expander": [c["demo_rd"] for c in per],
            "spill_demo_wr_per_expander": [c["demo_wr"] for c in per],
            "sync": _sync_contract(fab),
        }
        rows.append({"name": f"fabric.fleet.{name}",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"delivered={d['bottleneck_s'] * 1e6:.1f}us;"
                                f"bottleneck=e{d['bottleneck_expander']};"
                                f"spills={fab.spill_stats()['events']}"})

    # -- skew sweep (N=4, spill live) ---------------------------------------
    skew_rows = {}
    for share in (SKEWS_Q if quick else SKEWS_F):
        rest = (1.0 - share) / 3.0
        mk = lambda share=share, rest=rest: _fabric(
            cfg, 4, rates, seed, window,
            placement=WeightedInterleave(4, n_pages,
                                         [share, rest, rest, rest]),
            spill=True, spill_interval=1024)
        t0 = time.perf_counter()
        acc, fab = _rate(mk, ospn, wr, blk, reps)
        per = fab.counters_by_expander()
        host = [c["host_reads"] + c["host_writes"] for c in per]
        d = _delivered(fab)
        modeled = n_accesses / d["bottleneck_s"]
        pages = np.bincount(fab.placement.assign(np.arange(n_pages)),
                            minlength=4) / n_pages
        # page share is what the placement controls; access share also
        # depends on which zipf-head pages the hash lands on each expander
        skew_rows[f"{share:.2f}"] = {
            "wallclock_acc_per_sec": acc,
            "modeled_acc_per_sec": modeled,
            "delivered_time_s": d["bottleneck_s"],
            "page_share": pages.tolist(),
            "host_share": [h / max(sum(host), 1) for h in host],
            "spill": fab.spill_stats(),
            "sync": _sync_contract(fab),
        }
        rows.append({"name": f"fabric.skew.{share:.2f}",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"modeled={modeled:,.0f}acc/s;"
                                f"e0_pages={pages[0]:.2f};"
                                f"e0_host={host[0] / max(sum(host), 1):.2f};"
                                f"spills={fab.spill_stats()['events']}"})

    # -- sync-vs-overlapped migration pipeline (skew 0.8, N=4, rebalance) ----
    # the acceptance point: the overlapped segment scheduler's pipeline
    # pricing (max(replay, migration) per segment) against the synchronous
    # reference (replay + migration). overlapped <= sync is asserted on the
    # overlapped run's OWN deltas (mathematically max <= sum, so a violation
    # means the accounting broke); the depth-1 degenerate pipeline must be
    # bit-identical to the synchronous driver (pools + counters + overrides)
    mig_share = 0.8
    mig_rest = (1.0 - mig_share) / 3.0

    def mk_mig(**kw):
        return _fabric(cfg, 4, rates, seed, window,
                       placement=WeightedInterleave(
                           4, n_pages, [mig_share] + [mig_rest] * 3),
                       migration="rebalance", spill_interval=1024, **kw)

    t0 = time.perf_counter()
    fab_over = mk_mig(pipeline_depth=2)
    fab_over.replay(ospn, wr, blk)
    pt_over = fab_over.pipeline_times()
    _sync_contract(fab_over)
    fab_sync = mk_mig(sync_migration=True)
    fab_sync.replay(ospn, wr, blk)
    pt_sync = fab_sync.pipeline_times()
    _sync_contract(fab_sync)
    over_s = float(np.max(pt_over["overlapped_s"]))
    over_sync_s = float(np.max(pt_over["sync_s"]))
    sync_s = float(np.max(pt_sync["sync_s"]))
    assert (pt_over["overlapped_s"] <= pt_over["sync_s"] + 1e-15).all(), \
        "overlapped pricing exceeded sync pricing on the same deltas"
    _delivered(fab_over)     # per-expander counter/time parity, asserted

    fab_d1 = mk_mig(pipeline_depth=1)
    fab_d1.replay(ospn, wr, blk)
    fab_ref = mk_mig(sync_migration=True)
    fab_ref.replay(ospn, wr, blk)
    identical = fab_d1.state_identical(fab_ref)
    assert identical, "depth-1 pipeline drifted from the synchronous driver"

    migration = {
        "placement": f"weighted {mig_share:.2f} skew, 4 expanders",
        "policy": "rebalance",
        # the apples-to-apples pair (same run, same deltas, two pricings;
        # overlapped <= sync asserted): what the pipeline hides
        "overlapped_s": over_s,
        "sync_s": over_sync_s,
        "overlap_hidden_s": over_sync_s - over_s,
        # a separate run through the synchronous driver (its own migration
        # timing, so its counters differ slightly — informational)
        "sync_reference_run_s": sync_s,
        "overlapped_per_expander_s": [float(t)
                                      for t in pt_over["overlapped_s"]],
        "sync_per_expander_s": [float(t) for t in pt_over["sync_s"]],
        "epochs_overlapped": fab_over.epochs_applied,
        "epochs_sync": fab_sync.epochs_applied,
        "pages_moved_overlapped": int(fab_over.spill_pages_out.sum()),
        "sync_contract": _sync_contract(fab_over),
        "depth1_bit_identical_to_sync": bool(identical),
    }
    rows.append({"name": "fabric.migration.overlap",
                 "us": (time.perf_counter() - t0) * 1e6,
                 "derived": f"overlapped={over_s * 1e6:.1f}us;"
                            f"sync={over_sync_s * 1e6:.1f}us;"
                            f"hidden={(over_sync_s - over_s) * 1e6:.2f}us;"
                            f"epochs={fab_over.epochs_applied};"
                            f"depth1=bit-identical"})

    # -- telemetry piggyback A/B (DESIGN.md §16) ------------------------------
    # the SAME rebalance point replayed with an obs.Recorder attached.
    # Asserted: pool/counter state is bit-identical to the recording-off
    # run, the declared sync budgets still hold with the recorder draining
    # every fetch, the exported Perfetto per-expander track totals
    # reconcile with pipeline_times (same row matrices, same pricing), and
    # the trace validates (nesting + monotone timestamps). Wall-clock
    # overhead is recorded (warm same-run A/B; the ≤5% acceptance number)
    # rather than hard-asserted — shared-box preemption noise dwarfs it.
    from repro.obs import Recorder
    from repro.obs import export as OBX
    t0 = time.perf_counter()
    rec = Recorder()
    t_on0 = time.perf_counter()
    fab_rec = mk_mig(pipeline_depth=2, obs=rec)
    fab_rec.replay(ospn, wr, blk)
    t_on = time.perf_counter() - t_on0
    t_off0 = time.perf_counter()
    fab_off = mk_mig(pipeline_depth=2)
    fab_off.replay(ospn, wr, blk)
    t_off = time.perf_counter() - t_off0
    assert fab_rec.state_identical(fab_off), \
        "recording changed pool/counter state"
    sync_rec = _sync_contract(fab_rec)     # budgets unchanged, recorder ON
    pt_rec = fab_rec.pipeline_times()
    totals = OBX.fabric_track_totals(rec)
    assert np.allclose(totals["overlapped_s"], pt_rec["overlapped_s"],
                       rtol=1e-9), "trace totals drifted from pipeline_times"
    assert np.allclose(totals["sync_s"], pt_rec["sync_s"], rtol=1e-9), \
        "trace sync totals drifted from pipeline_times"
    trace = OBX.build_trace(rec)
    errors = OBX.validate_trace(trace)
    assert not errors, errors
    overhead = t_on / max(t_off, 1e-12)
    obs_ab = {
        "state_bit_identical": True,
        "sync": sync_rec,
        "segments_recorded": len(rec.segments),
        "epochs_recorded": len(rec.epochs),
        "plans_recorded": len(rec.plans),
        "trace_events": len(trace["traceEvents"]),
        "trace_valid": True,
        "track_totals_reconcile_pipeline_times": True,
        "wallclock_overhead_ratio": overhead,
        "counters": rec.metrics.snapshot()["counters"],
    }
    rows.append({"name": "fabric.obs.ab",
                 "us": (time.perf_counter() - t0) * 1e6,
                 "derived": f"overhead=x{overhead:.3f};bit_identical=True;"
                            f"events={len(trace['traceEvents'])};"
                            f"reconciled=True"})

    # -- parity (asserted) ---------------------------------------------------
    fab1 = _fabric(cfg, 1, rates, seed, window, spill=False)
    fab1.replay(ospn, wr, blk)
    pool1 = S.pool_slice(S.make_pool_stack(cfg, 1, seed=seed,
                                           rates_table=jnp.asarray(rates)), 0)
    pool1 = B.replay_trace(pool1, cfg,
                           fab1.policy, ospn, wr, blk, window=window)
    assert fab1.counters() == S.counters_dict(pool1), \
        "N=1 fabric drifted from single-pool replay"

    placement = StaticInterleave(2, n_pages)
    fab2 = _fabric(cfg, 2, rates, seed, window, placement=placement,
                   spill=False)
    fab2.replay(ospn, wr, blk)
    eids = placement.route(ospn)
    stack0 = S.make_pool_stack(cfg, 2, seed=seed,
                               rates_table=jnp.asarray(rates))
    total = {k: 0 for k in S.COUNTER_NAMES}
    for e in range(2):
        sel = eids == e
        ref = B.replay_trace(S.pool_slice(stack0, e), cfg, fab2.policy,
                             ospn[sel], wr[sel], blk[sel], window=window)
        for k, v in S.counters_dict(ref).items():
            total[k] += v
    assert fab2.counters() == total, \
        "N=2 fabric counter sums drifted from per-shard single-pool replays"

    merged_cfg = replace(cfg, n_pchunks=cfg.n_pchunks * 2,
                         n_cchunks=cfg.n_cchunks * 2,
                         mcache_sets=cfg.mcache_sets * 2)
    poolm = S.make_pool(merged_cfg, seed=seed,
                        rates_table=jnp.asarray(rates))
    poolm = B.replay_trace(poolm, merged_cfg, fab2.policy, ospn, wr, blk,
                           window=window)
    cm = S.counters_dict(poolm)
    rel = abs(_internal(fab2.counters()) - _internal(cm)) / \
        max(_internal(cm), 1)
    assert rel < MERGED_POOL_TOL, (rel, MERGED_POOL_TOL)
    rows.append({"name": "fabric.parity", "us": 0.0,
                 "derived": f"per_shard=exact;merged_pool_rel={rel:.3f}"
                            f"(tol={MERGED_POOL_TOL})"})

    # ---- sharded scaling (DESIGN.md §17): own process — the forced
    # host-device count must hit XLA before its backend initializes, and
    # this process imported jax long ago. The child asserts bit-identity
    # vs the vmap oracle, the sharded sync budgets, the D-invariant
    # modeled curve, and the device-track reconciliation per point, then
    # prints the section JSON on stdout.
    cmd = [sys.executable,
           str(pathlib.Path(__file__).resolve().parent /
               "fabric_sharded.py"), "--seed", str(seed)]
    if quick:
        cmd.append("--quick")
    proc = subprocess.run(cmd, capture_output=True, text=True,
                          env=os.environ.copy())
    if proc.returncode != 0:
        raise RuntimeError(
            f"fabric_sharded.py failed:\n{proc.stderr[-4000:]}")
    sharded = json.loads(proc.stdout)
    rows.append({"name": "fabric.sharded",
                 "us": 0.0,
                 "derived": ";".join(
                     f"D{d}={p['wallclock_acc_per_sec']:,.0f}acc/s"
                     for d, p in sorted(sharded["scales"].items(),
                                        key=lambda kv: int(kv[0])))})

    payload = {
        "meta": {**run_manifest(seed=seed),
                 "workload": WL, "n_accesses": n_accesses,
                 "promoted_pages_per_expander": prom, "n_pages": n_pages,
                 "window": window, "reps": reps,
                 "quick": quick,
                 "unit": "accesses/sec; wallclock = simulator steady state "
                         "(compile excluded; vmapped masked branches carry "
                         "a constant), delivered/modeled = bottleneck "
                         "expander's vectorized device-model time computed "
                         "inside the vmapped replay (the delivered-"
                         "bandwidth curve; per-expander DeviceConfig, "
                         "spill traffic charged where it occurs)"},
        "scaling": scaling,
        "calibration": calibration,
        "mixed_fleets": mixed,
        "skew": skew_rows,
        "migration": migration,
        "obs": obs_ab,
        "sharded": sharded,
        "parity": {"per_shard_exact": True,
                   "merged_pool_rel_diff": rel,
                   "merged_pool_tolerance": MERGED_POOL_TOL,
                   "scalar_vs_vectorized_time": "bitwise (asserted per "
                                                "expander on every scaling/"
                                                "fleet/skew point)"},
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    rows.append({"name": "fabric.json", "us": 0.0,
                 "derived": f"json={JSON_PATH.name}"})
    return rows
