"""Multi-expander fabric benchmark: scaling curves + skew sensitivity +
counter-sum parity (DESIGN.md §11).

  * **scaling** — the same merged trace replayed through fabrics of
    1/2/4/8 expanders (per-expander pool dimensions fixed, so capacity
    scales with N). Two rates per point: simulator wall-clock accesses/sec
    (steady state, compile excluded — NOTE: under vmap both sides of every
    masked-window branch execute for all expanders, so wall-clock carries
    a documented constant and is NOT the delivered-bandwidth story) and
    **modeled** accesses/sec: expanders serve in parallel, so modeled time
    is the *bottleneck* expander's `simx.device.exec_time` over its own
    traffic — that is the curve that scales with capacity and collapses
    under skew.
  * **skew** — a 4-expander fabric under WeightedInterleave placement with
    a growing expander-0 page share: delivered rate + per-expander host
    traffic share + spill activity (placement skew, not workload locality,
    is the lever that kills delivered bandwidth on real multi-device CXL).
  * **parity (asserted)** — an N=1 fabric is counter-for-counter identical
    to ``batch.replay_trace`` on one pool, and an N=2 fabric's summed
    counters equal the sum of single-pool replays of the merged trace's
    per-expander partitions EXACTLY (static interleave, no spill). Against
    ONE merged pool with N× regions + N× metadata cache, total internal
    traffic agrees within the documented tolerance (shared-vs-sharded
    cache and demotion cadence shift counters; see DESIGN.md §11).

Writes ``BENCH_fabric.json`` at the repo root.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import replace
from repro.core.engine import batch as B
from repro.core.engine import state as S
from repro.core.engine.policy import POLICIES
from repro.fabric import Fabric, StaticInterleave, WeightedInterleave
from repro.simx import device as DEV
from repro.simx.engine import TRAFFIC_KEYS, pool_cfg_for
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / \
    "BENCH_fabric.json"

SCALES = (1, 2, 4, 8)
SKEWS_Q = (0.5, 0.8)           # expander-0 page share at N=4
SKEWS_F = (0.25, 0.5, 0.8)
MERGED_POOL_TOL = 0.35         # documented tolerance vs ONE merged pool
WL = "mcf"


def _fabric(cfg, n, rates, seed, window, placement=None, **kw):
    placement = placement or StaticInterleave(n, cfg.n_pages)
    return Fabric(cfg, POLICIES["ibex"], placement, seed=seed,
                  rates_table=jnp.asarray(rates), window=window, **kw)


def _rate(make, ospn, wr, blk, reps: int):
    """Steady-state accesses/sec: compile+warm once, then min-of-reps on
    fresh fabrics (state shapes identical → jit cache hits). Returns
    (rate, last fabric) so callers read counters without another replay."""
    make().replay(ospn, wr, blk)                  # compile + warm
    best = np.inf
    for _ in range(reps):
        fab = make()
        t0 = time.perf_counter()
        jax.block_until_ready(fab.replay(ospn, wr, blk).pools.counters)
        best = min(best, time.perf_counter() - t0)
    return len(ospn) / best, fab


def _internal(c: Dict[str, int]) -> int:
    return sum(c[k] for k in TRAFFIC_KEYS)


def _modeled_time(per_expander: List[Dict[str, int]]) -> float:
    """Delivered time of a fabric serving one trace: expanders run in
    parallel, so the bottleneck expander's device-model time governs."""
    times = []
    for c in per_expander:
        traffic = {"internal_accesses": _internal(c),
                   "host_reads": c["host_reads"],
                   "host_writes": c["host_writes"],
                   "zero_served": c["zero_served"],
                   "promotions": c["promotions"],
                   "demotions_dirty": c["demotions_dirty"],
                   "recompress_retry": c["recompress_retry"]}
        times.append(DEV.exec_time(traffic, DEV.DeviceConfig()))
    return max(times)


def run(quick: bool, seed: int = 0) -> List[Dict]:
    prom = 32                      # per-expander promoted region
    n_pages = 256                  # shared OSPA page space: N=1 is 8x
    #                                oversubscribed, N=8 fully promotes —
    #                                the capacity side of the scaling story
    n_accesses = 2048 if quick else 8192
    window = 16
    reps = 2 if quick else 4
    cfg = pool_cfg_for(  # per-expander pool dimensions (fixed across N)
        POLICIES["ibex"], n_pages=n_pages, n_pchunks=prom,
        n_cchunks=2 * n_pages * 4)
    spec = WORKLOADS[WL]
    rates = make_rates_table(spec, n_pages, seed=seed)
    ospn, wr, blk = make_trace(spec, n_accesses=n_accesses, n_pages=n_pages,
                               seed=seed)
    rows = []

    # -- scaling curve -------------------------------------------------------
    scaling: Dict[str, Dict[str, float]] = {}
    for n in SCALES:
        t0 = time.perf_counter()
        acc, fab = _rate(lambda n=n: _fabric(cfg, n, rates, seed, window,
                                             spill=False), ospn, wr, blk,
                         reps)
        per = fab.counters_by_expander()
        modeled = n_accesses / _modeled_time(per)
        scaling[str(n)] = {
            "wallclock_acc_per_sec": acc,
            "modeled_acc_per_sec": modeled,
            "internal_accesses": _internal(fab.counters()),
        }
        rows.append({"name": f"fabric.scale.{n}x",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"wall={acc:,.0f}acc/s;"
                                f"modeled={modeled:,.0f}acc/s;"
                                f"internal={_internal(fab.counters())}"})

    # -- skew sweep (N=4, spill live) ---------------------------------------
    skew_rows = {}
    for share in (SKEWS_Q if quick else SKEWS_F):
        rest = (1.0 - share) / 3.0
        mk = lambda share=share, rest=rest: _fabric(
            cfg, 4, rates, seed, window,
            placement=WeightedInterleave(4, n_pages,
                                         [share, rest, rest, rest]),
            spill=True, spill_interval=1024)
        t0 = time.perf_counter()
        acc, fab = _rate(mk, ospn, wr, blk, reps)
        per = fab.counters_by_expander()
        host = [c["host_reads"] + c["host_writes"] for c in per]
        modeled = n_accesses / _modeled_time(per)
        pages = np.bincount(fab.placement.assign(np.arange(n_pages)),
                            minlength=4) / n_pages
        # page share is what the placement controls; access share also
        # depends on which zipf-head pages the hash lands on each expander
        skew_rows[f"{share:.2f}"] = {
            "wallclock_acc_per_sec": acc,
            "modeled_acc_per_sec": modeled,
            "page_share": pages.tolist(),
            "host_share": [h / max(sum(host), 1) for h in host],
            "spill": fab.spill_stats(),
        }
        rows.append({"name": f"fabric.skew.{share:.2f}",
                     "us": (time.perf_counter() - t0) * 1e6,
                     "derived": f"modeled={modeled:,.0f}acc/s;"
                                f"e0_pages={pages[0]:.2f};"
                                f"e0_host={host[0] / max(sum(host), 1):.2f};"
                                f"spills={fab.spill_stats()['events']}"})

    # -- parity (asserted) ---------------------------------------------------
    fab1 = _fabric(cfg, 1, rates, seed, window, spill=False)
    fab1.replay(ospn, wr, blk)
    pool1 = S.pool_slice(S.make_pool_stack(cfg, 1, seed=seed,
                                           rates_table=jnp.asarray(rates)), 0)
    pool1 = B.replay_trace(pool1, cfg,
                           fab1.policy, ospn, wr, blk, window=window)
    assert fab1.counters() == S.counters_dict(pool1), \
        "N=1 fabric drifted from single-pool replay"

    placement = StaticInterleave(2, n_pages)
    fab2 = _fabric(cfg, 2, rates, seed, window, placement=placement,
                   spill=False)
    fab2.replay(ospn, wr, blk)
    eids = placement.route(ospn)
    stack0 = S.make_pool_stack(cfg, 2, seed=seed,
                               rates_table=jnp.asarray(rates))
    total = {k: 0 for k in S.COUNTER_NAMES}
    for e in range(2):
        sel = eids == e
        ref = B.replay_trace(S.pool_slice(stack0, e), cfg, fab2.policy,
                             ospn[sel], wr[sel], blk[sel], window=window)
        for k, v in S.counters_dict(ref).items():
            total[k] += v
    assert fab2.counters() == total, \
        "N=2 fabric counter sums drifted from per-shard single-pool replays"

    merged_cfg = replace(cfg, n_pchunks=cfg.n_pchunks * 2,
                         n_cchunks=cfg.n_cchunks * 2,
                         mcache_sets=cfg.mcache_sets * 2)
    poolm = S.make_pool(merged_cfg, seed=seed,
                        rates_table=jnp.asarray(rates))
    poolm = B.replay_trace(poolm, merged_cfg, fab2.policy, ospn, wr, blk,
                           window=window)
    cm = S.counters_dict(poolm)
    rel = abs(_internal(fab2.counters()) - _internal(cm)) / \
        max(_internal(cm), 1)
    assert rel < MERGED_POOL_TOL, (rel, MERGED_POOL_TOL)
    rows.append({"name": "fabric.parity", "us": 0.0,
                 "derived": f"per_shard=exact;merged_pool_rel={rel:.3f}"
                            f"(tol={MERGED_POOL_TOL})"})

    payload = {
        "meta": {"workload": WL, "n_accesses": n_accesses,
                 "promoted_pages_per_expander": prom, "n_pages": n_pages,
                 "window": window, "reps": reps, "seed": seed,
                 "quick": quick,
                 "unit": "accesses/sec; wallclock = simulator steady state "
                         "(compile excluded; vmapped masked branches carry "
                         "a constant), modeled = bottleneck expander's "
                         "device-model time (the delivered-bandwidth "
                         "curve)"},
        "scaling": scaling,
        "skew": skew_rows,
        "parity": {"per_shard_exact": True,
                   "merged_pool_rel_diff": rel,
                   "merged_pool_tolerance": MERGED_POOL_TOL},
    }
    JSON_PATH.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
    rows.append({"name": "fabric.json", "us": 0.0,
                 "derived": f"json={JSON_PATH.name}"})
    return rows
