"""One benchmark per paper figure/table (DESIGN.md §7 index).

Each ``figXX`` function returns rows of dicts; run.py flattens them to the
``name,us_per_call,derived`` CSV contract. ``quick`` trims workloads and
access counts so the whole suite stays CPU-friendly.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.common.types import replace
from repro.simx import device as DEV
from repro.simx import time as TM
from repro.simx.engine import SCHEMES, run_workload
from repro.simx.trace import WORKLOADS, WorkloadSpec

QUICK_WL = ["mcf", "lbm", "omnetpp", "pr", "xsbench"]
FULL_WL = list(WORKLOADS)
N_Q, N_F = 4000, 12000
PROM_Q, PROM_F = 64, 96


def _wl(quick: bool) -> List[str]:
    return QUICK_WL if quick else FULL_WL


def _n(quick: bool) -> int:
    return N_Q if quick else N_F


def _prom(quick: bool) -> int:
    return PROM_Q if quick else PROM_F


def _cell(scheme: str, wl: str, quick: bool, **kw) -> Dict[str, float]:
    t0 = time.perf_counter()
    r = run_workload(scheme, WORKLOADS[wl], n_accesses=_n(quick),
                     promoted_pages=_prom(quick), **kw)
    r["wall_us"] = (time.perf_counter() - t0) * 1e6
    return r


def fig01_bandwidth(quick: bool) -> List[Dict]:
    """Fig. 1: dual-channel vs ideal internal bandwidth (block compression)."""
    rows = []
    for wl in _wl(quick):
        real = _cell("ibex_base", wl, quick)
        ideal = _cell("ibex_base", wl, quick,
                      device=DEV.ideal_bandwidth(DEV.DeviceConfig()))
        rows.append({"name": f"fig01.{wl}", "us": real["wall_us"],
                     "derived": f"limited/ideal="
                                f"{real['time_s'] / ideal['time_s']:.3f}"})
    return rows


def fig09_speedup(quick: bool) -> List[Dict]:
    """Fig. 9: normalized perf per scheme; headline IBEX-vs-TMCC/DyLeCT."""
    schemes = ["ibex", "tmcc", "dylect", "mxt", "dmc", "compresso"]
    perf: Dict[str, Dict[str, float]] = {s: {} for s in schemes}
    rows = []
    for s in schemes:
        for wl in _wl(quick):
            r = _cell(s, wl, quick)
            perf[s][wl] = r["normalized_perf"]
            rows.append({"name": f"fig09.{s}.{wl}", "us": r["wall_us"],
                         "derived": f"norm_perf={r['normalized_perf']:.3f}"})
    gm = {s: float(np.exp(np.mean(np.log([max(v, 1e-9) for v in perf[s].values()]))))
          for s in schemes}
    for other in ("tmcc", "dylect", "mxt", "dmc"):
        rows.append({"name": f"fig09.speedup_ibex_over_{other}", "us": 0.0,
                     "derived": f"x{gm['ibex'] / gm[other]:.2f}"})
    return rows


def fig10_ratio(quick: bool) -> List[Dict]:
    """Fig. 10: compression ratios (IBEX-1KB, IBEX-4KB, MXT, Compresso)."""
    rows = []
    for name, scheme in (("ibex_1kb", "ibex"), ("ibex_4kb", "ibex_base"),
                         ("mxt", "mxt"), ("compresso", "compresso")):
        ratios = []
        us = 0.0
        for wl in _wl(quick):
            r = _cell(scheme, wl, quick)
            ratios.append(max(r["compression_ratio"], 1e-3))
            us += r["wall_us"]
        gm = float(np.exp(np.mean(np.log(ratios))))
        rows.append({"name": f"fig10.{name}", "us": us,
                     "derived": f"ratio={gm:.2f}"})
    return rows


def fig11_breakdown(quick: bool) -> List[Dict]:
    """Fig. 11: per-class traffic, IBEX normalized to TMCC."""
    rows = []
    tot_i = tot_t = 0.0
    for wl in _wl(quick):
        ib = _cell("ibex", wl, quick)
        tm = _cell("tmcc", wl, quick)
        tot_i += ib["internal_accesses"]
        tot_t += tm["internal_accesses"]
        rows.append({
            "name": f"fig11.{wl}", "us": ib["wall_us"] + tm["wall_us"],
            "derived": (f"ibex/tmcc={ib['internal_accesses'] / max(tm['internal_accesses'], 1):.3f}"
                        f";clean_frac={ib['demotions_clean'] / max(ib['demotions_clean'] + ib['demotions_dirty'], 1):.2f}")})
    rows.append({"name": "fig11.total_traffic_reduction", "us": 0.0,
                 "derived": f"{1 - tot_i / max(tot_t, 1):.1%}"})
    return rows


def fig12_background(quick: bool) -> List[Dict]:
    """Fig. 12: practical vs miracle (no activity/scan traffic)."""
    rows = []
    for wl in _wl(quick):
        r = _cell("ibex", wl, quick)
        miracle = dict(r)
        miracle_traffic = r["internal_accesses"] - r["activity_rd"] - r["activity_wr"]
        t = {**{k: r[k] for k in ("host_reads", "host_writes", "zero_served",
                                  "promotions", "demotions_dirty",
                                  "recompress_retry")},
             "internal_accesses": miracle_traffic}
        tm = DEV.exec_time(t, DEV.DeviceConfig())
        rows.append({"name": f"fig12.{wl}", "us": r["wall_us"],
                     "derived": f"practical/miracle={r['time_s'] / tm:.3f}"})
    return rows


def fig13_ablation(quick: bool) -> List[Dict]:
    """Fig. 13: traffic as S, C, M are applied incrementally."""
    rows = []
    for wl in (_wl(quick)[:3] if quick else _wl(quick)):
        base = _cell("ibex_base", wl, quick)
        s = _cell("ibex_s", wl, quick)
        sc = _cell("ibex_sc", wl, quick)
        scm = _cell("ibex_scm", wl, quick)
        b = max(base["internal_accesses"], 1)
        rows.append({
            "name": f"fig13.{wl}", "us": base["wall_us"] + s["wall_us"]
            + sc["wall_us"] + scm["wall_us"],
            "derived": (f"S={s['internal_accesses'] / b:.3f};"
                        f"SC={sc['internal_accesses'] / b:.3f};"
                        f"SCM={scm['internal_accesses'] / b:.3f}")})
    return rows


def _device_sweep(r: Dict[str, float], devices) -> np.ndarray:
    """Normalized perf of one cell's traffic under a stacked device sweep:
    ONE replay, every device point priced in a single vectorized
    ``exec_time_vec`` call (traffic does not depend on the device model —
    the old loop re-ran the whole replay per point)."""
    lanes = TM.stack_devices(devices, xp=np)
    vec = TM.counters_from_dict(r)
    times = TM.exec_time_vec(
        np.broadcast_to(vec, (len(devices),) + vec.shape), lanes)
    host = r["host_reads"] + r["host_writes"]
    base = TM.uncompressed_time(np.full((len(devices),), host), lanes)
    return base / times


def fig14_latency(quick: bool) -> List[Dict]:
    """Fig. 14: sensitivity to CXL round-trip latency (vectorized sweep)."""
    r = _cell("ibex", "pr", quick)
    lats = (70e-9, 150e-9, 250e-9, 400e-9)
    norm = _device_sweep(r, [replace(TM.DeviceConfig(), cxl_lat=lat)
                             for lat in lats])
    return [{"name": f"fig14.cxl_{int(lat * 1e9)}ns",
             "us": r["wall_us"] if i == 0 else 0.0,
             "derived": f"norm_perf={norm[i]:.3f}"}
            for i, lat in enumerate(lats)]


def fig15_decomp(quick: bool) -> List[Dict]:
    """Fig. 15: sensitivity to decompression cycles (robustness claim;
    vectorized sweep)."""
    r = _cell("ibex", "mcf", quick)
    cycs = (64, 128, 256, 512)
    norm = _device_sweep(r, [replace(TM.DeviceConfig(), decomp_cycles=cyc)
                             for cyc in cycs])
    rows = [{"name": f"fig15.decomp_{cyc}cyc",
             "us": r["wall_us"] if i == 0 else 0.0,
             "derived": f"norm_perf={norm[i]:.3f}"}
            for i, cyc in enumerate(cycs)]
    drop = 1 - norm[-1] / max(norm[0], 1e-9)
    rows.append({"name": "fig15.total_drop", "us": 0.0,
                 "derived": f"{drop:.1%}"})
    return rows


def fig16_write(quick: bool) -> List[Dict]:
    """Fig. 16: write-intensity sweep on the read-only workload (XSBench)."""
    rows = []
    base = None
    for ratio in (0.0, 1 / 6, 1 / 3, 0.5, 2 / 3, 5 / 6):
        spec = WORKLOADS["xsbench"]
        spec = WorkloadSpec(spec.name, ratio, spec.zipf_a, spec.stream_frac,
                            spec.footprint_pages, spec.zero_frac, spec.mix4,
                            spec.mix8)
        r = run_workload("ibex", spec, n_accesses=_n(quick),
                         promoted_pages=_prom(quick))
        if base is None:
            base = r["time_s"]
        rows.append({"name": f"fig16.rw_{ratio:.2f}", "us": 0.0,
                     "derived": f"slowdown={r['time_s'] / base:.3f}"})
    return rows


def fig17_fault(quick: bool) -> List[Dict]:
    """Fig. 17: page-fault reduction under 50%-of-working-set memory, using
    each workload's measured compression ratio as the capacity multiplier."""
    rows = []
    rng = np.random.default_rng(0)
    for wl in _wl(quick):
        r = _cell("ibex", wl, quick)
        ratio = max(r["compression_ratio"], 1.0)
        spec = WORKLOADS[wl]
        n_pages = 512
        from repro.simx.trace import make_trace
        pages, _, _ = make_trace(spec, n_accesses=_n(quick), n_pages=n_pages)
        for label, cap in (("base", n_pages // 2),
                           ("ibex", min(int(n_pages // 2 * ratio), n_pages))):
            resident: dict = {}
            clockv = 0
            faults = 0
            for t, p in enumerate(pages):
                if p in resident:
                    resident[p] = t
                    continue
                faults += 1
                if len(resident) >= cap:
                    victim = min(resident, key=resident.get)
                    del resident[victim]
                resident[p] = t
            if label == "base":
                base_faults = faults
        red = 1 - faults / max(base_faults, 1)
        rows.append({"name": f"fig17.{wl}", "us": 0.0,
                     "derived": f"fault_reduction={red:.1%}"})
    return rows


ALL_FIGS = [fig01_bandwidth, fig09_speedup, fig10_ratio, fig11_breakdown,
            fig12_background, fig13_ablation, fig14_latency, fig15_decomp,
            fig16_write, fig17_fault]
