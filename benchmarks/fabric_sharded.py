"""Sharded fabric scaling bench (DESIGN.md §17) — subprocess half of
``fabric_bench.py``'s ``sharded`` section.

XLA must see the forced host-device count BEFORE its backend
initializes, and the parent bench has long since imported jax — so this
script runs in its own process, forces 8 host devices as its very first
statements, and prints one JSON document to stdout for the parent to
merge as ``BENCH_fabric.json["sharded"]``.

What it measures, at a fixed N=8-expander fabric under 0.8 placement
skew with the spill path LIVE, for mesh sizes D in {1, 2, 4, 8}:

  * **wall-clock accesses/sec** — steady state, compile excluded
    (min-of-reps on fresh fabrics; the jit cache is keyed on the Mesh so
    reps hit it). Forced host devices share the box's physical cores, so
    on a small machine the curve shows dispatch overhead, not real
    scaling — the MODELED delivered curve next to it is the bandwidth
    story, exactly as the vmap scaling section documents for its
    wall-clock column.
  * **modeled delivered accesses/sec** — the bottleneck expander's
    float64 device-model time over its own traffic, same pricing as the
    vmap sections (the counters are bit-identical, so the modeled curve
    is D-invariant by construction — asserted).
  * **bit-identity (asserted per point)** — every leaf of the sharded
    end state (counters included) equals the vmap synchronous reference
    via ``state_identical``, and per-expander counter dicts match
    exactly.
  * **host-sync contract (asserted per point)** — measured boundary /
    drain syncs match the budgets ``_commit_boundary`` /
    ``_drain_deferred`` declare via ``@sync_contract``, and the epoch
    host-sync total is STRICTLY below the PR 5 pipelined driver's on the
    same trace (one fused fetch per boundary vs one per segment plus one
    per epoch).
  * **per-device observability** — ``Fabric.device_times()`` reconciled
    against the Recorder-reconstructed per-device Perfetto track totals
    at rtol=1e-9 (the §16 contract extended to device tracks), zero
    extra syncs.
"""
from __future__ import annotations

import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=8")

import argparse     # noqa: E402
import json         # noqa: E402
import sys          # noqa: E402
import time         # noqa: E402
from typing import Dict   # noqa: E402

import jax                # noqa: E402
import jax.numpy as jnp   # noqa: E402
import numpy as np        # noqa: E402

from repro.common.contracts import verify_sync_counters      # noqa: E402
from repro.common.types import replace                       # noqa: E402
from repro.core.engine.policy import POLICIES                # noqa: E402
from repro.fabric import Fabric, WeightedInterleave          # noqa: E402
from repro.obs import Recorder                               # noqa: E402
from repro.obs import export as OBX                          # noqa: E402
from repro.simx.engine import pool_cfg_for                   # noqa: E402
from repro.simx.trace import WORKLOADS, make_rates_table, make_trace  # noqa: E402

N_EXP = 8
SCALES = (1, 2, 4, 8)
WL = "mcf"


def _verify_sharded_contract(fab: Fabric) -> Dict[str, int]:
    """Runtime cross-check of the sharded driver's declared budgets: one
    fused fetch per boundary, one deferred drain per replay() call, and
    nothing on the vmap counters."""
    ss = fab.sync_stats()
    verify_sync_counters(Fabric._commit_boundary, ss["boundaries"],
                         ss["boundary_syncs"], what=str(ss))
    assert ss["segment_syncs"] == 0 and ss["epoch_syncs"] == 0, ss
    return ss


def run(quick: bool, seed: int) -> Dict[str, object]:
    n_pages = 256
    n_accesses = 2048 if quick else 8192
    window = 16
    reps = 2 if quick else 4
    cfg = replace(pool_cfg_for(POLICIES["ibex"], n_pages=n_pages,
                               n_pchunks=32, n_cchunks=2 * n_pages * 4),
                  n_cchunks=256)   # shrink so the 0.8 skew starves e0
    spec = WORKLOADS[WL]
    rates = make_rates_table(spec, n_pages, seed=seed)
    ospn, wr, blk = make_trace(spec, n_accesses=n_accesses,
                               n_pages=n_pages, seed=seed)
    share = 0.8
    restw = (1.0 - share) / (N_EXP - 1)

    def mk(**kw):
        return Fabric(cfg, POLICIES["ibex"],
                      WeightedInterleave(N_EXP, n_pages,
                                         [share] + [restw] * (N_EXP - 1)),
                      seed=seed, rates_table=jnp.asarray(rates),
                      window=window, spill=True, spill_interval=512,
                      spill_k=8, spill_low=112, **kw)

    # vmap references on the same trace: the synchronous driver is the
    # bit-identity oracle; the PR 5 pipelined driver sets the host-sync
    # bar the sharded path must beat
    ref = mk(sync_migration=True)
    ref.replay(ospn, wr, blk)
    assert ref.spill_stats()["events"] > 0, \
        "spill never fired (deterministic config) — the bench point is dead"
    ref_counters = ref.counters_by_expander()
    pipe = mk(pipeline_depth=2)
    pipe.replay(ospn, wr, blk)
    pipe_syncs = pipe.sync_stats()["host_syncs"]

    points: Dict[str, Dict[str, object]] = {}
    for d in SCALES:
        t0 = time.perf_counter()
        mk(shard_devices=d).replay(ospn, wr, blk)      # compile + warm
        compile_s = time.perf_counter() - t0
        best = np.inf
        for _ in range(reps):
            fab = mk(shard_devices=d)
            t0 = time.perf_counter()
            jax.block_until_ready(
                fab.replay(ospn, wr, blk).pools.counters)
            best = min(best, time.perf_counter() - t0)

        # bit-identity vs the vmap oracle, per expander and per leaf
        assert fab.state_identical(ref), \
            f"D={d}: sharded end state drifted from the vmap reference"
        assert fab.counters_by_expander() == ref_counters, \
            f"D={d}: per-expander counters drifted"

        ss = _verify_sharded_contract(fab)
        assert ss["host_syncs"] < pipe_syncs, \
            (f"D={d}: sharded path used {ss['host_syncs']} host syncs, "
             f"not below the pipelined driver's {pipe_syncs}")

        per = fab.delivered_time()            # float64 exact, one fetch
        bottleneck = float(per.max())
        dt = fab.device_times()
        points[str(d)] = {
            "wallclock_acc_per_sec": n_accesses / best,
            "modeled_acc_per_sec": n_accesses / bottleneck,
            "delivered_time_s": bottleneck,
            "delivered_per_expander_s": [float(t) for t in per],
            "device_s": [float(t) for t in dt["device_s"]],
            "compile_s": compile_s,
            "sync": ss,
            "spill": fab.spill_stats(),
            "bit_identical_to_vmap": True,
        }
        print(f"  D={d}: wall={n_accesses / best:,.0f}acc/s "
              f"modeled={n_accesses / bottleneck:,.0f}acc/s "
              f"syncs={ss['host_syncs']}<{pipe_syncs} identical=True",
              file=sys.stderr)

    # modeled curve is D-invariant (bit-identical counters, same pricing)
    modeled = {k: p["modeled_acc_per_sec"] for k, p in points.items()}
    assert len({round(v, 6) for v in modeled.values()}) == 1, modeled

    # per-device track reconciliation at D=4 (obs satellite): Recorder
    # attached, state still bit-identical, device track totals equal
    # Fabric.device_times at rtol=1e-9, trace validates
    rec = Recorder()
    fab_rec = mk(shard_devices=4, obs=rec)
    fab_rec.replay(ospn, wr, blk)
    assert fab_rec.state_identical(ref), "recording changed sharded state"
    dt = fab_rec.device_times()
    tot = OBX.fabric_device_totals(rec)
    assert np.allclose(tot["device_s"], dt["device_s"], rtol=1e-9), \
        (tot["device_s"], dt["device_s"])
    trace = OBX.build_trace(rec)
    errs = OBX.validate_trace(trace)
    assert not errs, errs[:5]
    n_dev_spans = sum(1 for e in trace["traceEvents"]
                      if e["ph"] == "X" and e.get("tid", 0) >= 1000)
    assert n_dev_spans > 0

    return {
        "meta": {"n_expanders": N_EXP, "n_accesses": n_accesses,
                 "n_pages": n_pages, "window": window, "reps": reps,
                 "workload": WL, "placement_skew": share,
                 "forced_host_devices": jax.device_count(),
                 "unit": "accesses/sec; wallclock = forced host devices "
                         "share the physical cores (dispatch-overhead "
                         "curve), modeled = bottleneck expander's device-"
                         "model time (D-invariant, asserted)"},
        "scales": points,
        "pipelined_reference_host_syncs": pipe_syncs,
        "sync_reference_host_syncs": ref.sync_stats()["host_syncs"],
        "obs": {"device_tracks_reconcile_device_times": True,
                "device_track_spans": n_dev_spans,
                "state_bit_identical_with_recorder": True,
                "device_s": [float(t) for t in dt["device_s"]]},
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    payload = run(args.quick, args.seed)
    json.dump(payload, sys.stdout)
    sys.stdout.write("\n")


if __name__ == "__main__":
    main()
