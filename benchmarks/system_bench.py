"""System-level benchmarks: serving-engine throughput, optimizer-state
compression, gradient-compression collective bytes, pool op latency."""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.types import (OptimizerConfig, PoolConfig, ServeConfig,
                                replace)
from repro.configs import get_reduced
from repro.models import transformer as T
from repro.optim import adamw, gradcomp

KEY = jax.random.PRNGKey(0)


def run(quick: bool) -> List[Dict]:
    rows = []
    cfg = get_reduced("llama3_8b")
    params, _ = T.init_params(KEY, cfg)

    # serving throughput (continuous batching with preemption)
    from repro.serve.engine import Engine
    scfg = ServeConfig(max_running=2, hot_window=16, attn_chunk=32,
                       kv_rate_bits=8)
    eng = Engine(cfg, scfg, params, max_len=128)
    nreq = 3 if quick else 8
    for i in range(nreq):
        eng.submit(list(np.random.default_rng(i).integers(1, cfg.vocab_size,
                                                          20)), 6)
    t0 = time.perf_counter()
    eng.run_until_done(max_steps=500)
    dt = time.perf_counter() - t0
    rows.append({"name": "serve.engine_throughput",
                 "us": dt * 1e6 / max(eng.counters["tokens"], 1),
                 "derived": f"tokens={eng.counters['tokens']};"
                            f"promos={eng.counters['promotions']};"
                            f"demos={eng.counters['demotions']}"})

    # optimizer-state compression: bytes + codec cost
    dense = adamw.init(params, OptimizerConfig())
    comp = adamw.init(params, OptimizerConfig(compress_state=True))
    rows.append({"name": "optim.state_bytes", "us": 0.0,
                 "derived": f"dense={adamw.state_bytes(dense)};"
                            f"compressed={adamw.state_bytes(comp)};"
                            f"saving=x{adamw.state_bytes(dense) / adamw.state_bytes(comp):.2f}"})

    # gradient compression wire bytes
    g = {"w": jax.random.normal(KEY, (1 << 16,))}
    q, _ = gradcomp.compress_with_feedback(g, gradcomp.init_residual(g))
    raw = 4 * (1 << 16)
    comp_b = gradcomp.compressed_bytes(q)
    rows.append({"name": "optim.gradcomp_wire", "us": 0.0,
                 "derived": f"fp32_allreduce={2 * raw};"
                            f"rs+int8ag={raw + comp_b};"
                            f"saving=x{2 * raw / (raw + comp_b):.2f}"})

    # pool op latency (Layer A with payload)
    from repro.core import engine as P
    POL = P.DEFAULT_POLICY
    pcfg = PoolConfig(n_pages=64, n_cchunks=512, n_pchunks=32, mcache_sets=4,
                      mcache_ways=4, demote_watermark=4, store_payload=True)
    pool = P.make_pool(pcfg)
    page = (jax.random.normal(KEY, (pcfg.vals_per_page,)) * 0.1).astype(jnp.bfloat16)
    pool = P.host_write_page(pool, pcfg, POL, jnp.asarray(0), page)  # compile
    t0 = time.perf_counter()
    n = 16 if quick else 64
    for i in range(n):
        pool = P.host_write_page(pool, pcfg, POL, jnp.asarray(i % 48), page)
    jax.block_until_ready(pool.counters)
    rows.append({"name": "pool.host_write_page",
                 "us": (time.perf_counter() - t0) * 1e6 / n,
                 "derived": f"ratio={float(P.compression_ratio(pool, pcfg)):.2f}"})
    return rows
